//! Fig. 6 + Fig. 7 — scalability on the VLAD stand-in:
//!   (a) time vs input scale n (k fixed), with distortion (Fig. 7a)
//!   (b) time vs cluster count k (n fixed), with distortion (Fig. 7b)
//! for k-means, boost k-means, Mini-Batch, closure k-means, GK-means.
//!
//! Paper's reading: (a) GK-means constantly faster than closure, ≥10×
//! faster than k-means/BKM; (b) k-means/BKM/Mini-Batch time grows linearly
//! in k while closure and GK-means stay nearly flat; GK-means quality
//! tracks BKM everywhere and the gap to the rest *widens* as k grows.
//! Regenerate: `cargo bench --bench fig6_scalability`.

use gkmeans::bench_util;
use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::eval::report::{f, Table};

fn job(n: usize, m: Method, k: usize) -> ClusterJob {
    let mut j = ClusterJob::new(
        DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 },
        m,
        k,
    );
    j.kappa = 20;
    j.tau = 6;
    j.base.max_iters = 10; // paper fixes 30; scaled for the 1-core box
    j
}

fn main() {
    bench_util::banner("Fig.6+7", "scalability in n and in k on vlad_like (512-d)");
    let backend = bench_util::backend();
    let methods = [
        Method::Lloyd,
        Method::Boost,
        Method::MiniBatch,
        Method::Closure,
        Method::GkMeans,
    ];

    // --- (a): n sweep, k fixed (paper: 10K..10M, k=1024) ---
    let k_fixed = 128;
    let mut ta = Table::new(&["method", "n", "total_s", "distortion"]);
    println!("\n(a) n sweep, k={k_fixed}");
    for &nd in &[1_000usize, 2_000, 4_000, 8_000] {
        let n = bench_util::scaled(nd);
        let data = DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 }
            .load()
            .unwrap();
        for &m in &methods {
            // traditional k-means & BKM get too slow at the top sizes with
            // large k; the paper runs them anyway — we do too, but at this
            // bench's scaled sizes that stays tractable.
            let r = pipeline::run_job_on(&job(n, m, k_fixed), &data, &backend);
            ta.row(&[m.name().into(), n.to_string(), f(r.total_seconds), f(r.distortion)]);
            println!("  n={n:<7} {:<18} {:>8.2}s  E={:.4}", m.name(), r.total_seconds, r.distortion);
        }
    }
    println!("{}", ta.render());
    ta.write_csv(&gkmeans::eval::report::results_dir().join("fig6a_n_sweep.csv")).ok();

    // --- (b): k sweep, n fixed (paper: 1024..8192 on 1M) ---
    let n = bench_util::scaled(8_000);
    let data = DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 }
        .load()
        .unwrap();
    let mut tb = Table::new(&["method", "k", "total_s", "distortion"]);
    println!("\n(b) k sweep, n={n}");
    for &k in &[64usize, 128, 256, 512] {
        for &m in &methods {
            let r = pipeline::run_job_on(&job(n, m, k), &data, &backend);
            tb.row(&[m.name().into(), k.to_string(), f(r.total_seconds), f(r.distortion)]);
            println!("  k={k:<5} {:<18} {:>8.2}s  E={:.4}", m.name(), r.total_seconds, r.distortion);
        }
    }
    println!("{}", tb.render());
    tb.write_csv(&gkmeans::eval::report::results_dir().join("fig6b_k_sweep.csv")).ok();

    println!("\npaper shape checks:");
    println!("  (a) GK-means < closure < k-means/BKM in time at every n");
    println!("  (b) k-means/BKM time ~linear in k; GK-means/closure ~flat");
    println!("  (7) GK-means distortion ~= BKM; Mini-Batch clearly worst");
}
