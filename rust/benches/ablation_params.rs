//! §4.4 ablation — the paper's parameter discussion, as data:
//!   κ (neighbors consulted): quality stabilizes for κ ≳ 40; too small
//!     misses the true cluster, too large erodes the speed-up.
//!   ξ (cell size for Alg. 3): larger ξ → better graph but more pairwise
//!     comparisons; recommended range [40, 100].
//!   τ (rounds): 10 suffices for clustering (Fig. 2 covers the sweep).
//!
//! DESIGN.md calls these out as the design choices to ablate.
//! Regenerate: `cargo bench --bench ablation_params`.

use gkmeans::bench_util;
use gkmeans::data::synth;
use gkmeans::eval::report::{f, Table};
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::gkm::gkmeans as gk;
use gkmeans::gkm::gkmeans::GkMeansParams;
use gkmeans::graph::{brute, recall};
use gkmeans::kmeans::common::KmeansParams;
use gkmeans::util::timer::Timer;

fn main() {
    bench_util::banner("§4.4", "parameter ablations: kappa and xi");
    let backend = bench_util::backend();
    let n = bench_util::scaled(8_000);
    let k = (n / 100).max(4);
    let data = synth::sift_like(n, 20170707);
    let exact = brute::build(&data, 1, &backend);
    let base = KmeansParams { max_iters: 15, ..Default::default() };

    // --- κ sweep (graph κ fixed high; consult κ varies) ---
    println!("\nkappa sweep (xi=50, tau=8):");
    let g = construct::build(
        &data,
        &ConstructParams { kappa: 64, xi: 50, tau: 8, seed: 1, threads: 1, ..Default::default() },
        &backend,
    );
    let mut tk = Table::new(&["kappa", "iter_s", "distortion"]);
    for kappa in [1usize, 5, 10, 20, 40, 64] {
        let t = Timer::start();
        let out = gk::run_core(
            &data,
            k,
            &g.graph,
            &GkMeansParams { kappa, base: base.clone() },
            &backend,
        );
        let secs = t.elapsed_s() - out.init_seconds;
        tk.row(&[kappa.to_string(), f(secs), f(out.distortion())]);
        println!("  kappa={kappa:<3} iter={secs:.2}s E={:.2}", out.distortion());
    }
    println!("{}", tk.render());
    println!("paper: quality stable for kappa >~ 40; cost grows with kappa");

    // --- ξ sweep (graph quality + build cost trade-off) ---
    println!("\nxi sweep (kappa=20, tau=8):");
    let mut tx = Table::new(&["xi", "build_s", "recall@1", "distortion"]);
    for xi in [20usize, 40, 50, 70, 100] {
        let b = construct::build(
            &data,
            &ConstructParams { kappa: 20, xi, tau: 8, seed: 1, threads: 1, ..Default::default() },
            &backend,
        );
        let r = recall::recall_at_1(&b.graph, &exact);
        let out = gk::run_core(
            &data,
            k,
            &b.graph,
            &GkMeansParams { kappa: 20, base: base.clone() },
            &backend,
        );
        tx.row(&[xi.to_string(), f(b.total_seconds), f(r), f(out.distortion())]);
        println!(
            "  xi={xi:<4} build={:.2}s recall={r:.3} E={:.2}",
            b.total_seconds,
            out.distortion()
        );
    }
    println!("{}", tx.render());
    println!("paper: larger xi -> better graph, more comparisons; sweet spot [40,100]");
    tk.write_csv(&gkmeans::eval::report::results_dir().join("ablation_kappa.csv")).ok();
    tx.write_csv(&gkmeans::eval::report::results_dir().join("ablation_xi.csv")).ok();
}
