//! Out-of-core scan-order bench: chunk reads + wall time for GK-means
//! epoch scans over a disk-backed `ChunkedVecStore` under the global
//! shuffle vs the super-block plan (`data::plan`).
//!
//! The cache is sized to a small fraction of the chunks, so the global
//! order degenerates to ≈ one chunk read per sample while the planned
//! order reads each chunk once per epoch — the trajectory file records
//! both so storage PRs can compare.  Emits `BENCH_oocore.json`
//! (`$GKMEANS_BENCH_OOCORE_JSON` overrides the destination), uploaded by
//! CI alongside `BENCH_gkm.json`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use gkmeans::bench_util;
use gkmeans::data::plan::ScanOrder;
use gkmeans::data::store::ChunkedVecStore;
use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::gkm::gkmeans as gk;
use gkmeans::kmeans::common::{Clustering, KmeansParams};
use gkmeans::runtime::Backend;
use gkmeans::util::timer::Timer;

fn main() {
    bench_util::banner("OOCore", "scan-order locality: chunk reads + wall time per epoch");
    let n = bench_util::scaled(20_000);
    let d = 32;
    let k = (n / 100).max(2);
    let kappa = 10;
    let epochs = 5;
    let data = blobs(&BlobSpec::quick(n, d, 64), 7);

    // write the dataset as a raw flat f32 file and stream it back
    let path = std::env::temp_dir().join(format!("gkm_oocore_{}.bin", std::process::id()));
    let mut bytes = Vec::with_capacity(data.flat().len() * 4);
    for &x in data.flat() {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(&path, &bytes).expect("write bench dataset");

    let backend = Backend::native();
    let graph = gkmeans::graph::brute::build_threaded(&data, kappa, &backend, 0);
    let init = gkmeans::kmeans::two_means::run(
        &data,
        k,
        &gkmeans::kmeans::two_means::TwoMeansParams::default(),
        &backend,
    );

    // geometry: 64 rows per chunk, cache budget ~6% of the chunks
    let chunk_rows = 64;
    let n_chunks = n.div_ceil(chunk_rows);
    let cache_chunks = (n_chunks / 16).max(2);
    println!("n={n} d={d} k={k} chunks={n_chunks} cache={cache_chunks} epochs={epochs}");

    let mut lines = Vec::new();
    for order in [ScanOrder::Global, ScanOrder::Superblock] {
        let reads = Arc::new(AtomicU64::new(0));
        let store = ChunkedVecStore::open_flat(&path, d)
            .expect("open streamed dataset")
            .chunk_rows(chunk_rows)
            .cache_chunks(cache_chunks)
            .with_read_counter(reads.clone());
        let clustering = Clustering::from_labels(&store, init.clone(), k);
        reads.store(0, Ordering::Relaxed); // count only the epoch scans
        let params = gk::GkMeansParams {
            kappa,
            base: KmeansParams {
                max_iters: epochs,
                min_move_rate: 0.0,
                seed: 1,
                threads: 1,
                scan_order: order,
            },
        };
        let timer = Timer::start();
        let out = gk::run_from(&store, clustering, &graph, &params);
        let wall_s = timer.elapsed_s();
        let chunk_reads = reads.load(Ordering::Relaxed);
        println!(
            "scan_order={:<10} chunk_reads={chunk_reads:>8} wall={wall_s:.3}s distortion={:.5}",
            order.name(),
            out.distortion()
        );
        lines.push(format!(
            "{{\"name\":\"oocore_gk_epochs\",\"scan_order\":\"{}\",\"n\":{n},\"d\":{d},\"k\":{k},\
             \"chunk_rows\":{chunk_rows},\"cache_chunks\":{cache_chunks},\"epochs\":{epochs},\
             \"chunk_reads\":{chunk_reads},\"wall_s\":{wall_s:.4}}}",
            order.name()
        ));
    }
    std::fs::remove_file(&path).ok();

    let dest = std::env::var("GKMEANS_BENCH_OOCORE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_oocore.json"));
    match bench_util::write_json_array(&dest, &lines) {
        Ok(()) => println!("wrote {}", dest.display()),
        Err(e) => eprintln!("could not write {}: {e}", dest.display()),
    }
}
