//! Fig. 1 — co-occurrence rate of a sample and its κ-th nearest neighbor
//! in one cluster, for traditional k-means and the 2M-tree, with cluster
//! size fixed to 50 (paper: SIFT100K; here: sift_like at a scaled n).
//!
//! Paper's reading: rates ≫ random collision (50/n = 0.0005), decaying
//! with rank but staying above ~0.1 at rank 100.  Regenerate:
//! `cargo bench --bench fig1_cooccurrence`.

use gkmeans::bench_util;
use gkmeans::data::synth;
use gkmeans::eval::cooccur;
use gkmeans::eval::report::{f, Table};
use gkmeans::kmeans::two_means::{self, TwoMeansParams};
use gkmeans::model::{Clusterer, Lloyd, RunContext};

fn main() {
    bench_util::banner("Fig.1", "NN-rank vs same-cluster co-occurrence (cluster size 50)");
    let backend = bench_util::backend();
    let n = bench_util::scaled(10_000);
    let kappa = 100usize;
    let k = (n / 50).max(2); // cluster size fixed to 50
    let data = synth::sift_like(n, 20170707);

    println!("building exact {kappa}-NN ground truth (n={n}, d=128)...");
    let exact = gkmeans::graph::brute::build(&data, kappa, &backend);

    // traditional k-means labels, via the fit -> model surface
    let km = Lloyd::new(k).fit(&data, &RunContext::new(&backend));
    let km_series = cooccur::cooccurrence_by_rank(&exact, &km.labels, kappa);

    // 2M-tree labels
    let labels_2m = two_means::run(&data, k, &TwoMeansParams::default(), &backend);
    let tm_series = cooccur::cooccurrence_by_rank(&exact, &labels_2m, kappa);

    let random = cooccur::random_collision_rate(&km.labels, k);

    let mut t = Table::new(&["rank", "k-means", "2M-tree"]);
    for &rank in &[1usize, 2, 5, 10, 20, 40, 60, 80, 100] {
        if rank <= kappa {
            t.row(&[
                rank.to_string(),
                f(km_series[rank - 1]),
                f(tm_series[rank - 1]),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "random-collision baseline: {:.5} (paper quotes 50/n = {:.5})",
        random,
        50.0 / n as f64
    );
    println!(
        "paper shape check: rank-1 >> random? {} (km {:.3} vs {:.5})",
        if km_series[0] > 10.0 * random { "YES" } else { "NO" },
        km_series[0],
        random
    );
    t.write_csv(&gkmeans::eval::report::results_dir().join("fig1_cooccurrence.csv"))
        .ok();
}
