//! Serving load generator: drives a `gkm-serve` endpoint through the
//! wire protocol and measures QPS and client-observed latency across
//! batch-window × client-count × RAM/disk configurations, emitting
//! `BENCH_serve.json` (override with `$GKMEANS_BENCH_SERVE_JSON`).
//!
//! Two generator modes:
//! * **closed-loop** — each client keeps exactly one request in flight
//!   (back-to-back), so QPS measures service capacity at that
//!   concurrency.
//! * **open-loop** — each client fires on a fixed arrival schedule
//!   regardless of completions, so latency percentiles include queueing
//!   under a sustained offered load.
//!
//! By default the harness starts in-process servers (the same
//! `serve::Server` the binary wraps) over a freshly fitted model, once
//! RAM-resident and once disk-backed through a saved GKMODEL artifact.
//! Set `$GKM_SERVE_ADDR` to aim the generator at an already-running
//! external `gkm-serve` instead (what the CI smoke job does): only the
//! load grid runs, against that one endpoint.
//!
//! The batched-vs-unbatched pair at 8 clients is the PR 7 acceptance
//! gate: micro-batching must deliver ≥ 2× the unbatched QPS there
//! (asserted by CI over the JSON, and printed here).

use std::sync::Barrier;
use std::time::{Duration, Instant};

use gkmeans::bench_util;
use gkmeans::data::synth;
use gkmeans::model::{Clusterer, FittedModel, GkMeans, ModelVectors, RunContext};
use gkmeans::serve::proto::{stats_value, Client};
use gkmeans::serve::{ServeConfig, Server, ShardedIndex};
use gkmeans::util::pool;
use gkmeans::util::rng::Rng;

const TOPK: usize = 10;

struct Rec {
    mode: &'static str,
    backing: String,
    window_us: u64,
    max_batch: usize,
    clients: usize,
    threads: usize,
    requests: usize,
    qps: f64,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    batch_mean: f64,
    cache_hit_rate: f64,
}

impl Rec {
    fn to_json(&self) -> String {
        format!(
            "{{\"mode\":\"{}\",\"backing\":\"{}\",\"window_us\":{},\"max_batch\":{},\
             \"clients\":{},\"threads\":{},\"requests\":{},\"qps\":{:.1},\
             \"p50_us\":{:.1},\"p95_us\":{:.1},\"p99_us\":{:.1},\
             \"batch_mean\":{:.2},\"cache_hit_rate\":{:.4}}}",
            self.mode,
            self.backing,
            self.window_us,
            self.max_batch,
            self.clients,
            self.threads,
            self.requests,
            self.qps,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.batch_mean,
            self.cache_hit_rate
        )
    }
}

fn pct(sorted: &[u64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)] as f64
}

/// Run one load configuration; `interval_us = 0` is closed-loop,
/// otherwise each client fires every `interval_us` (open-loop).
fn run_load(
    addr: std::net::SocketAddr,
    clients: usize,
    per_client: usize,
    interval_us: u64,
    queries: &[Vec<f32>],
) -> (f64, Vec<u64>) {
    let barrier = Barrier::new(clients + 1);
    let mut lats: Vec<Vec<u64>> = Vec::new();
    let wall = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|tid| {
                let barrier = &barrier;
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    let mut lat = Vec::with_capacity(per_client);
                    barrier.wait();
                    let start = Instant::now();
                    for i in 0..per_client {
                        if interval_us > 0 {
                            // open-loop: hold the arrival schedule even
                            // when responses run late
                            let due = Duration::from_micros(interval_us * i as u64);
                            let now = start.elapsed();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                        }
                        let q = &queries[(tid * per_client + i) % queries.len()];
                        let t0 = Instant::now();
                        c.search(q, TOPK, 0).expect("search");
                        lat.push(t0.elapsed().as_micros() as u64);
                    }
                    lat
                })
            })
            .collect();
        barrier.wait();
        let t0 = Instant::now();
        for h in handles {
            lats.push(h.join().expect("client thread"));
        }
        t0.elapsed().as_secs_f64()
    });
    let total = clients * per_client;
    let mut all: Vec<u64> = lats.into_iter().flatten().collect();
    all.sort_unstable();
    (total as f64 / wall, all)
}

/// Pull batch-size / cache figures from the server's STATS verb.
fn server_stats(addr: std::net::SocketAddr) -> (f64, f64) {
    let mut c = match Client::connect(addr) {
        Ok(c) => c,
        Err(_) => return (0.0, 0.0),
    };
    match c.stats() {
        Ok(s) => (
            stats_value(&s, "batch_mean").unwrap_or(0.0),
            stats_value(&s, "cache_hit_rate").unwrap_or(0.0),
        ),
        Err(_) => (0.0, 0.0),
    }
}

fn measure_grid(
    addr: std::net::SocketAddr,
    backing: &str,
    window_us: u64,
    max_batch: usize,
    per_client: usize,
    queries: &[Vec<f32>],
    records: &mut Vec<Rec>,
) {
    let threads = pool::resolve_threads(0);
    for &clients in &[1usize, 8] {
        let (qps, lats) = run_load(addr, clients, per_client, 0, queries);
        let (batch_mean, cache_hit_rate) = server_stats(addr);
        println!(
            "closed {backing:<5} window={window_us:<5}us max_batch={max_batch:<3} \
             clients={clients} qps={qps:<8.0} p50={:<6.0}us p99={:.0}us batch_mean={batch_mean:.2}",
            pct(&lats, 0.50),
            pct(&lats, 0.99),
        );
        records.push(Rec {
            mode: "closed",
            backing: backing.to_string(),
            window_us,
            max_batch,
            clients,
            threads,
            requests: clients * per_client,
            qps,
            p50_us: pct(&lats, 0.50),
            p95_us: pct(&lats, 0.95),
            p99_us: pct(&lats, 0.99),
            batch_mean,
            cache_hit_rate,
        });
    }
    // one open-loop point: 8 clients at a sustainable arrival rate
    let clients = 8usize;
    let interval_us = 1500u64;
    let (qps, lats) = run_load(addr, clients, per_client, interval_us, queries);
    let (batch_mean, cache_hit_rate) = server_stats(addr);
    println!(
        "open   {backing:<5} window={window_us:<5}us max_batch={max_batch:<3} \
         clients={clients} qps={qps:<8.0} p50={:<6.0}us p99={:.0}us",
        pct(&lats, 0.50),
        pct(&lats, 0.99),
    );
    records.push(Rec {
        mode: "open",
        backing: backing.to_string(),
        window_us,
        max_batch,
        clients,
        threads,
        requests: clients * per_client,
        qps,
        p50_us: pct(&lats, 0.50),
        p95_us: pct(&lats, 0.95),
        p99_us: pct(&lats, 0.99),
        batch_mean,
        cache_hit_rate,
    });
}

fn main() {
    bench_util::banner("SERVE", "gkm-serve load: QPS x batch window x clients x RAM/disk");
    let per_client = if std::env::var("GKMEANS_BENCH_FAST").is_ok() {
        40
    } else {
        bench_util::scaled(150).min(2000)
    };
    let mut records: Vec<Rec> = Vec::new();

    // query pool: perturbed indexed rows (dim must match the model)
    let make_queries = |dim: usize, data: Option<&gkmeans::data::matrix::VecSet>| -> Vec<Vec<f32>> {
        let mut rng = Rng::new(99);
        (0..256)
            .map(|_| match data {
                Some(d) => {
                    let r = d.row(rng.below(d.rows()));
                    r.iter().map(|v| v + 0.1 * rng.normal()).collect()
                }
                None => (0..dim).map(|_| rng.normal()).collect(),
            })
            .collect()
    };

    if let Ok(ext) = std::env::var("GKM_SERVE_ADDR") {
        // external mode: the CI smoke job points us at a live gkm-serve
        let addr: std::net::SocketAddr = ext.parse().expect("GKM_SERVE_ADDR host:port");
        let mut probe = Client::connect(addr).expect("connect to GKM_SERVE_ADDR");
        probe.ping().expect("ping external server");
        // discover dim from STATS? the protocol doesn't carry it; the
        // caller passes it explicitly
        let dim: usize = std::env::var("GKM_SERVE_DIM")
            .ok()
            .and_then(|s| s.parse().ok())
            .expect("external mode needs GKM_SERVE_DIM");
        let queries = make_queries(dim, None);
        measure_grid(addr, "extern", 0, 0, per_client, &queries, &mut records);
    } else {
        // fit once; serve it RAM-resident and disk-backed
        let n = bench_util::scaled(3000);
        let data = synth::sift_like(n, 20170707);
        let backend = bench_util::backend();
        let k = (n / 100).max(4);
        let ctx = RunContext::new(&backend).keep_data(true).max_iters(3);
        println!("fitting serving model (n={n}, k={k})...");
        let model = GkMeans::new(k).kappa(10).tau(4).fit(&data, &ctx);
        let art = std::env::temp_dir().join(format!("serve_load_{}.gkm", std::process::id()));
        model.save(&art).expect("save artifact");
        let queries = make_queries(data.dim(), Some(&data));

        for (backing, window_us, max_batch) in [
            ("ram", 0u64, 1usize), // unbatched baseline
            ("ram", 200, 64),      // the production default
            ("ram", 1000, 64),     // a wide window
            ("disk", 0, 1),
            ("disk", 200, 64),
        ] {
            let shard = if backing == "ram" {
                model.clone()
            } else {
                let m = FittedModel::load(&art).expect("load artifact");
                assert!(
                    matches!(m.data, Some(ModelVectors::Disk(_))),
                    "v2 artifact must page vectors from disk"
                );
                m
            };
            let index = ShardedIndex::new(vec![shard]).expect("index");
            let cfg = ServeConfig {
                batch_window: Duration::from_micros(window_us),
                max_batch,
                ..ServeConfig::default()
            };
            let handle = Server::start(index, &cfg).expect("start server");
            measure_grid(
                handle.addr(),
                backing,
                window_us,
                max_batch,
                per_client,
                &queries,
                &mut records,
            );
            handle.shutdown();
        }
        std::fs::remove_file(&art).ok();

        // the acceptance gate: batched vs unbatched at 8 clients
        let find = |backing: &str, max_batch: usize, clients: usize| {
            records
                .iter()
                .find(|r| {
                    r.mode == "closed"
                        && r.backing == backing
                        && r.max_batch == max_batch
                        && r.clients == clients
                })
                .map(|r| r.qps)
        };
        if let (Some(unbatched), Some(batched)) = (find("ram", 1, 8), find("ram", 64, 8)) {
            println!(
                "batched/unbatched QPS at 8 clients (ram): {batched:.0}/{unbatched:.0} = {:.2}x",
                batched / unbatched
            );
        }
    }

    let lines: Vec<String> = records.iter().map(|r| r.to_json()).collect();
    let path = std::env::var("GKMEANS_BENCH_SERVE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_serve.json"));
    bench_util::write_json_array(&path, &lines).expect("write bench json");
    println!("wrote {} records to {}", lines.len(), path.display());
}
