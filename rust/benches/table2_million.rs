//! Tab. 2 — the extreme-k test: partition the VLAD stand-in into n/10
//! clusters (paper: VLAD10M → 1M clusters), comparing the only two
//! workable systems — closure k-means and GK-means — plus KGraph+GK-means.
//! Columns match the paper: init time, iteration time, total, distortion,
//! graph recall.
//!
//! Paper's reading: GK-means total ≈ ½ closure's and ~6× faster than
//! KGraph+GK-means (NN-Descent dominates its init); GK-means distortion
//! lowest despite its graph's *lower* raw recall — the Alg. 3 graph
//! carries clustering structure.
//!
//! The second half exercises the extreme-k serving story: a routing
//! tree is built over the fitted centroids and routed `predict` is
//! timed against the flat O(k) scan, with assignment agreement
//! (recall@1 of the routed label vs. the exact flat label) measured on
//! the same queries.  Results land in `BENCH_route.json`
//! (`$GKMEANS_BENCH_ROUTE_JSON` overrides the path) so CI can track
//! the routed-vs-flat trajectory.  Runs at every scale —
//! `GKMEANS_BENCH_FAST=1 cargo bench --bench table2_million` is the
//! CI smoke invocation.

use gkmeans::bench_util;
use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::eval::report::Table;
use gkmeans::gkm::tree::RouteTreeParams;
use gkmeans::util::timer::Timer;

fn main() {
    bench_util::banner("Tab.2", "extreme cluster count: k = n/10 on vlad_like");
    let backend = bench_util::backend();
    let n = bench_util::scaled(20_000);
    let k = n / 10;
    let data = DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 }
        .load()
        .unwrap();
    println!("n={n} d={} k={k}", data.dim());

    let mut t = Table::new(&["method", "init_s", "iter_s", "total_s", "distortion", "recall"]);
    for &m in &[Method::KGraphGkMeans, Method::GkMeans, Method::Closure] {
        let mut job = ClusterJob::new(
            DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 },
            m,
            k,
        );
        job.kappa = 20;
        job.tau = 6;
        job.base.max_iters = 10;
        job.measure_recall = m != Method::Closure;
        let r = pipeline::run_job_on(&job, &data, &backend);
        t.row(&[
            m.name().into(),
            format!("{:.2}", r.init_seconds),
            format!("{:.2}", r.iter_seconds),
            format!("{:.2}", r.total_seconds),
            format!("{:.4}", r.distortion),
            r.recall.map(|x| format!("{x:.2}")).unwrap_or_else(|| "N.A.".into()),
        ]);
        println!("{}", r.table_row());
    }
    println!("{}", t.render());

    // the paper's "3 years for traditional k-means" projection, scaled:
    // measure one Lloyd assignment pass and extrapolate 30 iterations.
    let timer = gkmeans::util::timer::Timer::start();
    let sample = 500.min(n);
    let centroids = data.gather(&(0..k).collect::<Vec<_>>());
    let _ = backend.assign_blocks(
        data.rows_flat(0, sample),
        centroids.flat(),
        data.dim(),
        k,
    );
    let per_sample = timer.elapsed_s() / sample as f64;
    let projected = per_sample * n as f64 * 30.0;
    println!(
        "projected traditional k-means (30 iters, measured assignment rate): {}",
        gkmeans::util::timer::fmt_secs(projected)
    );
    t.write_csv(&gkmeans::eval::report::results_dir().join("table2.csv")).ok();
    println!("paper shape checks: GK-means fastest total; distortion: GK < KGraph+GK < closure;");
    println!("GK recall < KGraph recall yet GK distortion lower (structure transfer).");

    // --- routed vs flat predict at extreme k ----------------------------
    // Fit once more through the model API, attach the routing tree, and
    // time `predict` both ways over the training vectors.  Agreement is
    // recall@1 of the routed assignment against the exact flat argmin.
    println!();
    println!("routed predict at extreme k (routing tree vs flat O(k) scan):");
    let mut job = ClusterJob::new(
        DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 },
        Method::GkMeans,
        k,
    );
    job.kappa = 20;
    job.tau = 6;
    job.base.max_iters = 10;
    let (mut model, _) = pipeline::fit_job(&job, &data, &backend);
    let build_timer = Timer::start();
    model.build_route(&RouteTreeParams::default());
    let build_secs = build_timer.elapsed_s();
    let tree = model.route.clone();
    let (branch, beam, nodes, depth) = {
        let t = tree.as_ref().expect("build_route just ran");
        (t.branch, t.default_beam, t.nodes(), t.depth())
    };
    println!(
        "tree: branch={branch} beam={beam} nodes={nodes} depth={depth} built in {}",
        gkmeans::util::timer::fmt_secs(build_secs)
    );

    model.route = None;
    let timer = Timer::start();
    let flat = model.predict(&data);
    let flat_secs = timer.elapsed_s().max(1e-12);

    model.route = tree;
    model.route_min_k = 0; // force routing even at smoke-scale k
    let timer = Timer::start();
    let routed = model.predict(&data);
    let routed_secs = timer.elapsed_s().max(1e-12);

    let agree =
        flat.iter().zip(&routed).filter(|(a, b)| a == b).count() as f64 / n.max(1) as f64;
    let flat_rate = n as f64 / flat_secs;
    let routed_rate = n as f64 / routed_secs;
    println!(
        "flat:   {:>10.0} samples/s ({})",
        flat_rate,
        gkmeans::util::timer::fmt_secs(flat_secs)
    );
    println!(
        "routed: {:>10.0} samples/s ({}) — {:.1}x, agreement(recall@1)={:.4}",
        routed_rate,
        gkmeans::util::timer::fmt_secs(routed_secs),
        flat_secs / routed_secs,
        agree
    );

    let d = data.dim();
    let lines = vec![
        format!(
            "{{\"name\":\"predict_flat\",\"n\":{n},\"d\":{d},\"k\":{k},\"branch\":0,\"beam\":0,\"samples_per_s\":{flat_rate:.1},\"agreement\":1.0}}"
        ),
        format!(
            "{{\"name\":\"predict_routed\",\"n\":{n},\"d\":{d},\"k\":{k},\"branch\":{branch},\"beam\":{beam},\"samples_per_s\":{routed_rate:.1},\"agreement\":{agree:.4}}}"
        ),
    ];
    let path = std::env::var("GKMEANS_BENCH_ROUTE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_route.json"));
    match bench_util::write_json_array(&path, &lines) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("warning: could not write {}: {e}", path.display()),
    }
}
