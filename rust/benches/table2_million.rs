//! Tab. 2 — the extreme-k test: partition the VLAD stand-in into n/10
//! clusters (paper: VLAD10M → 1M clusters), comparing the only two
//! workable systems — closure k-means and GK-means — plus KGraph+GK-means.
//! Columns match the paper: init time, iteration time, total, distortion,
//! graph recall.
//!
//! Paper's reading: GK-means total ≈ ½ closure's and ~6× faster than
//! KGraph+GK-means (NN-Descent dominates its init); GK-means distortion
//! lowest despite its graph's *lower* raw recall — the Alg. 3 graph
//! carries clustering structure.  Regenerate:
//! `cargo bench --bench table2_million`.

use gkmeans::bench_util;
use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::eval::report::Table;

fn main() {
    bench_util::banner("Tab.2", "extreme cluster count: k = n/10 on vlad_like");
    let backend = bench_util::backend();
    let n = bench_util::scaled(20_000);
    let k = n / 10;
    let data = DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 }
        .load()
        .unwrap();
    println!("n={n} d={} k={k}", data.dim());

    let mut t = Table::new(&["method", "init_s", "iter_s", "total_s", "distortion", "recall"]);
    for &m in &[Method::KGraphGkMeans, Method::GkMeans, Method::Closure] {
        let mut job = ClusterJob::new(
            DatasetSpec::Synth { kind: "vlad".into(), n, seed: 20170707 },
            m,
            k,
        );
        job.kappa = 20;
        job.tau = 6;
        job.base.max_iters = 10;
        job.measure_recall = m != Method::Closure;
        let r = pipeline::run_job_on(&job, &data, &backend);
        t.row(&[
            m.name().into(),
            format!("{:.2}", r.init_seconds),
            format!("{:.2}", r.iter_seconds),
            format!("{:.2}", r.total_seconds),
            format!("{:.4}", r.distortion),
            r.recall.map(|x| format!("{x:.2}")).unwrap_or_else(|| "N.A.".into()),
        ]);
        println!("{}", r.table_row());
    }
    println!("{}", t.render());

    // the paper's "3 years for traditional k-means" projection, scaled:
    // measure one Lloyd assignment pass and extrapolate 30 iterations.
    let timer = gkmeans::util::timer::Timer::start();
    let sample = 500.min(n);
    let centroids = data.gather(&(0..k).collect::<Vec<_>>());
    let _ = backend.assign_blocks(
        data.rows_flat(0, sample),
        centroids.flat(),
        data.dim(),
        k,
    );
    let per_sample = timer.elapsed_s() / sample as f64;
    let projected = per_sample * n as f64 * 30.0;
    println!(
        "projected traditional k-means (30 iters, measured assignment rate): {}",
        gkmeans::util::timer::fmt_secs(projected)
    );
    t.write_csv(&gkmeans::eval::report::results_dir().join("table2.csv")).ok();
    println!("paper shape checks: GK-means fastest total; distortion: GK < KGraph+GK < closure;");
    println!("GK recall < KGraph recall yet GK distortion lower (structure transfer).");
}
