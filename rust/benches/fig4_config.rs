//! Fig. 4 — configuration test on Alg. 2: final clustering distortion as
//! a function of the supplied KNN graph's recall, for three configs:
//!   GK-means            (boost core, Alg. 3 graph)    — the standard run
//!   GK-means*           (traditional core, Alg. 3 graph)
//!   KGraph+GK-means     (boost core, NN-Descent graph)
//!
//! Paper's reading (SIFT1M, k=10⁴): higher graph recall → steadily lower
//! distortion for all configs; the boost-core runs sit well below the
//! traditional-core one at every recall level; the Alg. 3 graph edges out
//! NN-Descent at equal recall.  Regenerate: `cargo bench --bench fig4_config`.

use gkmeans::bench_util;
use gkmeans::data::synth;
use gkmeans::eval::report::{f, Table};
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::gkm::gkmeans::GkMeansParams;
use gkmeans::gkm::gkmeans as gk;
use gkmeans::gkm::variant;
use gkmeans::graph::{brute, nn_descent, recall};
use gkmeans::kmeans::common::KmeansParams;

fn main() {
    bench_util::banner("Fig.4", "distortion vs supplied-graph recall, three Alg.2 configs");
    let backend = bench_util::backend();
    let n = bench_util::scaled(10_000);
    let k = (n / 100).max(4); // paper: k = n/100 (10^4 clusters on 1M)
    let kappa = 10;
    let data = synth::sift_like(n, 20170707);
    let exact = brute::build(&data, 1, &backend);
    let base = KmeansParams { max_iters: 15, ..Default::default() };
    let params = GkMeansParams { kappa, base };

    let mut t = Table::new(&["config", "graph_recall@1", "distortion"]);

    // Alg. 3 graphs of increasing quality (tau sweep)
    for tau in [1usize, 2, 4, 7, 10] {
        let g = construct::build(
            &data,
            &ConstructParams { kappa, xi: 50, tau, seed: 1, threads: 1, ..Default::default() },
            &backend,
        );
        let r = recall::recall_at_1(&g.graph, &exact);
        let gk = gk::run_core(&data, k, &g.graph, &params, &backend);
        t.row(&["GK-means".into(), f(r), f(gk.distortion())]);
        let tr = variant::run_core(&data, k, &g.graph, &params, &backend);
        t.row(&["GK-means*".into(), f(r), f(tr.distortion())]);
        println!(
            "tau={tau}: recall={r:.3} gk={:.2} gk*={:.2}",
            gk.distortion(),
            tr.distortion()
        );
    }

    // NN-Descent graphs of increasing quality (iteration sweep)
    for iters in [1usize, 2, 4, 8] {
        let g = nn_descent::build(
            &data,
            kappa,
            &nn_descent::NnDescentParams { max_iters: iters, ..Default::default() },
        );
        let r = recall::recall_at_1(&g, &exact);
        let gk = gk::run_core(&data, k, &g, &params, &backend);
        t.row(&["KGraph+GK-means".into(), f(r), f(gk.distortion())]);
        println!("nn-descent iters={iters}: recall={r:.3} distortion={:.2}", gk.distortion());
    }

    println!("{}", t.render());
    println!("paper shape checks:");
    println!("  (1) within each config, higher recall -> lower distortion");
    println!("  (2) GK-means (boost core) below GK-means* at matched recall");
    t.write_csv(&gkmeans::eval::report::results_dir().join("fig4_config.csv"))
        .ok();
}
