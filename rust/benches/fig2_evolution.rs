//! Fig. 2 — the intertwined evolution of Alg. 3: KNN-graph recall@1 and
//! cell-partition distortion as functions of the round τ.
//!
//! Paper's reading (SIFT100K): both start terrible (recall ≈ 0, random
//! clustering); after ~5 rounds recall exceeds 0.6 and distortion has
//! dropped considerably.  Regenerate: `cargo bench --bench fig2_evolution`.

use gkmeans::bench_util;
use gkmeans::data::synth;
use gkmeans::eval::report::{f, Table};
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::graph::{brute, recall};

fn main() {
    bench_util::banner("Fig.2", "graph recall and clustering distortion vs tau (Alg. 3)");
    let backend = bench_util::backend();
    let n = bench_util::scaled(10_000);
    let data = synth::sift_like(n, 20170707);
    let kappa = 10;
    let tau_max = 10;

    println!("building exact top-1 ground truth (n={n})...");
    let exact = brute::build(&data, 1, &backend);

    // Run construction once per tau so each point is a fresh, complete run
    // (matches how the paper sweeps the parameter).
    let mut t = Table::new(&["tau", "recall@1", "cell_distortion", "seconds"]);
    for tau in 1..=tau_max {
        let out = construct::build(
            &data,
            &ConstructParams {
                kappa,
                xi: 50,
                tau,
                seed: 20170707,
                threads: 1,
                ..Default::default()
            },
            &backend,
        );
        let r = recall::recall_at_1(&out.graph, &exact);
        let h = out.history.last().unwrap();
        t.row(&[
            tau.to_string(),
            f(r),
            f(h.distortion),
            f(out.total_seconds),
        ]);
        println!("tau={tau:>2} recall@1={r:.3} distortion={:.2}", h.distortion);
    }
    println!("{}", t.render());
    println!("paper shape check: recall(tau=5) > 0.6 and rising, distortion falling");
    t.write_csv(&gkmeans::eval::report::results_dir().join("fig2_evolution.csv"))
        .ok();
}
