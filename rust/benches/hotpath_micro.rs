//! §Perf microbenches over the hot paths: native vs PJRT block distance,
//! assignment tiles, scalar d2/dot, top-κ updates, and one GK-means epoch.
//! These are the numbers the EXPERIMENTS.md §Perf before/after table is
//! built from.  Regenerate: `cargo bench --bench hotpath_micro`.

use gkmeans::bench_util;
use gkmeans::core_ops::{blockdist, dist, topk};
use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::eval::report::{f, Table};
use gkmeans::runtime::Backend;
use gkmeans::util::rng::Rng;
use gkmeans::util::timer::Timer;

/// Run `op` repeatedly for ~`budget_s`, return (iters/s, total iters).
fn rate(budget_s: f64, mut op: impl FnMut()) -> (f64, usize) {
    // warmup
    op();
    let timer = Timer::start();
    let mut iters = 0usize;
    while timer.elapsed_s() < budget_s {
        op();
        iters += 1;
    }
    (iters as f64 / timer.elapsed_s(), iters)
}

fn main() {
    bench_util::banner("Perf", "hot-path microbenches (native vs PJRT)");
    let budget = 0.5;
    let mut rng = Rng::new(1);
    let mut t = Table::new(&["op", "shape", "backend", "GFLOP/s", "ops_per_s"]);

    // --- scalar d2 / dot ---
    for d in [128usize, 512, 960] {
        let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let (r, _) = rate(budget, || {
            std::hint::black_box(dist::d2(std::hint::black_box(&a), std::hint::black_box(&b)));
        });
        let gflops = r * (3.0 * d as f64) / 1e9;
        t.row(&["d2".into(), format!("d={d}"), "native".into(), f(gflops), f(r)]);
        println!("d2 d={d}: {r:.0}/s ({gflops:.2} GFLOP/s)");
    }

    // --- block_l2: native vs pjrt ---
    let pjrt = {
        let dir = gkmeans::runtime::artifact::default_dir();
        if dir.join("manifest.tsv").exists() {
            match Backend::pjrt(&dir) {
                Ok(b) => Some(b),
                Err(e) => {
                    eprintln!("pjrt unavailable ({e}); skipping pjrt rows");
                    None
                }
            }
        } else {
            None
        }
    };
    for (m, n, d) in [(256usize, 256usize, 128usize), (256, 256, 512), (64, 64, 128)] {
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; m * n];
        let flop = 3.0 * (m * n * d) as f64;
        let (r_nat, _) = rate(budget, || {
            blockdist::block_l2(&x, &y, d, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[
            "block_l2".into(),
            format!("{m}x{n}x{d}"),
            "native".into(),
            f(r_nat * flop / 1e9),
            f(r_nat),
        ]);
        println!("block_l2 {m}x{n} d={d} native: {r_nat:.1}/s ({:.2} GFLOP/s)", r_nat * flop / 1e9);
        if let Some(ref b) = pjrt {
            let (r_pj, _) = rate(budget, || {
                b.block_l2(&x, &y, d, &mut out);
                std::hint::black_box(&out);
            });
            t.row(&[
                "block_l2".into(),
                format!("{m}x{n}x{d}"),
                "pjrt".into(),
                f(r_pj * flop / 1e9),
                f(r_pj),
            ]);
            println!("block_l2 {m}x{n} d={d} pjrt:   {r_pj:.1}/s ({:.2} GFLOP/s)", r_pj * flop / 1e9);
        }
    }

    // --- full assignment (m x k) ---
    for (m, k, d) in [(2000usize, 256usize, 128usize), (2000, 256, 512)] {
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let flop = 3.0 * (m * k * d) as f64;
        let (r_nat, _) = rate(budget, || {
            std::hint::black_box(Backend::Native.assign_blocks(&x, &c, d, k));
        });
        t.row(&[
            "assign".into(),
            format!("{m}x{k}x{d}"),
            "native".into(),
            f(r_nat * flop / 1e9),
            f(r_nat),
        ]);
        println!("assign {m}x{k} d={d} native: {:.2} GFLOP/s", r_nat * flop / 1e9);
        if let Some(ref b) = pjrt {
            let (r_pj, _) = rate(budget, || {
                std::hint::black_box(b.assign_blocks(&x, &c, d, k));
            });
            t.row(&[
                "assign".into(),
                format!("{m}x{k}x{d}"),
                "pjrt".into(),
                f(r_pj * flop / 1e9),
                f(r_pj),
            ]);
            println!("assign {m}x{k} d={d} pjrt:   {:.2} GFLOP/s", r_pj * flop / 1e9);
        }
    }

    // --- top-κ update throughput ---
    {
        let mut g = gkmeans::graph::knn::KnnGraph::empty(1000, 50);
        let mut i = 0usize;
        let (r, _) = rate(budget, || {
            let j = ((i * 7919) % 999 + 1) as u32;
            g.update(i % 1000, j, (i % 1000) as f32);
            i += 1;
        });
        t.row(&["knn_update".into(), "kappa=50".into(), "native".into(), "-".into(), f(r)]);
        println!("knn update: {r:.0}/s");
        let mut tk = topk::TopK::new(50);
        let (r2, _) = rate(budget, || {
            tk.push(rng.f32(), 1);
        });
        t.row(&["topk_push".into(), "k=50".into(), "native".into(), "-".into(), f(r2)]);
    }

    // --- GK-means epoch throughput: serial vs the parallel layer ---
    // The threads sweep is the perf trajectory future PRs compare against;
    // records land in BENCH_gkm.json (acceptance: threads >= 4 shows >= 2x
    // epoch throughput over serial on a >= 4-core box).
    {
        let n = bench_util::scaled(5_000);
        let k = n / 50;
        let kappa = 20;
        let data = blobs(&BlobSpec::quick(n, 128, 32), 3);
        let graph = gkmeans::gkm::construct::build(
            &data,
            &gkmeans::gkm::construct::ConstructParams {
                kappa: 20,
                xi: 50,
                tau: 3,
                seed: 1,
                threads: 1,
                ..Default::default()
            },
            &Backend::native(),
        )
        .graph;
        let init = gkmeans::kmeans::two_means::cluster(
            &data,
            k,
            &gkmeans::kmeans::two_means::TwoMeansParams::default(),
            &Backend::native(),
        );
        let avail = gkmeans::util::pool::resolve_threads(0);
        let mut records = Vec::new();
        let mut serial_rate = 0f64;
        for &threads in &[1usize, 2, 4, 8] {
            if threads > 1 && threads > avail {
                println!("gk_epoch threads={threads}: skipped ({avail} cores available)");
                continue;
            }
            let params = gkmeans::gkm::gkmeans::GkMeansParams {
                kappa,
                base: gkmeans::kmeans::common::KmeansParams {
                    max_iters: 1,
                    threads,
                    ..Default::default()
                },
            };
            let timer = Timer::start();
            let mut epochs = 0;
            while timer.elapsed_s() < 2.0 {
                let _ = gkmeans::gkm::gkmeans::run_from(&data, init.clone(), &graph, &params);
                epochs += 1;
            }
            let per_epoch = timer.elapsed_s() / epochs as f64;
            let samples_per_s = n as f64 / per_epoch;
            if threads == 1 {
                serial_rate = samples_per_s;
            }
            let speedup = if serial_rate > 0.0 { samples_per_s / serial_rate } else { 1.0 };
            records.push(gkmeans::bench_util::GkBenchRecord {
                name: "gk_epoch".into(),
                n,
                d: 128,
                k,
                kappa,
                threads,
                epochs,
                samples_per_s,
            });
            t.row(&[
                "gk_epoch".into(),
                format!("n={n},kappa=20,d=128,t={threads}"),
                "native".into(),
                "-".into(),
                f(samples_per_s),
            ]);
            println!(
                "gk-means epoch (threads={threads}): {per_epoch:.3}s ({samples_per_s:.0} samples/s, {speedup:.2}x vs serial)"
            );
        }
        match gkmeans::bench_util::write_gk_bench_json(&records) {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => eprintln!("could not write BENCH_gkm.json: {e}"),
        }
    }

    println!("{}", t.render());
    t.write_csv(&gkmeans::eval::report::results_dir().join("hotpath_micro.csv")).ok();
}
