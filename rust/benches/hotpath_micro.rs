//! §Perf microbenches over the hot paths: native vs PJRT block distance,
//! assignment tiles, scalar d2/dot, batched vs scalar candidate-set
//! evaluation (the Alg. 2 inner loop), top-κ updates, and one GK-means
//! epoch.  These are the numbers the EXPERIMENTS.md §Perf before/after
//! table is built from.  Regenerate: `cargo bench --bench hotpath_micro`.

use gkmeans::bench_util;
use gkmeans::core_ops::{blockdist, dist, topk};
use gkmeans::data::synth::{blobs, BlobSpec};
use gkmeans::eval::report::{f, Table};
use gkmeans::runtime::Backend;
use gkmeans::util::rng::Rng;
use gkmeans::util::timer::Timer;

/// Run `op` repeatedly for ~`budget_s`, return (iters/s, total iters).
fn rate(budget_s: f64, mut op: impl FnMut()) -> (f64, usize) {
    // warmup
    op();
    let timer = Timer::start();
    let mut iters = 0usize;
    while timer.elapsed_s() < budget_s {
        op();
        iters += 1;
    }
    (iters as f64 / timer.elapsed_s(), iters)
}

fn main() {
    bench_util::banner("Perf", "hot-path microbenches (native vs PJRT)");
    let budget = 0.5;
    let mut rng = Rng::new(1);
    let mut t = Table::new(&["op", "shape", "backend", "GFLOP/s", "ops_per_s"]);
    let mut records = Vec::new();

    // --- scalar d2 / dot ---
    for d in [128usize, 512, 960] {
        let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let (r, _) = rate(budget, || {
            std::hint::black_box(dist::d2(std::hint::black_box(&a), std::hint::black_box(&b)));
        });
        let gflops = r * (3.0 * d as f64) / 1e9;
        t.row(&["d2".into(), format!("d={d}"), "native".into(), f(gflops), f(r)]);
        println!("d2 d={d}: {r:.0}/s ({gflops:.2} GFLOP/s)");
    }

    // --- block_l2: native vs pjrt ---
    let pjrt = {
        let dir = gkmeans::runtime::artifact::default_dir();
        if dir.join("manifest.tsv").exists() {
            match Backend::pjrt(&dir) {
                Ok(b) => Some(b),
                Err(e) => {
                    eprintln!("pjrt unavailable ({e}); skipping pjrt rows");
                    None
                }
            }
        } else {
            None
        }
    };
    for (m, n, d) in [(256usize, 256usize, 128usize), (256, 256, 512), (64, 64, 128)] {
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; m * n];
        let flop = 3.0 * (m * n * d) as f64;
        let (r_nat, _) = rate(budget, || {
            blockdist::block_l2(&x, &y, d, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[
            "block_l2".into(),
            format!("{m}x{n}x{d}"),
            "native".into(),
            f(r_nat * flop / 1e9),
            f(r_nat),
        ]);
        println!("block_l2 {m}x{n} d={d} native: {r_nat:.1}/s ({:.2} GFLOP/s)", r_nat * flop / 1e9);
        if let Some(ref b) = pjrt {
            let (r_pj, _) = rate(budget, || {
                b.block_l2(&x, &y, d, &mut out);
                std::hint::black_box(&out);
            });
            t.row(&[
                "block_l2".into(),
                format!("{m}x{n}x{d}"),
                "pjrt".into(),
                f(r_pj * flop / 1e9),
                f(r_pj),
            ]);
            println!("block_l2 {m}x{n} d={d} pjrt:   {r_pj:.1}/s ({:.2} GFLOP/s)", r_pj * flop / 1e9);
        }
    }

    // --- full assignment (m x k) ---
    for (m, k, d) in [(2000usize, 256usize, 128usize), (2000, 256, 512)] {
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let flop = 3.0 * (m * k * d) as f64;
        let (r_nat, _) = rate(budget, || {
            std::hint::black_box(Backend::Native.assign_blocks(&x, &c, d, k));
        });
        t.row(&[
            "assign".into(),
            format!("{m}x{k}x{d}"),
            "native".into(),
            f(r_nat * flop / 1e9),
            f(r_nat),
        ]);
        println!("assign {m}x{k} d={d} native: {:.2} GFLOP/s", r_nat * flop / 1e9);
        if let Some(ref b) = pjrt {
            let (r_pj, _) = rate(budget, || {
                std::hint::black_box(b.assign_blocks(&x, &c, d, k));
            });
            t.row(&[
                "assign".into(),
                format!("{m}x{k}x{d}"),
                "pjrt".into(),
                f(r_pj * flop / 1e9),
                f(r_pj),
            ]);
            println!("assign {m}x{k} d={d} pjrt:   {:.2} GFLOP/s", r_pj * flop / 1e9);
        }
    }

    // --- candidate-set evaluation: scalar vs batched (the Δℐ / Alg. 2
    //     inner loop; acceptance: batched ≥ 1.5× the scalar l2 path at
    //     d ≥ 128, κ ≥ 10 — all three variants land in BENCH_gkm.json).
    //     Two scalar baselines keep the comparison honest:
    //       * cand_eval_scalar      — one plain `d2` per candidate (the
    //         issue's "one scalar l2_sq at a time" framing; still what
    //         closure assignment does per candidate)
    //       * cand_eval_scalar_dot  — one `d2_via_dot` per candidate
    //         (the pre-batch Δℐ / GK-means* inner loop since PR 1),
    //         isolating the pure tiling+gather win from the norm-identity
    //         saving that loop already had
    //     cand_eval_batched pins the *portable* tiled kernel
    //     (d2_batch_scalar) so the row stays comparable across feature
    //     sets; cand_eval_simd is the dispatched entry point (identical
    //     without `--features simd`, the runtime-detected tier with it —
    //     acceptance: ≥ 1.5× over cand_eval_batched at d ≥ 128 on an
    //     AVX2/NEON host); cand_eval_sq8 runs the same gather+evaluate
    //     shape over u8 codes (d bytes of candidate bandwidth instead of
    //     4d — the quantized serving hot path, see data::quant).
    for (d, kappa) in [(128usize, 10usize), (128, 50), (512, 20)] {
        let k = 256; // candidate pool the κ candidates are drawn from
        let centroids: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let cnorms: Vec<f32> = centroids.chunks_exact(d).map(dist::norm2).collect();
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let xx = dist::norm2(&x);
        let cand: Vec<usize> = (0..kappa).map(|t| (t * 37) % k).collect();
        let (r_scalar, it_s) = rate(budget, || {
            let mut best = f32::INFINITY;
            let mut best_c = 0usize;
            for &c in &cand {
                let dd = dist::d2(&x, &centroids[c * d..(c + 1) * d]);
                if dd < best {
                    best = dd;
                    best_c = c;
                }
            }
            std::hint::black_box((best, best_c));
        });
        let (r_dot, it_d) = rate(budget, || {
            let mut best = f32::INFINITY;
            let mut best_c = 0usize;
            for &c in &cand {
                let col = &centroids[c * d..(c + 1) * d];
                let dd = dist::d2_via_dot(xx, cnorms[c], dist::dot(&x, col));
                if dd < best {
                    best = dd;
                    best_c = c;
                }
            }
            std::hint::black_box((best, best_c));
        });
        // batched path: gather the candidate block + cached norms, one
        // d2_batch kernel call (gather cost included — it is part of the
        // real hot path)
        let mut block = vec![0f32; kappa * d];
        let mut nsel = vec![0f32; kappa];
        let mut out = vec![0f32; kappa];
        let (r_batch, it_b) = rate(budget, || {
            for (j, &c) in cand.iter().enumerate() {
                block[j * d..(j + 1) * d].copy_from_slice(&centroids[c * d..(c + 1) * d]);
                nsel[j] = cnorms[c];
            }
            dist::d2_batch_scalar(&x, xx, &block, &nsel, d, &mut out);
            let mut best = f32::INFINITY;
            let mut best_c = 0usize;
            for (j, &v) in out.iter().enumerate() {
                if v < best {
                    best = v;
                    best_c = cand[j];
                }
            }
            std::hint::black_box((best, best_c));
        });
        let (r_simd, it_v) = rate(budget, || {
            for (j, &c) in cand.iter().enumerate() {
                block[j * d..(j + 1) * d].copy_from_slice(&centroids[c * d..(c + 1) * d]);
                nsel[j] = cnorms[c];
            }
            dist::d2_batch(&x, xx, &block, &nsel, d, &mut out);
            let mut best = f32::INFINITY;
            let mut best_c = 0usize;
            for (j, &v) in out.iter().enumerate() {
                if v < best {
                    best = v;
                    best_c = cand[j];
                }
            }
            std::hint::black_box((best, best_c));
        });
        // SQ8 path: codes gathered per candidate (d bytes, not 4d), one
        // asymmetric kernel call — the quantized serving shape
        let qs = gkmeans::data::quant::QuantizedVecStore::from_store(
            &gkmeans::data::matrix::VecSet::from_flat(d, centroids.clone()),
            0,
        );
        let cand_ids: Vec<u32> = cand.iter().map(|&c| c as u32).collect();
        let mut cbuf: Vec<u8> = Vec::new();
        let (r_sq8, it_q) = rate(budget, || {
            qs.d2_gather(&x, &cand_ids, &mut cbuf, &mut out);
            let mut best = f32::INFINITY;
            let mut best_c = 0usize;
            for (j, &v) in out.iter().enumerate() {
                if v < best {
                    best = v;
                    best_c = cand[j];
                }
            }
            std::hint::black_box((best, best_c));
        });
        for (name, r, iters) in [
            ("cand_eval_scalar", r_scalar, it_s),
            ("cand_eval_scalar_dot", r_dot, it_d),
            ("cand_eval_batched", r_batch, it_b),
            ("cand_eval_simd", r_simd, it_v),
            ("cand_eval_sq8", r_sq8, it_q),
        ] {
            records.push(gkmeans::bench_util::GkBenchRecord {
                name: name.into(),
                n: k,
                d,
                k,
                kappa,
                threads: 1,
                epochs: iters,
                samples_per_s: r,
            });
            t.row(&[
                name.into(),
                format!("d={d},kappa={kappa}"),
                "native".into(),
                f(r * (2.0 * (d * kappa) as f64) / 1e9),
                f(r),
            ]);
        }
        println!(
            "cand_eval d={d} kappa={kappa}: l2 {r_scalar:.0}/s, dot {r_dot:.0}/s, batched {r_batch:.0}/s ({:.2}x vs l2), simd {r_simd:.0}/s ({:.2}x vs batched), sq8 {r_sq8:.0}/s",
            r_batch / r_scalar.max(1e-12),
            r_simd / r_batch.max(1e-12)
        );
    }

    // --- top-κ update throughput ---
    {
        let mut g = gkmeans::graph::knn::KnnGraph::empty(1000, 50);
        let mut i = 0usize;
        let (r, _) = rate(budget, || {
            let j = ((i * 7919) % 999 + 1) as u32;
            g.update(i % 1000, j, (i % 1000) as f32);
            i += 1;
        });
        t.row(&["knn_update".into(), "kappa=50".into(), "native".into(), "-".into(), f(r)]);
        println!("knn update: {r:.0}/s");
        let mut tk = topk::TopK::new(50);
        let (r2, _) = rate(budget, || {
            tk.push(rng.f32(), 1);
        });
        t.row(&["topk_push".into(), "k=50".into(), "native".into(), "-".into(), f(r2)]);
    }

    // --- routed vs flat single-query predict at large k ---
    // The routing-tree hot path: one query against k=2048 centroids,
    // flat O(k) assign_blocks vs O(depth·branch·beam) tree descent at
    // the default beam.  Random centroids are the worst case for the
    // tree (no cluster structure to exploit), so the speedup here is a
    // floor; clustered fits route strictly better.
    {
        let k = 2048usize;
        let d = 128usize;
        let flat_c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let centroids = gkmeans::data::matrix::VecSet::from_flat(d, flat_c);
        let backend = Backend::native();
        let tree = gkmeans::gkm::tree::RouteTree::build(
            &centroids,
            &gkmeans::gkm::tree::RouteTreeParams::default(),
            &backend,
        );
        let beam = tree.default_beam as usize;
        let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let (r_flat, it_f) = rate(budget, || {
            std::hint::black_box(backend.assign_blocks(&q, centroids.flat(), d, k));
        });
        let mut scratch = gkmeans::gkm::tree::RouteScratch::new();
        let (r_routed, it_r) = rate(budget, || {
            std::hint::black_box(tree.predict_one(&q, &centroids, beam, &backend, &mut scratch));
        });
        for (name, r, iters) in
            [("predict_flat", r_flat, it_f), ("predict_routed", r_routed, it_r)]
        {
            records.push(gkmeans::bench_util::GkBenchRecord {
                name: name.into(),
                n: 1,
                d,
                k,
                kappa: beam,
                threads: 1,
                epochs: iters,
                samples_per_s: r,
            });
            t.row(&[
                name.into(),
                format!("k={k},d={d},beam={beam}"),
                "native".into(),
                "-".into(),
                f(r),
            ]);
        }
        println!(
            "predict k={k} d={d}: flat {r_flat:.0}/s, routed {r_routed:.0}/s ({:.2}x, beam={beam}, depth={})",
            r_routed / r_flat.max(1e-12),
            tree.depth()
        );
    }

    // --- GK-means epoch throughput: serial vs the parallel layer ---
    // The threads sweep is the perf trajectory future PRs compare against;
    // records land in BENCH_gkm.json (acceptance: threads >= 4 shows >= 2x
    // epoch throughput over serial on a >= 4-core box).
    {
        let n = bench_util::scaled(5_000);
        let k = n / 50;
        let kappa = 20;
        let data = blobs(&BlobSpec::quick(n, 128, 32), 3);
        let graph = gkmeans::gkm::construct::build(
            &data,
            &gkmeans::gkm::construct::ConstructParams {
                kappa: 20,
                xi: 50,
                tau: 3,
                seed: 1,
                threads: 1,
                ..Default::default()
            },
            &Backend::native(),
        )
        .graph;
        let init = gkmeans::kmeans::two_means::cluster(
            &data,
            k,
            &gkmeans::kmeans::two_means::TwoMeansParams::default(),
            &Backend::native(),
        );
        let avail = gkmeans::util::pool::resolve_threads(0);
        let mut serial_rate = 0f64;
        for &threads in &[1usize, 2, 4, 8] {
            if threads > 1 && threads > avail {
                println!("gk_epoch threads={threads}: skipped ({avail} cores available)");
                continue;
            }
            let params = gkmeans::gkm::gkmeans::GkMeansParams {
                kappa,
                base: gkmeans::kmeans::common::KmeansParams {
                    max_iters: 1,
                    threads,
                    ..Default::default()
                },
            };
            let timer = Timer::start();
            let mut epochs = 0;
            while timer.elapsed_s() < 2.0 {
                let _ = gkmeans::gkm::gkmeans::run_from(&data, init.clone(), &graph, &params);
                epochs += 1;
            }
            let per_epoch = timer.elapsed_s() / epochs as f64;
            let samples_per_s = n as f64 / per_epoch;
            if threads == 1 {
                serial_rate = samples_per_s;
            }
            let speedup = if serial_rate > 0.0 { samples_per_s / serial_rate } else { 1.0 };
            records.push(gkmeans::bench_util::GkBenchRecord {
                name: "gk_epoch".into(),
                n,
                d: 128,
                k,
                kappa,
                threads,
                epochs,
                samples_per_s,
            });
            t.row(&[
                "gk_epoch".into(),
                format!("n={n},kappa=20,d=128,t={threads}"),
                "native".into(),
                "-".into(),
                f(samples_per_s),
            ]);
            println!(
                "gk-means epoch (threads={threads}): {per_epoch:.3}s ({samples_per_s:.0} samples/s, {speedup:.2}x vs serial)"
            );
        }
        match gkmeans::bench_util::write_gk_bench_json(&records) {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => eprintln!("could not write BENCH_gkm.json: {e}"),
        }
    }

    println!("{}", t.render());
    t.write_csv(&gkmeans::eval::report::results_dir().join("hotpath_micro.csv")).ok();
}
