//! Fig. 5 — clustering quality on SIFT / GloVe / GIST stand-ins:
//! distortion as a function of (a,c,e) iteration count and (b,d,f)
//! wall-clock time, for k-means, boost k-means, Mini-Batch, closure
//! k-means, GK-means and KGraph+GK-means.  k = n/100 (paper: 10⁴ on 1M).
//!
//! Paper's reading: BKM best quality; GK-means within a hair of BKM (and
//! beating traditional k-means on SIFT/GIST) at a fraction of the time;
//! Mini-Batch clearly worst; closure k-means in between.  Regenerate:
//! `cargo bench --bench fig5_quality`.

use gkmeans::bench_util;
use gkmeans::coordinator::job::{ClusterJob, Method};
use gkmeans::coordinator::pipeline;
use gkmeans::data::DatasetSpec;
use gkmeans::eval::report::{f, Table};

fn main() {
    bench_util::banner("Fig.5", "distortion vs iteration and vs time, three datasets");
    let backend = bench_util::backend();
    let methods = [
        Method::Lloyd,
        Method::Boost,
        Method::MiniBatch,
        Method::Closure,
        Method::GkMeans,
        Method::KGraphGkMeans,
    ];

    for (kind, n_default) in [("sift", 10_000usize), ("glove", 10_000), ("gist", 3_000)] {
        let n = bench_util::scaled(n_default);
        let k = (n / 100).max(4);
        let data = DatasetSpec::Synth { kind: kind.into(), n, seed: 20170707 }
            .load()
            .unwrap();
        println!("\n--- {kind} (n={n}, d={}, k={k}) ---", data.dim());

        let mut curves = Table::new(&["method", "iter", "seconds", "distortion"]);
        let mut summary = Table::new(&["method", "total_s", "final_distortion"]);
        for &m in &methods {
            let mut job = ClusterJob::new(
                DatasetSpec::Synth { kind: kind.into(), n, seed: 20170707 },
                m,
                k,
            );
            job.kappa = 20;
            job.tau = 8;
            job.base.max_iters = 30;
            let r = pipeline::run_job_on(&job, &data, &backend);
            for h in &r.history {
                curves.row(&[
                    m.name().into(),
                    h.iter.to_string(),
                    f(h.seconds),
                    f(h.distortion),
                ]);
            }
            summary.row(&[m.name().into(), f(r.total_seconds), f(r.distortion)]);
            println!(
                "{:<18} total={:>8.2}s distortion={:.2}",
                m.name(),
                r.total_seconds,
                r.distortion
            );
        }
        println!("{}", summary.render());
        curves
            .write_csv(
                &gkmeans::eval::report::results_dir().join(format!("fig5_{kind}_curves.csv")),
            )
            .ok();
        summary
            .write_csv(
                &gkmeans::eval::report::results_dir().join(format!("fig5_{kind}_summary.csv")),
            )
            .ok();
    }
    println!("\npaper shape checks: BKM lowest distortion; GK-means close behind at far");
    println!("lower time; Mini-Batch fastest-but-worst; see EXPERIMENTS.md.");
}
