//! §4.3's ANNS application — the Alg. 3 graph serving approximate
//! nearest-neighbor queries, vs a NN-Descent graph of the same κ.
//! Reports recall@1 against exact search vs per-query distance
//! evaluations and latency, over an `ef` sweep.
//!
//! Paper's reading: the Alg. 3 graph's raw recall is below NN-Descent's,
//! yet its search performance is competitive (the paper quotes <3 ms at
//! recall >0.9 on 100M SIFT with τ up to 32).  Regenerate:
//! `cargo bench --bench ann_search`.

use gkmeans::bench_util;
use gkmeans::data::synth;
use gkmeans::eval::report::{f, Table};
use gkmeans::gkm::ann::{self, SearchParams};
use gkmeans::gkm::construct::{self, ConstructParams};
use gkmeans::graph::nn_descent;
use gkmeans::model::{Clusterer, FittedModel, GkMeans, RunContext};
use gkmeans::util::rng::Rng;
use gkmeans::util::timer::Timer;

fn main() {
    bench_util::banner("ANNS", "graph-based search: Alg.3 graph vs NN-Descent graph");
    let backend = bench_util::backend();
    let n = bench_util::scaled(10_000);
    let kappa = 20;
    let data = synth::sift_like(n, 20170707);
    let nq = 200.min(n / 10);

    println!("building graphs (n={n}, kappa={kappa})...");
    let (g_alg3, t_alg3) = gkmeans::util::timer::timed(|| {
        construct::build(
            &data,
            &ConstructParams { kappa, xi: 50, tau: 16, seed: 1, threads: 1, ..Default::default() },
            &backend,
        )
        .graph
    });
    let (g_nnd, t_nnd) = gkmeans::util::timer::timed(|| {
        nn_descent::build(&data, kappa, &nn_descent::NnDescentParams::default())
    });
    println!("alg3 graph: {t_alg3:.2}s, nn-descent graph: {t_nnd:.2}s");

    // query set: perturbed data points with known exact answers
    let mut rng = Rng::new(42);
    let queries: Vec<(usize, Vec<f32>)> = (0..nq)
        .map(|_| {
            let qi = rng.below(n);
            let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.5 * rng.normal()).collect();
            (qi, q)
        })
        .collect();
    // exact answers by brute force
    let truth: Vec<u32> = queries
        .iter()
        .map(|(_, q)| {
            let mut best = f32::INFINITY;
            let mut idx = 0u32;
            for j in 0..n {
                let dd = gkmeans::core_ops::dist::d2(q, data.row(j));
                if dd < best {
                    best = dd;
                    idx = j as u32;
                }
            }
            idx
        })
        .collect();

    let mut t = Table::new(&["graph", "build_s", "ef", "recall@1", "dist_evals", "us_per_query"]);
    for (name, graph, build_s) in [("Alg.3", &g_alg3, t_alg3), ("NN-Descent", &g_nnd, t_nnd)] {
        for &ef in &[8usize, 16, 32, 64, 128] {
            let sp = SearchParams { ef, entries: 48, seed: 7 }; // sift_like has ~50 components; entries must cover them
            let mut srng = Rng::new(7);
            let mut hits = 0usize;
            let mut evals = 0usize;
            let timer = Timer::start();
            for ((_, q), &want) in queries.iter().zip(&truth) {
                let (res, stats) = ann::search(&data, graph, q, 1, &sp, &mut srng);
                evals += stats.dist_evals;
                if res.first().map(|r| r.1) == Some(want) {
                    hits += 1;
                }
            }
            let secs = timer.elapsed_s();
            t.row(&[
                name.into(),
                f(build_s),
                ef.to_string(),
                f(hits as f64 / nq as f64),
                (evals / nq).to_string(),
                f(secs / nq as f64 * 1e6),
            ]);
            println!(
                "{name:<11} ef={ef:<4} recall@1={:.3} evals/q={} {:.0}us/q",
                hits as f64 / nq as f64,
                evals / nq,
                secs / nq as f64 * 1e6
            );
        }
    }
    println!("{}", t.render());
    println!("paper shape checks: Alg.3 builds faster than NN-Descent; both reach");
    println!("high recall with ef; Alg.3 competitive despite lower raw graph recall.");
    t.write_csv(&gkmeans::eval::report::results_dir().join("ann_search.csv")).ok();

    // --- the serving-artifact path: fit -> save -> load -> search ---
    // (what examples/ann_service.rs deploys; recall should track the raw
    // Alg.3 rows above since the model embeds the same graph + vectors)
    let k = (n / 100).max(4);
    let ctx = RunContext::new(&backend).keep_data(true).max_iters(5);
    let model = GkMeans::new(k).kappa(kappa).tau(16).fit(&data, &ctx);
    let path = std::env::temp_dir().join(format!("ann_search_bench_{}.gkm", std::process::id()));
    model.save(&path).expect("save model");
    let served = FittedModel::load(&path).expect("load model");
    std::fs::remove_file(&path).ok();
    let sp = SearchParams { ef: 64, entries: 48, seed: 7 };
    let timer = Timer::start();
    let mut hits = 0usize;
    for ((_, q), &want) in queries.iter().zip(&truth) {
        let res = served.search(q, 1, &sp).expect("served search");
        if res.first().map(|r| r.1) == Some(want) {
            hits += 1;
        }
    }
    let secs = timer.elapsed_s();
    println!(
        "served artifact (fit->save->load->search): recall@1={:.3} {:.0}us/q \
         (graph built in {:.2}s inside fit)",
        hits as f64 / nq as f64,
        secs / nq as f64 * 1e6,
        model.graph_seconds
    );
}
