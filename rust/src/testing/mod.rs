//! In-tree property-based testing mini-framework (proptest substitute)
//! and deterministic I/O fault injection ([`fault`]).

pub mod fault;
pub mod prop;
