//! In-tree property-based testing mini-framework (proptest substitute).

pub mod prop;
