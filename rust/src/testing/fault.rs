//! Deterministic I/O fault injection for the out-of-core storage layer.
//!
//! [`FaultStore`] wraps a [`ChunkedVecStore`] and makes its physical
//! chunk reads fail on a schedule derived *only* from a seed and a
//! global operation counter — no wall clock, no OS randomness — so a
//! "flaky disk" run is exactly reproducible:
//!
//! * **Transient faults** (`ErrorKind::Interrupted`) fire on the ops
//!   where `splitmix64(seed ^ op·φ)` falls below `transient_rate`.
//!   Combined with a [`FaultPolicy`] retry budget on the inner store,
//!   a fit over a transiently-faulty store must be *bit-identical* to
//!   the fault-free fit: retries re-read the same bytes.
//! * **Permanent faults** (`ErrorKind::Other`) fire on every op from
//!   `fail_at_op` onward, modeling a disk that dies mid-fit and stays
//!   dead.  Retry policies rightly give up immediately (the kind is
//!   not transient) and the failure surfaces to the caller.
//!
//! The injection point is [`ChunkedVecStore::with_fault_hook`]: the
//! hook is consulted once per *physical* read attempt (retries
//! included), so injected faults exercise the exact code path real
//! ones take.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::data::plan::ScanGeometry;
use crate::data::store::{ChunkedVecStore, FaultHook, FaultPolicy, StoreCursor, VecStore};

/// What to inject, derived deterministically from (seed, op index).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Seed for the per-op hash deciding transient faults.
    pub seed: u64,
    /// Probability in `[0, 1]` that any given read attempt fails with
    /// a transient (`Interrupted`) error.
    pub transient_rate: f64,
    /// First op index at which the store fails *permanently*: that op
    /// and every later one error with `ErrorKind::Other`.
    pub fail_at_op: Option<u64>,
}

impl FaultPlan {
    /// A plan that never injects anything (useful for op counting).
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan { seed, transient_rate: 0.0, fail_at_op: None }
    }

    /// Transient faults only, at `rate`.
    pub fn transient(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, transient_rate: rate, fail_at_op: None }
    }

    /// Permanent failure from op `at` onward, no transient noise.
    pub fn dies_at(seed: u64, at: u64) -> FaultPlan {
        FaultPlan { seed, transient_rate: 0.0, fail_at_op: Some(at) }
    }
}

/// SplitMix64 finalizer: the standard 64-bit avalanche used to turn
/// `(seed, op)` into an i.i.d.-looking decision stream.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A [`VecStore`] that reads through a fault-injecting
/// [`ChunkedVecStore`], counting every physical attempt and every
/// injected fault.
pub struct FaultStore {
    inner: ChunkedVecStore,
    ops: Arc<AtomicU64>,
    injected: Arc<AtomicU64>,
}

impl FaultStore {
    /// Wrap `store` so its chunk reads fail per `plan`, retried per
    /// `policy`.  The hook and policy are installed on a clone-free
    /// move of `store`; the original cursors (if any) are unaffected.
    pub fn new(store: ChunkedVecStore, plan: FaultPlan, policy: FaultPolicy) -> FaultStore {
        let ops = Arc::new(AtomicU64::new(0));
        let injected = Arc::new(AtomicU64::new(0));
        let (ops_h, injected_h) = (ops.clone(), injected.clone());
        let hook = FaultHook(Arc::new(move || {
            let op = ops_h.fetch_add(1, Ordering::SeqCst);
            if let Some(at) = plan.fail_at_op {
                if op >= at {
                    injected_h.fetch_add(1, Ordering::SeqCst);
                    return Some(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        format!("injected permanent fault at op {op}"),
                    ));
                }
            }
            if plan.transient_rate > 0.0 {
                let h = splitmix64(plan.seed ^ op.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                if (h as f64 / u64::MAX as f64) < plan.transient_rate {
                    injected_h.fetch_add(1, Ordering::SeqCst);
                    return Some(std::io::Error::new(
                        std::io::ErrorKind::Interrupted,
                        format!("injected transient fault at op {op}"),
                    ));
                }
            }
            None
        }));
        FaultStore {
            inner: store.with_fault_hook(hook).with_fault_policy(policy),
            ops,
            injected,
        }
    }

    /// Physical read attempts seen so far (retries included).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::SeqCst)
    }

    /// Faults injected so far (transient + permanent).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// The wrapped store (hook and policy installed).
    pub fn inner(&self) -> &ChunkedVecStore {
        &self.inner
    }
}

impl VecStore for FaultStore {
    fn rows(&self) -> usize {
        VecStore::rows(&self.inner)
    }

    fn dim(&self) -> usize {
        VecStore::dim(&self.inner)
    }

    fn open(&self) -> StoreCursor<'_> {
        self.inner.open()
    }

    fn disk_backing(&self) -> Option<&ChunkedVecStore> {
        Some(&self.inner)
    }

    fn scan_geometry(&self) -> Option<ScanGeometry> {
        self.inner.scan_geometry()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::VecSet;
    use crate::data::store::materialize;
    use crate::model::{checkpoint, Clusterer, GkMeans, RunContext};
    use crate::runtime::Backend;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gkm_fault_{}_{name}", std::process::id()))
    }

    fn write_dataset(path: &std::path::Path, n: usize, d: usize, seed: u64) -> VecSet {
        let mut rng = Rng::new(seed);
        let v = VecSet::from_flat(d, (0..n * d).map(|_| rng.normal()).collect());
        crate::data::io::write_fvecs(path, &v).unwrap();
        v
    }

    fn open_chunked(path: &std::path::Path) -> ChunkedVecStore {
        ChunkedVecStore::open_fvecs(path).unwrap().chunk_rows(16).cache_chunks(4)
    }

    #[test]
    fn splitmix64_known_answers() {
        // SplitMix64 reference values (seed 0 stream: first two outputs).
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(0xE220_A839_7B1D_CDAF ^ 1), splitmix64(0xE220_A839_7B1D_CDAF ^ 1));
        assert_ne!(splitmix64(1), splitmix64(2));
    }

    #[test]
    fn transient_faults_leave_reads_bit_identical() {
        let p = tmp("transient.fvecs");
        let v = write_dataset(&p, 120, 6, 11);
        let clean = open_chunked(&p);
        let faulty = FaultStore::new(
            open_chunked(&p),
            FaultPlan::transient(42, 0.1),
            FaultPolicy { retries: 12, backoff: std::time::Duration::ZERO },
        );
        assert_eq!(materialize(&faulty), materialize(&clean));
        assert_eq!(materialize(&faulty), v);
        assert!(faulty.injected() > 0, "rate 0.1 over {} ops injected nothing", faulty.ops());
        assert!(faulty.ops() > faulty.injected());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn transient_faults_do_not_change_a_fit() {
        let p = tmp("fit.fvecs");
        write_dataset(&p, 240, 8, 3);
        let backend = Backend::native();
        let ctx = RunContext::new(&backend).threads(1).seed(5).max_iters(6).min_move_rate(0.0);

        let clean = open_chunked(&p);
        let want = GkMeans::new(6).kappa(4).fit_store(&clean, &ctx);

        let faulty = FaultStore::new(
            open_chunked(&p),
            FaultPlan::transient(42, 0.1),
            FaultPolicy { retries: 12, backoff: std::time::Duration::ZERO },
        );
        let got = GkMeans::new(6).kappa(4).fit_store(&faulty, &ctx);

        assert!(faulty.injected() > 0, "no faults injected over {} ops", faulty.ops());
        assert_eq!(got.labels, want.labels);
        assert_eq!(got.centroids.flat(), want.centroids.flat());
        assert_eq!(got.history.len(), want.history.len());
        for (a, b) in got.history.iter().zip(want.history.iter()) {
            assert_eq!(a.distortion.to_bits(), b.distortion.to_bits());
            assert_eq!(a.moves, b.moves);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn permanent_fault_fails_cleanly_and_resume_completes() {
        let p = tmp("perm.fvecs");
        write_dataset(&p, 240, 8, 7);
        let dir = tmp("perm_ckpt");
        std::fs::remove_dir_all(&dir).ok();
        let backend = Backend::native();
        let ctx = |resume: bool| {
            RunContext::new(&backend)
                .threads(1)
                .seed(9)
                .max_iters(8)
                .min_move_rate(0.0)
                .checkpoint(&dir, 1)
                .resume(resume)
        };

        // Pass 1: count the ops a fault-free fit performs end to end.
        let counting = FaultStore::new(open_chunked(&p), FaultPlan::none(0), FaultPolicy::none());
        let want = GkMeans::new(6).kappa(4).fit_store(&counting, &ctx(false));
        let total = counting.ops();
        assert!(total > 2, "op count {total} too small to stage a late failure");
        std::fs::remove_dir_all(&dir).ok();

        // Pass 2: same fit, but the disk dies one read before the end.
        let dying =
            FaultStore::new(open_chunked(&p), FaultPlan::dies_at(0, total - 1), FaultPolicy::none());
        let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            GkMeans::new(6).kappa(4).fit_store(&dying, &ctx(false))
        }));
        assert!(crashed.is_err(), "fit should fail once the store dies");
        assert!(dying.injected() > 0);

        // The periodic checkpoint survived the crash and names a later epoch.
        let ck = checkpoint::load(&checkpoint::checkpoint_path(&dir)).unwrap();
        assert!(ck.next_iter >= 2, "checkpoint stuck at next_iter {}", ck.next_iter);

        // Pass 3: resume on a healthy store finishes and matches the
        // uninterrupted fit bit-for-bit (threads = 1 contract).
        let clean = open_chunked(&p);
        let resumed = GkMeans::new(6).kappa(4).fit_store(&clean, &ctx(true));
        assert_eq!(resumed.labels, want.labels);
        assert_eq!(resumed.centroids.flat(), want.centroids.flat());
        assert_eq!(resumed.history.len(), want.history.len());

        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_file(&p).ok();
    }
}
