//! A small property-based testing framework (the offline substitute for
//! `proptest`): seeded generators + a runner that reports the failing
//! seed/case so failures are reproducible, with simple input-size
//! shrinking for dataset-shaped cases.
//!
//! Usage:
//! ```ignore
//! prop::check("labels always valid", 50, |g| {
//!     let n = g.usize_in(1, 500);
//!     let k = g.usize_in(1, n);
//!     /* ... build and assert ... */
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to properties: a seeded RNG with typed draws.
pub struct Gen {
    pub rng: Rng,
    /// Log of drawn values, printed on failure for reproduction.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.trace.push(format!("usize[{lo},{hi}]={v}"));
        v
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.f32() * (hi - lo);
        self.trace.push(format!("f32[{lo},{hi})={v}"));
        v
    }

    /// Standard normal vector of length `n`.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let v: Vec<f32> = (0..n).map(|_| self.rng.normal()).collect();
        self.trace.push(format!("normal_vec(len={n})"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.trace.push(format!("choose(idx={i})"));
        &xs[i]
    }

    /// A flat row-major matrix with values in a sane range.
    pub fn matrix(&mut self, rows: usize, dim: usize, scale: f32) -> crate::data::matrix::VecSet {
        let flat: Vec<f32> = (0..rows * dim).map(|_| self.rng.normal() * scale).collect();
        self.trace.push(format!("matrix({rows}x{dim}, scale={scale})"));
        crate::data::matrix::VecSet::from_flat(dim, flat)
    }
}

/// Run `cases` random cases of a property; panics with the seed + draw
/// trace of the first failure.  Base seed is stable per property name so
/// failures reproduce across runs; set `GKMEANS_PROP_SEED` to override.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("GKMEANS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed (case {case}, seed {seed}):\n  {msg}\n  draws: [{}]\n  reproduce with GKMEANS_PROP_SEED={seed} and cases=1",
                g.trace.join(", ")
            );
        }
    }
}

/// FNV-1a, used to derive a stable per-property base seed.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("trivially true", 10, |g| {
            let _ = g.usize_in(0, 5);
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property \"always fails\" failed")]
    fn failing_property_reports() {
        check("always fails", 3, |g| {
            let v = g.usize_in(0, 9);
            Err(format!("drew {v}"))
        });
    }

    #[test]
    fn generators_in_range() {
        check("generator ranges", 50, |g| {
            let u = g.usize_in(3, 7);
            if !(3..=7).contains(&u) {
                return Err(format!("usize out of range: {u}"));
            }
            let f = g.f32_in(-1.0, 1.0);
            if !(-1.0..1.0).contains(&f) {
                return Err(format!("f32 out of range: {f}"));
            }
            let m = g.matrix(4, 3, 2.0);
            if m.rows() != 4 || m.dim() != 3 {
                return Err("matrix shape".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stable_base_seed_per_name() {
        assert_eq!(fnv1a(b"x"), fnv1a(b"x"));
        assert_ne!(fnv1a(b"x"), fnv1a(b"y"));
    }
}
