//! Alg. 2 — GK-means: graph-driven boost k-means.
//!
//! For each sample `x_i` (random visit order), collect the candidate set
//! `Q = { cLabel[b] : b ∈ G[i] }` — the clusters its κ graph-neighbors
//! currently reside in — and move `x_i` to the `v ∈ Q` maximizing Δℐ
//! (Eqn. 3) when the best Δℐ is positive.  Because `|Q| ≤ κ ≪ k` (and in
//! practice ≪ κ after dedup), the per-epoch cost is `O(n·d·κ̃)` —
//! independent of `k`, which is the paper's whole point.
//!
//! Initialization is Alg. 1 (2M-tree), exactly as the paper specifies.
//!
//! ## Batched candidate evaluation (the mini-GEMM hot path)
//!
//! The per-sample inner loop no longer evaluates the κ̃ candidates one
//! scalar dot at a time: `EpochScratch::best_move` gathers `D_u` plus
//! every candidate composite into a contiguous block and computes all
//! the cross dots with one tiled
//! [`dot_batch`](crate::core_ops::dist::dot_batch) call (four candidates
//! share each load of `x`), then folds Δℐ from the `DeltaCache`'s
//! composite-norm cache.  Because `dot_batch` replicates the scalar
//! `dot` accumulation order per column and the `*_from_dot` folds are
//! the scalar expressions verbatim, the batched scan picks the same
//! moves with the same Δℐ bits — `threads = 1` results remain
//! **bit-identical to the seed implementation**, which the tests pin
//! against an in-test replica of the seed scalar loop.  (GK-means\* in
//! [`crate::gkm::variant`] batches through the norm-identity `d2_batch`
//! instead and is allowed to shift at f32 rounding.)
//!
//! ## Parallel epochs (`threads > 1`): batch-synchronous commit protocol
//!
//! The serial epoch is a chain of dependent moves: each move updates the
//! composites/`DeltaCache`, and the next sample's Δℐ reads them.  To
//! parallelize without locks, the epoch is processed in **batches** over
//! the shuffled visit order:
//!
//! 1. **Scan (parallel).** The batch is sharded contiguously across
//!    workers.  Each worker evaluates its samples against a *frozen
//!    snapshot* of the clustering state (labels, composites, cached
//!    ‖D_r‖²) — shared immutable borrows, no synchronization — and
//!    records a move proposal `(i, v, ‖x_i‖²)` whenever the snapshot says
//!    Δℐ > 0.
//! 2. **Commit (serial).** Proposals are folded back in shard order.
//!    Because earlier commits in the same batch may have changed the
//!    state the proposal was computed against, each proposal's Δℐ is
//!    **re-validated against the current state** (two O(d) dots) and
//!    applied via [`DeltaCache::commit_move`] only if still positive.
//!
//! Monotonicity is therefore preserved *exactly*, not just in
//! expectation: every applied move has a positive Δℐ with respect to the
//! state it is applied to, so the objective ℐ rises (and distortion ℰ
//! falls) monotonically — the same invariant the serial path has.  The
//! cost is that a few stale proposals are discarded; they get a fresh
//! chance next epoch.  Re-validation is ~2 dots versus the ~|Q|+1 dots of
//! the scan, so the serial fraction stays small and epoch throughput
//! scales with cores.
//!
//! With `threads = 1` the historical serial loop runs unchanged (same RNG
//! stream, same visit order, same arithmetic): results are bit-identical
//! to the pre-parallel implementation, which the seed tests rely on.

use crate::core_ops::dist::{dot_batch, norm2};
use crate::data::matrix::VecSet;
use crate::data::plan::ScanPlan;
use crate::data::store::VecStore;
use crate::gkm::CandidateSet;
use crate::graph::knn::KnnGraph;
use crate::kmeans::boost::{fire_epoch, DeltaCache};
use crate::kmeans::common::{Clustering, FitHooks, IterStat, KmeansOutput, KmeansParams};
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::runtime::Backend;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// GK-means parameters.  Defaults follow §4.4: κ = 50.
#[derive(Debug, Clone)]
pub struct GkMeansParams {
    /// Number of graph neighbors consulted per sample (κ).
    pub kappa: usize,
    pub base: KmeansParams,
}

impl Default for GkMeansParams {
    fn default() -> Self {
        GkMeansParams { kappa: 50, base: KmeansParams::default() }
    }
}

/// Deprecated shim over [`run_core`] — the pre-`Clusterer` entry point.
/// The modern surface is `model::GkMeans` (which builds the Alg. 3 graph
/// itself and then runs this engine, resident or out-of-core via
/// `fit`/`fit_store`); to run Alg. 2 on a *caller-supplied* graph as this
/// shim does, call [`run_core`] directly.
#[deprecated(
    note = "use `model::GkMeans::new(k).kappa(..).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data); for a caller-supplied graph use `run_core`"
)]
pub fn run(
    data: &VecSet,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    run_core(data, k, graph, params, backend)
}

/// The Alg. 2 engine with a 2M-tree initialization
/// ([`crate::model::GkMeans`] / [`crate::model::KGraphGkMeans`] execute
/// this on their respective graphs).  Runs over any [`VecStore`]; the
/// epoch scans read the store through per-worker cursors.
pub fn run_core(
    data: &dyn VecStore,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    run_core_hooked(data, k, graph, params, backend, &mut FitHooks::none())
}

/// [`run_core`] with fit instrumentation: a resume point skips the
/// 2M-tree initialization entirely (the mid-fit state comes from the
/// checkpoint), and on a fresh fit the initialization seconds are folded
/// into `hooks.seconds_offset` before the first epoch fires, so hook
/// consumers see the same wall-clock accounting the final model reports.
pub fn run_core_hooked(
    data: &dyn VecStore,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
    hooks: &mut FitHooks<'_>,
) -> KmeansOutput {
    if hooks.resume.is_some() {
        let placeholder = Clustering {
            labels: Vec::new(),
            composite: Vec::new(),
            counts: Vec::new(),
            k,
            dim: data.dim(),
        };
        return run_from_hooked(data, placeholder, graph, params, hooks);
    }
    let timer = Timer::start();
    let labels = two_means::run(
        data,
        k,
        &TwoMeansParams {
            seed: params.base.seed,
            threads: params.base.threads,
            scan_order: params.base.scan_order,
            ..Default::default()
        },
        backend,
    );
    let clustering = Clustering::from_labels(data, labels, k);
    let init_seconds = timer.elapsed_s();
    hooks.seconds_offset += init_seconds;
    hooks.init_seconds = init_seconds;
    let mut out = run_from_hooked(data, clustering, graph, params, hooks);
    out.init_seconds = init_seconds;
    out.total_seconds += init_seconds;
    for h in out.history.iter_mut() {
        h.seconds += init_seconds;
    }
    out
}

/// A move proposed by a parallel scan shard, pending serial re-validation.
struct Proposal {
    /// Sample index.
    i: u32,
    /// Destination cluster from the snapshot evaluation.
    v: u32,
    /// Cached ‖x_i‖² so the commit does not recompute it.
    xx: f64,
}

/// Per-worker scratch reused across batches and epochs: the shared
/// [`CandidateSet`] (epoch-stamped mark array, O(κ) dedup — see
/// [`crate::gkm`]), this core's proposal buffer, and the gathered
/// composite block the batched Δℐ evaluation runs on.
struct EpochScratch {
    cand: CandidateSet,
    proposals: Vec<Proposal>,
    /// Gathered composite block for [`EpochScratch::best_move`]: column 0
    /// is `D_u` (the leave term), then one column per entry of `cand.q`.
    block: Vec<f32>,
    /// `⟨D, x⟩` per gathered column, filled by one [`dot_batch`] call.
    dots: Vec<f32>,
}

impl EpochScratch {
    fn new(k: usize, kappa: usize) -> EpochScratch {
        EpochScratch {
            cand: CandidateSet::new(k, kappa),
            proposals: Vec::new(),
            block: Vec::new(),
            dots: Vec::new(),
        }
    }

    /// Evaluate the collected candidate set for sample `x` (current
    /// cluster `u`, ‖x‖² = `xx`) through the batched mini-GEMM path:
    /// gather `D_u` plus every candidate composite into one contiguous
    /// block, compute all the cross dots in a single [`dot_batch`] call,
    /// and fold Δℐ from the [`DeltaCache`]'s cached ‖D_r‖².  Returns the
    /// best destination and its Δℐ.
    ///
    /// Exact-arithmetic contract: `dot_batch` reproduces the scalar
    /// `dot` bit-for-bit per column, and the `*_from_dot` fold is the
    /// scalar Δℐ expression verbatim — so this evaluation selects the
    /// same move, with the same Δℐ bits, as the seed per-candidate loop
    /// (asserted by `batched_eval_bit_identical_to_seed_scalar_loop`).
    fn best_move(
        &mut self,
        c: &Clustering,
        cache: &DeltaCache,
        x: &[f32],
        xx: f64,
        u: usize,
    ) -> (usize, f64) {
        if self.cand.q.len() + 1 < crate::core_ops::dist::BATCH_TILE {
            // Too narrow to fill one tile: the kernel would degenerate to
            // per-column scalar dots on a gathered copy, so skip the
            // gather and take the scalar entry points straight from the
            // composites — the exact same dots, hence the same bits.
            let leave = cache.leave(c, x, xx, u);
            let mut best_v = u;
            let mut best_delta = 0f64;
            for &v in &self.cand.q {
                let v = v as usize;
                let delta = cache.gain(c, x, xx, v) + leave;
                if delta > best_delta {
                    best_delta = delta;
                    best_v = v;
                }
            }
            return (best_v, best_delta);
        }
        self.block.clear();
        self.block.extend_from_slice(c.composite_of(u));
        for &v in &self.cand.q {
            self.block.extend_from_slice(c.composite_of(v as usize));
        }
        self.dots.clear();
        self.dots.resize(self.cand.q.len() + 1, 0.0);
        dot_batch(x, &self.block, c.dim, &mut self.dots);
        let leave = cache.leave_from_dot(c, xx, u, self.dots[0] as f64);
        let mut best_v = u;
        let mut best_delta = 0f64;
        for (t, &v) in self.cand.q.iter().enumerate() {
            let delta = cache.gain_from_dot(c, xx, v as usize, self.dots[t + 1] as f64) + leave;
            if delta > best_delta {
                best_delta = delta;
                best_v = v as usize;
            }
        }
        (best_v, best_delta)
    }
}

/// Snapshot-evaluate one shard of the batch, pushing proposals into the
/// worker's scratch (no shared mutable state: `c`/`cache`/`graph` are
/// frozen for the whole scan phase).
fn scan_shard(
    data: &dyn VecStore,
    c: &Clustering,
    cache: &DeltaCache,
    graph: &KnnGraph,
    kappa: usize,
    samples: &[usize],
    scratch: &mut EpochScratch,
) {
    let mut cur = data.open();
    for &i in samples {
        let u = c.labels[i] as usize;
        scratch.cand.collect(&c.labels, graph.neighbors(i), kappa, None, Some(u as u32));
        if scratch.cand.q.is_empty() {
            continue;
        }
        let x = cur.row(i);
        let xx = norm2(x) as f64;
        let (best_v, best_delta) = scratch.best_move(c, cache, x, xx, u);
        if best_v != u && best_delta > 0.0 {
            scratch.proposals.push(Proposal { i: i as u32, v: best_v as u32, xx });
        }
    }
}

/// Run Alg. 2's optimization loop from an existing partition.
pub fn run_from(
    data: &dyn VecStore,
    c: Clustering,
    graph: &KnnGraph,
    params: &GkMeansParams,
) -> KmeansOutput {
    run_from_hooked(data, c, graph, params, &mut FitHooks::none())
}

/// [`run_from`] with fit instrumentation (per-epoch hook + resume).  With
/// [`FitHooks::none`] this IS the historical `run_from`: same RNG stream,
/// same visit order, same arithmetic — bit-identical output (the seed
/// replica test pins this).
pub fn run_from_hooked(
    data: &dyn VecStore,
    mut c: Clustering,
    graph: &KnnGraph,
    params: &GkMeansParams,
    hooks: &mut FitHooks<'_>,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();
    assert_eq!(graph.n(), n, "graph size != dataset size");
    let kappa = params.kappa.min(graph.kappa());
    let threads = pool::resolve_threads(params.base.threads).min(n.max(1));
    // the epoch visit order comes from the scan planner: a global
    // Fisher–Yates on resident data (bit-identical to the historical
    // loop) or chunk-aligned super-block shuffles on paged stores
    let plan = ScanPlan::new(data, params.base.scan_order);
    let mut cur = data.open();
    let total_norm: f64 = (0..n).map(|i| norm2(cur.row(i)) as f64).sum();
    let mut rng = Rng::new(params.base.seed ^ 0x6B6D_6561);
    let mut order: Vec<usize> = (0..n).collect();

    let (mut cache, mut history, start_iter, seconds_base) = match hooks.resume.take() {
        Some(r) => {
            // Restore the exact mid-fit state (labels, composites, counts
            // and cached norms are raw checkpointed bits — rebuilding any
            // of them would perturb the last ulp), then replay the epoch
            // shuffles so the visit-order permutation and the RNG stream
            // both match the uninterrupted run.
            c = Clustering {
                labels: r.labels,
                composite: r.composite.expect("GK-means checkpoint carries composite vectors"),
                counts: r.counts.expect("GK-means checkpoint carries cluster counts"),
                k: c.k,
                dim: c.dim,
            };
            let cache = DeltaCache {
                comp_norm2: r.comp_norm2.expect("GK-means checkpoint carries ‖D_r‖²"),
            };
            for _ in 1..r.next_iter {
                plan.shuffle_epoch(&mut order, &mut rng);
            }
            debug_assert_eq!(rng.state(), r.rng, "resume RNG replay diverged from the checkpoint");
            let base = r.history.last().map(|h| h.seconds).unwrap_or(0.0);
            (cache, r.history, r.next_iter, base)
        }
        None => {
            let cache = DeltaCache::new(&c);
            let history = vec![IterStat {
                iter: 0,
                seconds: timer.elapsed_s(),
                distortion: (total_norm - c.objective()) / n as f64,
                moves: 0,
            }];
            fire_epoch(hooks, &history, &rng, &c, &cache);
            (cache, history, 1, 0.0)
        }
    };

    if threads <= 1 {
        // --- serial path: bit-identical to the historical implementation ---
        let mut scratch = EpochScratch::new(c.k, kappa);
        for iter in start_iter..=params.base.max_iters {
            plan.shuffle_epoch(&mut order, &mut rng);
            let mut moves = 0usize;
            for &i in &order {
                let x = cur.row(i);
                let u = c.labels[i] as usize;
                // --- collect Q (lines 6–11), O(κ) dedup via CandidateSet ---
                scratch.cand.collect(&c.labels, graph.neighbors(i), kappa, None, Some(u as u32));
                if scratch.cand.q.is_empty() {
                    continue;
                }
                // --- seek v maximizing Δℐ (line 12): one batched kernel
                //     pass over the gathered candidate composites, bit-
                //     identical to the seed per-candidate loop ---
                let xx = norm2(x) as f64;
                let (best_v, best_delta) = scratch.best_move(&c, &cache, x, xx, u);
                // --- move when positive (lines 13–15) ---
                if best_v != u && best_delta > 0.0 {
                    cache.commit_move(&mut c, i, x, xx, u, best_v);
                    moves += 1;
                }
            }
            history.push(IterStat {
                iter,
                seconds: seconds_base + timer.elapsed_s(),
                distortion: (total_norm - c.objective()) / n as f64,
                moves,
            });
            fire_epoch(hooks, &history, &rng, &c, &cache);
            if (moves as f64) < params.base.min_move_rate * n as f64 {
                break;
            }
        }
    } else {
        // --- batch-synchronous parallel path (see module docs) ---
        let mut scratches: Vec<EpochScratch> =
            (0..threads).map(|_| EpochScratch::new(c.k, kappa)).collect();
        // Batch size trades commit-staleness against sync overhead: big
        // enough that spawn cost amortizes, small enough that the frozen
        // snapshot stays fresh within an epoch.
        let batch = (threads * 2048).max(4096);
        for iter in start_iter..=params.base.max_iters {
            plan.shuffle_epoch(&mut order, &mut rng);
            let mut moves = 0usize;
            let mut start = 0usize;
            while start < n {
                let end = (start + batch).min(n);
                let slice = &order[start..end];
                let shard = (slice.len() + threads - 1) / threads;
                // scan phase: frozen snapshot, per-worker proposal buffers
                std::thread::scope(|s| {
                    for (t, scratch) in scratches.iter_mut().enumerate() {
                        let lo = (t * shard).min(slice.len());
                        let hi = ((t + 1) * shard).min(slice.len());
                        let my = &slice[lo..hi];
                        let c_ref = &c;
                        let cache_ref = &cache;
                        s.spawn(move || {
                            scan_shard(data, c_ref, cache_ref, graph, kappa, my, scratch)
                        });
                    }
                });
                // commit phase: serial, in shard order, Δℐ re-validated
                // against the *current* state so distortion stays monotone.
                // The re-check is the scalar-verify side of the batched
                // scan: two plain dots through the scalar entry points,
                // deliberately not batched (one proposal at a time).
                for scratch in scratches.iter_mut() {
                    for p in scratch.proposals.drain(..) {
                        let i = p.i as usize;
                        let u = c.labels[i] as usize;
                        let v = p.v as usize;
                        if u == v {
                            continue;
                        }
                        let x = cur.row(i);
                        let delta = cache.gain(&c, x, p.xx, v) + cache.leave(&c, x, p.xx, u);
                        if delta > 0.0 {
                            cache.commit_move(&mut c, i, x, p.xx, u, v);
                            moves += 1;
                        }
                    }
                }
                start = end;
            }
            history.push(IterStat {
                iter,
                seconds: seconds_base + timer.elapsed_s(),
                distortion: (total_norm - c.objective()) / n as f64,
                moves,
            });
            fire_epoch(hooks, &history, &rng, &c, &cache);
            if (moves as f64) < params.base.min_move_rate * n as f64 {
                break;
            }
        }
    }

    KmeansOutput {
        clustering: c,
        history,
        total_seconds: seconds_base + timer.elapsed_s(),
        init_seconds: 0.0,
    }
}

/// Produce a partition into clusters-as-member-lists (the `S` view used by
/// Alg. 3's refinement scan).
pub fn members_of(c: &Clustering) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); c.k];
    for (i, &l) in c.labels.iter().enumerate() {
        out[l as usize].push(i as u32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::brute;

    fn setup(n: usize, k: usize) -> (VecSet, KnnGraph) {
        let data = blobs(&BlobSpec::quick(n, 8, k), 1);
        let graph = brute::build(&data, 10, &Backend::native());
        (data, graph)
    }

    #[test]
    fn distortion_monotone_and_valid() {
        let (data, graph) = setup(500, 10);
        let out = run_core(&data, 10, &graph, &GkMeansParams { kappa: 10, ..Default::default() }, &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
        for w in out.history.windows(2) {
            assert!(w[1].distortion <= w[0].distortion + 1e-9);
        }
    }

    #[test]
    fn close_to_bkm_quality_on_blobs() {
        // Paper Fig. 5: GK-means ≈ BKM quality. With an exact graph the
        // candidate pruning should barely hurt.
        let (data, graph) = setup(600, 12);
        let p = KmeansParams::default();
        let gk = run_core(&data, 12, &graph, &GkMeansParams { kappa: 10, base: p.clone() }, &Backend::native());
        let bkm = crate::kmeans::boost::run_core(&data, 12, &p, &Backend::native());
        assert!(
            gk.distortion() <= bkm.distortion() * 1.15 + 1e-9,
            "gk={} bkm={}",
            gk.distortion(),
            bkm.distortion()
        );
    }

    #[test]
    fn candidate_pruning_visits_fewer_clusters() {
        // indirect check: with kappa=1 the candidate set per sample is ≤1,
        // so the run must still terminate and produce a valid clustering.
        let (data, graph) = setup(300, 8);
        let out = run_core(&data, 8, &graph, &GkMeansParams { kappa: 1, ..Default::default() }, &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
    }

    #[test]
    fn members_of_roundtrip() {
        let (data, graph) = setup(200, 5);
        let out = run_core(&data, 5, &graph, &GkMeansParams { kappa: 5, ..Default::default() }, &Backend::native());
        let members = members_of(&out.clustering);
        assert_eq!(members.iter().map(|m| m.len()).sum::<usize>(), 200);
        for (cid, m) in members.iter().enumerate() {
            for &i in m {
                assert_eq!(out.clustering.labels[i as usize] as usize, cid);
            }
        }
    }

    #[test]
    fn empty_graph_degenerates_gracefully() {
        let data = blobs(&BlobSpec::quick(100, 4, 4), 2);
        let graph = KnnGraph::empty(100, 5);
        // all slots vacant -> no candidates -> no moves; init partition kept
        let out = run_core(&data, 4, &graph, &GkMeansParams::default(), &Backend::native());
        assert_eq!(out.history.last().unwrap().moves, 0);
    }

    #[test]
    fn parallel_epoch_monotone_and_close_to_serial() {
        let (data, graph) = setup(800, 12);
        let serial = run_core(
            &data,
            12,
            &graph,
            &GkMeansParams { kappa: 10, ..Default::default() },
            &Backend::native(),
        );
        let par_params = GkMeansParams {
            kappa: 10,
            base: KmeansParams { threads: 4, ..Default::default() },
        };
        let par = run_core(&data, 12, &graph, &par_params, &Backend::native());
        par.clustering.check_invariants(&data).unwrap();
        for w in par.history.windows(2) {
            assert!(
                w[1].distortion <= w[0].distortion + 1e-9,
                "parallel epoch raised distortion: {} -> {}",
                w[0].distortion,
                w[1].distortion
            );
        }
        // different 2M-tree split trees → different local optima; the
        // band only guards against gross quality regressions
        let (ds, dp) = (serial.distortion(), par.distortion());
        assert!(
            (dp - ds).abs() <= 0.25 * ds.max(1e-12) + 1e-9,
            "parallel distortion {dp} too far from serial {ds}"
        );
    }

    #[test]
    fn batched_eval_bit_identical_to_seed_scalar_loop() {
        // The exact-arithmetic contract of the batched candidate
        // evaluation: `run_from` at threads = 1 must reproduce the seed
        // per-candidate scalar loop — replicated verbatim below through
        // the scalar DeltaCache entry points — label for label, move
        // count for move count, composite bit for composite bit.
        let (data, graph) = setup(600, 12);
        let params = GkMeansParams {
            kappa: 10,
            base: KmeansParams { max_iters: 8, ..Default::default() },
        };
        let init = two_means::cluster(
            &data,
            12,
            &TwoMeansParams { seed: params.base.seed, ..Default::default() },
            &Backend::native(),
        );
        let batched = run_from(&data, init.clone(), &graph, &params);

        // --- the seed scalar epoch loop, replicated verbatim ---
        let mut c = init;
        let n = data.rows();
        let kappa = params.kappa.min(graph.kappa());
        let plan = ScanPlan::new(&data, params.base.scan_order);
        let mut cur = crate::data::store::VecStore::open(&data);
        let mut rng = Rng::new(params.base.seed ^ 0x6B6D_6561);
        let mut cache = DeltaCache::new(&c);
        let mut order: Vec<usize> = (0..n).collect();
        let mut cand = CandidateSet::new(c.k, kappa);
        let mut moves_per_epoch = Vec::new();
        for _ in 1..=params.base.max_iters {
            plan.shuffle_epoch(&mut order, &mut rng);
            let mut moves = 0usize;
            for &i in &order {
                let x = cur.row(i);
                let u = c.labels[i] as usize;
                cand.collect(&c.labels, graph.neighbors(i), kappa, None, Some(u as u32));
                if cand.q.is_empty() {
                    continue;
                }
                let xx = norm2(x) as f64;
                let leave = cache.leave(&c, x, xx, u);
                let mut best_v = u;
                let mut best_delta = 0f64;
                for &v in &cand.q {
                    let v = v as usize;
                    let delta = cache.gain(&c, x, xx, v) + leave;
                    if delta > best_delta {
                        best_delta = delta;
                        best_v = v;
                    }
                }
                if best_v != u && best_delta > 0.0 {
                    cache.commit_move(&mut c, i, x, xx, u, best_v);
                    moves += 1;
                }
            }
            moves_per_epoch.push(moves);
            if (moves as f64) < params.base.min_move_rate * n as f64 {
                break;
            }
        }

        assert_eq!(batched.clustering.labels, c.labels, "labels diverged from the seed path");
        let batched_moves: Vec<usize> = batched.history.iter().skip(1).map(|h| h.moves).collect();
        assert_eq!(batched_moves, moves_per_epoch, "per-epoch move counts diverged");
        for (a, b) in batched.clustering.composite.iter().zip(&c.composite) {
            assert_eq!(a.to_bits(), b.to_bits(), "composite accumulators diverged");
        }
    }

    #[test]
    fn threads_one_is_deterministic() {
        let (data, graph) = setup(400, 8);
        let p = GkMeansParams { kappa: 8, ..Default::default() };
        let a = run_core(&data, 8, &graph, &p, &Backend::native());
        let b = run_core(&data, 8, &graph, &p, &Backend::native());
        assert_eq!(a.clustering.labels, b.clustering.labels);
        assert_eq!(a.history.len(), b.history.len());
        for (ha, hb) in a.history.iter().zip(&b.history) {
            assert_eq!(ha.moves, hb.moves);
            assert_eq!(ha.distortion.to_bits(), hb.distortion.to_bits());
        }
    }
}
