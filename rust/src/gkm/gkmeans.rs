//! Alg. 2 — GK-means: graph-driven boost k-means.
//!
//! For each sample `x_i` (random visit order), collect the candidate set
//! `Q = { cLabel[b] : b ∈ G[i] }` — the clusters its κ graph-neighbors
//! currently reside in — and move `x_i` to the `v ∈ Q` maximizing Δℐ
//! (Eqn. 3) when the best Δℐ is positive.  Because `|Q| ≤ κ ≪ k` (and in
//! practice ≪ κ after dedup), the per-epoch cost is `O(n·d·κ̃)` —
//! independent of `k`, which is the paper's whole point.
//!
//! Initialization is Alg. 1 (2M-tree), exactly as the paper specifies.

use crate::core_ops::dist::norm2;
use crate::data::matrix::VecSet;
use crate::graph::knn::KnnGraph;
use crate::kmeans::boost::DeltaCache;
use crate::kmeans::common::{Clustering, IterStat, KmeansOutput, KmeansParams};
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// GK-means parameters.  Defaults follow §4.4: κ = 50.
#[derive(Debug, Clone)]
pub struct GkMeansParams {
    /// Number of graph neighbors consulted per sample (κ).
    pub kappa: usize,
    pub base: KmeansParams,
}

impl Default for GkMeansParams {
    fn default() -> Self {
        GkMeansParams { kappa: 50, base: KmeansParams::default() }
    }
}

/// Run Alg. 2 with a 2M-tree initialization.
pub fn run(
    data: &VecSet,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    let timer = Timer::start();
    let labels = two_means::run(
        data,
        k,
        &TwoMeansParams { seed: params.base.seed, ..Default::default() },
        backend,
    );
    let clustering = Clustering::from_labels(data, labels, k);
    let init_seconds = timer.elapsed_s();
    let mut out = run_from(data, clustering, graph, params);
    out.init_seconds = init_seconds;
    out.total_seconds += init_seconds;
    for h in out.history.iter_mut() {
        h.seconds += init_seconds;
    }
    out
}

/// Run Alg. 2's optimization loop from an existing partition.
pub fn run_from(
    data: &VecSet,
    mut c: Clustering,
    graph: &KnnGraph,
    params: &GkMeansParams,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();
    assert_eq!(graph.n(), n, "graph size != dataset size");
    let kappa = params.kappa.min(graph.kappa());
    let total_norm: f64 = (0..n).map(|i| norm2(data.row(i)) as f64).sum();
    let mut rng = Rng::new(params.base.seed ^ 0x6B6D_6561);
    let mut cache = DeltaCache::new(&c);
    let mut order: Vec<usize> = (0..n).collect();
    // candidate scratch (Q in Alg. 2), reused across samples
    let mut q: Vec<u32> = Vec::with_capacity(kappa + 1);

    let mut history = vec![IterStat {
        iter: 0,
        seconds: timer.elapsed_s(),
        distortion: (total_norm - c.objective()) / n as f64,
        moves: 0,
    }];

    for iter in 1..=params.base.max_iters {
        rng.shuffle(&mut order);
        let mut moves = 0usize;
        for &i in &order {
            let x = data.row(i);
            let u = c.labels[i] as usize;
            // --- collect Q (lines 6–11) ---
            q.clear();
            for &b in graph.neighbors(i).iter().take(kappa) {
                if b != u32::MAX {
                    let lbl = c.labels[b as usize];
                    if lbl as usize != u && !q.contains(&lbl) {
                        q.push(lbl);
                    }
                }
            }
            if q.is_empty() {
                continue;
            }
            // --- seek v maximizing Δℐ (line 12) ---
            let xx = norm2(x) as f64;
            let leave = cache.leave(&c, x, xx, u);
            let mut best_v = u;
            let mut best_delta = 0f64;
            for &v in &q {
                let v = v as usize;
                let delta = cache.gain(&c, x, xx, v) + leave;
                if delta > best_delta {
                    best_delta = delta;
                    best_v = v;
                }
            }
            // --- move when positive (lines 13–15) ---
            if best_v != u && best_delta > 0.0 {
                cache.on_move(&c, x, xx, u, best_v);
                c.apply_move(i, x, u, best_v);
                moves += 1;
            }
        }
        history.push(IterStat {
            iter,
            seconds: timer.elapsed_s(),
            distortion: (total_norm - c.objective()) / n as f64,
            moves,
        });
        if (moves as f64) < params.base.min_move_rate * n as f64 {
            break;
        }
    }

    KmeansOutput { clustering: c, history, total_seconds: timer.elapsed_s(), init_seconds: 0.0 }
}

/// Produce a partition into clusters-as-member-lists (the `S` view used by
/// Alg. 3's refinement scan).
pub fn members_of(c: &Clustering) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); c.k];
    for (i, &l) in c.labels.iter().enumerate() {
        out[l as usize].push(i as u32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::brute;

    fn setup(n: usize, k: usize) -> (VecSet, KnnGraph) {
        let data = blobs(&BlobSpec::quick(n, 8, k), 1);
        let graph = brute::build(&data, 10, &Backend::native());
        (data, graph)
    }

    #[test]
    fn distortion_monotone_and_valid() {
        let (data, graph) = setup(500, 10);
        let out = run(&data, 10, &graph, &GkMeansParams { kappa: 10, ..Default::default() }, &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
        for w in out.history.windows(2) {
            assert!(w[1].distortion <= w[0].distortion + 1e-9);
        }
    }

    #[test]
    fn close_to_bkm_quality_on_blobs() {
        // Paper Fig. 5: GK-means ≈ BKM quality. With an exact graph the
        // candidate pruning should barely hurt.
        let (data, graph) = setup(600, 12);
        let p = KmeansParams::default();
        let gk = run(&data, 12, &graph, &GkMeansParams { kappa: 10, base: p.clone() }, &Backend::native());
        let bkm = crate::kmeans::boost::run(&data, 12, &p, &Backend::native());
        assert!(
            gk.distortion() <= bkm.distortion() * 1.15 + 1e-9,
            "gk={} bkm={}",
            gk.distortion(),
            bkm.distortion()
        );
    }

    #[test]
    fn candidate_pruning_visits_fewer_clusters() {
        // indirect check: with kappa=1 the candidate set per sample is ≤1,
        // so the run must still terminate and produce a valid clustering.
        let (data, graph) = setup(300, 8);
        let out = run(&data, 8, &graph, &GkMeansParams { kappa: 1, ..Default::default() }, &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
    }

    #[test]
    fn members_of_roundtrip() {
        let (data, graph) = setup(200, 5);
        let out = run(&data, 5, &graph, &GkMeansParams { kappa: 5, ..Default::default() }, &Backend::native());
        let members = members_of(&out.clustering);
        assert_eq!(members.iter().map(|m| m.len()).sum::<usize>(), 200);
        for (cid, m) in members.iter().enumerate() {
            for &i in m {
                assert_eq!(out.clustering.labels[i as usize] as usize, cid);
            }
        }
    }

    #[test]
    fn empty_graph_degenerates_gracefully() {
        let data = blobs(&BlobSpec::quick(100, 4, 4), 2);
        let graph = KnnGraph::empty(100, 5);
        // all slots vacant -> no candidates -> no moves; init partition kept
        let out = run(&data, 4, &graph, &GkMeansParams::default(), &Backend::native());
        assert_eq!(out.history.last().unwrap().moves, 0);
    }
}
