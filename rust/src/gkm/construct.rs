//! Alg. 3 — KNN-graph construction by intertwined fast k-means.
//!
//! Round `t`: (1) call GK-means (one optimization epoch, 2M-tree init) to
//! partition the data into `k₀ = ⌊n/ξ⌋` fixed-size cells, driven by the
//! *current* graph `Gᵗ`; (2) exhaustively compare all pairs inside each
//! cell and fold the results into the graph.  The partition quality and
//! the graph quality co-evolve: random graph → rough cells → better graph
//! → better cells → … (paper Fig. 2/3).  τ = 10 suffices for clustering;
//! up to 32 for ANNS-grade graphs (§4.4).

use crate::core_ops::dist;
use crate::data::store::{StoreCursor, VecStore};
use crate::gkm::gkmeans::{self, GkMeansParams};
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::{Clustering, KmeansParams};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Alg. 3 parameters; defaults are the paper's §4.4 choices.
#[derive(Debug, Clone)]
pub struct ConstructParams {
    /// Graph scale κ (neighbors kept per node).
    pub kappa: usize,
    /// Cell size ξ (recommended range [40, 100]).
    pub xi: usize,
    /// Rounds τ (10 for clustering; up to 32 for ANNS).
    pub tau: usize,
    pub seed: u64,
    /// Worker threads, threaded through to the in-round GK-means epochs,
    /// the 2M-tree init and the in-cell refinement scan (`1` = serial,
    /// bit-identical to the historical build; `0` = auto).
    pub threads: usize,
    /// Visit-order policy for the in-round GK-means epoch scans and the
    /// 2M-tree subset reads (see [`crate::data::plan`]).  The in-cell
    /// refinement needs no planning: `members_of` emits every cell in
    /// ascending row order, which is already the chunk-grouped order.
    pub scan_order: crate::data::plan::ScanOrder,
}

impl Default for ConstructParams {
    fn default() -> Self {
        ConstructParams {
            kappa: 50,
            xi: 50,
            tau: 10,
            seed: 20170707,
            threads: 1,
            scan_order: crate::data::plan::ScanOrder::Auto,
        }
    }
}

/// Per-round progress of the intertwined evolution (Fig. 2's series).
#[derive(Debug, Clone)]
pub struct RoundStat {
    pub round: usize,
    /// Cumulative seconds.
    pub seconds: f64,
    /// Distortion of the round's cell partition.
    pub distortion: f64,
    /// Graph updates applied this round (a convergence proxy).
    pub updates: usize,
}

/// Output of Alg. 3.
#[derive(Debug)]
pub struct GraphBuildOutput {
    pub graph: KnnGraph,
    pub history: Vec<RoundStat>,
    pub total_seconds: f64,
    /// The final round's cell partition (kept because Tab. 2 reuses the
    /// clustering structure embedded in the graph).
    pub last_partition: Option<Clustering>,
}

/// Build the approximate KNN graph (Alg. 3) over any [`VecStore`].
pub fn build(data: &dyn VecStore, params: &ConstructParams, backend: &Backend) -> GraphBuildOutput {
    let timer = Timer::start();
    let n = data.rows();
    assert!(n >= 2, "need at least two samples");
    let xi = params.xi.max(2).min(n);
    let k0 = (n / xi).max(1);
    let mut rng = Rng::new(params.seed);
    let mut graph = KnnGraph::random(n, params.kappa, &mut rng);
    let mut history = Vec::with_capacity(params.tau);
    let mut last_partition = None;

    for t in 0..params.tau {
        // --- step 1: fast k-means into k0 cells, driven by G^t ---
        // t is fixed to 1 epoch inside the construction (paper §4.5)
        let gk_params = GkMeansParams {
            kappa: params.kappa,
            base: KmeansParams {
                max_iters: 1,
                min_move_rate: 0.0,
                seed: params.seed ^ (t as u64).wrapping_mul(0x9E37_79B9),
                threads: params.threads,
                scan_order: params.scan_order,
            },
        };
        let out = gkmeans::run_core(data, k0, &graph, &gk_params, backend);
        let members = gkmeans::members_of(&out.clustering);

        // --- step 2: exhaustive in-cell refinement (lines 8–14) ---
        let updates = refine_cells_threaded(data, &members, &mut graph, backend, params.threads);

        history.push(RoundStat {
            round: t,
            seconds: timer.elapsed_s(),
            distortion: out.distortion(),
            updates,
        });
        crate::log_debug!(
            "alg3 round {t}: distortion={:.4} updates={updates}",
            out.distortion()
        );
        last_partition = Some(out.clustering);
    }

    GraphBuildOutput { graph, history, total_seconds: timer.elapsed_s(), last_partition }
}

/// How a refinement scan consumes surviving candidate pairs.  The serial
/// scan folds straight into the live graph (so bounds tighten mid-cell);
/// threaded workers prune against a threshold *snapshot* and record the
/// pair for the ordered serial merge.  Emitted distances are always
/// complete sums — the early-exit path only truncates values that then
/// fail the bound filter — so both sinks observe identical distances and
/// the merge reproduces the serial fold exactly (see
/// [`refine_cells_threaded`]).
trait PairSink {
    /// Pruning bound for a pair: the looser of the two rows' current
    /// κ-th-neighbor distances (`∞` while either row has free slots).
    fn bound(&self, ia: usize, ib: usize) -> f32;
    /// A pair whose full distance beat [`PairSink::bound`] at scan time.
    fn emit(&mut self, a: u32, b: u32, dd: f32);
}

/// Serial sink: fold into the live graph, counting applied updates.
struct FoldSink<'a> {
    graph: &'a mut KnnGraph,
    updates: &'a mut usize,
}

impl PairSink for FoldSink<'_> {
    fn bound(&self, ia: usize, ib: usize) -> f32 {
        self.graph.threshold(ia).max(self.graph.threshold(ib))
    }
    fn emit(&mut self, a: u32, b: u32, dd: f32) {
        if self.graph.update_pair(a as usize, b as usize, dd) {
            *self.updates += 1;
        }
    }
}

/// Worker sink: prune against a snapshot, gather for the ordered merge.
struct GatherSink<'a> {
    graph: &'a KnnGraph,
    out: &'a mut Vec<(u32, u32, f32)>,
}

impl PairSink for GatherSink<'_> {
    fn bound(&self, ia: usize, ib: usize) -> f32 {
        self.graph.threshold(ia).max(self.graph.threshold(ib))
    }
    fn emit(&mut self, a: u32, b: u32, dd: f32) {
        self.out.push((a, b, dd));
    }
}

/// Oversized-cell pair scan (cells past the dense m×m cutoff, where an
/// m×m distance buffer would be quadratic).  The cell's rows gather once
/// into a contiguous block; each anchor row then evaluates its tail
/// `[a+1, m)` through the batched bit-exact kernel
/// ([`dist::d2_batch_exact`] — one load of the anchor serves four
/// candidates, and the `simd` feature tier widens that further), with
/// the per-pair bound filter applied to the results.  Tails too narrow
/// to fill a tile — and every scan below [`dist::BATCH_MIN_DIM`] — keep
/// the historical early-exit partial-distance path
/// ([`dist::d2_bounded`]), where the bound check every 16 components
/// beats batching.
fn scan_oversized_cell(
    cell: &[u32],
    d: usize,
    cur: &mut StoreCursor<'_>,
    gathered: &mut Vec<f32>,
    d2s: &mut Vec<f32>,
    sink: &mut impl PairSink,
) {
    let m = cell.len();
    gathered.clear();
    gathered.reserve(m * d);
    for &i in cell {
        gathered.extend_from_slice(cur.row(i as usize));
    }
    for a in 0..m - 1 {
        let ia = cell[a] as usize;
        let w = m - a - 1;
        let (xa, tail) = gathered[a * d..m * d].split_at(d);
        if dist::batch_eligible(d, w) {
            d2s.resize(w, 0.0);
            dist::d2_batch_exact(xa, tail, d, d2s);
            for (t, &dd) in d2s.iter().enumerate() {
                let ib = cell[a + 1 + t] as usize;
                if dd < sink.bound(ia, ib) {
                    sink.emit(cell[a], cell[a + 1 + t], dd);
                }
            }
        } else {
            for (t, yb) in tail.chunks_exact(d).enumerate() {
                let ib = cell[a + 1 + t] as usize;
                let bound = sink.bound(ia, ib);
                let dd = dist::d2_bounded(xa, yb, bound);
                if dd < bound {
                    sink.emit(cell[a], cell[a + 1 + t], dd);
                }
            }
        }
    }
}

/// Exhaustive pairwise comparison inside each cell, folding every pair
/// into the graph.  Cells up to the small-block size go through the
/// backend's pairwise kernel; larger ones are chunked.
pub fn refine_cells(
    data: &dyn VecStore,
    members: &[Vec<u32>],
    graph: &mut KnnGraph,
    backend: &Backend,
) -> usize {
    // §Perf: three strategies measured — (a) dense m×m block via
    // backend.pairwise_among + upper-triangle fold, (b) scalar pairs with
    // early-exit bounded distances, (c) gathered anchor tails through the
    // batched bit-exact kernel, bound filter applied afterwards.
    // (b)-everywhere measured ~8% SLOWER end-to-end at n=5000/d=128: the
    // every-16-components bound check breaks vectorization and the prune
    // rate doesn't recover it at these dims.  Dense blocks stay the
    // ξ-cell path; oversized cells (the equal-size init can't always hit
    // ξ exactly) run (c), falling back to (b) for tile-starved tails and
    // tiny dims — see [`scan_oversized_cell`].
    let mut updates = 0usize;
    let mut buf = Vec::new();
    let mut gathered = Vec::new();
    let mut d2s = Vec::new();
    let mut cur = data.open();
    let d = data.dim();
    for cell in members {
        let m = cell.len();
        if m < 2 {
            continue;
        }
        if m <= 64 {
            buf.resize(m * m, 0.0);
            backend.pairwise_among(data, cell, &mut buf);
            for a in 0..m {
                for b in (a + 1)..m {
                    if graph.update_pair(cell[a] as usize, cell[b] as usize, buf[a * m + b]) {
                        updates += 1;
                    }
                }
            }
        } else {
            let mut sink = FoldSink { graph: &mut *graph, updates: &mut updates };
            scan_oversized_cell(cell, d, &mut cur, &mut gathered, &mut d2s, &mut sink);
        }
    }
    updates
}

/// One localized NN-Descent join round around row `g`: compare `g`
/// against its neighbors' neighbors (the classic NN-Descent local join
/// restricted to a single row's neighborhood) and fold improvements
/// into the graph with [`KnnGraph::update_pair`] — both directions, so
/// old rows adopt the new one too.  `seen` is caller-owned scratch
/// (cleared here) that bounds the round to ≤ κ² distance evaluations.
/// Serial and deterministic: candidates are visited in neighbor-list
/// order.  Returns the number of accepted updates; `0` means the
/// neighborhood is locally converged and the caller can stop iterating.
///
/// This is the repair primitive behind
/// [`crate::model::FittedModel::extend`]: a freshly appended row gets
/// its candidate pool from a seeded graph search, then a few of these
/// rounds stitch it into the mutual-neighbor structure.
pub fn local_join(
    graph: &mut KnnGraph,
    cur: &mut StoreCursor<'_>,
    g: usize,
    seen: &mut std::collections::HashSet<u32>,
) -> usize {
    let mut updates = 0usize;
    seen.clear();
    seen.insert(g as u32);
    seen.extend(graph.neighbors(g).iter().copied().filter(|&u| u != u32::MAX));
    let hood: Vec<u32> =
        graph.neighbors(g).iter().copied().filter(|&u| u != u32::MAX).collect();
    for u in hood {
        let second: Vec<u32> = graph
            .neighbors(u as usize)
            .iter()
            .copied()
            .filter(|&w| w != u32::MAX && !seen.contains(&w))
            .collect();
        for w in second {
            seen.insert(w);
            let dd = cur.d2_pair(g, w as usize);
            if (dd < graph.threshold(g) || dd < graph.threshold(w as usize))
                && graph.update_pair(g, w as usize, dd)
            {
                updates += 1;
            }
        }
    }
    updates
}

/// Multi-threaded [`refine_cells`]: cells partition the samples, so the
/// graph rows touched by different cells are disjoint — but `KnnGraph` is
/// deliberately lock-free, so workers gather candidate pairs against a
/// threshold *snapshot* and a serial fold applies them in cell order.
/// Thresholds only tighten, so the gathered set is a superset of what the
/// fresh-threshold serial scan keeps, and `update_pair` re-checks every
/// candidate against the live lists: the resulting graph (and update
/// count) is identical to the serial scan's.  (That holds on both
/// backends: the serial dense path, `Backend::pairwise_among`, is
/// unconditionally native — see its §Perf note — exactly the kernel the
/// workers run.)
pub fn refine_cells_threaded(
    data: &dyn VecStore,
    members: &[Vec<u32>],
    graph: &mut KnnGraph,
    backend: &Backend,
    threads: usize,
) -> usize {
    let threads = crate::util::pool::resolve_threads(threads).min(members.len().max(1));
    if threads <= 1 {
        return refine_cells(data, members, graph, backend);
    }
    let d = data.dim();
    let graph_ref: &KnnGraph = graph;
    let parts = crate::util::pool::par_map_chunks(threads, members.len(), |_, range| {
        let mut out: Vec<(u32, u32, f32)> = Vec::new();
        let mut buf = Vec::new();
        let mut gathered = Vec::new();
        let mut d2s = Vec::new();
        let mut cur = data.open();
        for cell in &members[range] {
            let m = cell.len();
            if m < 2 {
                continue;
            }
            if m <= 64 {
                // dense m×m block via the native kernel (workers never
                // share a PJRT engine; see runtime::backend docs)
                gathered.clear();
                for &i in cell.iter() {
                    gathered.extend_from_slice(cur.row(i as usize));
                }
                buf.resize(m * m, 0.0);
                crate::core_ops::blockdist::block_l2(&gathered, &gathered, d, &mut buf);
                for a in 0..m {
                    for b in (a + 1)..m {
                        out.push((cell[a], cell[b], buf[a * m + b]));
                    }
                }
            } else {
                // batched tails pruned against the threshold snapshot
                let mut sink = GatherSink { graph: graph_ref, out: &mut out };
                scan_oversized_cell(cell, d, &mut cur, &mut gathered, &mut d2s, &mut sink);
            }
        }
        out
    });
    let mut updates = 0usize;
    for part in parts {
        for (a, b, dd) in part {
            if graph.update_pair(a as usize, b as usize, dd) {
                updates += 1;
            }
        }
    }
    updates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::{brute, recall};

    #[test]
    fn recall_improves_over_rounds() {
        let data = blobs(&BlobSpec::quick(600, 8, 12), 1);
        let exact = brute::build(&data, 5, &Backend::native());
        let b = Backend::native();
        let r1 = {
            let out = build(&data, &ConstructParams { kappa: 5, xi: 25, tau: 1, ..Default::default() }, &b);
            recall::recall_at_1(&out.graph, &exact)
        };
        let r5 = {
            let out = build(&data, &ConstructParams { kappa: 5, xi: 25, tau: 5, ..Default::default() }, &b);
            recall::recall_at_1(&out.graph, &exact)
        };
        assert!(r5 > r1 * 0.95, "recall did not improve: τ=1 {r1} vs τ=5 {r5}");
        assert!(r5 > 0.5, "5 rounds should reach decent recall, got {r5}");
    }

    #[test]
    fn distortion_decreases_over_rounds() {
        let data = blobs(&BlobSpec::quick(500, 6, 8), 2);
        let out = build(&data, &ConstructParams { kappa: 8, xi: 25, tau: 6, ..Default::default() }, &Backend::native());
        let first = out.history.first().unwrap().distortion;
        let last = out.history.last().unwrap().distortion;
        assert!(last < first, "cell distortion should fall: {first} -> {last}");
        out.graph.check_invariants().unwrap();
    }

    #[test]
    fn refine_handles_oversized_and_tiny_cells() {
        let data = blobs(&BlobSpec::quick(200, 4, 4), 3);
        let mut graph = KnnGraph::empty(200, 4);
        let members = vec![
            (0..100u32).collect::<Vec<_>>(),   // oversized (>64)
            vec![100],                          // singleton
            (101..200u32).collect::<Vec<_>>(), // oversized
        ];
        let updates = refine_cells(&data, &members, &mut graph, &Backend::native());
        assert!(updates > 0);
        graph.check_invariants().unwrap();
    }

    #[test]
    fn threaded_refine_matches_serial_exactly() {
        // gather-then-merge must reproduce the serial scan bit-for-bit:
        // supersets of stale-threshold candidates are filtered by
        // update_pair, and the merge preserves cell order.
        let data = blobs(&BlobSpec::quick(400, 6, 8), 9);
        let labels = crate::kmeans::two_means::run(
            &data,
            10,
            &crate::kmeans::two_means::TwoMeansParams::default(),
            &Backend::native(),
        );
        let members = gkmeans::members_of(&Clustering::from_labels(&data, labels, 10));
        let mut rng = Rng::new(4);
        let base = KnnGraph::random(400, 6, &mut rng);
        let mut serial = base.clone();
        let su = refine_cells(&data, &members, &mut serial, &Backend::native());
        for threads in [2usize, 4] {
            let mut par = base.clone();
            let pu = refine_cells_threaded(&data, &members, &mut par, &Backend::native(), threads);
            assert_eq!(su, pu, "update counts diverged at threads={threads}");
            for i in 0..400 {
                assert_eq!(serial.neighbors(i), par.neighbors(i), "row {i}");
                assert_eq!(serial.distances(i), par.distances(i), "row {i}");
            }
        }
    }

    #[test]
    fn oversized_batched_tails_match_serial_exactly() {
        // d ≥ BATCH_MIN_DIM with cells past the dense cutoff drives the
        // batched-tail branch of scan_oversized_cell (the existing
        // threaded_refine test stays on the d2_bounded fallback at d=6);
        // serial and snapshot-bound threaded scans must still agree to
        // the bit, and the kept distances must be the exact d2.
        let data = blobs(&BlobSpec::quick(300, 24, 4), 13);
        let members = vec![
            (0..150u32).collect::<Vec<_>>(),
            (150..280u32).collect::<Vec<_>>(),
            (280..300u32).collect::<Vec<_>>(), // small cell: dense path
        ];
        let mut rng = Rng::new(6);
        let base = KnnGraph::random(300, 5, &mut rng);
        let mut serial = base.clone();
        let su = refine_cells(&data, &members, &mut serial, &Backend::native());
        assert!(su > 0);
        serial.check_invariants().unwrap();
        for threads in [2usize, 3] {
            let mut par = base.clone();
            let pu = refine_cells_threaded(&data, &members, &mut par, &Backend::native(), threads);
            assert_eq!(su, pu, "update counts diverged at threads={threads}");
            for i in 0..300 {
                assert_eq!(serial.neighbors(i), par.neighbors(i), "row {i}");
                assert_eq!(serial.distances(i), par.distances(i), "row {i}");
            }
        }
        for i in (0..280).step_by(17) {
            for (t, &j) in serial.neighbors(i).iter().enumerate() {
                if j == u32::MAX {
                    continue;
                }
                let want = crate::core_ops::dist::d2(data.row(i), data.row(j as usize));
                let got = serial.distances(i)[t];
                assert!((got - want).abs() <= 1e-3 * (1.0 + want), "({i},{j})");
            }
        }
    }

    #[test]
    fn parallel_construct_build_is_valid() {
        let data = blobs(&BlobSpec::quick(500, 6, 8), 11);
        let out = build(
            &data,
            &ConstructParams { kappa: 8, xi: 25, tau: 4, threads: 4, ..Default::default() },
            &Backend::native(),
        );
        out.graph.check_invariants().unwrap();
        let exact = brute::build(&data, 1, &Backend::native());
        let r = recall::recall_at_1(&out.graph, &exact);
        assert!(r > 0.4, "parallel alg3 recall@1 = {r}");
    }

    #[test]
    fn graph_distances_are_exact() {
        let data = blobs(&BlobSpec::quick(300, 4, 6), 4);
        let out = build(&data, &ConstructParams { kappa: 4, xi: 30, tau: 3, ..Default::default() }, &Backend::native());
        for i in (0..300).step_by(41) {
            for (t, &j) in out.graph.neighbors(i).iter().enumerate() {
                if j == u32::MAX {
                    continue;
                }
                let want = crate::core_ops::dist::d2(data.row(i), data.row(j as usize));
                let got = out.graph.distances(i)[t];
                assert!((got - want).abs() < 1e-3 * (1.0 + want), "({i},{j})");
            }
        }
    }

    #[test]
    fn tiny_dataset_edge_cases() {
        let data = blobs(&BlobSpec::quick(10, 3, 2), 5);
        let out = build(&data, &ConstructParams { kappa: 3, xi: 50, tau: 2, ..Default::default() }, &Backend::native());
        out.graph.check_invariants().unwrap();
        // xi > n -> k0 = 1 single cell; graph becomes exact
        let exact = brute::build(&data, 3, &Backend::native());
        assert!(recall::recall_at_1(&out.graph, &exact) > 0.99);
    }
}
