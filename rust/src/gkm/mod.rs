//! GK-means: the paper's contribution.
//!
//! * [`gkmeans`] — Alg. 2: boost k-means where each sample is only
//!   compared against the clusters its κ graph-neighbors reside in.
//! * [`variant`] — the Alg. 2 variant built on traditional k-means
//!   ("GK-means\*" in Fig. 4): seek the closest *centroid* among the
//!   candidate clusters instead of maximizing Δℐ.
//! * [`construct`] — Alg. 3: intertwined KNN-graph construction by
//!   repeatedly calling the fast k-means on fixed-size-ξ cells.
//! * [`ann`] — graph-based greedy ANN search (§4.3's application).

pub mod ann;
pub mod construct;
pub mod gkmeans;
pub mod variant;

use crate::data::matrix::VecSet;
use crate::kmeans::common::KmeansOutput;
use crate::runtime::Backend;

/// End-to-end GK-means: build the KNN graph with Alg. 3, then cluster
/// with Alg. 2 (the paper's "two major steps", §4.3 summary).
pub fn cluster(
    data: &VecSet,
    k: usize,
    params: &gkmeans::GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    let build = construct::build(data, &construct::ConstructParams {
        kappa: params.kappa,
        seed: params.base.seed,
        threads: params.base.threads,
        ..Default::default()
    }, backend);
    let mut out = gkmeans::run(data, k, &build.graph, params, backend);
    // account graph-construction time as initialization cost
    out.init_seconds += build.total_seconds;
    out.total_seconds += build.total_seconds;
    for h in out.history.iter_mut() {
        h.seconds += build.total_seconds;
    }
    out
}
