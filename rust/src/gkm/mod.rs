//! GK-means: the paper's contribution.
//!
//! * [`gkmeans`] — Alg. 2: boost k-means where each sample is only
//!   compared against the clusters its κ graph-neighbors reside in.
//! * [`variant`] — the Alg. 2 variant built on traditional k-means
//!   ("GK-means\*" in Fig. 4): seek the closest *centroid* among the
//!   candidate clusters instead of maximizing Δℐ.
//! * [`construct`] — Alg. 3: intertwined KNN-graph construction by
//!   repeatedly calling the fast k-means on fixed-size-ξ cells.
//! * [`ann`] — graph-based greedy ANN search (§4.3's application).

pub mod ann;
pub mod construct;
pub mod gkmeans;
pub mod tree;
pub mod variant;

use crate::data::matrix::VecSet;
use crate::kmeans::common::KmeansOutput;
use crate::runtime::Backend;

/// Epoch-stamped candidate-cluster dedup shared by both Alg. 2 cores
/// (the Δℐ core in [`gkmeans`] and the traditional core in [`variant`]).
///
/// Collecting `Q = { cLabel[b] : b ∈ G[i] }` must deduplicate labels;
/// `mark[cluster] == stamp` makes that O(κ) per sample with no
/// allocation (vs. the old O(κ²) `q.contains` scan), and candidates come
/// out in first-occurrence order — identical to the scan it replaced.
pub(crate) struct CandidateSet {
    /// `mark[cluster] == stamp` ⇔ cluster already collected this sample.
    mark: Vec<u32>,
    stamp: u32,
    /// The collected candidate labels (valid until the next `collect`).
    pub q: Vec<u32>,
}

impl CandidateSet {
    pub fn new(k: usize, kappa: usize) -> CandidateSet {
        CandidateSet { mark: vec![0; k], stamp: 0, q: Vec::with_capacity(kappa + 1) }
    }

    /// Advance the stamp; resets the mark array on the (astronomically
    /// rare) u32 wraparound so stale stamps can never collide.
    #[inline]
    fn next_stamp(&mut self) -> u32 {
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            self.mark.iter_mut().for_each(|m| *m = 0);
            self.stamp = 1;
        }
        self.stamp
    }

    /// Rebuild `q` with the deduplicated labels of the first `kappa`
    /// non-vacant `neighbors`.  `include` seeds `q` with a label before
    /// the scan (GK-means\* keeps the current cluster as a candidate);
    /// `exclude` drops a label from collection (the Δℐ core never
    /// proposes a self-move).  First-occurrence order is preserved.
    #[inline]
    pub fn collect(
        &mut self,
        labels: &[u32],
        neighbors: &[u32],
        kappa: usize,
        include: Option<u32>,
        exclude: Option<u32>,
    ) {
        let stamp = self.next_stamp();
        self.q.clear();
        if let Some(l) = include {
            self.mark[l as usize] = stamp;
            self.q.push(l);
        }
        let ex = exclude.map(|l| l as usize).unwrap_or(usize::MAX);
        for &b in neighbors.iter().take(kappa) {
            if b == u32::MAX {
                continue;
            }
            let lbl = labels[b as usize];
            let l = lbl as usize;
            if l != ex && self.mark[l] != stamp {
                self.mark[l] = stamp;
                self.q.push(lbl);
            }
        }
    }
}

/// Deprecated shim — the pre-`Clusterer` end-to-end entry point
/// (Alg. 3 graph build, then Alg. 2).  `model::GkMeans` is the same
/// pipeline behind the trait, with `fit_store` for disk-backed data and
/// a `FittedModel` (predict / ANN search / save / load) coming back.
#[deprecated(
    note = "use `model::GkMeans::new(k).kappa(..).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data)"
)]
pub fn cluster(
    data: &VecSet,
    k: usize,
    params: &gkmeans::GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    let build = construct::build(
        data,
        &construct::ConstructParams {
            kappa: params.kappa,
            seed: params.base.seed,
            threads: params.base.threads,
            ..Default::default()
        },
        backend,
    );
    let mut out = gkmeans::run_core(data, k, &build.graph, params, backend);
    // account graph-construction time as initialization cost
    out.init_seconds += build.total_seconds;
    out.total_seconds += build.total_seconds;
    for h in out.history.iter_mut() {
        h.seconds += build.total_seconds;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_set_dedups_in_first_occurrence_order() {
        let labels = vec![3u32, 1, 3, 2, 1, 0];
        let mut cs = CandidateSet::new(4, 6);
        // neighbors 0..6 -> labels 3,1,3,2,1,0; exclude label 1
        cs.collect(&labels, &[0, 1, 2, 3, 4, 5], 6, None, Some(1));
        assert_eq!(cs.q, vec![3, 2, 0]);
        // include the current cluster first; vacant slots skipped
        cs.collect(&labels, &[0, u32::MAX, 3], 3, Some(2), None);
        assert_eq!(cs.q, vec![2, 3]);
        // kappa truncation
        cs.collect(&labels, &[0, 1, 2, 3, 4, 5], 2, None, None);
        assert_eq!(cs.q, vec![3, 1]);
    }

    #[test]
    fn candidate_set_reuse_across_many_samples() {
        let labels: Vec<u32> = (0..100u32).map(|i| i % 7).collect();
        let mut cs = CandidateSet::new(7, 10);
        for i in 0..100u32 {
            let nbrs: Vec<u32> = (0..10).map(|t| (i + t) % 100).collect();
            cs.collect(&labels, &nbrs, 10, None, Some(labels[i as usize]));
            let mut sorted = cs.q.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), cs.q.len(), "duplicates at sample {i}");
            assert!(!cs.q.contains(&labels[i as usize]), "excluded label leaked");
        }
    }
}
