//! "GK-means\*": Alg. 2 built on *traditional* k-means (Fig. 4's second
//! configuration).
//!
//! Lines 12–15 of Alg. 2 are replaced by "seek the closest centroid among
//! the collected clusters": assignment moves to the candidate cluster with
//! the nearest centroid, and centroids are recomputed Lloyd-style at epoch
//! end.  The paper shows this keeps the speed-up but converges to visibly
//! higher distortion than the Δℐ-driven version — our Fig. 4 bench
//! reproduces exactly that gap.

use crate::core_ops::dist::{d2_via_dot, dot, norm2};
use crate::data::matrix::VecSet;
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::{Clustering, IterStat, KmeansOutput};
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

pub use crate::gkm::gkmeans::GkMeansParams;

/// Run the traditional-core variant.
pub fn run(
    data: &VecSet,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();
    let kappa = params.kappa.min(graph.kappa());
    let labels = two_means::run(
        data,
        k,
        &TwoMeansParams {
            seed: params.base.seed,
            threads: params.base.threads,
            ..Default::default()
        },
        backend,
    );
    let mut clustering = Clustering::from_labels(data, labels, k);
    let init_seconds = timer.elapsed_s();
    let mut centroids = clustering.centroids();
    let total_norm: f64 = (0..n).map(|i| norm2(data.row(i)) as f64).sum();
    let mut rng = Rng::new(params.base.seed ^ 0x7452_6164);
    let mut order: Vec<usize> = (0..n).collect();
    let mut q: Vec<u32> = Vec::with_capacity(kappa + 1);

    let mut history = vec![IterStat {
        iter: 0,
        seconds: timer.elapsed_s(),
        distortion: (total_norm - clustering.objective()) / n as f64,
        moves: 0,
    }];

    for iter in 1..=params.base.max_iters {
        rng.shuffle(&mut order);
        let mut new_labels = clustering.labels.clone();
        let mut moves = 0usize;
        // Precomputed-norm candidate evaluation (the d2_via_dot path): the
        // centroid norms are fixed for the whole epoch, so each candidate
        // costs one ⟨x, C_v⟩ dot — the same inner product a tiled
        // mini-GEMM produces, keeping this loop GEMM-compatible.  Note the
        // norm+dot identity rounds differently than a direct (x−y)² sum
        // for near-zero distances (same tolerance class as the blocked
        // kernels Lloyd assignment already uses), so GK-means* results
        // shift at f32 precision relative to the pre-GEMM-form code; the
        // Δℐ-driven GK-means proper (gkmeans.rs) is untouched.
        let cnorms: Vec<f32> = (0..k).map(|r| norm2(centroids.row(r))).collect();
        for &i in &order {
            let x = data.row(i);
            let xx = norm2(x);
            let u = clustering.labels[i] as usize;
            q.clear();
            q.push(u as u32);
            for &b in graph.neighbors(i).iter().take(kappa) {
                if b != u32::MAX {
                    let lbl = clustering.labels[b as usize];
                    if !q.contains(&lbl) {
                        q.push(lbl);
                    }
                }
            }
            let mut best = f32::INFINITY;
            let mut best_c = u as u32;
            for &cand in &q {
                let c = cand as usize;
                let dd = d2_via_dot(xx, cnorms[c], dot(x, centroids.row(c)));
                if dd < best {
                    best = dd;
                    best_c = cand;
                }
            }
            if best_c as usize != u {
                moves += 1;
            }
            new_labels[i] = best_c;
        }
        // Lloyd-style batch update
        centroids = crate::kmeans::lloyd::update_centroids(data, &new_labels, k, &centroids);
        clustering = Clustering::from_labels(data, new_labels, k);
        history.push(IterStat {
            iter,
            seconds: timer.elapsed_s(),
            distortion: (total_norm - clustering.objective()) / n as f64,
            moves,
        });
        if (moves as f64) < params.base.min_move_rate * n as f64 {
            break;
        }
    }

    KmeansOutput { clustering, history, total_seconds: timer.elapsed_s(), init_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::brute;

    #[test]
    fn runs_and_improves() {
        let data = blobs(&BlobSpec::quick(400, 6, 8), 1);
        let graph = brute::build(&data, 8, &Backend::native());
        let out = run(&data, 8, &graph, &GkMeansParams { kappa: 8, ..Default::default() }, &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
        assert!(out.history.last().unwrap().distortion <= out.history[0].distortion + 1e-9);
    }

    #[test]
    fn boost_core_beats_traditional_core() {
        // the Fig. 4 ordering: Δℐ-driven GK-means converges lower
        let data = blobs(&BlobSpec { sigma: 2.5, ..BlobSpec::quick(800, 8, 16) }, 2);
        let graph = brute::build(&data, 10, &Backend::native());
        let p = GkMeansParams { kappa: 10, ..Default::default() };
        let trad = run(&data, 16, &graph, &p, &Backend::native());
        let boost = crate::gkm::gkmeans::run(&data, 16, &graph, &p, &Backend::native());
        assert!(
            boost.distortion() <= trad.distortion() * 1.02,
            "boost={} trad={}",
            boost.distortion(),
            trad.distortion()
        );
    }
}
