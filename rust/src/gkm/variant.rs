//! "GK-means\*": Alg. 2 built on *traditional* k-means (Fig. 4's second
//! configuration).
//!
//! Lines 12–15 of Alg. 2 are replaced by "seek the closest centroid among
//! the collected clusters": assignment moves to the candidate cluster with
//! the nearest centroid, and centroids are recomputed Lloyd-style at epoch
//! end.  The paper shows this keeps the speed-up but converges to visibly
//! higher distortion than the Δℐ-driven version — our Fig. 4 bench
//! reproduces exactly that gap.

use crate::core_ops::dist::{batch_eligible, d2, norm2};
use crate::data::matrix::VecSet;
use crate::data::plan::ScanPlan;
use crate::data::store::VecStore;
use crate::gkm::CandidateSet;
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::{Clustering, EpochState, FitHooks, IterStat, KmeansOutput};
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

pub use crate::gkm::gkmeans::GkMeansParams;

/// Deprecated shim over [`run_core`] — the pre-`Clusterer` entry point.
/// The modern surface is `model::GkMeansStar` (which builds the Alg. 3
/// graph itself, resident or out-of-core via `fit`/`fit_store`); to run
/// on a *caller-supplied* graph as this shim does, call [`run_core`].
#[deprecated(
    note = "use `model::GkMeansStar::new(k).kappa(..).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data); for a caller-supplied graph use `run_core`"
)]
pub fn run(
    data: &VecSet,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    run_core(data, k, graph, params, backend)
}

/// The traditional-core engine ([`crate::model::GkMeansStar`] executes
/// this).  Runs over any [`VecStore`].
pub fn run_core(
    data: &dyn VecStore,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
) -> KmeansOutput {
    run_core_hooked(data, k, graph, params, backend, &mut FitHooks::none())
}

/// [`run_core`] with fit instrumentation (per-epoch hook + resume).  A
/// resume point skips the 2M-tree initialization; the clustering state is
/// rebuilt from the checkpointed labels (bit-identical to the state the
/// uninterrupted run carried — `from_labels_with_centroids` is pinned to
/// equal `from_labels` + `update_centroids`) and the centroids restored
/// from their raw checkpointed bits.
pub fn run_core_hooked(
    data: &dyn VecStore,
    k: usize,
    graph: &KnnGraph,
    params: &GkMeansParams,
    backend: &Backend,
    hooks: &mut FitHooks<'_>,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();
    let d = data.dim();
    let kappa = params.kappa.min(graph.kappa());
    let resume = hooks.resume.take();

    let (mut clustering, mut centroids, init_seconds) = match &resume {
        Some(r) => {
            let c = Clustering::from_labels(data, r.labels.clone(), k);
            let cent = VecSet::from_flat(
                d,
                r.centroids.clone().expect("GK-means* checkpoint carries centroids"),
            );
            (c, cent, 0.0)
        }
        None => {
            let labels = two_means::run(
                data,
                k,
                &TwoMeansParams {
                    seed: params.base.seed,
                    threads: params.base.threads,
                    scan_order: params.base.scan_order,
                    ..Default::default()
                },
                backend,
            );
            let c = Clustering::from_labels(data, labels, k);
            let init_seconds = timer.elapsed_s();
            hooks.init_seconds = init_seconds;
            let cent = c.centroids();
            (c, cent, init_seconds)
        }
    };
    let plan = ScanPlan::new(data, params.base.scan_order);
    let mut cur = data.open();
    let total_norm: f64 = (0..n).map(|i| norm2(cur.row(i)) as f64).sum();
    let mut rng = Rng::new(params.base.seed ^ 0x7452_6164);
    let mut order: Vec<usize> = (0..n).collect();
    // shared O(κ) epoch-stamped dedup (the Δℐ core uses the same helper;
    // this loop previously re-scanned `q` per neighbor — O(κ²))
    let mut cand = CandidateSet::new(k, kappa);
    // batched-evaluation scratch, reused across samples: the gathered
    // candidate-centroid block, their cached norms, and the distances
    let mut cblock: Vec<f32> = Vec::new();
    let mut cnorm_sel: Vec<f32> = Vec::new();
    let mut cdist: Vec<f32> = Vec::new();

    let (mut history, start_iter, seconds_base) = match resume {
        Some(r) => {
            // replay the epoch shuffles so the visit-order permutation and
            // the RNG stream both match the uninterrupted run
            for _ in 1..r.next_iter {
                plan.shuffle_epoch(&mut order, &mut rng);
            }
            debug_assert_eq!(rng.state(), r.rng, "resume RNG replay diverged from the checkpoint");
            let base = r.history.last().map(|h| h.seconds).unwrap_or(0.0);
            (r.history, r.next_iter, base)
        }
        None => {
            let history = vec![IterStat {
                iter: 0,
                seconds: timer.elapsed_s(),
                distortion: (total_norm - clustering.objective()) / n as f64,
                moves: 0,
            }];
            fire_variant_epoch(hooks, &history, &rng, &clustering, &centroids);
            (history, 1, 0.0)
        }
    };

    for iter in start_iter..=params.base.max_iters {
        plan.shuffle_epoch(&mut order, &mut rng);
        let mut new_labels = clustering.labels.clone();
        let mut moves = 0usize;
        // Batched candidate evaluation (the mini-GEMM hot path): the
        // centroid norms are fixed for the whole epoch (this loop's
        // centroid-norm cache), so evaluating the candidate set costs one
        // gathered `Backend::candidate_d2` call — a tiled `d2_batch` pass
        // where four candidates share every load of `x` — instead of one
        // scalar dot per candidate.  Note the norm+dot identity rounds
        // differently than a direct (x−y)² sum for near-zero distances
        // (same tolerance class as the blocked kernels Lloyd assignment
        // already uses; tiny dims take the kernel's one-shot scalar
        // fallback), so GK-means* results may shift at f32 precision; the
        // Δℐ-driven GK-means proper (gkmeans.rs) keeps its bit-exact
        // contract through `dot_batch` instead.
        let cnorms: Vec<f32> = (0..k).map(|r| norm2(centroids.row(r))).collect();
        for &i in &order {
            let x = cur.row(i);
            let xx = norm2(x);
            let u = clustering.labels[i] as usize;
            cand.collect(&clustering.labels, graph.neighbors(i), kappa, Some(u as u32), None);
            let mut best = f32::INFINITY;
            let mut best_c = u as u32;
            if !batch_eligible(d, cand.q.len()) {
                // the kernel would take its one-shot scalar fallback on
                // this shape — evaluate in place (same arithmetic as the
                // fallback, without paying the gather)
                for &v in &cand.q {
                    let dd = d2(x, centroids.row(v as usize));
                    if dd < best {
                        best = dd;
                        best_c = v;
                    }
                }
            } else {
                // gather the candidate centroids + cached norms
                // contiguously and evaluate the set in one kernel call
                cblock.clear();
                cnorm_sel.clear();
                for &v in &cand.q {
                    cblock.extend_from_slice(centroids.row(v as usize));
                    cnorm_sel.push(cnorms[v as usize]);
                }
                cdist.clear();
                cdist.resize(cand.q.len(), 0.0);
                backend.candidate_d2(x, xx, &cblock, &cnorm_sel, d, &mut cdist);
                for (t, &v) in cand.q.iter().enumerate() {
                    if cdist[t] < best {
                        best = cdist[t];
                        best_c = v;
                    }
                }
            }
            if best_c as usize != u {
                moves += 1;
            }
            new_labels[i] = best_c;
        }
        // Lloyd-style batch update, fused with the state rebuild so a
        // streamed store is scanned once here instead of twice
        let (next, next_centroids) =
            Clustering::from_labels_with_centroids(data, new_labels, k, &centroids);
        clustering = next;
        centroids = next_centroids;
        history.push(IterStat {
            iter,
            seconds: seconds_base + timer.elapsed_s(),
            distortion: (total_norm - clustering.objective()) / n as f64,
            moves,
        });
        fire_variant_epoch(hooks, &history, &rng, &clustering, &centroids);
        if (moves as f64) < params.base.min_move_rate * n as f64 {
            break;
        }
    }

    KmeansOutput {
        clustering,
        history,
        total_seconds: seconds_base + timer.elapsed_s(),
        init_seconds,
    }
}

/// Fire the per-epoch hook for the centroid-maintaining GK-means* loop
/// (labels come from the clustering, centroids from the Lloyd-style
/// update; no composite cache to snapshot — resume rebuilds it from the
/// labels bit-identically).
fn fire_variant_epoch(
    hooks: &mut FitHooks<'_>,
    history: &[IterStat],
    rng: &Rng,
    clustering: &Clustering,
    centroids: &VecSet,
) {
    if hooks.on_epoch.is_none() {
        return;
    }
    let seconds_offset = hooks.seconds_offset;
    let init_seconds = hooks.init_seconds;
    let stat = history.last().expect("fire_variant_epoch: history has the entry just pushed");
    let state = EpochState {
        completed_epoch: stat.iter,
        rng: rng.state(),
        stat,
        history,
        seconds_offset,
        init_seconds,
        labels: &clustering.labels,
        composite: None,
        counts: None,
        comp_norm2: None,
        centroids: Some(centroids.flat()),
    };
    hooks.fire(&state);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::brute;

    #[test]
    fn runs_and_improves() {
        let data = blobs(&BlobSpec::quick(400, 6, 8), 1);
        let graph = brute::build(&data, 8, &Backend::native());
        let out = run_core(&data, 8, &graph, &GkMeansParams { kappa: 8, ..Default::default() }, &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
        assert!(out.history.last().unwrap().distortion <= out.history[0].distortion + 1e-9);
    }

    #[test]
    fn boost_core_beats_traditional_core() {
        // the Fig. 4 ordering: Δℐ-driven GK-means converges lower
        let data = blobs(&BlobSpec { sigma: 2.5, ..BlobSpec::quick(800, 8, 16) }, 2);
        let graph = brute::build(&data, 10, &Backend::native());
        let p = GkMeansParams { kappa: 10, ..Default::default() };
        let trad = run_core(&data, 16, &graph, &p, &Backend::native());
        let boost = crate::gkm::gkmeans::run_core(&data, 16, &graph, &p, &Backend::native());
        assert!(
            boost.distortion() <= trad.distortion() * 1.02,
            "boost={} trad={}",
            boost.distortion(),
            trad.distortion()
        );
    }
}
