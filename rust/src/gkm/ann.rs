//! Graph-based approximate nearest-neighbor search (§4.3's application).
//!
//! Best-first greedy search over the KNN graph, HNSW-base-layer style:
//! start from a few random entry points, repeatedly expand the closest
//! unexpanded candidate's neighbor list, keep an `ef`-sized result pool,
//! stop when the pool no longer improves.  The paper reports that graphs
//! from Alg. 3 serve ANN queries competitively despite lower raw recall —
//! `benches/ann_search.rs` reproduces that comparison vs NN-Descent.
//!
//! Each frontier expansion evaluates its ≤ κ unvisited neighbors as one
//! gathered block through the exact-form batched kernel
//! ([`crate::core_ops::dist::d2_batch_exact`]): four neighbors share
//! every load of the query, and because the kernel is bit-identical per
//! column to the scalar `d2`, results and stats are exactly those of the
//! historical per-neighbor loop.

use crate::core_ops::dist::{d2, d2_batch_exact, d2_batch_sq8};
use crate::core_ops::topk::TopK;
use crate::data::quant::QuantizedVecStore;
use crate::data::store::VecStore;
use crate::graph::knn::KnnGraph;
use crate::util::rng::Rng;

/// Search parameters.
#[derive(Debug, Clone)]
pub struct SearchParams {
    /// Result-pool width (quality/latency knob; ≥ k).
    pub ef: usize,
    /// Number of random entry points.
    pub entries: usize,
    pub seed: u64,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams { ef: 64, entries: 4, seed: 20170707 }
    }
}

impl SearchParams {
    /// Set the result-pool width (quality/latency knob).
    pub fn with_ef(mut self, ef: usize) -> Self {
        self.ef = ef;
        self
    }

    /// Set the number of entry points (random draws, or seed rows kept
    /// when the caller routes).
    pub fn with_entries(mut self, entries: usize) -> Self {
        self.entries = entries;
        self
    }

    /// Set the per-query RNG seed (random-entry selection only; seeded
    /// searches draw no randomness).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Search statistics (distance evaluations = the latency proxy the
/// paper's "3 ms / query" claim is about).
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    pub dist_evals: usize,
    pub hops: usize,
}

/// Reusable per-thread search state: the visited set (epoch-stamped so a
/// new query costs O(1) to reset, not an O(n) clear) and the frontier
/// heap.  Hoisted out of [`search`] so batched serving
/// (`FittedModel::search_batch`) and long-lived services do not allocate
/// an O(n) buffer per query.
pub struct SearchScratch {
    /// Epoch stamp per node; `stamp[i] == epoch` means visited.
    stamp: Vec<u32>,
    epoch: u32,
    frontier: std::collections::BinaryHeap<std::cmp::Reverse<(Ordered, u32)>>,
    /// Ids of the unvisited neighbors gathered for one frontier expansion.
    batch_ids: Vec<u32>,
    /// Their rows, gathered contiguously for the batched distance kernel.
    batch_rows: Vec<f32>,
    /// Per-gathered-neighbor squared distances from `d2_batch_exact`.
    batch_d2: Vec<f32>,
    /// SQ8 code rows gathered for one frontier expansion
    /// ([`search_sq8_with_scratch`]'s mirror of `batch_rows`).
    batch_codes: Vec<u8>,
}

impl SearchScratch {
    /// Scratch sized for an `n`-node graph.
    pub fn new(n: usize) -> SearchScratch {
        SearchScratch {
            stamp: vec![0; n],
            epoch: 0,
            frontier: std::collections::BinaryHeap::new(),
            batch_ids: Vec::new(),
            batch_rows: Vec::new(),
            batch_d2: Vec::new(),
            batch_codes: Vec::new(),
        }
    }

    /// Start a new query: bump the epoch (clearing the visited set in
    /// O(1)) and empty the frontier.
    fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // stamp wrap-around (once every 2^32 queries): hard reset
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
        self.frontier.clear();
    }

    /// Mark node `i` visited; returns false if it already was.
    #[inline]
    fn visit(&mut self, i: usize) -> bool {
        if self.stamp[i] == self.epoch {
            return false;
        }
        self.stamp[i] = self.epoch;
        true
    }
}

/// Find the approximate top-`k` neighbors of `query` in `data` using the
/// graph.  Returns ascending-distance (dist, id) pairs plus stats.
/// Allocates fresh scratch per call — batch callers should hold a
/// [`SearchScratch`] and use [`search_with_scratch`].
pub fn search(
    data: &dyn VecStore,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    rng: &mut Rng,
) -> (Vec<(f32, u32)>, SearchStats) {
    assert_eq!(data.rows(), graph.n(), "store/graph size mismatch");
    let mut scratch = SearchScratch::new(data.rows());
    let mut cur = data.open();
    search_with_scratch(&mut cur, graph, query, k, params, rng, &mut scratch)
}

/// How a search picks its graph entry points.
///
/// `Random` is the historical behavior (`entries` draws from the query
/// RNG).  `Seeds` starts from caller-chosen rows instead — the routing
/// tree ([`crate::gkm::tree::RouteTree`]) descends to the nearest
/// clusters and hands their representative rows here, which replaces
/// O(k)-ish random placement with O(depth·branch) routed placement.
/// Out-of-range or duplicate seed rows are skipped.
enum EntrySel<'a> {
    Random { rng: &'a mut Rng, count: usize },
    Seeds(&'a [u32]),
}

/// [`search`] with caller-owned cursor and scratch: identical results,
/// no per-query O(n) allocation, and (for disk-backed stores) the
/// cursor's block cache stays warm across a batch of queries.
pub fn search_with_scratch(
    cur: &mut crate::data::store::StoreCursor<'_>,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    rng: &mut Rng,
    scratch: &mut SearchScratch,
) -> (Vec<(f32, u32)>, SearchStats) {
    let entry = EntrySel::Random { rng, count: params.entries };
    search_core(cur, graph, query, k, params, entry, scratch)
}

/// [`search_with_scratch`] starting from caller-chosen entry rows
/// (routed seeding) instead of random draws.  `seeds` must be
/// non-empty; invalid rows are skipped, and if every seed is invalid
/// the result is empty — callers fall back to the random variant.
pub fn search_seeded_with_scratch(
    cur: &mut crate::data::store::StoreCursor<'_>,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    seeds: &[u32],
    scratch: &mut SearchScratch,
) -> (Vec<(f32, u32)>, SearchStats) {
    search_core(cur, graph, query, k, params, EntrySel::Seeds(seeds), scratch)
}

fn search_core(
    cur: &mut crate::data::store::StoreCursor<'_>,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    entry: EntrySel<'_>,
    scratch: &mut SearchScratch,
) -> (Vec<(f32, u32)>, SearchStats) {
    let n = graph.n();
    let ef = params.ef.max(k);
    let mut stats = SearchStats::default();
    scratch.begin(n);
    // candidate min-queue (dist, id): BinaryHeap is a max-heap, use Reverse
    let mut pool = TopK::new(ef);

    match entry {
        EntrySel::Random { rng, count } => {
            for _ in 0..count.max(1) {
                let e = rng.below(n);
                if !scratch.visit(e) {
                    continue;
                }
                let dd = d2(query, cur.row(e));
                stats.dist_evals += 1;
                pool.push(dd, e as u32);
                scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), e as u32)));
            }
        }
        EntrySel::Seeds(rows) => {
            for &r in rows {
                let e = r as usize;
                if e >= n || !scratch.visit(e) {
                    continue;
                }
                let dd = d2(query, cur.row(e));
                stats.dist_evals += 1;
                pool.push(dd, e as u32);
                scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), e as u32)));
            }
        }
    }

    while let Some(std::cmp::Reverse((od, node))) = scratch.frontier.pop() {
        let dcur = od.0;
        if dcur > pool.threshold() {
            break; // closest frontier node is worse than the worst pooled
        }
        stats.hops += 1;
        // Frontier expansion, batched: mark + gather the unvisited
        // neighbors' rows into a contiguous block, evaluate the whole
        // block through the tiled kernel, then replay the pool/frontier
        // updates in neighbor order.  `d2_batch_exact` is bit-identical
        // per column to the scalar `d2` and the threshold sequence is
        // replayed in the same order, so results and stats match the
        // historical per-neighbor loop exactly (search ≡ search_batch
        // equivalence is untouched).
        scratch.batch_ids.clear();
        for &nb in graph.neighbors(node as usize) {
            if nb == u32::MAX {
                continue;
            }
            if !scratch.visit(nb as usize) {
                continue;
            }
            scratch.batch_ids.push(nb);
        }
        stats.dist_evals += scratch.batch_ids.len();
        if scratch.batch_ids.len() < crate::core_ops::dist::BATCH_TILE {
            // too narrow to fill one tile — evaluate in place (the
            // historical loop; same bits, no gather)
            for &nb in &scratch.batch_ids {
                let dd = d2(query, cur.row(nb as usize));
                if dd < pool.threshold() {
                    pool.push(dd, nb);
                    scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), nb)));
                }
            }
            continue;
        }
        scratch.batch_rows.clear();
        for &nb in &scratch.batch_ids {
            scratch.batch_rows.extend_from_slice(cur.row(nb as usize));
        }
        scratch.batch_d2.clear();
        scratch.batch_d2.resize(scratch.batch_ids.len(), 0.0);
        d2_batch_exact(query, &scratch.batch_rows, query.len(), &mut scratch.batch_d2);
        for (t, &nb) in scratch.batch_ids.iter().enumerate() {
            let dd = scratch.batch_d2[t];
            if dd < pool.threshold() {
                pool.push(dd, nb);
                scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), nb)));
            }
        }
    }

    let mut out: Vec<(f32, u32)> = pool.into_sorted().into_iter().map(|n| (n.dist, n.id)).collect();
    out.truncate(k);
    (out, stats)
}

/// Asymmetric distance from an f32 query to one SQ8 code row.
fn d2_sq8_one(store: &QuantizedVecStore, query: &[f32], id: u32) -> f32 {
    let mut out = [0f32; 1];
    let q = store.quantizer();
    d2_batch_sq8(query, store.code_row(id as usize), q.min(), q.scale(), query.len(), &mut out);
    out[0]
}

/// [`search`] over SQ8 codes: the greedy traversal evaluates every
/// candidate against the quantized store (¼ the memory traffic of the
/// f32 rows), then the surviving `ef`-pool is **re-ranked with exact
/// f32 distances** from `exact` before the top-`k` cut — so the
/// returned distances are true squared distances and recall tracks the
/// f32 search (the pool is `ef ≥ k` wide, which absorbs quantization
/// reorderings near the cut).  Allocates fresh scratch per call; batch
/// callers use [`search_sq8_with_scratch`].
pub fn search_sq8(
    store: &QuantizedVecStore,
    exact: &dyn VecStore,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    rng: &mut Rng,
) -> (Vec<(f32, u32)>, SearchStats) {
    assert_eq!(store.rows(), graph.n(), "quantized store/graph size mismatch");
    assert_eq!(exact.rows(), graph.n(), "exact store/graph size mismatch");
    let mut scratch = SearchScratch::new(store.rows());
    let mut cur = exact.open();
    search_sq8_with_scratch(store, &mut cur, graph, query, k, params, rng, &mut scratch)
}

/// [`search_sq8`] with caller-owned scratch and re-rank cursor.  The
/// traversal never touches `exact` — only the final pool re-rank reads
/// f32 rows (≤ `ef` of them per query), so a disk-backed `exact` store
/// costs a handful of page hits while the scan bandwidth all comes from
/// the RAM-resident codes.  `stats.dist_evals` counts both the SQ8
/// evaluations and the exact re-rank distances.
#[allow(clippy::too_many_arguments)]
pub fn search_sq8_with_scratch(
    store: &QuantizedVecStore,
    exact: &mut crate::data::store::StoreCursor<'_>,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    rng: &mut Rng,
    scratch: &mut SearchScratch,
) -> (Vec<(f32, u32)>, SearchStats) {
    let entry = EntrySel::Random { rng, count: params.entries };
    search_sq8_core(store, exact, graph, query, k, params, entry, scratch)
}

/// [`search_sq8_with_scratch`] starting from caller-chosen entry rows
/// (routed seeding); see [`search_seeded_with_scratch`].
#[allow(clippy::too_many_arguments)]
pub fn search_sq8_seeded_with_scratch(
    store: &QuantizedVecStore,
    exact: &mut crate::data::store::StoreCursor<'_>,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    seeds: &[u32],
    scratch: &mut SearchScratch,
) -> (Vec<(f32, u32)>, SearchStats) {
    search_sq8_core(store, exact, graph, query, k, params, EntrySel::Seeds(seeds), scratch)
}

#[allow(clippy::too_many_arguments)]
fn search_sq8_core(
    store: &QuantizedVecStore,
    exact: &mut crate::data::store::StoreCursor<'_>,
    graph: &KnnGraph,
    query: &[f32],
    k: usize,
    params: &SearchParams,
    entry: EntrySel<'_>,
    scratch: &mut SearchScratch,
) -> (Vec<(f32, u32)>, SearchStats) {
    let n = graph.n();
    let ef = params.ef.max(k);
    let mut stats = SearchStats::default();
    scratch.begin(n);
    let mut pool = TopK::new(ef);

    match entry {
        EntrySel::Random { rng, count } => {
            for _ in 0..count.max(1) {
                let e = rng.below(n);
                if !scratch.visit(e) {
                    continue;
                }
                let dd = d2_sq8_one(store, query, e as u32);
                stats.dist_evals += 1;
                pool.push(dd, e as u32);
                scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), e as u32)));
            }
        }
        EntrySel::Seeds(rows) => {
            for &r in rows {
                let e = r as usize;
                if e >= n || !scratch.visit(e) {
                    continue;
                }
                let dd = d2_sq8_one(store, query, e as u32);
                stats.dist_evals += 1;
                pool.push(dd, e as u32);
                scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), e as u32)));
            }
        }
    }

    while let Some(std::cmp::Reverse((od, node))) = scratch.frontier.pop() {
        let dcur = od.0;
        if dcur > pool.threshold() {
            break;
        }
        stats.hops += 1;
        scratch.batch_ids.clear();
        for &nb in graph.neighbors(node as usize) {
            if nb == u32::MAX {
                continue;
            }
            if !scratch.visit(nb as usize) {
                continue;
            }
            scratch.batch_ids.push(nb);
        }
        stats.dist_evals += scratch.batch_ids.len();
        if scratch.batch_ids.len() < crate::core_ops::dist::BATCH_TILE {
            for &nb in &scratch.batch_ids {
                let dd = d2_sq8_one(store, query, nb);
                if dd < pool.threshold() {
                    pool.push(dd, nb);
                    scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), nb)));
                }
            }
            continue;
        }
        scratch.batch_d2.clear();
        scratch.batch_d2.resize(scratch.batch_ids.len(), 0.0);
        store.d2_gather(query, &scratch.batch_ids, &mut scratch.batch_codes, &mut scratch.batch_d2);
        for (t, &nb) in scratch.batch_ids.iter().enumerate() {
            let dd = scratch.batch_d2[t];
            if dd < pool.threshold() {
                pool.push(dd, nb);
                scratch.frontier.push(std::cmp::Reverse((ordered_from(dd), nb)));
            }
        }
    }

    // Exact re-rank: replace every pooled (approximate) distance with the
    // true f32 distance, then re-sort and cut to k.  The pool is ef ≥ k
    // wide, so candidates the quantization error pushed just past the
    // would-be top-k boundary get pulled back in here.
    let mut out: Vec<(f32, u32)> = pool
        .into_sorted()
        .into_iter()
        .map(|c| {
            stats.dist_evals += 1;
            (d2(query, exact.row(c.id as usize)), c.id)
        })
        .collect();
    out.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    out.truncate(k);
    (out, stats)
}

/// Total-ordered f32 wrapper for the frontier heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ordered(pub f32);

fn ordered_from(v: f32) -> Ordered {
    Ordered(v)
}

impl Eq for Ordered {}
impl PartialOrd for Ordered {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ordered {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::brute;
    use crate::runtime::Backend;

    #[test]
    fn finds_true_nn_on_exact_graph() {
        let data = blobs(&BlobSpec::quick(500, 8, 8), 1);
        let graph = brute::build(&data, 10, &Backend::native());
        let mut rng = Rng::new(2);
        // a pure KNN graph over separated blobs has disconnected
        // components; enough random entries guarantee one lands in the
        // right component (this is inherent to KNN-graph search — HNSW
        // adds long links for exactly this reason).
        let params = SearchParams { entries: 32, ..Default::default() };
        let mut hits = 0;
        for qi in (0..500).step_by(29) {
            let q = data.row(qi).to_vec();
            let (res, _) = search(&data, &graph, &q, 1, &params, &mut rng);
            if res[0].1 as usize == qi {
                hits += 1;
            }
        }
        assert!(hits >= 16, "self-query hit {hits}/18");
    }

    #[test]
    fn results_sorted_and_unique() {
        let data = blobs(&BlobSpec::quick(300, 6, 5), 3);
        let graph = brute::build(&data, 8, &Backend::native());
        let mut rng = Rng::new(4);
        let q: Vec<f32> = data.row(7).iter().map(|v| v + 0.01).collect();
        let (res, stats) = search(&data, &graph, &q, 10, &SearchParams::default(), &mut rng);
        assert_eq!(res.len(), 10);
        assert!(res.windows(2).all(|w| w[0].0 <= w[1].0));
        let ids: std::collections::HashSet<u32> = res.iter().map(|r| r.1).collect();
        assert_eq!(ids.len(), 10);
        assert!(stats.dist_evals > 0 && stats.dist_evals < 300, "visited {} nodes", stats.dist_evals);
    }

    #[test]
    fn reused_scratch_matches_fresh_allocation() {
        let data = blobs(&BlobSpec::quick(400, 6, 6), 7);
        let graph = brute::build(&data, 8, &Backend::native());
        let mut scratch = SearchScratch::new(400);
        let params = SearchParams::default();
        for qi in (0..400).step_by(23) {
            let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.02).collect();
            let mut rng_a = Rng::new(qi as u64);
            let mut rng_b = Rng::new(qi as u64);
            let (fresh, fs) = search(&data, &graph, &q, 5, &params, &mut rng_a);
            let mut cur = crate::data::store::VecStore::open(&data);
            let (reused, rs) =
                search_with_scratch(&mut cur, &graph, &q, 5, &params, &mut rng_b, &mut scratch);
            assert_eq!(fresh, reused, "query {qi}");
            assert_eq!(fs.dist_evals, rs.dist_evals);
            assert_eq!(fs.hops, rs.hops);
        }
    }

    #[test]
    fn sq8_search_rerank_returns_exact_distances() {
        let data = blobs(&BlobSpec::quick(400, 8, 6), 9);
        let graph = brute::build(&data, 8, &Backend::native());
        let store = QuantizedVecStore::from_store(&data, 0);
        let params = SearchParams { entries: 16, ..Default::default() };
        let mut agree = 0;
        for qi in (0..400).step_by(17) {
            let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.01).collect();
            let mut rng_a = Rng::new(qi as u64);
            let mut rng_b = Rng::new(qi as u64);
            let (f32_res, _) = search(&data, &graph, &q, 5, &params, &mut rng_a);
            let (sq8_res, _) = search_sq8(&store, &data, &graph, &q, 5, &params, &mut rng_b);
            assert_eq!(sq8_res.len(), 5, "query {qi}");
            assert!(sq8_res.windows(2).all(|w| w[0].0 <= w[1].0), "query {qi}: unsorted");
            // re-ranked distances are true f32 distances, bit for bit
            for &(dd, id) in &sq8_res {
                assert_eq!(
                    dd.to_bits(),
                    d2(&q, data.row(id as usize)).to_bits(),
                    "query {qi} id {id}: re-rank must be exact"
                );
            }
            if sq8_res[0].1 == f32_res[0].1 {
                agree += 1;
            }
        }
        // same traversal over mildly-perturbed distances: the top hit
        // overwhelmingly agrees with the f32 search
        assert!(agree >= 20, "sq8 top-1 agreed on {agree}/24 queries");
    }

    #[test]
    fn sq8_search_scratch_reuse_matches_fresh() {
        let data = blobs(&BlobSpec::quick(300, 6, 5), 11);
        let graph = brute::build(&data, 8, &Backend::native());
        let store = QuantizedVecStore::from_store(&data, 50);
        let mut scratch = SearchScratch::new(300);
        let params = SearchParams::default();
        for qi in (0..300).step_by(31) {
            let q: Vec<f32> = data.row(qi).iter().map(|v| v + 0.02).collect();
            let mut rng_a = Rng::new(qi as u64);
            let mut rng_b = Rng::new(qi as u64);
            let (fresh, fs) = search_sq8(&store, &data, &graph, &q, 4, &params, &mut rng_a);
            let mut cur = crate::data::store::VecStore::open(&data);
            let (reused, rs) = search_sq8_with_scratch(
                &store, &mut cur, &graph, &q, 4, &params, &mut rng_b, &mut scratch,
            );
            assert_eq!(fresh, reused, "query {qi}");
            assert_eq!(fs.dist_evals, rs.dist_evals);
            assert_eq!(fs.hops, rs.hops);
        }
    }

    #[test]
    fn seeded_search_starts_where_told() {
        let data = blobs(&BlobSpec::quick(500, 8, 8), 1);
        let graph = brute::build(&data, 10, &Backend::native());
        let params = SearchParams::default();
        let mut scratch = SearchScratch::new(500);
        for qi in (0..500).step_by(41) {
            let q = data.row(qi).to_vec();
            let mut cur = crate::data::store::VecStore::open(&data);
            let (res, _) = search_seeded_with_scratch(
                &mut cur,
                &graph,
                &q,
                1,
                &params,
                &[qi as u32],
                &mut scratch,
            );
            // entry IS the true NN: no random-component luck needed
            assert_eq!(res[0].1 as usize, qi);
        }
        // out-of-range seeds are skipped; all-invalid ⇒ empty result so
        // the caller can fall back to random entries
        let q = data.row(0).to_vec();
        let mut cur = crate::data::store::VecStore::open(&data);
        let seeds = [u32::MAX];
        let (res, stats) =
            search_seeded_with_scratch(&mut cur, &graph, &q, 3, &params, &seeds, &mut scratch);
        assert!(res.is_empty());
        assert_eq!(stats.dist_evals, 0);
    }

    #[test]
    fn seeded_sq8_search_starts_where_told() {
        let data = blobs(&BlobSpec::quick(300, 8, 6), 9);
        let graph = brute::build(&data, 8, &Backend::native());
        let store = QuantizedVecStore::from_store(&data, 0);
        let params = SearchParams::default();
        let mut scratch = SearchScratch::new(300);
        for qi in (0..300).step_by(37) {
            let q = data.row(qi).to_vec();
            let mut cur = crate::data::store::VecStore::open(&data);
            let (res, _) = search_sq8_seeded_with_scratch(
                &store,
                &mut cur,
                &graph,
                &q,
                1,
                &params,
                &[qi as u32],
                &mut scratch,
            );
            assert_eq!(res[0].1 as usize, qi);
        }
    }

    #[test]
    fn ef_trades_quality_for_work() {
        let data = blobs(&BlobSpec::quick(800, 8, 10), 5);
        let graph = brute::build(&data, 6, &Backend::native());
        let mut rng_a = Rng::new(6);
        let mut rng_b = Rng::new(6);
        let q: Vec<f32> = data.row(11).iter().map(|v| v + 0.05).collect();
        let (_, s_small) = search(&data, &graph, &q, 1, &SearchParams { ef: 4, ..Default::default() }, &mut rng_a);
        let (_, s_big) = search(&data, &graph, &q, 1, &SearchParams { ef: 128, ..Default::default() }, &mut rng_b);
        assert!(s_big.dist_evals >= s_small.dist_evals);
    }
}
