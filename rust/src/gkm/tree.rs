//! Hierarchical cluster **routing tree** — O(depth·branch) coarse→fine
//! assignment for very large k.
//!
//! The paper's most striking structural move is that the KNN graph is
//! built by *recursively calling fast k-means itself*.  This module makes
//! that recursion a first-class, persisted artifact: a top-down tree over
//! the final k centroids, built with the same 2M-tree bisection + BKM
//! polish ([`crate::kmeans::two_means`]) the fits already use.  Each
//! internal node holds one routing vector (the mean of its subtree's
//! centroids); each leaf holds ≤ `branch` centroid ids.
//!
//! Routed `predict` descends with a beam: at every level the query is
//! scored against the children of the surviving frontier nodes — one
//! [`Backend::candidate_d2`] call per node over a *contiguous* block of
//! routing vectors (children are laid out consecutively, see below) —
//! the best `beam` nodes survive, and at the bottom the union of the
//! frontier leaves' members is evaluated exactly.  Cost is
//! O(beam·branch·depth + beam·branch) distances instead of O(k): at
//! k = 1M, branch = 32, beam = 8 that is ~10³ distance evaluations per
//! query, not 10⁶.
//!
//! **Exactness dial**: `beam ≥ k` means the frontier is never truncated
//! (a frontier is an antichain of subtrees, each owning ≥ 1 of the k
//! centroids, so it can never exceed k entries), every leaf is reached,
//! and the candidate set is all k centroids — [`RouteTree::predict_one`]
//! special-cases this to the *identical* flat
//! [`Backend::assign_blocks`] scan, so routed assignment is bit-for-bit
//! the flat assignment.  Smaller beams trade agreement for speed;
//! `beam = 8` keeps agreement ≥ 0.95 on clustered data (pinned by
//! `tests/route.rs`).
//!
//! **Layout invariant**: nodes are numbered in BFS order and a node's
//! children occupy consecutive ids, so the routing vectors of one
//! node's children are contiguous in `node_vecs` — the descent scores
//! them with a single batched-kernel call and zero gathers.  Children
//! always have larger ids than their parent, which also makes descent
//! termination a structural property (checked on load).
//!
//! The tree rides in GKMODEL v2 as the append-only `RTREE` section
//! (kind 8, CRC'd, skipped by older readers); see
//! [`crate::model::serde`].

use crate::core_ops::dist::{d2_batch_exact, norm2};
use crate::data::matrix::VecSet;
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::runtime::Backend;
use std::collections::VecDeque;

/// Default fan-out per internal node.  32 keeps the per-level
/// `candidate_d2` block comfortably inside the batched kernels' sweet
/// spot while holding depth to ⌈log₃₂ k⌉ (4 levels at k = 1M).
pub const DEFAULT_BRANCH: usize = 32;

/// Default beam width.  8 × 32 = 256 routing evaluations per level —
/// cheap — while keeping assignment agreement ≥ 0.95 on clustered data.
pub const DEFAULT_BEAM: usize = 8;

/// Below this k the flat scan is already fast enough that routing only
/// adds overhead; [`crate::model::FittedModel`] ignores an attached
/// tree for smaller models unless forced (`--route tree` at predict
/// time sets the threshold to 0).
pub const ROUTE_MIN_K: usize = 1024;

/// Build-time knobs for [`RouteTree::build`].
#[derive(Debug, Clone)]
pub struct RouteTreeParams {
    /// Fan-out per internal node (≥ 2).
    pub branch: usize,
    /// Default beam width stored on the tree (query-time overridable).
    pub beam: usize,
    /// Seed for the per-node 2M-tree splits (each node derives its own
    /// stream, so the build is deterministic per `(seed, threads)`).
    pub seed: u64,
    /// Worker threads handed to the per-node splits (`0` = auto).
    pub threads: usize,
}

impl Default for RouteTreeParams {
    fn default() -> RouteTreeParams {
        RouteTreeParams {
            branch: DEFAULT_BRANCH,
            beam: DEFAULT_BEAM,
            seed: 20170707,
            threads: 1,
        }
    }
}

/// Per-query (or per-worker) reusable buffers for descent — keeps the
/// routed hot path allocation-free across queries.
#[derive(Debug, Default)]
pub struct RouteScratch {
    dists: Vec<f32>,
    frontier: Vec<(f32, u32)>,
    next: Vec<(f32, u32)>,
    cand: Vec<u32>,
    gather: Vec<f32>,
}

impl RouteScratch {
    pub fn new() -> RouteScratch {
        RouteScratch::default()
    }
}

/// The routing tree: BFS-ordered nodes whose leaves partition the k
/// centroid ids.  Immutable after build/load; all query state lives in
/// [`RouteScratch`].
#[derive(Debug, Clone, PartialEq)]
pub struct RouteTree {
    pub dim: usize,
    pub k: usize,
    pub branch: u32,
    pub default_beam: u32,
    /// `nodes × dim` routing vectors, node 0 = root; a node's children
    /// are contiguous rows starting at `first_child`.
    pub(crate) node_vecs: Vec<f32>,
    /// ‖routing vector‖² per node — recomputed on load, not serialized.
    pub(crate) node_norms: Vec<f32>,
    /// First child node id (0 for leaves — node 0 is the root, never a
    /// child, so 0 is unambiguous as "none").
    pub(crate) first_child: Vec<u32>,
    /// Number of children (0 = leaf).
    pub(crate) child_count: Vec<u32>,
    /// CSR offsets into `member_ids`, length `nodes + 1`; internal
    /// nodes own empty ranges.
    pub(crate) member_start: Vec<u32>,
    /// Centroid ids owned by each leaf; the leaves partition `0..k`.
    pub(crate) member_ids: Vec<u32>,
    /// `reps[c]` = a training row whose label is `c` (`u32::MAX` if the
    /// cluster is empty) — used to seed graph-ANN search at the routed
    /// entry clusters.  Empty when the model kept no labels.
    pub(crate) reps: Vec<u32>,
}

impl RouteTree {
    /// Build the tree over `centroids` by recursive `branch`-way
    /// 2M-tree splits (largest-first bisection + BKM polish — the same
    /// engine the fits use for initialization).  Deterministic per
    /// `(params.seed, params.threads)`.
    pub fn build(centroids: &VecSet, params: &RouteTreeParams, backend: &Backend) -> RouteTree {
        let k = centroids.rows();
        let dim = centroids.dim();
        assert!(k >= 1, "routing tree over zero centroids");
        assert!(params.branch >= 2, "branch factor must be ≥ 2");
        let branch = params.branch;

        let mut node_vecs: Vec<f32> = Vec::new();
        let mut first_child: Vec<u32> = Vec::new();
        let mut child_count: Vec<u32> = Vec::new();
        let mut member_start: Vec<u32> = vec![0];
        let mut member_ids: Vec<u32> = Vec::new();

        // BFS so children get consecutive ids ⇒ contiguous routing
        // vectors per node (the descent's zero-gather invariant).
        let mut pending: VecDeque<Vec<u32>> = VecDeque::new();
        pending.push_back((0..k as u32).collect());
        let mut next_id = 1usize;
        while let Some(members) = pending.pop_front() {
            let node = first_child.len();
            // routing vector = f64-accumulated mean of member centroids
            let mut acc = vec![0f64; dim];
            for &c in &members {
                for (a, &v) in acc.iter_mut().zip(centroids.row(c as usize)) {
                    *a += f64::from(v);
                }
            }
            let inv = 1.0 / members.len() as f64;
            node_vecs.extend(acc.iter().map(|a| (*a * inv) as f32));

            if members.len() <= branch {
                first_child.push(0);
                child_count.push(0);
                member_ids.extend_from_slice(&members);
            } else {
                let seed = params
                    .seed
                    .wrapping_add((node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let parts =
                    split_members(centroids, &members, branch, seed, params.threads, backend);
                first_child.push(next_id as u32);
                child_count.push(parts.len() as u32);
                next_id += parts.len();
                for p in parts {
                    pending.push_back(p);
                }
            }
            member_start.push(member_ids.len() as u32);
        }

        RouteTree::from_parts(
            dim,
            k,
            branch as u32,
            params.beam.max(1) as u32,
            node_vecs,
            first_child,
            child_count,
            member_start,
            member_ids,
            Vec::new(),
        )
        .expect("freshly built routing tree must validate")
    }

    /// Assemble (and fully validate) a tree from raw parts — the single
    /// constructor both [`build`](RouteTree::build) and the GKMODEL
    /// `RTREE` parser go through, so a hostile artifact can never
    /// produce a structurally unsound tree (descent termination and
    /// slice bounds are all checked here, once).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        dim: usize,
        k: usize,
        branch: u32,
        default_beam: u32,
        node_vecs: Vec<f32>,
        first_child: Vec<u32>,
        child_count: Vec<u32>,
        member_start: Vec<u32>,
        member_ids: Vec<u32>,
        reps: Vec<u32>,
    ) -> Result<RouteTree, String> {
        let nn = child_count.len();
        if dim == 0 || k == 0 {
            return Err("empty routing tree geometry".into());
        }
        if branch < 2 {
            return Err(format!("branch factor {branch} < 2"));
        }
        if default_beam == 0 {
            return Err("beam width 0".into());
        }
        if nn == 0 || first_child.len() != nn || member_start.len() != nn + 1 {
            return Err(format!(
                "inconsistent node arrays: {} nodes, {} first_child, {} member_start",
                nn,
                first_child.len(),
                member_start.len()
            ));
        }
        if node_vecs.len() != nn * dim {
            return Err(format!(
                "routing vectors: {} floats for {nn} nodes × {dim} dims",
                node_vecs.len()
            ));
        }
        if member_ids.len() != k {
            return Err(format!("{} leaf members for k={k}", member_ids.len()));
        }
        if member_start[0] != 0 || member_start[nn] as usize != member_ids.len() {
            return Err("member offsets do not span the member table".into());
        }
        let mut seen = vec![false; k];
        for (node, w) in member_start.windows(2).enumerate() {
            let (a, b) = (w[0] as usize, w[1] as usize);
            if a > b {
                return Err(format!("member offsets decrease at node {node}"));
            }
            let cc = child_count[node] as usize;
            let fc = first_child[node] as usize;
            if cc == 0 {
                if first_child[node] != 0 {
                    return Err(format!("leaf {node} has a first_child"));
                }
            } else {
                // children strictly after the parent (BFS layout):
                // guarantees descent terminates on any loaded artifact.
                if fc <= node || fc + cc > nn {
                    return Err(format!(
                        "node {node}: children [{fc}, {}) out of order or out of range",
                        fc + cc
                    ));
                }
                if a != b {
                    return Err(format!("internal node {node} owns leaf members"));
                }
            }
            for &c in &member_ids[a..b] {
                let c = c as usize;
                if c >= k {
                    return Err(format!("member id {c} ≥ k={k}"));
                }
                if seen[c] {
                    return Err(format!("centroid {c} owned by two leaves"));
                }
                seen[c] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaves do not cover all k centroids".into());
        }
        if !reps.is_empty() && reps.len() != k {
            return Err(format!("{} reps for k={k}", reps.len()));
        }
        let node_norms: Vec<f32> = node_vecs.chunks(dim).map(norm2).collect();
        Ok(RouteTree {
            dim,
            k,
            branch,
            default_beam,
            node_vecs,
            node_norms,
            first_child,
            child_count,
            member_start,
            member_ids,
            reps,
        })
    }

    /// Number of nodes (internal + leaf).
    pub fn nodes(&self) -> usize {
        self.child_count.len()
    }

    /// Longest root→leaf path (root alone = 1).
    pub fn depth(&self) -> usize {
        let mut depth = vec![0u32; self.nodes()];
        depth[0] = 1;
        let mut max = 1;
        // BFS order ⇒ parents precede children, one forward pass suffices
        for n in 0..self.nodes() {
            let (fc, cc) = (self.first_child[n] as usize, self.child_count[n] as usize);
            for c in fc..fc + cc {
                depth[c] = depth[n] + 1;
                max = max.max(depth[c]);
            }
        }
        max as usize
    }

    /// Whether `reps` (routed search seeding) is populated.
    pub fn has_reps(&self) -> bool {
        !self.reps.is_empty()
    }

    /// Attach per-cluster representative rows (first training row of
    /// each cluster), enabling routed seeding of the graph-ANN search.
    pub fn set_reps(&mut self, reps: Vec<u32>) {
        assert!(reps.is_empty() || reps.len() == self.k, "reps must cover all k clusters");
        self.reps = reps;
    }

    /// Beam descent: fill `s.cand` with the candidate centroid ids
    /// (ascending) owned by the best `beam` leaves for query `q`.
    fn descend(&self, q: &[f32], beam: usize, backend: &Backend, s: &mut RouteScratch) {
        debug_assert_eq!(q.len(), self.dim);
        let beam = beam.max(1);
        let qq = norm2(q);
        s.frontier.clear();
        s.frontier.push((0.0, 0));
        loop {
            s.next.clear();
            let mut any_internal = false;
            for &(dd, nid) in s.frontier.iter() {
                let n = nid as usize;
                let cc = self.child_count[n] as usize;
                if cc == 0 {
                    // leaves keep competing against deeper levels
                    s.next.push((dd, nid));
                    continue;
                }
                any_internal = true;
                let fc = self.first_child[n] as usize;
                let block = &self.node_vecs[fc * self.dim..(fc + cc) * self.dim];
                let norms = &self.node_norms[fc..fc + cc];
                s.dists.resize(cc, 0.0);
                backend.candidate_d2(q, qq, block, norms, self.dim, &mut s.dists);
                for (j, &dj) in s.dists.iter().enumerate() {
                    s.next.push((dj, (fc + j) as u32));
                }
            }
            if !any_internal {
                break;
            }
            s.next
                .sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            s.next.truncate(beam);
            std::mem::swap(&mut s.frontier, &mut s.next);
        }
        s.cand.clear();
        for &(_, nid) in s.frontier.iter() {
            let n = nid as usize;
            let (a, b) = (self.member_start[n] as usize, self.member_start[n + 1] as usize);
            s.cand.extend_from_slice(&self.member_ids[a..b]);
        }
        // leaves own disjoint members, so this is dedup-free; ascending
        // order gives the flat scan's lowest-id tie-break within the
        // candidate set (assign_blocks keeps the first strict minimum).
        s.cand.sort_unstable();
    }

    /// Routed nearest-centroid assignment for one query.
    ///
    /// With `beam ≥ k` the candidate set is provably all k centroids
    /// and the evaluation is the verbatim flat
    /// [`Backend::assign_blocks`] scan — bit-identical to unrouted
    /// `predict`.
    pub fn predict_one(
        &self,
        q: &[f32],
        centroids: &VecSet,
        beam: usize,
        backend: &Backend,
        s: &mut RouteScratch,
    ) -> u32 {
        self.descend(q, beam, backend, s);
        let RouteScratch { ref cand, ref mut gather, .. } = *s;
        if cand.len() == self.k {
            return backend.assign_blocks(q, centroids.flat(), self.dim, self.k).idx[0];
        }
        gather.clear();
        for &c in cand.iter() {
            gather.extend_from_slice(centroids.row(c as usize));
        }
        let local = backend.assign_blocks(q, gather, self.dim, cand.len()).idx[0];
        cand[local as usize]
    }

    /// Routed candidate centroids for one query, nearest-first, capped
    /// at `want` — the coarse half of routed graph-ANN seeding.
    /// Distances use [`d2_batch_exact`], ties break on lower id.
    fn top_candidates(
        &self,
        q: &[f32],
        centroids: &VecSet,
        beam: usize,
        want: usize,
        backend: &Backend,
        s: &mut RouteScratch,
    ) -> Vec<u32> {
        self.descend(q, beam, backend, s);
        let RouteScratch { ref cand, ref mut gather, ref mut dists, .. } = *s;
        gather.clear();
        for &c in cand.iter() {
            gather.extend_from_slice(centroids.row(c as usize));
        }
        dists.resize(cand.len(), 0.0);
        d2_batch_exact(q, gather, self.dim, dists);
        let mut order: Vec<(f32, u32)> =
            dists.iter().zip(cand.iter()).map(|(&d, &c)| (d, c)).collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        order.truncate(want.max(1));
        order.into_iter().map(|(_, c)| c).collect()
    }

    /// Append centroid id `k` (the freshly pushed last row of
    /// `centroids`) as a new leaf member — the incremental-extend path
    /// for cells the drift trigger split.  Greedy-descends to the
    /// nearest leaf, inserts the id there, folds the new centroid into
    /// the routing vectors along the descent path, and — once the leaf
    /// outgrows `2·branch` members — re-splits **just that leaf** into
    /// tail-appended child nodes (subtree-local: every other node keeps
    /// its id, vector, and members).  Clears `reps` (stale per-cluster
    /// rows would be indexed out of bounds at the new k); callers
    /// re-attach via [`RouteTree::set_reps`].  Deterministic.
    pub fn insert_centroid(&mut self, centroids: &VecSet, backend: &Backend) {
        assert_eq!(
            centroids.rows(),
            self.k + 1,
            "insert_centroid expects exactly one appended centroid"
        );
        assert_eq!(centroids.dim(), self.dim, "centroid dim mismatch");
        let new_id = self.k as u32;
        let q = centroids.row(self.k);
        let qq = norm2(q);

        // pre-insert subtree member counts: children follow parents, so
        // one reverse pass folds leaves upward
        let nn = self.nodes();
        let mut sizes = vec![0u64; nn];
        for node in (0..nn).rev() {
            let cc = self.child_count[node] as usize;
            sizes[node] = if cc == 0 {
                u64::from(self.member_start[node + 1] - self.member_start[node])
            } else {
                let fc = self.first_child[node] as usize;
                (fc..fc + cc).map(|c| sizes[c]).sum()
            };
        }

        // greedy descent to the nearest leaf (ties break on lower id)
        let mut path = vec![0u32];
        let mut dists: Vec<f32> = Vec::new();
        let mut node = 0usize;
        while self.child_count[node] > 0 {
            let fc = self.first_child[node] as usize;
            let cc = self.child_count[node] as usize;
            let block = &self.node_vecs[fc * self.dim..(fc + cc) * self.dim];
            let norms = &self.node_norms[fc..fc + cc];
            dists.resize(cc, 0.0);
            backend.candidate_d2(q, qq, block, norms, self.dim, &mut dists);
            let mut best = 0usize;
            for (j, &dj) in dists.iter().enumerate().skip(1) {
                if dj < dists[best] {
                    best = j;
                }
            }
            node = fc + best;
            path.push(node as u32);
        }
        let leaf = node;

        // the new id is the largest, so appending at the end of the
        // leaf's range keeps member order intact
        let end = self.member_start[leaf + 1] as usize;
        self.member_ids.insert(end, new_id);
        for ms in self.member_start[leaf + 1..].iter_mut() {
            *ms += 1;
        }
        self.k += 1;

        // fold q into the routing means on the descent path:
        // mean' = (mean·s + q) / (s + 1)
        for &p in &path {
            let p = p as usize;
            let s = sizes[p] as f64;
            let row = &mut self.node_vecs[p * self.dim..(p + 1) * self.dim];
            for (m, &v) in row.iter_mut().zip(q) {
                *m = ((f64::from(*m) * s + f64::from(v)) / (s + 1.0)) as f32;
            }
            self.node_norms[p] = norm2(&self.node_vecs[p * self.dim..(p + 1) * self.dim]);
        }

        // subtree-local re-split once the leaf overflows 2·branch
        let (a, b) = (self.member_start[leaf] as usize, self.member_start[leaf + 1] as usize);
        if b - a > 2 * self.branch as usize {
            let members: Vec<u32> = self.member_ids.drain(a..b).collect();
            let removed = members.len() as u32;
            for ms in self.member_start[leaf + 1..].iter_mut() {
                *ms -= removed;
            }
            let seed = 20170707u64
                .wrapping_add((self.nodes() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let parts = split_members(centroids, &members, self.branch as usize, seed, 1, backend);
            self.first_child[leaf] = self.nodes() as u32;
            self.child_count[leaf] = parts.len() as u32;
            for part in parts {
                let mut acc = vec![0f64; self.dim];
                for &c in &part {
                    for (av, &v) in acc.iter_mut().zip(centroids.row(c as usize)) {
                        *av += f64::from(v);
                    }
                }
                let inv = 1.0 / part.len() as f64;
                let start = self.node_vecs.len();
                self.node_vecs.extend(acc.iter().map(|av| (*av * inv) as f32));
                self.node_norms.push(norm2(&self.node_vecs[start..]));
                self.first_child.push(0);
                self.child_count.push(0);
                self.member_ids.extend_from_slice(&part);
                self.member_start.push(self.member_ids.len() as u32);
            }
        }
        self.reps.clear();

        #[cfg(debug_assertions)]
        RouteTree::from_parts(
            self.dim,
            self.k,
            self.branch,
            self.default_beam,
            self.node_vecs.clone(),
            self.first_child.clone(),
            self.child_count.clone(),
            self.member_start.clone(),
            self.member_ids.clone(),
            Vec::new(),
        )
        .expect("insert_centroid must preserve tree invariants");
    }

    /// Entry rows for routed graph-ANN search: descend to the nearest
    /// clusters and return each one's representative training row.
    /// Empty when `reps` is absent (caller falls back to random
    /// entries) or every routed cluster is empty.
    pub fn seed_rows(
        &self,
        q: &[f32],
        centroids: &VecSet,
        beam: usize,
        entries: usize,
        backend: &Backend,
        s: &mut RouteScratch,
    ) -> Vec<u32> {
        if self.reps.is_empty() {
            return Vec::new();
        }
        self.top_candidates(q, centroids, beam, entries.max(1), backend, s)
            .into_iter()
            .filter_map(|c| {
                let r = self.reps[c as usize];
                (r != u32::MAX).then_some(r)
            })
            .collect()
    }
}

/// `reps[c]` = lowest training row labelled `c` (`u32::MAX` for empty
/// clusters) — the routed search's per-cluster graph entry points.
pub fn reps_from_labels(labels: &[u32], k: usize) -> Vec<u32> {
    let mut reps = vec![u32::MAX; k];
    for (i, &l) in labels.iter().enumerate() {
        let l = l as usize;
        if l < k && reps[l] == u32::MAX {
            reps[l] = i as u32;
        }
    }
    reps
}

/// Partition `members` into ≤ `branch` non-empty groups by running the
/// 2M-tree initializer over the gathered member centroids.  Falls back
/// to an equal-size chunked split if the bisection degenerates (e.g.
/// all-duplicate centroids).
fn split_members(
    centroids: &VecSet,
    members: &[u32],
    branch: usize,
    seed: u64,
    threads: usize,
    backend: &Backend,
) -> Vec<Vec<u32>> {
    let idx: Vec<usize> = members.iter().map(|&c| c as usize).collect();
    let sub = centroids.gather(&idx);
    let params = TwoMeansParams { seed, threads, ..Default::default() };
    let labels = two_means::run(&sub, branch, &params, backend);
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); branch];
    for (i, &l) in labels.iter().enumerate() {
        parts[l as usize].push(members[i]);
    }
    parts.retain(|p| !p.is_empty());
    if parts.len() < 2 {
        let chunk = members.len().div_ceil(branch).max(1);
        parts = members.chunks(chunk).map(<[u32]>::to_vec).collect();
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_centroids(k: usize, d: usize, seed: u64) -> VecSet {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0f32; k * d];
        for v in flat.iter_mut() {
            *v = rng.f32() * 2.0 - 1.0;
        }
        VecSet::from_flat(d, flat)
    }

    fn flat_argmin(q: &[f32], c: &VecSet) -> u32 {
        Backend::Native.assign_blocks(q, c.flat(), c.dim(), c.rows()).idx[0]
    }

    #[test]
    fn build_produces_valid_partition_and_contiguous_children() {
        let c = random_centroids(300, 24, 7);
        let params = RouteTreeParams { branch: 8, ..Default::default() };
        let t = RouteTree::build(&c, &params, &Backend::Native);
        assert_eq!(t.k, 300);
        assert_eq!(t.dim, 24);
        assert!(t.depth() >= 2);
        // from_parts already revalidated the partition; spot-check the
        // BFS child-contiguity invariant drives real fan-out
        let internal = t.child_count.iter().filter(|&&cc| cc > 0).count();
        assert!(internal >= 1);
        assert_eq!(t.member_ids.len(), 300);
    }

    #[test]
    fn beam_at_least_k_routes_to_every_centroid() {
        let c = random_centroids(150, 16, 11);
        let t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 5, ..Default::default() },
            &Backend::Native,
        );
        let mut s = RouteScratch::new();
        let q: Vec<f32> = c.row(3).to_vec();
        t.descend(&q, t.k, &Backend::Native, &mut s);
        assert_eq!(s.cand.len(), t.k, "untruncated beam must reach every leaf");
        assert!(s.cand.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn full_beam_predict_is_bit_identical_to_flat() {
        let c = random_centroids(200, 32, 3);
        let t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 6, ..Default::default() },
            &Backend::Native,
        );
        let mut s = RouteScratch::new();
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let mut q = vec![0f32; 32];
            for v in q.iter_mut() {
                *v = rng.f32() * 2.0 - 1.0;
            }
            let routed = t.predict_one(&q, &c, t.k, &Backend::Native, &mut s);
            assert_eq!(routed, flat_argmin(&q, &c));
        }
    }

    #[test]
    fn default_beam_finds_exact_centroid_queries() {
        // querying a centroid itself must route back to it: its leaf's
        // routing ancestors are the nearest at every level
        let c = random_centroids(128, 16, 21);
        let t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 4, ..Default::default() },
            &Backend::Native,
        );
        let mut s = RouteScratch::new();
        let mut hits = 0;
        for i in 0..128 {
            if t.predict_one(c.row(i), &c, DEFAULT_BEAM, &Backend::Native, &mut s) == i as u32 {
                hits += 1;
            }
        }
        assert!(hits >= 122, "only {hits}/128 centroid self-queries routed home");
    }

    #[test]
    fn build_is_deterministic() {
        let c = random_centroids(120, 8, 5);
        let p = RouteTreeParams { branch: 4, ..Default::default() };
        let a = RouteTree::build(&c, &p, &Backend::Native);
        let b = RouteTree::build(&c, &p, &Backend::Native);
        assert_eq!(a, b);
    }

    #[test]
    fn duplicate_centroids_fall_back_to_chunked_split() {
        let c = VecSet::from_flat(4, vec![1.0; 40 * 4]);
        let t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 4, ..Default::default() },
            &Backend::Native,
        );
        assert_eq!(t.member_ids.len(), 40);
        let mut s = RouteScratch::new();
        let lbl = t.predict_one(&[1.0; 4], &c, DEFAULT_BEAM, &Backend::Native, &mut s);
        assert!(lbl < 40);
    }

    #[test]
    fn from_parts_rejects_malformed_trees() {
        let c = random_centroids(50, 8, 1);
        let t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 4, ..Default::default() },
            &Backend::Native,
        );
        // child pointing at or before its parent (cycle risk)
        let mut fc = t.first_child.clone();
        let node = t.child_count.iter().position(|&cc| cc > 0).unwrap();
        fc[node] = node as u32;
        assert!(RouteTree::from_parts(
            t.dim,
            t.k,
            t.branch,
            t.default_beam,
            t.node_vecs.clone(),
            fc,
            t.child_count.clone(),
            t.member_start.clone(),
            t.member_ids.clone(),
            Vec::new(),
        )
        .is_err());
        // duplicated member
        let mut mid = t.member_ids.clone();
        mid[0] = mid[1];
        assert!(RouteTree::from_parts(
            t.dim,
            t.k,
            t.branch,
            t.default_beam,
            t.node_vecs.clone(),
            t.first_child.clone(),
            t.child_count.clone(),
            t.member_start.clone(),
            mid,
            Vec::new(),
        )
        .is_err());
        // reps of the wrong length
        assert!(RouteTree::from_parts(
            t.dim,
            t.k,
            t.branch,
            t.default_beam,
            t.node_vecs.clone(),
            t.first_child.clone(),
            t.child_count.clone(),
            t.member_start.clone(),
            t.member_ids.clone(),
            vec![0; 3],
        )
        .is_err());
    }

    #[test]
    fn insert_centroid_appends_leaf_member_and_keeps_routing_exact() {
        let mut c = random_centroids(100, 12, 31);
        let mut t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 5, ..Default::default() },
            &Backend::Native,
        );
        t.set_reps((0..100).collect());
        c.push_row(&vec![0.75; 12]);
        t.insert_centroid(&c, &Backend::Native);
        assert_eq!(t.k, 101);
        assert_eq!(t.member_ids.len(), 101);
        assert!(!t.has_reps(), "stale reps must be dropped");
        // full-beam routed predict stays bit-identical to flat over the
        // grown centroid set — the partition still covers 0..k
        let mut s = RouteScratch::new();
        let mut rng = Rng::new(5);
        for _ in 0..25 {
            let mut q = vec![0f32; 12];
            for v in q.iter_mut() {
                *v = rng.f32() * 2.0 - 1.0;
            }
            assert_eq!(t.predict_one(&q, &c, t.k, &Backend::Native, &mut s), flat_argmin(&q, &c));
        }
        // the new centroid routes home under the default beam
        assert_eq!(t.predict_one(c.row(100), &c, DEFAULT_BEAM, &Backend::Native, &mut s), 100);
    }

    #[test]
    fn insert_centroid_resplits_overflowing_leaf_locally() {
        // tiny branch so repeated inserts overflow a leaf quickly
        let mut c = random_centroids(6, 8, 17);
        let mut t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 2, ..Default::default() },
            &Backend::Native,
        );
        let nodes_before = t.nodes();
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let mut row = vec![0f32; 8];
            for v in row.iter_mut() {
                *v = rng.f32() * 2.0 - 1.0;
            }
            c.push_row(&row);
            t.insert_centroid(&c, &Backend::Native);
        }
        assert_eq!(t.k, 26);
        assert!(t.nodes() > nodes_before, "overflowing leaves must re-split");
        // every leaf honours the 2·branch cap after local re-splits
        for n in 0..t.nodes() {
            if t.child_count[n] == 0 {
                let m = (t.member_start[n + 1] - t.member_start[n]) as usize;
                assert!(m <= 2 * t.branch as usize, "leaf {n} holds {m} members");
            }
        }
        let mut s = RouteScratch::new();
        for i in 0..26 {
            assert_eq!(
                t.predict_one(c.row(i), &c, t.k, &Backend::Native, &mut s),
                flat_argmin(c.row(i), &c)
            );
        }
    }

    #[test]
    fn reps_from_labels_picks_first_row_per_cluster() {
        let reps = reps_from_labels(&[2, 0, 2, 1], 4);
        assert_eq!(reps, vec![1, 3, 0, u32::MAX]);
    }

    #[test]
    fn seed_rows_maps_through_reps_and_skips_empties() {
        let c = random_centroids(60, 8, 13);
        let mut t = RouteTree::build(
            &c,
            &RouteTreeParams { branch: 4, ..Default::default() },
            &Backend::Native,
        );
        let mut s = RouteScratch::new();
        assert!(t.seed_rows(c.row(0), &c, DEFAULT_BEAM, 4, &Backend::Native, &mut s).is_empty());
        let mut reps = vec![u32::MAX; 60];
        for (i, r) in reps.iter_mut().enumerate().skip(1) {
            *r = (i * 10) as u32;
        }
        t.set_reps(reps);
        let rows = t.seed_rows(c.row(5), &c, t.k, 60, &Backend::Native, &mut s);
        // cluster 0 has no rep and must be skipped
        assert_eq!(rows.len(), 59);
        assert!(rows.iter().all(|&r| r % 10 == 0 && r > 0));
    }
}
