//! The [`Clusterer`] trait and the seven typed method configs.
//!
//! Each config owns the knobs that are *about the method* (k, κ, ξ, τ,
//! batch size, tree count…) and exposes builder-style setters; everything
//! about *how to run* (backend, threads, seed, iteration control,
//! progress) comes from the shared [`RunContext`] at fit time.  That
//! split replaces the old duplicated `{seed, threads, max_iters, …}`
//! fields every params struct used to carry.

use crate::coordinator::job::Method;
use crate::data::matrix::VecSet;
use crate::data::store::VecStore;
use crate::gkm::{construct, gkmeans, variant};
use crate::graph::nn_descent;
use crate::kmeans::{boost, closure, lloyd, minibatch};
use crate::model::{FittedModel, RunContext};
use crate::util::timer::Timer;

/// A clustering method that can be fitted to a dataset.
///
/// Implementations are plain config values; `fit` consumes nothing and
/// may be called repeatedly (e.g. over seeds via
/// [`RunContext::seed`]).
pub trait Clusterer {
    /// The [`Method`] this config trains.
    fn method(&self) -> Method;

    /// Human-readable method name (the paper's label).
    fn name(&self) -> &'static str {
        self.method().name()
    }

    /// Train on a resident dataset under `ctx`, producing a
    /// [`FittedModel`].  Equivalent to [`Clusterer::fit_store`] on the
    /// in-RAM store (bit-identical at `threads = 1`).
    fn fit(&self, data: &VecSet, ctx: &RunContext) -> FittedModel {
        self.fit_store(data, ctx)
    }

    /// Train on any [`VecStore`] under `ctx`.  Every method — the graph
    /// methods, Lloyd, Mini-Batch, Boost, and Closure k-means — streams
    /// a disk-backed store through planned cursors (out-of-core), with
    /// the random-access scans visiting rows in the locality-aware order
    /// [`RunContext::scan_order`] selects.
    ///
    /// ```
    /// use gkmeans::data::synth::{blobs, BlobSpec};
    /// use gkmeans::model::{Clusterer, Lloyd, RunContext};
    /// use gkmeans::runtime::Backend;
    ///
    /// let data = blobs(&BlobSpec::quick(120, 6, 3), 1);
    /// let backend = Backend::native();
    /// let ctx = RunContext::new(&backend).max_iters(4);
    /// // a resident `VecSet` is a `VecStore` too; a disk-backed
    /// // `ChunkedVecStore` streams through the exact same call
    /// let model = Lloyd::new(3).fit_store(&data, &ctx);
    /// assert_eq!(model.labels.len(), 120);
    /// assert_eq!(model.k, 3);
    /// ```
    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel;
}

/// Clamp k to the dataset size (a 5-point dataset cannot hold 8 clusters).
fn clamp_k(k: usize, data: &dyn VecStore) -> usize {
    k.min(data.rows()).max(1)
}

/// Alg. 3 construction params shared by both graph-building configs
/// ([`GkMeans`], [`GkMeansStar`]): method knobs from the config, run
/// knobs from the context.
fn alg3_params(
    kappa: usize,
    xi: usize,
    tau: usize,
    ctx: &RunContext,
) -> construct::ConstructParams {
    construct::ConstructParams {
        kappa,
        xi,
        tau,
        seed: ctx.seed,
        threads: ctx.threads,
        scan_order: ctx.scan_order,
    }
}

/// Traditional k-means (Lloyd) with k-means++ seeding.
#[derive(Debug, Clone)]
pub struct Lloyd {
    k: usize,
}

impl Lloyd {
    pub fn new(k: usize) -> Lloyd {
        Lloyd { k }
    }
}

impl Clusterer for Lloyd {
    fn method(&self) -> Method {
        Method::Lloyd
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let out = lloyd::run_core(data, clamp_k(self.k, data), &ctx.kmeans_params(), ctx.backend);
        FittedModel::from_output(Method::Lloyd, data, ctx, out, None, 0.0)
    }
}

/// Boost k-means (BKM) — incremental Δℐ optimization, the quality
/// reference.
#[derive(Debug, Clone)]
pub struct Boost {
    k: usize,
}

impl Boost {
    pub fn new(k: usize) -> Boost {
        Boost { k }
    }
}

impl Clusterer for Boost {
    fn method(&self) -> Method {
        Method::Boost
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let out = boost::run_core(data, clamp_k(self.k, data), &ctx.kmeans_params(), ctx.backend);
        FittedModel::from_output(Method::Boost, data, ctx, out, None, 0.0)
    }
}

/// Mini-Batch k-means (Sculley) — the web-scale baseline.
#[derive(Debug, Clone)]
pub struct MiniBatch {
    k: usize,
    batch: usize,
}

impl MiniBatch {
    pub fn new(k: usize) -> MiniBatch {
        MiniBatch { k, batch: minibatch::MiniBatchParams::default().batch }
    }

    /// Samples per batch step.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }
}

impl Clusterer for MiniBatch {
    fn method(&self) -> Method {
        Method::MiniBatch
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let params =
            minibatch::MiniBatchParams { batch: self.batch, base: ctx.kmeans_params() };
        let out = minibatch::run_core(data, clamp_k(self.k, data), &params, ctx.backend);
        FittedModel::from_output(Method::MiniBatch, data, ctx, out, None, 0.0)
    }
}

/// Closure k-means (Wang et al.) — the strongest fast baseline.
#[derive(Debug, Clone)]
pub struct ClosureKmeans {
    k: usize,
    trees: usize,
    leaf_max: usize,
}

impl ClosureKmeans {
    pub fn new(k: usize) -> ClosureKmeans {
        let d = closure::ClosureParams::default();
        ClosureKmeans { k, trees: d.trees, leaf_max: d.leaf_max }
    }

    /// Number of independent random-partition trees.
    pub fn trees(mut self, trees: usize) -> Self {
        self.trees = trees;
        self
    }

    /// Maximum leaf size of each tree.
    pub fn leaf_max(mut self, leaf_max: usize) -> Self {
        self.leaf_max = leaf_max;
        self
    }
}

impl Clusterer for ClosureKmeans {
    fn method(&self) -> Method {
        Method::Closure
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let params = closure::ClosureParams {
            trees: self.trees,
            leaf_max: self.leaf_max,
            base: ctx.kmeans_params(),
        };
        let out = closure::run_core(data, clamp_k(self.k, data), &params, ctx.backend);
        FittedModel::from_output(Method::Closure, data, ctx, out, None, 0.0)
    }
}

/// GK-means (the paper): Alg. 3 builds the KNN graph, Alg. 2 clusters
/// with it on the Δℐ (boost) core.  The fitted model keeps the graph.
#[derive(Debug, Clone)]
pub struct GkMeans {
    k: usize,
    kappa: usize,
    xi: usize,
    tau: usize,
}

impl GkMeans {
    pub fn new(k: usize) -> GkMeans {
        let d = construct::ConstructParams::default();
        GkMeans { k, kappa: d.kappa, xi: d.xi, tau: d.tau }
    }

    /// Graph scale κ (neighbors kept and consulted per sample).
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }

    /// Cell size ξ for the intertwined graph construction.
    pub fn xi(mut self, xi: usize) -> Self {
        self.xi = xi;
        self
    }

    /// Construction rounds τ (10 for clustering, up to 32 for ANNS).
    pub fn tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }
}

impl Clusterer for GkMeans {
    fn method(&self) -> Method {
        Method::GkMeans
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let timer = Timer::start();
        let build =
            construct::build(data, &alg3_params(self.kappa, self.xi, self.tau, ctx), ctx.backend);
        let graph_seconds = timer.elapsed_s();
        let params = gkmeans::GkMeansParams { kappa: self.kappa, base: ctx.kmeans_params() };
        let out =
            gkmeans::run_core(data, clamp_k(self.k, data), &build.graph, &params, ctx.backend);
        FittedModel::from_output(Method::GkMeans, data, ctx, out, Some(build.graph), graph_seconds)
    }
}

/// GK-means\* — Alg. 2 on a *traditional* k-means core (Fig. 4's second
/// configuration): faster convergence per epoch, visibly higher final
/// distortion.
#[derive(Debug, Clone)]
pub struct GkMeansStar {
    k: usize,
    kappa: usize,
    xi: usize,
    tau: usize,
}

impl GkMeansStar {
    pub fn new(k: usize) -> GkMeansStar {
        let d = construct::ConstructParams::default();
        GkMeansStar { k, kappa: d.kappa, xi: d.xi, tau: d.tau }
    }

    /// Graph scale κ.
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }

    /// Cell size ξ.
    pub fn xi(mut self, xi: usize) -> Self {
        self.xi = xi;
        self
    }

    /// Construction rounds τ.
    pub fn tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }
}

impl Clusterer for GkMeansStar {
    fn method(&self) -> Method {
        Method::GkMeansTrad
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let timer = Timer::start();
        let build =
            construct::build(data, &alg3_params(self.kappa, self.xi, self.tau, ctx), ctx.backend);
        let graph_seconds = timer.elapsed_s();
        let params = gkmeans::GkMeansParams { kappa: self.kappa, base: ctx.kmeans_params() };
        let out =
            variant::run_core(data, clamp_k(self.k, data), &build.graph, &params, ctx.backend);
        FittedModel::from_output(
            Method::GkMeansTrad,
            data,
            ctx,
            out,
            Some(build.graph),
            graph_seconds,
        )
    }
}

/// GK-means driven by an NN-Descent graph ("KGraph+GK-means"): same
/// optimization core, different graph builder.
#[derive(Debug, Clone)]
pub struct KGraphGkMeans {
    k: usize,
    kappa: usize,
}

impl KGraphGkMeans {
    pub fn new(k: usize) -> KGraphGkMeans {
        KGraphGkMeans { k, kappa: construct::ConstructParams::default().kappa }
    }

    /// Graph scale κ.
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }
}

impl Clusterer for KGraphGkMeans {
    fn method(&self) -> Method {
        Method::KGraphGkMeans
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let timer = Timer::start();
        let graph = nn_descent::build(
            data,
            self.kappa,
            &nn_descent::NnDescentParams {
                seed: ctx.seed,
                threads: ctx.threads,
                scan_order: ctx.scan_order,
                ..Default::default()
            },
        );
        let graph_seconds = timer.elapsed_s();
        let params = gkmeans::GkMeansParams { kappa: self.kappa, base: ctx.kmeans_params() };
        let out = gkmeans::run_core(data, clamp_k(self.k, data), &graph, &params, ctx.backend);
        FittedModel::from_output(Method::KGraphGkMeans, data, ctx, out, Some(graph), graph_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::runtime::Backend;

    #[test]
    fn all_seven_configs_fit() {
        let data = blobs(&BlobSpec::quick(400, 6, 8), 1);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(5);
        let configs: Vec<Box<dyn Clusterer>> = vec![
            Box::new(Lloyd::new(8)),
            Box::new(Boost::new(8)),
            Box::new(MiniBatch::new(8).batch(128)),
            Box::new(ClosureKmeans::new(8).trees(2)),
            Box::new(GkMeans::new(8).kappa(8).tau(3).xi(25)),
            Box::new(GkMeansStar::new(8).kappa(8).tau(3).xi(25)),
            Box::new(KGraphGkMeans::new(8).kappa(8)),
        ];
        for c in &configs {
            let m = c.fit(&data, &ctx);
            assert_eq!(m.method, c.method(), "{}", c.name());
            assert_eq!(m.labels.len(), 400, "{}", c.name());
            assert_eq!(m.k, 8, "{}", c.name());
            assert_eq!(m.centroids.rows(), 8, "{}", c.name());
            assert!(m.distortion().is_finite(), "{}", c.name());
            m.check_time_accounting().unwrap();
            let graphy = matches!(
                c.method(),
                Method::GkMeans | Method::GkMeansTrad | Method::KGraphGkMeans
            );
            assert_eq!(m.graph.is_some(), graphy, "{}", c.name());
            assert_eq!(m.graph_seconds > 0.0, graphy, "{}", c.name());
        }
    }

    #[test]
    fn k_is_clamped_to_n() {
        let data = blobs(&BlobSpec::quick(20, 3, 2), 2);
        let b = Backend::native();
        let m = Lloyd::new(500).fit(&data, &RunContext::new(&b).max_iters(3));
        assert_eq!(m.k, 20);
    }

    #[test]
    fn progress_callback_sees_every_epoch() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let data = blobs(&BlobSpec::quick(200, 4, 4), 3);
        let b = Backend::native();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let ctx = RunContext::new(&b).max_iters(4).on_progress(move |name, _| {
            assert_eq!(name, "boost k-means");
            c.fetch_add(1, Ordering::Relaxed);
        });
        let m = Boost::new(4).fit(&data, &ctx);
        assert_eq!(count.load(Ordering::Relaxed), m.history.len());
    }

    #[test]
    fn fit_is_deterministic_for_fixed_seed() {
        let data = blobs(&BlobSpec::quick(300, 5, 6), 4);
        let b = Backend::native();
        let cfg = GkMeans::new(6).kappa(6).tau(2).xi(25);
        let a = cfg.fit(&data, &RunContext::new(&b).seed(5));
        let c = cfg.fit(&data, &RunContext::new(&b).seed(5));
        assert_eq!(a.labels, c.labels);
        for (x, y) in a.centroids.flat().iter().zip(c.centroids.flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
