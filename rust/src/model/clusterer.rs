//! The [`Clusterer`] trait and the seven typed method configs.
//!
//! Each config owns the knobs that are *about the method* (k, κ, ξ, τ,
//! batch size, tree count…) and exposes builder-style setters; everything
//! about *how to run* (backend, threads, seed, iteration control,
//! progress) comes from the shared [`RunContext`] at fit time.  That
//! split replaces the old duplicated `{seed, threads, max_iters, …}`
//! fields every params struct used to carry.

use crate::coordinator::job::Method;
use crate::data::matrix::VecSet;
use crate::data::store::VecStore;
use crate::gkm::{construct, gkmeans, variant};
use crate::graph::knn::KnnGraph;
use crate::graph::nn_descent;
use crate::kmeans::common::{EpochState, FitHooks, IterStat, KmeansOutput};
use crate::kmeans::{boost, closure, lloyd, minibatch};
use crate::model::checkpoint::{self, CheckpointState};
use crate::model::{FittedModel, RunContext};
use crate::util::timer::Timer;

/// A clustering method that can be fitted to a dataset.
///
/// Implementations are plain config values; `fit` consumes nothing and
/// may be called repeatedly (e.g. over seeds via
/// [`RunContext::seed`]).
pub trait Clusterer {
    /// The [`Method`] this config trains.
    fn method(&self) -> Method;

    /// Human-readable method name (the paper's label).
    fn name(&self) -> &'static str {
        self.method().name()
    }

    /// Train on a resident dataset under `ctx`, producing a
    /// [`FittedModel`].  Equivalent to [`Clusterer::fit_store`] on the
    /// in-RAM store (bit-identical at `threads = 1`).
    fn fit(&self, data: &VecSet, ctx: &RunContext) -> FittedModel {
        self.fit_store(data, ctx)
    }

    /// Train on any [`VecStore`] under `ctx`.  Every method — the graph
    /// methods, Lloyd, Mini-Batch, Boost, and Closure k-means — streams
    /// a disk-backed store through planned cursors (out-of-core), with
    /// the random-access scans visiting rows in the locality-aware order
    /// [`RunContext::scan_order`] selects.
    ///
    /// ```
    /// use gkmeans::data::synth::{blobs, BlobSpec};
    /// use gkmeans::model::{Clusterer, Lloyd, RunContext};
    /// use gkmeans::runtime::Backend;
    ///
    /// let data = blobs(&BlobSpec::quick(120, 6, 3), 1);
    /// let backend = Backend::native();
    /// let ctx = RunContext::new(&backend).max_iters(4);
    /// // a resident `VecSet` is a `VecStore` too; a disk-backed
    /// // `ChunkedVecStore` streams through the exact same call
    /// let model = Lloyd::new(3).fit_store(&data, &ctx);
    /// assert_eq!(model.labels.len(), 120);
    /// assert_eq!(model.k, 3);
    /// ```
    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel;
}

/// Clamp k to the dataset size (a 5-point dataset cannot hold 8 clusters).
fn clamp_k(k: usize, data: &dyn VecStore) -> usize {
    k.min(data.rows()).max(1)
}

/// Alg. 3 construction params shared by both graph-building configs
/// ([`GkMeans`], [`GkMeansStar`]): method knobs from the config, run
/// knobs from the context.
fn alg3_params(
    kappa: usize,
    xi: usize,
    tau: usize,
    ctx: &RunContext,
) -> construct::ConstructParams {
    construct::ConstructParams {
        kappa,
        xi,
        tau,
        seed: ctx.seed,
        threads: ctx.threads,
        scan_order: ctx.scan_order,
    }
}

/// Load + validate the resume checkpoint for a job, when the context
/// asks for one.  A missing file means "start fresh" (the first run of a
/// job that will checkpoint); a corrupt or job-mismatched checkpoint is
/// a hard, actionable panic — silently refitting from scratch would hide
/// exactly the failure the operator asked to recover from.
fn load_resume(
    ctx: &RunContext,
    method: Method,
    k: usize,
    dim: usize,
    n_train: usize,
) -> Option<CheckpointState> {
    if !ctx.resume {
        return None;
    }
    let cfg = ctx.checkpoint.as_ref().expect(
        "RunContext::resume(true) needs RunContext::checkpoint(dir, every) \
         to name the checkpoint directory",
    );
    let path = checkpoint::checkpoint_path(&cfg.dir);
    if !path.exists() {
        crate::log_info!("no checkpoint at {}; starting fresh", path.display());
        return None;
    }
    let state = checkpoint::load(&path).unwrap_or_else(|e| panic!("cannot resume: {e}"));
    state
        .validate(method, k, dim, n_train, ctx.seed)
        .unwrap_or_else(|e| panic!("cannot resume from {}: {e}", path.display()));
    crate::log_info!(
        "resuming {} fit from {} at epoch {}",
        method.name(),
        path.display(),
        state.next_iter
    );
    Some(state)
}

/// Run a hooked engine under the context's fit instrumentation: stream
/// each epoch stat (wall-clock folded) through the progress callback,
/// write a checkpoint after every `every`-th completed epoch, and feed
/// the engine a resume point when the context carries one.  Returns the
/// engine output plus `Some((graph_seconds, init_seconds))` — the
/// original run's clocks — when the fit resumed from a checkpoint.
fn fit_hooked(
    method: Method,
    data: &dyn VecStore,
    ctx: &RunContext,
    k: usize,
    graph_seconds: f64,
    run: impl FnOnce(&mut FitHooks<'_>) -> KmeansOutput,
) -> (KmeansOutput, Option<(f64, f64)>) {
    let (dim, n_train) = (data.dim(), data.rows());
    let resume = load_resume(ctx, method, k, dim, n_train);
    let resumed_clocks = resume.as_ref().map(|s| (s.graph_seconds, s.init_seconds));
    // the clocks a checkpoint written by *this* run reports: a fresh fit
    // measures its own graph share and lets the engine contribute the
    // seeding time; a resumed fit carries the original values forward
    let (ckpt_graph, init_override) = match resumed_clocks {
        Some((g, i)) => (g, Some(i)),
        None => (graph_seconds, None),
    };
    let mut hook = move |state: &EpochState<'_>| {
        let folded = IterStat {
            iter: state.stat.iter,
            seconds: state.stat.seconds + state.seconds_offset,
            distortion: state.stat.distortion,
            moves: state.stat.moves,
        };
        ctx.emit(method.name(), &folded);
        if let Some(cfg) = &ctx.checkpoint {
            if state.completed_epoch > 0 && state.completed_epoch % cfg.every == 0 {
                let mut history = state.history.to_vec();
                for h in history.iter_mut() {
                    h.seconds += state.seconds_offset;
                }
                let snap = CheckpointState {
                    method,
                    k,
                    dim,
                    n_train,
                    seed: ctx.seed,
                    next_iter: state.completed_epoch + 1,
                    rng: state.rng,
                    history,
                    labels: state.labels.to_vec(),
                    composite: state.composite.map(|v| v.to_vec()),
                    counts: state.counts.map(|v| v.to_vec()),
                    comp_norm2: state.comp_norm2.map(|v| v.to_vec()),
                    centroids: state.centroids.map(|v| v.to_vec()),
                    init_seconds: init_override
                        .unwrap_or(state.init_seconds + ckpt_graph),
                    graph_seconds: ckpt_graph,
                };
                // checkpointing is belt-and-braces: a full disk must not
                // kill the healthy fit it was meant to protect
                if let Err(e) = checkpoint::save(&snap, &cfg.dir) {
                    crate::log_warn!("checkpoint write failed (fit continues): {e}");
                }
            }
        }
    };
    let mut hooks = FitHooks {
        on_epoch: Some(&mut hook),
        seconds_offset: if resumed_clocks.is_some() { 0.0 } else { graph_seconds },
        init_seconds: 0.0,
        resume: resume.map(|s| s.into_resume_point()),
    };
    let out = run(&mut hooks);
    (out, resumed_clocks)
}

/// Assemble the [`FittedModel`] for a hooked fit: the streamed fresh
/// path folds the graph clock, the resumed path restores the original
/// run's clocks verbatim.
fn assemble(
    method: Method,
    data: &dyn VecStore,
    ctx: &RunContext,
    out: KmeansOutput,
    graph: Option<KnnGraph>,
    graph_seconds: f64,
    resumed: Option<(f64, f64)>,
) -> FittedModel {
    match resumed {
        Some((g, i)) => FittedModel::from_resumed(method, data, ctx, out, graph, g, i),
        None => FittedModel::from_output_streamed(method, data, ctx, out, graph, graph_seconds),
    }
}

/// Traditional k-means (Lloyd) with k-means++ seeding.
#[derive(Debug, Clone)]
pub struct Lloyd {
    k: usize,
}

impl Lloyd {
    pub fn new(k: usize) -> Lloyd {
        Lloyd { k }
    }
}

impl Clusterer for Lloyd {
    fn method(&self) -> Method {
        Method::Lloyd
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let k = clamp_k(self.k, data);
        let params = ctx.kmeans_params();
        let (out, resumed) = fit_hooked(Method::Lloyd, data, ctx, k, 0.0, |hooks| {
            lloyd::run_core_hooked(data, k, &params, ctx.backend, hooks)
        });
        assemble(Method::Lloyd, data, ctx, out, None, 0.0, resumed)
    }
}

/// Boost k-means (BKM) — incremental Δℐ optimization, the quality
/// reference.
#[derive(Debug, Clone)]
pub struct Boost {
    k: usize,
}

impl Boost {
    pub fn new(k: usize) -> Boost {
        Boost { k }
    }
}

impl Clusterer for Boost {
    fn method(&self) -> Method {
        Method::Boost
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let k = clamp_k(self.k, data);
        let params = ctx.kmeans_params();
        let (out, resumed) = fit_hooked(Method::Boost, data, ctx, k, 0.0, |hooks| {
            boost::run_core_hooked(data, k, &params, ctx.backend, hooks)
        });
        assemble(Method::Boost, data, ctx, out, None, 0.0, resumed)
    }
}

/// Mini-Batch k-means (Sculley) — the web-scale baseline.
#[derive(Debug, Clone)]
pub struct MiniBatch {
    k: usize,
    batch: usize,
}

impl MiniBatch {
    pub fn new(k: usize) -> MiniBatch {
        MiniBatch { k, batch: minibatch::MiniBatchParams::default().batch }
    }

    /// Samples per batch step.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }
}

impl Clusterer for MiniBatch {
    fn method(&self) -> Method {
        Method::MiniBatch
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let params =
            minibatch::MiniBatchParams { batch: self.batch, base: ctx.kmeans_params() };
        let out = minibatch::run_core(data, clamp_k(self.k, data), &params, ctx.backend);
        FittedModel::from_output(Method::MiniBatch, data, ctx, out, None, 0.0)
    }
}

/// Closure k-means (Wang et al.) — the strongest fast baseline.
#[derive(Debug, Clone)]
pub struct ClosureKmeans {
    k: usize,
    trees: usize,
    leaf_max: usize,
}

impl ClosureKmeans {
    pub fn new(k: usize) -> ClosureKmeans {
        let d = closure::ClosureParams::default();
        ClosureKmeans { k, trees: d.trees, leaf_max: d.leaf_max }
    }

    /// Number of independent random-partition trees.
    pub fn trees(mut self, trees: usize) -> Self {
        self.trees = trees;
        self
    }

    /// Maximum leaf size of each tree.
    pub fn leaf_max(mut self, leaf_max: usize) -> Self {
        self.leaf_max = leaf_max;
        self
    }
}

impl Clusterer for ClosureKmeans {
    fn method(&self) -> Method {
        Method::Closure
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let params = closure::ClosureParams {
            trees: self.trees,
            leaf_max: self.leaf_max,
            base: ctx.kmeans_params(),
        };
        let out = closure::run_core(data, clamp_k(self.k, data), &params, ctx.backend);
        FittedModel::from_output(Method::Closure, data, ctx, out, None, 0.0)
    }
}

/// GK-means (the paper): Alg. 3 builds the KNN graph, Alg. 2 clusters
/// with it on the Δℐ (boost) core.  The fitted model keeps the graph.
#[derive(Debug, Clone)]
pub struct GkMeans {
    k: usize,
    kappa: usize,
    xi: usize,
    tau: usize,
}

impl GkMeans {
    pub fn new(k: usize) -> GkMeans {
        let d = construct::ConstructParams::default();
        GkMeans { k, kappa: d.kappa, xi: d.xi, tau: d.tau }
    }

    /// Graph scale κ (neighbors kept and consulted per sample).
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }

    /// Cell size ξ for the intertwined graph construction.
    pub fn xi(mut self, xi: usize) -> Self {
        self.xi = xi;
        self
    }

    /// Construction rounds τ (10 for clustering, up to 32 for ANNS).
    pub fn tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }
}

impl Clusterer for GkMeans {
    fn method(&self) -> Method {
        Method::GkMeans
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let timer = Timer::start();
        let build =
            construct::build(data, &alg3_params(self.kappa, self.xi, self.tau, ctx), ctx.backend);
        let graph_seconds = timer.elapsed_s();
        let k = clamp_k(self.k, data);
        let params = gkmeans::GkMeansParams { kappa: self.kappa, base: ctx.kmeans_params() };
        let (out, resumed) = fit_hooked(Method::GkMeans, data, ctx, k, graph_seconds, |hooks| {
            gkmeans::run_core_hooked(data, k, &build.graph, &params, ctx.backend, hooks)
        });
        assemble(Method::GkMeans, data, ctx, out, Some(build.graph), graph_seconds, resumed)
    }
}

/// GK-means\* — Alg. 2 on a *traditional* k-means core (Fig. 4's second
/// configuration): faster convergence per epoch, visibly higher final
/// distortion.
#[derive(Debug, Clone)]
pub struct GkMeansStar {
    k: usize,
    kappa: usize,
    xi: usize,
    tau: usize,
}

impl GkMeansStar {
    pub fn new(k: usize) -> GkMeansStar {
        let d = construct::ConstructParams::default();
        GkMeansStar { k, kappa: d.kappa, xi: d.xi, tau: d.tau }
    }

    /// Graph scale κ.
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }

    /// Cell size ξ.
    pub fn xi(mut self, xi: usize) -> Self {
        self.xi = xi;
        self
    }

    /// Construction rounds τ.
    pub fn tau(mut self, tau: usize) -> Self {
        self.tau = tau;
        self
    }
}

impl Clusterer for GkMeansStar {
    fn method(&self) -> Method {
        Method::GkMeansTrad
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let timer = Timer::start();
        let build =
            construct::build(data, &alg3_params(self.kappa, self.xi, self.tau, ctx), ctx.backend);
        let graph_seconds = timer.elapsed_s();
        let k = clamp_k(self.k, data);
        let params = gkmeans::GkMeansParams { kappa: self.kappa, base: ctx.kmeans_params() };
        let (out, resumed) =
            fit_hooked(Method::GkMeansTrad, data, ctx, k, graph_seconds, |hooks| {
                variant::run_core_hooked(data, k, &build.graph, &params, ctx.backend, hooks)
            });
        assemble(
            Method::GkMeansTrad,
            data,
            ctx,
            out,
            Some(build.graph),
            graph_seconds,
            resumed,
        )
    }
}

/// GK-means driven by an NN-Descent graph ("KGraph+GK-means"): same
/// optimization core, different graph builder.
#[derive(Debug, Clone)]
pub struct KGraphGkMeans {
    k: usize,
    kappa: usize,
}

impl KGraphGkMeans {
    pub fn new(k: usize) -> KGraphGkMeans {
        KGraphGkMeans { k, kappa: construct::ConstructParams::default().kappa }
    }

    /// Graph scale κ.
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa;
        self
    }
}

impl Clusterer for KGraphGkMeans {
    fn method(&self) -> Method {
        Method::KGraphGkMeans
    }

    fn fit_store(&self, data: &dyn VecStore, ctx: &RunContext) -> FittedModel {
        let timer = Timer::start();
        let graph = nn_descent::build(
            data,
            self.kappa,
            &nn_descent::NnDescentParams {
                seed: ctx.seed,
                threads: ctx.threads,
                scan_order: ctx.scan_order,
                ..Default::default()
            },
        );
        let graph_seconds = timer.elapsed_s();
        let k = clamp_k(self.k, data);
        let params = gkmeans::GkMeansParams { kappa: self.kappa, base: ctx.kmeans_params() };
        let (out, resumed) =
            fit_hooked(Method::KGraphGkMeans, data, ctx, k, graph_seconds, |hooks| {
                gkmeans::run_core_hooked(data, k, &graph, &params, ctx.backend, hooks)
            });
        assemble(Method::KGraphGkMeans, data, ctx, out, Some(graph), graph_seconds, resumed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::runtime::Backend;

    #[test]
    fn all_seven_configs_fit() {
        let data = blobs(&BlobSpec::quick(400, 6, 8), 1);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(5);
        let configs: Vec<Box<dyn Clusterer>> = vec![
            Box::new(Lloyd::new(8)),
            Box::new(Boost::new(8)),
            Box::new(MiniBatch::new(8).batch(128)),
            Box::new(ClosureKmeans::new(8).trees(2)),
            Box::new(GkMeans::new(8).kappa(8).tau(3).xi(25)),
            Box::new(GkMeansStar::new(8).kappa(8).tau(3).xi(25)),
            Box::new(KGraphGkMeans::new(8).kappa(8)),
        ];
        for c in &configs {
            let m = c.fit(&data, &ctx);
            assert_eq!(m.method, c.method(), "{}", c.name());
            assert_eq!(m.labels.len(), 400, "{}", c.name());
            assert_eq!(m.k, 8, "{}", c.name());
            assert_eq!(m.centroids.rows(), 8, "{}", c.name());
            assert!(m.distortion().is_finite(), "{}", c.name());
            m.check_time_accounting().unwrap();
            let graphy = matches!(
                c.method(),
                Method::GkMeans | Method::GkMeansTrad | Method::KGraphGkMeans
            );
            assert_eq!(m.graph.is_some(), graphy, "{}", c.name());
            assert_eq!(m.graph_seconds > 0.0, graphy, "{}", c.name());
        }
    }

    #[test]
    fn k_is_clamped_to_n() {
        let data = blobs(&BlobSpec::quick(20, 3, 2), 2);
        let b = Backend::native();
        let m = Lloyd::new(500).fit(&data, &RunContext::new(&b).max_iters(3));
        assert_eq!(m.k, 20);
    }

    #[test]
    fn progress_callback_sees_every_epoch() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let data = blobs(&BlobSpec::quick(200, 4, 4), 3);
        let b = Backend::native();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let ctx = RunContext::new(&b).max_iters(4).on_progress(move |name, _| {
            assert_eq!(name, "boost k-means");
            c.fetch_add(1, Ordering::Relaxed);
        });
        let m = Boost::new(4).fit(&data, &ctx);
        assert_eq!(count.load(Ordering::Relaxed), m.history.len());
    }

    fn ckpt_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("gkm_resume_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// labels + centroid bits + history (iter/distortion-bits/moves; the
    /// seconds are wall-clock and differ between runs by construction)
    fn assert_fit_equal(a: &FittedModel, b: &FittedModel) {
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids.flat().len(), b.centroids.flat().len());
        for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.iter, y.iter);
            assert_eq!(x.moves, y.moves);
            assert_eq!(x.distortion.to_bits(), y.distortion.to_bits());
        }
    }

    #[test]
    fn gkmeans_kill_and_resume_is_bit_identical() {
        let data = blobs(&BlobSpec::quick(300, 5, 6), 4);
        let b = Backend::native();
        let cfg = GkMeans::new(6).kappa(6).tau(2).xi(25);
        let full = cfg.fit(
            &data,
            &RunContext::new(&b).seed(5).max_iters(7).min_move_rate(0.0),
        );
        // "kill" at epoch 3 (the fit simply stops there), then resume
        let dir = ckpt_dir("gkm");
        let partial = cfg.fit(
            &data,
            &RunContext::new(&b).seed(5).max_iters(3).min_move_rate(0.0).checkpoint(&dir, 3),
        );
        assert_eq!(partial.history.len(), 4, "iter-0 entry + 3 epochs");
        let state = checkpoint::load(&checkpoint::checkpoint_path(&dir)).unwrap();
        assert_eq!(state.next_iter, 4);
        state.validate(Method::GkMeans, 6, 5, 300, 5).unwrap();
        let resumed = cfg.fit(
            &data,
            &RunContext::new(&b)
                .seed(5)
                .max_iters(7)
                .min_move_rate(0.0)
                .checkpoint(&dir, 3)
                .resume(true),
        );
        assert_fit_equal(&full, &resumed);
        resumed.check_time_accounting().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lloyd_and_boost_kill_and_resume_are_bit_identical() {
        let data = blobs(&BlobSpec::quick(250, 4, 5), 8);
        let b = Backend::native();
        let configs: Vec<Box<dyn Clusterer>> =
            vec![Box::new(Lloyd::new(5)), Box::new(Boost::new(5))];
        for (t, cfg) in configs.iter().enumerate() {
            let full = cfg.fit(
                &data,
                &RunContext::new(&b).seed(3).max_iters(6).min_move_rate(0.0),
            );
            let dir = ckpt_dir(&format!("lb{t}"));
            let _partial = cfg.fit(
                &data,
                &RunContext::new(&b).seed(3).max_iters(3).min_move_rate(0.0).checkpoint(&dir, 2),
            );
            let resumed = cfg.fit(
                &data,
                &RunContext::new(&b)
                    .seed(3)
                    .max_iters(6)
                    .min_move_rate(0.0)
                    .checkpoint(&dir, 2)
                    .resume(true),
            );
            // the checkpoint lands at epoch 2 of 3, so the resume re-runs
            // epoch 3 — it must land on the exact same trajectory
            assert_fit_equal(&full, &resumed);
            resumed.check_time_accounting().unwrap();
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn gkmeans_star_kill_and_resume_is_bit_identical() {
        let data = blobs(&BlobSpec::quick(300, 5, 6), 9);
        let b = Backend::native();
        let cfg = GkMeansStar::new(6).kappa(6).tau(2).xi(25);
        let full = cfg.fit(
            &data,
            &RunContext::new(&b).seed(7).max_iters(6).min_move_rate(0.0),
        );
        let dir = ckpt_dir("star");
        let _partial = cfg.fit(
            &data,
            &RunContext::new(&b).seed(7).max_iters(2).min_move_rate(0.0).checkpoint(&dir, 2),
        );
        let resumed = cfg.fit(
            &data,
            &RunContext::new(&b)
                .seed(7)
                .max_iters(6)
                .min_move_rate(0.0)
                .checkpoint(&dir, 2)
                .resume(true),
        );
        assert_fit_equal(&full, &resumed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn threaded_kill_and_resume_stays_in_tolerance() {
        // threads > 1: per-epoch move application commutes only up to
        // f32 rounding, so resume equivalence is a tolerance class, not
        // bit identity
        let data = blobs(&BlobSpec::quick(400, 6, 8), 10);
        let b = Backend::native();
        let cfg = GkMeans::new(8).kappa(8).tau(2).xi(25);
        let full = cfg.fit(
            &data,
            &RunContext::new(&b).seed(2).threads(4).max_iters(6).min_move_rate(0.0),
        );
        let dir = ckpt_dir("mt");
        let _partial = cfg.fit(
            &data,
            &RunContext::new(&b)
                .seed(2)
                .threads(4)
                .max_iters(3)
                .min_move_rate(0.0)
                .checkpoint(&dir, 3),
        );
        let resumed = cfg.fit(
            &data,
            &RunContext::new(&b)
                .seed(2)
                .threads(4)
                .max_iters(6)
                .min_move_rate(0.0)
                .checkpoint(&dir, 3)
                .resume(true),
        );
        assert_eq!(resumed.history.len(), full.history.len());
        assert!(
            resumed.distortion() <= full.distortion() * 1.10 + 1e-9,
            "resumed={} full={}",
            resumed.distortion(),
            full.distortion()
        );
        resumed.check_time_accounting().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_with_no_checkpoint_file_starts_fresh() {
        let data = blobs(&BlobSpec::quick(150, 4, 3), 11);
        let b = Backend::native();
        let dir = ckpt_dir("fresh");
        let plain = Lloyd::new(3).fit(&data, &RunContext::new(&b).seed(4).max_iters(4));
        let resumed = Lloyd::new(3).fit(
            &data,
            &RunContext::new(&b).seed(4).max_iters(4).checkpoint(&dir, 2).resume(true),
        );
        assert_eq!(plain.labels, resumed.labels);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "cannot resume")]
    fn resume_rejects_a_mismatched_job() {
        let data = blobs(&BlobSpec::quick(120, 4, 3), 12);
        let b = Backend::native();
        let dir = ckpt_dir("mismatch");
        let _ = Lloyd::new(3).fit(
            &data,
            &RunContext::new(&b).seed(4).max_iters(4).min_move_rate(0.0).checkpoint(&dir, 2),
        );
        // different seed: replaying a different stream from this state
        // would silently diverge — it must be refused loudly
        let _ = Lloyd::new(3).fit(
            &data,
            &RunContext::new(&b).seed(5).max_iters(4).checkpoint(&dir, 2).resume(true),
        );
    }

    #[test]
    fn fit_is_deterministic_for_fixed_seed() {
        let data = blobs(&BlobSpec::quick(300, 5, 6), 4);
        let b = Backend::native();
        let cfg = GkMeans::new(6).kappa(6).tau(2).xi(25);
        let a = cfg.fit(&data, &RunContext::new(&b).seed(5));
        let c = cfg.fit(&data, &RunContext::new(&b).seed(5));
        assert_eq!(a.labels, c.labels);
        for (x, y) in a.centroids.flat().iter().zip(c.centroids.flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
