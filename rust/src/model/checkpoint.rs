//! GKCKPT — the epoch-level fit checkpoint artifact.
//!
//! A fit configured with [`RunContext::checkpoint`](crate::model::RunContext::checkpoint)
//! periodically serializes its mid-fit engine state (labels, composites /
//! centroids, cached norms, RNG state, epoch counter, folded history)
//! into `<dir>/fit.gkckpt`; a later run with `resume` enabled picks the
//! fit back up from the last completed checkpointed epoch.  At
//! `threads = 1` the continued fit is **bit-identical** to the
//! uninterrupted one: floating-point state is stored as raw bits, and
//! the engines replay their epoch shuffles to land on the exact RNG
//! stream position.
//!
//! Write protocol (crash safety): encode to a sibling temp file, `fsync`
//! it, atomically rename over the target, then `fsync` the directory —
//! a crash at any point leaves either the previous checkpoint or the new
//! one, never a torn file.  The payload carries a trailing CRC-32, so a
//! torn or bit-rotted file is rejected at load with a typed
//! [`RtErrorKind::Corrupt`](crate::runtime::RtErrorKind) error instead
//! of resuming from garbage.

use std::path::{Path, PathBuf};

use crate::coordinator::job::Method;
use crate::kmeans::common::{IterStat, ResumePoint};
use crate::runtime::{RtError, RtResult};
use crate::util::crc32::crc32;

/// Magic prefix of a GKCKPT file.
pub const MAGIC: &[u8; 8] = b"GKCKPT\0\0";
/// Current format version.
pub const VERSION: u32 = 1;

/// The canonical checkpoint file inside a checkpoint directory.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join("fit.gkckpt")
}

/// Everything a fit needs to continue from a completed epoch, plus the
/// identity fields ([`CheckpointState::validate`]) that guard against
/// resuming with a mismatched job.
#[derive(Debug, Clone)]
pub struct CheckpointState {
    /// Method that wrote the checkpoint.
    pub method: Method,
    /// Number of clusters.
    pub k: usize,
    /// Dimensionality.
    pub dim: usize,
    /// Training rows.
    pub n_train: usize,
    /// Fit seed.
    pub seed: u64,
    /// First epoch the resumed fit should run.
    pub next_iter: usize,
    /// Engine RNG state at the checkpoint (replay consistency guard).
    pub rng: [u64; 4],
    /// History up to the checkpoint, seconds folded to the wall-clock
    /// values the final model reports.
    pub history: Vec<IterStat>,
    /// Labels at the checkpoint.
    pub labels: Vec<u32>,
    /// Composite vectors (composite-maintaining engines), raw f32 bits.
    pub composite: Option<Vec<f32>>,
    /// Cluster sizes (composite-maintaining engines).
    pub counts: Option<Vec<u32>>,
    /// Cached ‖D_r‖² (engines carrying a `DeltaCache`), raw f64 bits.
    pub comp_norm2: Option<Vec<f64>>,
    /// Centroids (centroid-maintaining engines), raw f32 bits.
    pub centroids: Option<Vec<f32>>,
    /// Model-level initialization seconds (graph + seeding) the original
    /// fit reported; restored verbatim into the resumed model.
    pub init_seconds: f64,
    /// Graph-construction seconds the original fit reported.
    pub graph_seconds: f64,
}

impl CheckpointState {
    /// Reject resuming into a job that does not match the checkpoint.
    pub fn validate(
        &self,
        method: Method,
        k: usize,
        dim: usize,
        n_train: usize,
        seed: u64,
    ) -> RtResult<()> {
        if self.method != method {
            return Err(RtError::msg(format!(
                "checkpoint was written by {} but the job runs {}",
                self.method.name(),
                method.name()
            )));
        }
        if (self.k, self.dim, self.n_train) != (k, dim, n_train) {
            return Err(RtError::msg(format!(
                "checkpoint shape (k={}, dim={}, n={}) != job shape (k={k}, dim={dim}, n={n_train})",
                self.k, self.dim, self.n_train
            )));
        }
        if self.seed != seed {
            return Err(RtError::msg(format!(
                "checkpoint seed {} != job seed {seed} (resume must replay the same stream)",
                self.seed
            )));
        }
        Ok(())
    }

    /// The engine-facing slice of this state.
    pub fn into_resume_point(self) -> ResumePoint {
        ResumePoint {
            next_iter: self.next_iter,
            rng: self.rng,
            history: self.history,
            labels: self.labels,
            composite: self.composite,
            counts: self.counts,
            comp_norm2: self.comp_norm2,
            centroids: self.centroids,
        }
    }
}

// --- little-endian encode/decode helpers (self-contained: the GKMODEL
//     writer keeps its own — the formats evolve independently) ---

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        put_u32(out, v);
    }
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        put_f64(out, v);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> RtResult<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(RtError::corrupt("GKCKPT", "truncated checkpoint payload"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> RtResult<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> RtResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> RtResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> RtResult<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn len_checked(&mut self, elem: usize) -> RtResult<usize> {
        let n = self.u64()? as usize;
        // cheap sanity bound before allocating: the payload must actually
        // contain the claimed bytes
        if n.checked_mul(elem).map(|b| self.pos + b > self.buf.len()).unwrap_or(true) {
            return Err(RtError::corrupt("GKCKPT", "array length exceeds payload"));
        }
        Ok(n)
    }

    fn u32s(&mut self) -> RtResult<Vec<u32>> {
        let n = self.len_checked(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32s(&mut self) -> RtResult<Vec<f32>> {
        let n = self.len_checked(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f64s(&mut self) -> RtResult<Vec<f64>> {
        let n = self.len_checked(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

fn encode(state: &CheckpointState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    out.push(state.method.tag());
    put_u64(&mut out, state.k as u64);
    put_u64(&mut out, state.dim as u64);
    put_u64(&mut out, state.n_train as u64);
    put_u64(&mut out, state.seed);
    put_u64(&mut out, state.next_iter as u64);
    for w in state.rng {
        put_u64(&mut out, w);
    }
    put_f64(&mut out, state.init_seconds);
    put_f64(&mut out, state.graph_seconds);
    put_u64(&mut out, state.history.len() as u64);
    for h in &state.history {
        put_u64(&mut out, h.iter as u64);
        put_f64(&mut out, h.seconds);
        put_f64(&mut out, h.distortion);
        put_u64(&mut out, h.moves as u64);
    }
    put_u32s(&mut out, &state.labels);
    let flags = (state.composite.is_some() as u8)
        | (state.counts.is_some() as u8) << 1
        | (state.comp_norm2.is_some() as u8) << 2
        | (state.centroids.is_some() as u8) << 3;
    out.push(flags);
    if let Some(v) = &state.composite {
        put_f32s(&mut out, v);
    }
    if let Some(v) = &state.counts {
        put_u32s(&mut out, v);
    }
    if let Some(v) = &state.comp_norm2 {
        put_f64s(&mut out, v);
    }
    if let Some(v) = &state.centroids {
        put_f32s(&mut out, v);
    }
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    out
}

fn decode(bytes: &[u8]) -> RtResult<CheckpointState> {
    if bytes.len() < MAGIC.len() + 8 {
        return Err(RtError::corrupt("GKCKPT", "file shorter than header"));
    }
    if &bytes[..MAGIC.len()] != MAGIC {
        return Err(RtError::corrupt("GKCKPT", "bad magic (not a GKCKPT file)"));
    }
    let body = &bytes[..bytes.len() - 4];
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let actual = crc32(body);
    if stored != actual {
        return Err(RtError::corrupt(
            "GKCKPT",
            format!("CRC mismatch: stored {stored:#010x}, computed {actual:#010x}"),
        ));
    }
    let mut r = Reader { buf: body, pos: MAGIC.len() };
    let version = r.u32()?;
    if version != VERSION {
        return Err(RtError::msg(format!("unsupported GKCKPT version {version}")));
    }
    let method = Method::from_tag(r.u8()?).map_err(RtError::msg)?;
    let k = r.u64()? as usize;
    let dim = r.u64()? as usize;
    let n_train = r.u64()? as usize;
    let seed = r.u64()?;
    let next_iter = r.u64()? as usize;
    let mut rng = [0u64; 4];
    for w in rng.iter_mut() {
        *w = r.u64()?;
    }
    let init_seconds = r.f64()?;
    let graph_seconds = r.f64()?;
    let hist_len = r.len_checked(32)?;
    let mut history = Vec::with_capacity(hist_len);
    for _ in 0..hist_len {
        let iter = r.u64()? as usize;
        let seconds = r.f64()?;
        let distortion = r.f64()?;
        let moves = r.u64()? as usize;
        history.push(IterStat { iter, seconds, distortion, moves });
    }
    let labels = r.u32s()?;
    let flags = r.u8()?;
    let composite = if flags & 1 != 0 { Some(r.f32s()?) } else { None };
    let counts = if flags & 2 != 0 { Some(r.u32s()?) } else { None };
    let comp_norm2 = if flags & 4 != 0 { Some(r.f64s()?) } else { None };
    let centroids = if flags & 8 != 0 { Some(r.f32s()?) } else { None };
    if r.pos != body.len() {
        return Err(RtError::corrupt("GKCKPT", "trailing bytes after payload"));
    }
    Ok(CheckpointState {
        method,
        k,
        dim,
        n_train,
        seed,
        next_iter,
        rng,
        history,
        labels,
        composite,
        counts,
        comp_norm2,
        centroids,
        init_seconds,
        graph_seconds,
    })
}

/// Best-effort directory fsync (crash safety of the rename; a filesystem
/// that cannot fsync a directory handle just skips it).
fn fsync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Atomically write the checkpoint into `dir` (created if missing):
/// temp file → fsync → rename over `fit.gkckpt` → fsync dir.
pub fn save(state: &CheckpointState, dir: &Path) -> RtResult<()> {
    std::fs::create_dir_all(dir)
        .map_err(|e| RtError::msg(format!("creating checkpoint dir {}: {e}", dir.display())))?;
    let target = checkpoint_path(dir);
    let tmp = dir.join(format!("fit.gkckpt.tmp.{}", std::process::id()));
    let bytes = encode(state);
    let write = || -> std::io::Result<()> {
        let f = std::fs::File::create(&tmp)?;
        {
            use std::io::Write;
            let mut w = std::io::BufWriter::new(&f);
            w.write_all(&bytes)?;
            w.flush()?;
        }
        f.sync_all()?;
        std::fs::rename(&tmp, &target)?;
        Ok(())
    };
    if let Err(e) = write() {
        let _ = std::fs::remove_file(&tmp);
        return Err(RtError::msg(format!("writing checkpoint {}: {e}", target.display())));
    }
    fsync_dir(dir);
    Ok(())
}

/// Load and CRC-verify a checkpoint file.
pub fn load(path: &Path) -> RtResult<CheckpointState> {
    let bytes = std::fs::read(path)
        .map_err(|e| RtError::msg(format!("reading checkpoint {}: {e}", path.display())))?;
    decode(&bytes).map_err(|e| e.context(format!("loading checkpoint {}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RtErrorKind;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gkckpt_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_state() -> CheckpointState {
        CheckpointState {
            method: Method::GkMeans,
            k: 4,
            dim: 3,
            n_train: 10,
            seed: 42,
            next_iter: 3,
            rng: [1, 2, 3, 4],
            history: vec![
                IterStat { iter: 0, seconds: 0.5, distortion: 9.0, moves: 0 },
                IterStat { iter: 1, seconds: 1.5, distortion: 5.0, moves: 7 },
                IterStat { iter: 2, seconds: 2.5, distortion: 4.0, moves: 3 },
            ],
            labels: (0..10u32).map(|i| i % 4).collect(),
            composite: Some((0..12).map(|i| i as f32 * 0.25).collect()),
            counts: Some(vec![3, 3, 2, 2]),
            comp_norm2: Some(vec![1.25, 2.5, 3.75, 5.0]),
            centroids: None,
            init_seconds: 0.5,
            graph_seconds: 0.25,
        }
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let dir = tmpdir("roundtrip");
        let s = sample_state();
        save(&s, &dir).unwrap();
        let r = load(&checkpoint_path(&dir)).unwrap();
        assert_eq!(r.method, s.method);
        assert_eq!((r.k, r.dim, r.n_train, r.seed, r.next_iter), (4, 3, 10, 42, 3));
        assert_eq!(r.rng, s.rng);
        assert_eq!(r.labels, s.labels);
        assert_eq!(r.counts, s.counts);
        assert_eq!(r.centroids, None);
        for (a, b) in r.composite.unwrap().iter().zip(s.composite.as_ref().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in r.comp_norm2.unwrap().iter().zip(s.comp_norm2.as_ref().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(r.history.len(), 3);
        assert_eq!(r.history[1].moves, 7);
        assert_eq!(r.history[2].seconds.to_bits(), 2.5f64.to_bits());
        assert_eq!(r.init_seconds.to_bits(), 0.5f64.to_bits());
        assert_eq!(r.graph_seconds.to_bits(), 0.25f64.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resave_overwrites_atomically() {
        let dir = tmpdir("resave");
        let mut s = sample_state();
        save(&s, &dir).unwrap();
        s.next_iter = 9;
        save(&s, &dir).unwrap();
        assert_eq!(load(&checkpoint_path(&dir)).unwrap().next_iter, 9);
        // no temp litter left behind
        let litter: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(litter.is_empty(), "temp files left behind: {litter:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_and_truncation_are_rejected_as_corrupt() {
        let dir = tmpdir("corrupt");
        save(&sample_state(), &dir).unwrap();
        let path = checkpoint_path(&dir);
        let clean = std::fs::read(&path).unwrap();
        // flip one payload byte -> CRC mismatch
        let mut bad = clean.clone();
        bad[MAGIC.len() + 20] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let e = load(&path).unwrap_err();
        assert!(e.is_corrupt(), "kind={:?}", e.kind);
        assert!(format!("{e}").contains("CRC"), "{e}");
        // truncate -> corrupt too
        std::fs::write(&path, &clean[..clean.len() / 2]).unwrap();
        assert!(load(&path).unwrap_err().is_corrupt());
        // bad magic
        let mut nonsense = clean.clone();
        nonsense[0] = b'X';
        std::fs::write(&path, &nonsense).unwrap();
        let e = load(&path).unwrap_err();
        assert_eq!(
            e.kind,
            RtErrorKind::Corrupt { section: "GKCKPT".into() },
            "magic failure must be typed"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validate_guards_job_identity() {
        let s = sample_state();
        s.validate(Method::GkMeans, 4, 3, 10, 42).unwrap();
        assert!(s.validate(Method::Lloyd, 4, 3, 10, 42).is_err());
        assert!(s.validate(Method::GkMeans, 5, 3, 10, 42).is_err());
        assert!(s.validate(Method::GkMeans, 4, 3, 10, 7).is_err());
        let msg = format!("{}", s.validate(Method::Boost, 4, 3, 10, 42).unwrap_err());
        assert!(msg.contains("GK-means") && msg.contains("boost"), "{msg}");
    }

    #[test]
    fn resume_point_carries_everything() {
        let rp = sample_state().into_resume_point();
        assert_eq!(rp.next_iter, 3);
        assert_eq!(rp.rng, [1, 2, 3, 4]);
        assert_eq!(rp.history.len(), 3);
        assert_eq!(rp.labels.len(), 10);
        assert!(rp.composite.is_some() && rp.counts.is_some() && rp.comp_norm2.is_some());
        assert!(rp.centroids.is_none());
    }
}
