//! Versioned binary save/load for [`FittedModel`] — no external deps.
//!
//! Layout (all integers/floats little-endian):
//!
//! ```text
//! magic   8 × u8   "GKMODEL\0"
//! version u32      1
//! method  u8       Method tag (see Method::tag)
//! flags   u8       bit0 = graph present, bit1 = data present
//! threads u32      predict thread preference
//! k/dim/n 3 × u64
//! timings 3 × f64  total_seconds, init_seconds, graph_seconds
//! history u64 len, then per entry: u64 iter, f64 seconds,
//!                  f64 distortion, u64 moves
//! labels  u64 len, len × u32
//! centroids        u64 rows, rows·dim × f32
//! [graph]          u64 n, u64 kappa, n·kappa × u32 ids,
//!                  n·kappa × f32 dists
//! [data]           u64 rows, rows·dim × f32
//! ```
//!
//! The encoding is exact (`to_le_bytes`/`from_le_bytes`), so a
//! save → load round trip is bit-identical — including the `+∞` distance
//! sentinels in partially-filled graph rows — which the round-trip tests
//! assert.  Unknown magic/version and trailing or missing bytes are
//! errors, never misreads.

use std::path::Path;

use crate::coordinator::job::Method;
use crate::data::matrix::VecSet;
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::IterStat;
use crate::model::FittedModel;

const MAGIC: &[u8; 8] = b"GKMODEL\0";
const VERSION: u32 = 1;

const FLAG_GRAPH: u8 = 1 << 0;
const FLAG_DATA: u8 = 1 << 1;

/// Serialize a model to bytes.
pub fn encode(m: &FittedModel) -> Vec<u8> {
    let mut buf = Vec::with_capacity(
        64 + m.labels.len() * 4
            + m.centroids.flat().len() * 4
            + m.graph.as_ref().map_or(0, |g| g.ids_flat().len() * 8)
            + m.data.as_ref().map_or(0, |d| d.flat().len() * 4),
    );
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, VERSION);
    buf.push(m.method.tag());
    let mut flags = 0u8;
    if m.graph.is_some() {
        flags |= FLAG_GRAPH;
    }
    if m.data.is_some() {
        flags |= FLAG_DATA;
    }
    buf.push(flags);
    put_u32(&mut buf, m.threads as u32);
    put_u64(&mut buf, m.k as u64);
    put_u64(&mut buf, m.dim as u64);
    put_u64(&mut buf, m.n_train as u64);
    put_f64(&mut buf, m.total_seconds);
    put_f64(&mut buf, m.init_seconds);
    put_f64(&mut buf, m.graph_seconds);
    put_u64(&mut buf, m.history.len() as u64);
    for h in &m.history {
        put_u64(&mut buf, h.iter as u64);
        put_f64(&mut buf, h.seconds);
        put_f64(&mut buf, h.distortion);
        put_u64(&mut buf, h.moves as u64);
    }
    put_u64(&mut buf, m.labels.len() as u64);
    for &l in &m.labels {
        put_u32(&mut buf, l);
    }
    put_u64(&mut buf, m.centroids.rows() as u64);
    for &v in m.centroids.flat() {
        put_f32(&mut buf, v);
    }
    if let Some(g) = &m.graph {
        put_u64(&mut buf, g.n() as u64);
        put_u64(&mut buf, g.kappa() as u64);
        for &id in g.ids_flat() {
            put_u32(&mut buf, id);
        }
        for &d in g.dists_flat() {
            put_f32(&mut buf, d);
        }
    }
    if let Some(d) = &m.data {
        put_u64(&mut buf, d.rows() as u64);
        for &v in d.flat() {
            put_f32(&mut buf, v);
        }
    }
    buf
}

/// Deserialize a model from bytes.
pub fn decode(bytes: &[u8]) -> Result<FittedModel, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    if r.take(8)? != MAGIC {
        return Err("not a gkmeans model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(format!("unsupported model version {version} (this build reads {VERSION})"));
    }
    let method = Method::from_tag(r.u8()?)?;
    let flags = r.u8()?;
    let threads = r.u32()? as usize;
    let k = r.len_u64("k")?;
    let dim = r.len_u64("dim")?;
    if dim == 0 {
        return Err("model dim is zero".into());
    }
    let n_train = r.len_u64("n_train")?;
    let total_seconds = r.f64()?;
    let init_seconds = r.f64()?;
    let graph_seconds = r.f64()?;
    let hist_len = r.len_u64("history length")?;
    let mut history = Vec::with_capacity(hist_len.min(1 << 20));
    for _ in 0..hist_len {
        let iter = r.len_u64("history iter")?;
        let seconds = r.f64()?;
        let distortion = r.f64()?;
        let moves = r.len_u64("history moves")?;
        history.push(IterStat { iter, seconds, distortion, moves });
    }
    let lab_len = r.len_u64("label count")?;
    let labels = r.u32_vec(lab_len)?;
    let crows = r.len_u64("centroid rows")?;
    if crows != k {
        return Err(format!("centroid rows {crows} != k {k}"));
    }
    let cflat = r.f32_vec(checked_mul(crows, dim, "centroid buffer")?)?;
    let centroids = VecSet::from_flat(dim, cflat);
    let graph = if flags & FLAG_GRAPH != 0 {
        let gn = r.len_u64("graph n")?;
        let gk = r.len_u64("graph kappa")?;
        if gn != n_train {
            return Err(format!("graph covers {gn} nodes but the model trained on {n_train}"));
        }
        let cells = checked_mul(gn, gk, "graph buffer")?;
        let ids = r.u32_vec(cells)?;
        let dists = r.f32_vec(cells)?;
        Some(KnnGraph::from_parts(gn, gk, ids, dists)?)
    } else {
        None
    };
    let data = if flags & FLAG_DATA != 0 {
        let rows = r.len_u64("data rows")?;
        if rows != n_train {
            return Err(format!("embedded {rows} vectors but the model trained on {n_train}"));
        }
        let flat = r.f32_vec(checked_mul(rows, dim, "data buffer")?)?;
        Some(VecSet::from_flat(dim, flat))
    } else {
        None
    };
    if r.pos != bytes.len() {
        return Err(format!(
            "{} trailing bytes after model payload",
            bytes.len() - r.pos
        ));
    }
    Ok(FittedModel {
        method,
        k,
        dim,
        n_train,
        threads,
        centroids,
        labels,
        history,
        total_seconds,
        init_seconds,
        graph_seconds,
        graph,
        data,
    })
}

/// Write a model to `path`.
pub fn save(m: &FittedModel, path: &Path) -> Result<(), String> {
    std::fs::write(path, encode(m)).map_err(|e| format!("{}: {e}", path.display()))
}

/// Read a model from `path`.
pub fn load(path: &Path) -> Result<FittedModel, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    decode(&bytes)
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn checked_mul(a: usize, b: usize, what: &str) -> Result<usize, String> {
    a.checked_mul(b).ok_or_else(|| format!("{what} size overflows"))
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| "model file offset overflows".to_string())?;
        if end > self.buf.len() {
            return Err(format!(
                "model file truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A u64 length/count field, checked to fit in usize.
    fn len_u64(&mut self, what: &str) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("{what} {v} does not fit in usize"))
    }

    fn u32_vec(&mut self, len: usize) -> Result<Vec<u32>, String> {
        let raw = self.take(checked_mul(len, 4, "u32 buffer")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32_vec(&mut self, len: usize) -> Result<Vec<f32>, String> {
        let raw = self.take(checked_mul(len, 4, "f32 buffer")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::model::{Clusterer, GkMeans, Lloyd, RunContext};
    use crate::runtime::Backend;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gkm_model_{}_{name}", std::process::id()))
    }

    #[test]
    fn encode_decode_bit_identical() {
        let data = blobs(&BlobSpec::quick(250, 5, 4), 7);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(4).keep_data(true);
        let model = GkMeans::new(4).kappa(5).tau(2).xi(25).fit(&data, &ctx);
        let back = decode(&encode(&model)).unwrap();
        assert_eq!(back.method, model.method);
        assert_eq!(back.k, model.k);
        assert_eq!(back.dim, model.dim);
        assert_eq!(back.n_train, model.n_train);
        assert_eq!(back.labels, model.labels);
        assert_eq!(back.centroids.flat().len(), model.centroids.flat().len());
        for (a, b) in back.centroids.flat().iter().zip(model.centroids.flat()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.total_seconds.to_bits(), model.total_seconds.to_bits());
        let (ga, gb) = (back.graph.unwrap(), model.graph.as_ref().unwrap());
        assert_eq!(ga.ids_flat(), gb.ids_flat());
        for (a, b) in ga.dists_flat().iter().zip(gb.dists_flat()) {
            assert_eq!(a.to_bits(), b.to_bits(), "graph distances must round-trip bitwise");
        }
        let (da, db) = (back.data.unwrap(), model.data.as_ref().unwrap());
        assert_eq!(da.flat().len(), db.flat().len());
        for (a, b) in da.flat().iter().zip(db.flat()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.history.len(), model.history.len());
    }

    #[test]
    fn save_load_file_roundtrip() {
        let data = blobs(&BlobSpec::quick(120, 4, 3), 8);
        let b = Backend::native();
        let model = Lloyd::new(3).fit(&data, &RunContext::new(&b).max_iters(5));
        let path = tmp("roundtrip.gkm");
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        assert_eq!(back.labels, model.labels);
        assert!(back.graph.is_none() && back.data.is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        let data = blobs(&BlobSpec::quick(60, 3, 2), 9);
        let b = Backend::native();
        let model = Lloyd::new(2).fit(&data, &RunContext::new(&b).max_iters(3));
        let bytes = encode(&model);
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().contains("magic"));
        // bad version
        let mut bad = bytes.clone();
        bad[8] = 99;
        assert!(decode(&bad).unwrap_err().contains("version"));
        // truncation at every eighth boundary must error, never panic
        for cut in (0..bytes.len() - 1).step_by(8) {
            assert!(decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode(&long).unwrap_err().contains("trailing"));
    }
}
