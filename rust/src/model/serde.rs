//! Versioned binary save/load for [`FittedModel`] — no external deps.
//!
//! ## GKMODEL v2 (written by [`save`])
//!
//! A section-offset layout so every component is independently
//! addressable (all integers/floats little-endian):
//!
//! ```text
//! magic    8 × u8   "GKMODEL\0"
//! version  u32      2
//! count    u32      number of table entries
//! table    count ×  { kind u32, reserved u32 = 0, offset u64, len u64 }
//! ...      sections at their table offsets, each 64-byte aligned
//! ```
//!
//! Section kinds (append-only; readers skip unknown kinds):
//!
//! | kind | section   | payload                                            |
//! |-----:|-----------|----------------------------------------------------|
//! | 1    | META      | method u8, threads u32, k/dim/n u64, 3 × f64 clocks, history (u64 len + 32-byte entries) |
//! | 2    | LABELS    | u64 len, len × u32                                 |
//! | 3    | CENTROIDS | u64 rows, rows·dim × f32                           |
//! | 4    | GRAPH     | u64 n, u64 kappa, n·κ × u32 ids, n·κ × f32 dists   |
//! | 5    | VECTORS   | u64 rows, rows·dim × f32                           |
//! | 6    | CRC       | per-section { kind u32, crc32 u32 } records        |
//! | 7    | QVECTORS  | u64 rows, dim × f32 min, dim × f32 scale, rows·dim × u8 codes |
//! | 8    | RTREE     | u32 branch, u32 beam, u64 dim/k/nodes, routing vectors + topology + leaf members + reps (see [`SEC_RTREE`]) |
//!
//! The CRC section (always written last) holds a CRC-32 (IEEE) of every
//! other section's payload bytes; the vectors checksum is accumulated
//! while the section streams out, so integrity costs no extra pass at
//! save time.  [`load`] and [`decode`] verify every checksummed section
//! — vectors are streamed through the hash in bounded blocks — and
//! reject mismatches as typed [`RtError`] corruption errors naming the
//! damaged section.  v2 files written before this section existed carry
//! no kind-6 entry and load exactly as before (verification is simply
//! skipped), and pre-CRC readers skip kind 6 as an unknown section:
//! append-only compatibility in both directions.
//!
//! The aligned, raw-`f32` VECTORS payload is exactly a
//! [`ChunkedVecStore::from_section`] region: [`load`] does **not** read
//! it — the returned model pages vectors from disk on demand
//! ([`ModelVectors::Disk`]), so a multi-GB index opens in milliseconds
//! and serves `predict_batch`/`search_batch` with a bounded RAM
//! footprint.  [`save`] streams the vectors out in blocks, so writing an
//! out-of-core model never materializes them either.
//!
//! ## v1 (legacy, still read)
//!
//! The original single-blob layout (everything eagerly embedded).
//! [`load`]/[`decode`] accept it transparently; [`encode_v1`] keeps a
//! writer around for fixtures and migration tests.
//!
//! Both encodings are exact (`to_le_bytes`/`from_le_bytes`), so a
//! save → load round trip is bit-identical — including the `+∞` distance
//! sentinels in partially-filled graph rows.  Unknown magic/version,
//! truncation, out-of-bounds sections, and checksum mismatches are
//! errors, never misreads.  [`save`] is crash-safe: temp sibling →
//! fsync → rename → fsync directory, so a crash at any point leaves
//! either the old artifact or the new one, never a torn file.

use std::io::Write;
use std::path::Path;

use crate::coordinator::job::Method;
use crate::data::matrix::VecSet;
use crate::data::quant::{QuantizedVecStore, Sq8Quantizer};
use crate::data::store::{ChunkedVecStore, VecStore};
use crate::gkm::tree::{RouteTree, ROUTE_MIN_K};
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::IterStat;
use crate::model::fitted::ModelVectors;
use crate::model::FittedModel;
use crate::runtime::{RtError, RtResult};
use crate::util::crc32::{crc32, Crc32};

const MAGIC: &[u8; 8] = b"GKMODEL\0";
const V1: u32 = 1;
const V2: u32 = 2;

const SEC_META: u32 = 1;
const SEC_LABELS: u32 = 2;
const SEC_CENTROIDS: u32 = 3;
const SEC_GRAPH: u32 = 4;
const SEC_VECTORS: u32 = 5;
const SEC_CRC: u32 = 6;
/// SQ8-quantized vectors (PR 8).  Appended after SEC_CRC was assigned,
/// so pre-quantization readers skip it as an unknown kind.
const SEC_QVECTORS: u32 = 7;
/// Hierarchical routing tree (PR 9).  Append-only like QVECTORS:
/// pre-routing readers skip it as an unknown kind and serve the flat
/// scan.  Payload: `u32 branch, u32 beam, u64 dim, u64 k, u64 nodes,
/// nodes·dim × f32 routing vectors, nodes × u32 first_child,
/// nodes × u32 child_count, (nodes+1) × u32 member offsets,
/// u64 member count + u32 member ids, u64 rep count + u32 rep rows`.
const SEC_RTREE: u32 = 8;
/// Incremental-extend drift baselines (PR 10).  Append-only like its
/// predecessors: pre-extend readers skip it as an unknown kind.
/// Payload: `u64 k, k × f64 per-cell mean-distortion baselines` (NaN
/// bits = "not captured yet" — NaN round-trips bitwise through
/// `to_le_bytes`).
const SEC_DRIFT: u32 = 9;

/// Section alignment: offsets are multiples of 64 so payloads start on
/// cache-line boundaries and the vectors region can be paged directly.
const ALIGN: u64 = 64;

const FLAG_GRAPH: u8 = 1 << 0;
const FLAG_DATA: u8 = 1 << 1;

/// Rows per write when streaming the vectors section to disk.
const VEC_STREAM_ROWS: usize = 4096;

/// Cap on the persisted thread preference: a corrupt artifact's
/// `threads` field must not become a thread-spawn bomb at serve time.
const MAX_THREADS: usize = 1024;

fn align_up(v: u64) -> u64 {
    v.div_ceil(ALIGN) * ALIGN
}

// --- section payload builders (v2) -------------------------------------

fn meta_payload(m: &FittedModel) -> Vec<u8> {
    let mut buf = Vec::with_capacity(61 + 32 * m.history.len());
    buf.push(m.method.tag());
    put_u32(&mut buf, m.threads as u32);
    put_u64(&mut buf, m.k as u64);
    put_u64(&mut buf, m.dim as u64);
    put_u64(&mut buf, m.n_train as u64);
    put_f64(&mut buf, m.total_seconds);
    put_f64(&mut buf, m.init_seconds);
    put_f64(&mut buf, m.graph_seconds);
    put_u64(&mut buf, m.history.len() as u64);
    for h in &m.history {
        put_u64(&mut buf, h.iter as u64);
        put_f64(&mut buf, h.seconds);
        put_f64(&mut buf, h.distortion);
        put_u64(&mut buf, h.moves as u64);
    }
    buf
}

fn labels_payload(m: &FittedModel) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + 4 * m.labels.len());
    put_u64(&mut buf, m.labels.len() as u64);
    for &l in &m.labels {
        put_u32(&mut buf, l);
    }
    buf
}

fn centroids_payload(m: &FittedModel) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + 4 * m.centroids.flat().len());
    put_u64(&mut buf, m.centroids.rows() as u64);
    for &v in m.centroids.flat() {
        put_f32(&mut buf, v);
    }
    buf
}

fn graph_payload(g: &KnnGraph) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + 8 * g.ids_flat().len());
    put_u64(&mut buf, g.n() as u64);
    put_u64(&mut buf, g.kappa() as u64);
    for &id in g.ids_flat() {
        put_u32(&mut buf, id);
    }
    for &d in g.dists_flat() {
        put_f32(&mut buf, d);
    }
    buf
}

fn qvectors_payload(q: &QuantizedVecStore) -> Vec<u8> {
    let quant = q.quantizer();
    let mut buf = Vec::with_capacity(8 + 8 * q.dim() + q.codes().len());
    put_u64(&mut buf, q.rows() as u64);
    for &v in quant.min() {
        put_f32(&mut buf, v);
    }
    for &v in quant.scale() {
        put_f32(&mut buf, v);
    }
    buf.extend_from_slice(q.codes());
    buf
}

fn rtree_payload(t: &RouteTree) -> Vec<u8> {
    let nn = t.nodes();
    let mut buf = Vec::with_capacity(48 + 4 * (nn * (t.dim + 3) + 1 + t.k * 2));
    put_u32(&mut buf, t.branch);
    put_u32(&mut buf, t.default_beam);
    put_u64(&mut buf, t.dim as u64);
    put_u64(&mut buf, t.k as u64);
    put_u64(&mut buf, nn as u64);
    for &v in &t.node_vecs {
        put_f32(&mut buf, v);
    }
    for &v in &t.first_child {
        put_u32(&mut buf, v);
    }
    for &v in &t.child_count {
        put_u32(&mut buf, v);
    }
    for &v in &t.member_start {
        put_u32(&mut buf, v);
    }
    put_u64(&mut buf, t.member_ids.len() as u64);
    for &v in &t.member_ids {
        put_u32(&mut buf, v);
    }
    put_u64(&mut buf, t.reps.len() as u64);
    for &v in &t.reps {
        put_u32(&mut buf, v);
    }
    buf
}

fn drift_payload(d: &crate::model::extend::DriftState) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + 8 * d.baseline.len());
    put_u64(&mut buf, d.baseline.len() as u64);
    for &b in &d.baseline {
        put_f64(&mut buf, b);
    }
    buf
}

fn parse_drift(bytes: &[u8], k: usize) -> Result<crate::model::extend::DriftState, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let dk = r.len_u64("drift baseline count")?;
    if dk != k {
        return Err(format!("drift baselines cover {dk} cells but the model has k={k}"));
    }
    let mut baseline = Vec::with_capacity(dk.min(1 << 24));
    for _ in 0..dk {
        baseline.push(r.f64()?);
    }
    r.done("DRIFT")?;
    Ok(crate::model::extend::DriftState { baseline })
}

/// Parse the RTREE payload.  All structural validation (descent
/// termination, slice bounds, leaf partition of `0..k`) happens in
/// [`RouteTree::from_parts`] — the one constructor every tree goes
/// through — so a hostile artifact can fail but never mis-route.
fn parse_rtree(bytes: &[u8], k: usize, dim: usize) -> Result<RouteTree, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let branch = r.u32()?;
    let default_beam = r.u32()?;
    let tdim = r.len_u64("routing tree dim")?;
    if tdim != dim {
        return Err(format!("routing tree dim {tdim} != model dim {dim}"));
    }
    let tk = r.len_u64("routing tree k")?;
    if tk != k {
        return Err(format!("routing tree over {tk} centroids but the model has k={k}"));
    }
    let nn = r.len_u64("routing tree nodes")?;
    // A valid tree (every internal node ≥ 2 children, leaves ≥ 1
    // member) has at most 2k − 1 nodes; reject anything claiming more
    // before touching the node arrays.
    if nn == 0 || nn > 2 * k {
        return Err(format!("implausible routing tree node count {nn} for k={k}"));
    }
    let node_vecs = r.f32_vec(checked_mul(nn, dim, "routing vector buffer")?)?;
    let first_child = r.u32_vec(nn)?;
    let child_count = r.u32_vec(nn)?;
    let member_start = r.u32_vec(nn + 1)?;
    let mlen = r.len_u64("leaf member count")?;
    if mlen != k {
        return Err(format!("{mlen} leaf members for k={k}"));
    }
    let member_ids = r.u32_vec(mlen)?;
    let rlen = r.len_u64("rep count")?;
    if rlen != 0 && rlen != k {
        return Err(format!("{rlen} reps for k={k}"));
    }
    let reps = r.u32_vec(rlen)?;
    r.done("RTREE")?;
    RouteTree::from_parts(
        dim,
        k,
        branch,
        default_beam,
        node_vecs,
        first_child,
        child_count,
        member_start,
        member_ids,
        reps,
    )
}

/// Write a model in the v2 layout to any sink, streaming the vectors
/// section in [`VEC_STREAM_ROWS`]-row blocks.
fn write_v2<W: Write>(
    m: &FittedModel,
    vectors: Option<&dyn VecStore>,
    w: &mut W,
) -> std::io::Result<()> {
    let meta = meta_payload(m);
    let labels = labels_payload(m);
    let centroids = centroids_payload(m);
    let graph = m.graph.as_ref().map(graph_payload);
    let vec_len = vectors.map(|v| 8 + 4 * (v.rows() as u64) * (v.dim() as u64));
    let qvectors = m.quantized.as_ref().map(qvectors_payload);
    let rtree = m.route.as_ref().map(rtree_payload);
    let drift = m.drift.as_ref().map(drift_payload);

    let mut sections: Vec<(u32, u64)> = vec![
        (SEC_META, meta.len() as u64),
        (SEC_LABELS, labels.len() as u64),
        (SEC_CENTROIDS, centroids.len() as u64),
    ];
    if let Some(g) = &graph {
        sections.push((SEC_GRAPH, g.len() as u64));
    }
    if let Some(len) = vec_len {
        sections.push((SEC_VECTORS, len));
    }
    if let Some(q) = &qvectors {
        sections.push((SEC_QVECTORS, q.len() as u64));
    }
    if let Some(t) = &rtree {
        sections.push((SEC_RTREE, t.len() as u64));
    }
    if let Some(d) = &drift {
        sections.push((SEC_DRIFT, d.len() as u64));
    }
    // One { kind, crc } record per payload section; the in-RAM payloads
    // hash now, vectors hash as they stream, and the CRC section itself
    // (always last in table and file) is written once every record is in.
    let mut crc_records: Vec<(u32, u32)> = vec![
        (SEC_META, crc32(&meta)),
        (SEC_LABELS, crc32(&labels)),
        (SEC_CENTROIDS, crc32(&centroids)),
    ];
    if let Some(g) = &graph {
        crc_records.push((SEC_GRAPH, crc32(g)));
    }
    if let Some(q) = &qvectors {
        crc_records.push((SEC_QVECTORS, crc32(q)));
    }
    if let Some(t) = &rtree {
        crc_records.push((SEC_RTREE, crc32(t)));
    }
    if let Some(d) = &drift {
        crc_records.push((SEC_DRIFT, crc32(d)));
    }
    sections.push((SEC_CRC, 8 * sections.len() as u64));

    // header + table, then offsets assigned in table order, 64-aligned
    let header_len = 16 + 24 * sections.len() as u64;
    let mut offsets = Vec::with_capacity(sections.len());
    let mut at = align_up(header_len);
    for (_, len) in &sections {
        offsets.push(at);
        at = align_up(at + len);
    }

    let mut head = Vec::with_capacity(header_len as usize);
    head.extend_from_slice(MAGIC);
    put_u32(&mut head, V2);
    put_u32(&mut head, sections.len() as u32);
    for ((kind, len), off) in sections.iter().zip(&offsets) {
        put_u32(&mut head, *kind);
        put_u32(&mut head, 0);
        put_u64(&mut head, *off);
        put_u64(&mut head, *len);
    }
    w.write_all(&head)?;
    let mut written = header_len;
    let pad_to = |w: &mut W, written: &mut u64, target: u64| -> std::io::Result<()> {
        debug_assert!(target >= *written);
        let pad = (target - *written) as usize;
        w.write_all(&vec![0u8; pad])?;
        *written = target;
        Ok(())
    };

    for ((kind, _), off) in sections.iter().zip(&offsets) {
        pad_to(w, &mut written, *off)?;
        match *kind {
            SEC_META => {
                w.write_all(&meta)?;
                written += meta.len() as u64;
            }
            SEC_LABELS => {
                w.write_all(&labels)?;
                written += labels.len() as u64;
            }
            SEC_CENTROIDS => {
                w.write_all(&centroids)?;
                written += centroids.len() as u64;
            }
            SEC_GRAPH => {
                let g = graph.as_ref().expect("graph section implies a graph");
                w.write_all(g)?;
                written += g.len() as u64;
            }
            SEC_VECTORS => {
                let v = vectors.expect("vectors section implies a store");
                let mut hasher = Crc32::new();
                let mut hdr = Vec::with_capacity(8);
                put_u64(&mut hdr, v.rows() as u64);
                w.write_all(&hdr)?;
                hasher.update(&hdr);
                let mut cur = v.open();
                let (n, d) = (v.rows(), v.dim());
                let mut lo = 0;
                let mut block_bytes: Vec<u8> = Vec::new();
                while lo < n {
                    let hi = (lo + VEC_STREAM_ROWS).min(n);
                    let block = cur.block(lo, hi);
                    block_bytes.clear();
                    block_bytes.reserve(block.len() * 4);
                    for &x in block {
                        block_bytes.extend_from_slice(&x.to_le_bytes());
                    }
                    w.write_all(&block_bytes)?;
                    hasher.update(&block_bytes);
                    lo = hi;
                }
                written += 8 + 4 * (n as u64) * (d as u64);
                crc_records.push((SEC_VECTORS, hasher.finish()));
            }
            SEC_QVECTORS => {
                let q = qvectors.as_ref().expect("qvectors section implies a quantized store");
                w.write_all(q)?;
                written += q.len() as u64;
            }
            SEC_RTREE => {
                let t = rtree.as_ref().expect("rtree section implies a routing tree");
                w.write_all(t)?;
                written += t.len() as u64;
            }
            SEC_DRIFT => {
                let d = drift.as_ref().expect("drift section implies drift state");
                w.write_all(d)?;
                written += d.len() as u64;
            }
            SEC_CRC => {
                let mut payload = Vec::with_capacity(8 * crc_records.len());
                for (k, crc) in &crc_records {
                    put_u32(&mut payload, *k);
                    put_u32(&mut payload, *crc);
                }
                w.write_all(&payload)?;
                written += payload.len() as u64;
            }
            other => unreachable!("writer emitted unknown section kind {other}"),
        }
    }
    w.flush()
}

// --- section payload parsers (v2) --------------------------------------

struct Meta {
    method: Method,
    threads: usize,
    k: usize,
    dim: usize,
    n_train: usize,
    total_seconds: f64,
    init_seconds: f64,
    graph_seconds: f64,
    history: Vec<IterStat>,
}

fn parse_meta(bytes: &[u8]) -> Result<Meta, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let method = Method::from_tag(r.u8()?)?;
    let threads = (r.u32()? as usize).min(MAX_THREADS);
    let k = r.len_u64("k")?;
    let dim = r.len_u64("dim")?;
    if dim == 0 || dim > (1 << 20) {
        return Err(format!("implausible model dim {dim}"));
    }
    let n_train = r.len_u64("n_train")?;
    let total_seconds = r.f64()?;
    let init_seconds = r.f64()?;
    let graph_seconds = r.f64()?;
    let hist_len = r.len_u64("history length")?;
    let mut history = Vec::with_capacity(hist_len.min(1 << 20));
    for _ in 0..hist_len {
        let iter = r.len_u64("history iter")?;
        let seconds = r.f64()?;
        let distortion = r.f64()?;
        let moves = r.len_u64("history moves")?;
        history.push(IterStat { iter, seconds, distortion, moves });
    }
    r.done("META")?;
    Ok(Meta {
        method,
        threads,
        k,
        dim,
        n_train,
        total_seconds,
        init_seconds,
        graph_seconds,
        history,
    })
}

fn parse_labels(bytes: &[u8]) -> Result<Vec<u32>, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let len = r.len_u64("label count")?;
    let labels = r.u32_vec(len)?;
    r.done("LABELS")?;
    Ok(labels)
}

fn parse_centroids(bytes: &[u8], k: usize, dim: usize) -> Result<VecSet, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let rows = r.len_u64("centroid rows")?;
    if rows != k {
        return Err(format!("centroid rows {rows} != k {k}"));
    }
    let flat = r.f32_vec(checked_mul(rows, dim, "centroid buffer")?)?;
    r.done("CENTROIDS")?;
    Ok(VecSet::from_flat(dim, flat))
}

fn parse_graph(bytes: &[u8], n_train: usize) -> Result<KnnGraph, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let gn = r.len_u64("graph n")?;
    let gk = r.len_u64("graph kappa")?;
    if gn != n_train {
        return Err(format!("graph covers {gn} nodes but the model trained on {n_train}"));
    }
    let cells = checked_mul(gn, gk, "graph buffer")?;
    let ids = r.u32_vec(cells)?;
    let dists = r.f32_vec(cells)?;
    r.done("GRAPH")?;
    KnnGraph::from_parts(gn, gk, ids, dists)
}

fn parse_vectors_eager(bytes: &[u8], n_train: usize, dim: usize) -> Result<VecSet, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let rows = r.len_u64("data rows")?;
    if rows != n_train {
        return Err(format!("embedded {rows} vectors but the model trained on {n_train}"));
    }
    let flat = r.f32_vec(checked_mul(rows, dim, "data buffer")?)?;
    r.done("VECTORS")?;
    Ok(VecSet::from_flat(dim, flat))
}

fn parse_qvectors(bytes: &[u8], n_train: usize, dim: usize) -> Result<QuantizedVecStore, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let rows = r.len_u64("quantized rows")?;
    if rows != n_train {
        return Err(format!("quantized {rows} vectors but the model trained on {n_train}"));
    }
    let min = r.f32_vec(dim)?;
    let scale = r.f32_vec(dim)?;
    let codes = r.take(checked_mul(rows, dim, "code buffer")?)?.to_vec();
    r.done("QVECTORS")?;
    let quant = Sq8Quantizer::from_parts(min, scale)?;
    QuantizedVecStore::from_parts(rows, dim, codes, quant)
}

/// One parsed v2 table entry.
struct Section {
    kind: u32,
    offset: u64,
    len: u64,
}

/// Parse the v2 header + section table from the first bytes of a file
/// or buffer; `total_len` bounds the section extents.
fn parse_table(head: &[u8], total_len: u64) -> Result<Vec<Section>, String> {
    let mut r = Reader { buf: head, pos: 0 };
    if r.take(8)? != MAGIC {
        return Err("not a gkmeans model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version != V2 {
        return Err(format!("internal: parse_table on version {version}"));
    }
    let count = r.u32()? as usize;
    if count > 64 {
        return Err(format!("implausible section count {count}"));
    }
    let mut sections = Vec::with_capacity(count);
    for _ in 0..count {
        let kind = r.u32()?;
        let _reserved = r.u32()?;
        let offset = r.u64()?;
        let len = r.u64()?;
        let end = offset
            .checked_add(len)
            .ok_or_else(|| "section extent overflows".to_string())?;
        if end > total_len {
            return Err(format!(
                "section kind {kind} extent [{offset}, {end}) exceeds file length {total_len}"
            ));
        }
        sections.push(Section { kind, offset, len });
    }
    for need in [SEC_META, SEC_LABELS, SEC_CENTROIDS] {
        if !sections.iter().any(|s| s.kind == need) {
            return Err(format!("missing required section kind {need}"));
        }
    }
    Ok(sections)
}

fn section<'a>(sections: &'a [Section], kind: u32) -> Option<&'a Section> {
    sections.iter().find(|s| s.kind == kind)
}

/// Human name for a section kind (error messages).
fn sec_name(kind: u32) -> String {
    match kind {
        SEC_META => "META".into(),
        SEC_LABELS => "LABELS".into(),
        SEC_CENTROIDS => "CENTROIDS".into(),
        SEC_GRAPH => "GRAPH".into(),
        SEC_VECTORS => "VECTORS".into(),
        SEC_CRC => "CRC".into(),
        SEC_QVECTORS => "QVECTORS".into(),
        SEC_RTREE => "RTREE".into(),
        SEC_DRIFT => "DRIFT".into(),
        other => format!("kind {other}"),
    }
}

/// Parse the CRC section payload: `{ kind u32, crc u32 }` records.
fn parse_crc_records(bytes: &[u8]) -> Result<Vec<(u32, u32)>, String> {
    if bytes.len() % 8 != 0 {
        return Err(format!("CRC section length {} is not a whole number of records", bytes.len()));
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes(c[0..4].try_into().unwrap()),
                u32::from_le_bytes(c[4..8].try_into().unwrap()),
            )
        })
        .collect())
}

/// The stored checksum for `kind`, when the artifact carries one.
fn stored_crc(records: &Option<Vec<(u32, u32)>>, kind: u32) -> Option<u32> {
    records.as_ref().and_then(|r| r.iter().find(|(k, _)| *k == kind).map(|(_, c)| *c))
}

fn crc_mismatch(kind: u32, stored: u32, computed: u32) -> String {
    format!(
        "{} section checksum mismatch (stored {stored:#010x}, computed {computed:#010x})",
        sec_name(kind)
    )
}

#[allow(clippy::too_many_arguments)]
fn assemble(
    meta: Meta,
    labels: Vec<u32>,
    centroids: VecSet,
    graph: Option<KnnGraph>,
    data: Option<ModelVectors>,
    quantized: Option<QuantizedVecStore>,
    route: Option<RouteTree>,
    drift: Option<crate::model::extend::DriftState>,
) -> FittedModel {
    FittedModel {
        method: meta.method,
        k: meta.k,
        dim: meta.dim,
        n_train: meta.n_train,
        threads: meta.threads,
        centroids,
        labels,
        history: meta.history,
        total_seconds: meta.total_seconds,
        init_seconds: meta.init_seconds,
        graph_seconds: meta.graph_seconds,
        graph,
        data,
        quantized,
        route,
        route_min_k: ROUTE_MIN_K,
        drift,
        tombstones: Vec::new(),
    }
}

// --- public surface -----------------------------------------------------

/// Serialize a model to v2 bytes (vectors embedded eagerly — use
/// [`save`] to stream them to a file instead).
pub fn encode(m: &FittedModel) -> Vec<u8> {
    if !m.tombstones.is_empty() {
        // same compact-at-persistence boundary as `save`: tombstones are
        // in-RAM state, never serialized
        let compacted = m.compacted().expect("compacting a valid model cannot fail");
        return encode(&compacted);
    }
    let mut buf = Vec::new();
    let vectors = m.data.as_ref().map(|d| d as &dyn VecStore);
    write_v2(m, vectors, &mut buf).expect("writing to a Vec cannot fail");
    buf
}

/// Deserialize a model from bytes (v1 or v2).  Vector sections are
/// materialized in RAM — bytes have no backing file to page from.
pub fn decode(bytes: &[u8]) -> Result<FittedModel, String> {
    if bytes.len() < 12 {
        return Err("model file truncated before the version field".into());
    }
    if &bytes[..8] != MAGIC {
        return Err("not a gkmeans model file (bad magic)".into());
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    match version {
        V1 => decode_v1(bytes),
        V2 => {
            let count = u32::from_le_bytes(
                bytes
                    .get(12..16)
                    .ok_or("model file truncated in the header")?
                    .try_into()
                    .unwrap(),
            ) as usize;
            if count > 64 {
                return Err(format!("implausible section count {count}"));
            }
            let table_end = 16 + 24 * count;
            let head = bytes
                .get(..table_end)
                .ok_or("model file truncated in the section table")?;
            let sections = parse_table(head, bytes.len() as u64)?;
            fn slice_of<'b>(bytes: &'b [u8], s: &Section) -> &'b [u8] {
                &bytes[s.offset as usize..(s.offset + s.len) as usize]
            }
            let get = |s: &Section| slice_of(bytes, s);
            // Verify every checksummed section before parsing anything.
            if let Some(c) = section(&sections, SEC_CRC) {
                for (kind, stored) in parse_crc_records(get(c))? {
                    let s = section(&sections, kind).ok_or_else(|| {
                        format!("checksum record names missing section {}", sec_name(kind))
                    })?;
                    let computed = crc32(get(s));
                    if computed != stored {
                        return Err(crc_mismatch(kind, stored, computed));
                    }
                }
            }
            let meta = parse_meta(get(section(&sections, SEC_META).unwrap()))?;
            let labels = parse_labels(get(section(&sections, SEC_LABELS).unwrap()))?;
            let centroids =
                parse_centroids(get(section(&sections, SEC_CENTROIDS).unwrap()), meta.k, meta.dim)?;
            let graph = match section(&sections, SEC_GRAPH) {
                Some(s) => Some(parse_graph(get(s), meta.n_train)?),
                None => None,
            };
            let data = match section(&sections, SEC_VECTORS) {
                Some(s) => Some(ModelVectors::Ram(parse_vectors_eager(
                    get(s),
                    meta.n_train,
                    meta.dim,
                )?)),
                None => None,
            };
            let quantized = match section(&sections, SEC_QVECTORS) {
                Some(s) => Some(parse_qvectors(get(s), meta.n_train, meta.dim)?),
                None => None,
            };
            let route = match section(&sections, SEC_RTREE) {
                Some(s) => Some(parse_rtree(get(s), meta.k, meta.dim)?),
                None => None,
            };
            let drift = match section(&sections, SEC_DRIFT) {
                Some(s) => Some(parse_drift(get(s), meta.k)?),
                None => None,
            };
            if labels.len() != meta.n_train {
                return Err(format!(
                    "label count {} != n_train {}",
                    labels.len(),
                    meta.n_train
                ));
            }
            Ok(assemble(meta, labels, centroids, graph, data, quantized, route, drift))
        }
        other => Err(format!("unsupported model version {other} (this build reads 1 and 2)")),
    }
}

/// Write a model to `path` in the v2 layout.  The vectors section (if
/// any) is streamed block by block, so saving a disk-backed model never
/// materializes its vectors in RAM.
///
/// The write is crash-safe: it goes to a temporary sibling first, the
/// file is fsynced, renamed over the target, and the parent directory
/// is fsynced — a crash (or power cut) at any point leaves either the
/// complete old artifact or the complete new one on disk, never a torn
/// file.  The rename also means any artifact another model is currently
/// paging from — including this model's own backing file — is never
/// truncated mid-read, and a failed save never destroys a pre-existing
/// artifact.
pub fn save(m: &FittedModel, path: &Path) -> RtResult<()> {
    // Pending removals compact at the save boundary: the persisted
    // artifact drops tombstoned rows (labels / vectors / codes filtered,
    // graph remapped) so readers never see them.  The in-RAM model keeps
    // its tombstones — `save` takes `&self` — and keeps filtering.
    if !m.tombstones.is_empty() {
        let compacted = m.compacted()?;
        return save(&compacted, path);
    }
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(format!(".tmp.{}", std::process::id()));
    let target = path.with_file_name(name);
    let vectors: Option<&dyn VecStore> = m.data.as_ref().map(|mv| mv as &dyn VecStore);
    let write = || -> std::io::Result<()> {
        let f = std::fs::File::create(&target)?;
        {
            let mut w = std::io::BufWriter::new(&f);
            write_v2(m, vectors, &mut w)?;
            w.flush()?;
        }
        f.sync_all()
    };
    if let Err(e) = write() {
        std::fs::remove_file(&target).ok();
        return Err(RtError::msg(format!("{}: {e}", target.display())));
    }
    std::fs::rename(&target, path)
        .map_err(|e| RtError::msg(format!("{}: {e}", path.display())))?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(if dir.as_os_str().is_empty() {
            Path::new(".")
        } else {
            dir
        }) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Read one section's bytes out of an open model file.
fn read_section_bytes(
    f: &mut std::fs::File,
    path: &Path,
    s: &Section,
) -> RtResult<Vec<u8>> {
    use std::io::{Read, Seek, SeekFrom};
    let mut buf = vec![0u8; s.len as usize];
    f.seek(SeekFrom::Start(s.offset))
        .and_then(|_| f.read_exact(&mut buf))
        .map_err(|e| {
            RtError::corrupt(
                sec_name(s.kind),
                format!("{}: reading section: {e}", path.display()),
            )
        })?;
    Ok(buf)
}

/// Read a model from `path` (v1 or v2).  A v2 vectors section is
/// **not** materialized: the model pages it from disk on demand
/// ([`ModelVectors::Disk`]), so opening a large artifact stays cheap —
/// but when the artifact carries a CRC section, every section is
/// verified first (the vectors payload streams through the hash in
/// bounded blocks, one sequential pass at disk bandwidth).  Corruption
/// — bad magic, truncation, parse failures, checksum mismatches —
/// surfaces as [`RtError`] values with
/// [`is_corrupt`](RtError::is_corrupt) set and the damaged section
/// named; plain I/O failures (missing file, permissions) stay generic.
pub fn load(path: &Path) -> RtResult<FittedModel> {
    use std::io::{Read, Seek, SeekFrom};
    let corrupt = |section: &str, detail: String| RtError::corrupt(section, detail);
    let mut f = std::fs::File::open(path)
        .map_err(|e| RtError::msg(format!("{}: {e}", path.display())))?;
    let total_len = f
        .metadata()
        .map_err(|e| RtError::msg(format!("{}: {e}", path.display())))?
        .len();
    let mut head16 = [0u8; 16];
    f.read_exact(&mut head16)
        .map_err(|_| corrupt("header", format!("{}: truncated model header", path.display())))?;
    if &head16[..8] != MAGIC {
        return Err(corrupt("header", "not a gkmeans model file (bad magic)".into()));
    }
    let version = u32::from_le_bytes(head16[8..12].try_into().unwrap());
    if version == V1 {
        let bytes =
            std::fs::read(path).map_err(|e| RtError::msg(format!("{}: {e}", path.display())))?;
        return decode_v1(&bytes).map_err(|e| corrupt("v1", e));
    }
    if version != V2 {
        return Err(RtError::msg(format!(
            "unsupported model version {version} (this build reads 1 and 2)"
        )));
    }
    let count = u32::from_le_bytes(head16[12..16].try_into().unwrap()) as usize;
    if count > 64 {
        return Err(corrupt("header", format!("implausible section count {count}")));
    }
    let mut head = head16.to_vec();
    let mut table = vec![0u8; 24 * count];
    f.read_exact(&mut table)
        .map_err(|_| corrupt("header", format!("{}: truncated section table", path.display())))?;
    head.extend_from_slice(&table);
    let sections = parse_table(&head, total_len).map_err(|e| corrupt("header", e))?;
    let crcs = match section(&sections, SEC_CRC) {
        Some(s) => Some(
            parse_crc_records(&read_section_bytes(&mut f, path, s)?)
                .map_err(|e| corrupt("CRC", e))?,
        ),
        None => None,
    };
    let mut read_verified = |s: &Section| -> RtResult<Vec<u8>> {
        let buf = read_section_bytes(&mut f, path, s)?;
        if let Some(stored) = stored_crc(&crcs, s.kind) {
            let computed = crc32(&buf);
            if computed != stored {
                return Err(RtError::corrupt(
                    sec_name(s.kind),
                    crc_mismatch(s.kind, stored, computed),
                ));
            }
        }
        Ok(buf)
    };
    let meta = parse_meta(&read_verified(section(&sections, SEC_META).unwrap())?)
        .map_err(|e| corrupt("META", e))?;
    let labels = parse_labels(&read_verified(section(&sections, SEC_LABELS).unwrap())?)
        .map_err(|e| corrupt("LABELS", e))?;
    let centroids = parse_centroids(
        &read_verified(section(&sections, SEC_CENTROIDS).unwrap())?,
        meta.k,
        meta.dim,
    )
    .map_err(|e| corrupt("CENTROIDS", e))?;
    let graph = match section(&sections, SEC_GRAPH) {
        Some(s) => Some(
            parse_graph(&read_verified(s)?, meta.n_train).map_err(|e| corrupt("GRAPH", e))?,
        ),
        None => None,
    };
    // QVECTORS load eagerly: the codes being RAM-resident is the point
    // (the f32 vectors stay lazily paged for the exact re-rank reads).
    let quantized = match section(&sections, SEC_QVECTORS) {
        Some(s) => Some(
            parse_qvectors(&read_verified(s)?, meta.n_train, meta.dim)
                .map_err(|e| corrupt("QVECTORS", e))?,
        ),
        None => None,
    };
    // RTREE loads eagerly too — routing state must be RAM-resident for
    // the descent's contiguous-block kernel calls.
    let route = match section(&sections, SEC_RTREE) {
        Some(s) => Some(
            parse_rtree(&read_verified(s)?, meta.k, meta.dim)
                .map_err(|e| corrupt("RTREE", e))?,
        ),
        None => None,
    };
    let drift = match section(&sections, SEC_DRIFT) {
        Some(s) => {
            Some(parse_drift(&read_verified(s)?, meta.k).map_err(|e| corrupt("DRIFT", e))?)
        }
        None => None,
    };
    let data = match section(&sections, SEC_VECTORS) {
        Some(s) => {
            if s.len < 8 {
                return Err(corrupt(
                    "VECTORS",
                    "vectors section shorter than its row header".into(),
                ));
            }
            let mut hdr = [0u8; 8];
            f.seek(SeekFrom::Start(s.offset))
                .and_then(|_| f.read_exact(&mut hdr))
                .map_err(|e| {
                    corrupt("VECTORS", format!("{}: reading vectors header: {e}", path.display()))
                })?;
            let rows = u64::from_le_bytes(hdr) as usize;
            if rows != meta.n_train {
                return Err(corrupt(
                    "VECTORS",
                    format!("embedded {rows} vectors but the model trained on {}", meta.n_train),
                ));
            }
            let payload = (rows as u64)
                .checked_mul(meta.dim as u64)
                .and_then(|c| c.checked_mul(4))
                .and_then(|c| c.checked_add(8))
                .ok_or_else(|| corrupt("VECTORS", "vectors section size overflows".into()))?;
            if payload != s.len {
                return Err(corrupt(
                    "VECTORS",
                    format!("vectors section length {} != expected {payload}", s.len),
                ));
            }
            // Stream the (not-materialized) vectors payload through the
            // hash in bounded blocks: integrity is checked up front, the
            // rows still page lazily afterwards.
            if let Some(stored) = stored_crc(&crcs, SEC_VECTORS) {
                f.seek(SeekFrom::Start(s.offset)).map_err(|e| {
                    corrupt("VECTORS", format!("{}: seeking for checksum: {e}", path.display()))
                })?;
                let mut hasher = Crc32::new();
                let mut block = vec![0u8; 1 << 20];
                let mut remaining = s.len;
                while remaining > 0 {
                    let take = remaining.min(block.len() as u64) as usize;
                    f.read_exact(&mut block[..take]).map_err(|e| {
                        corrupt(
                            "VECTORS",
                            format!("{}: reading for checksum: {e}", path.display()),
                        )
                    })?;
                    hasher.update(&block[..take]);
                    remaining -= take as u64;
                }
                let computed = hasher.finish();
                if computed != stored {
                    return Err(corrupt(
                        "VECTORS",
                        crc_mismatch(SEC_VECTORS, stored, computed),
                    ));
                }
            }
            Some(ModelVectors::Disk(
                ChunkedVecStore::from_section(path, s.offset + 8, rows, meta.dim)
                    .map_err(|e| corrupt("VECTORS", e))?,
            ))
        }
        None => None,
    };
    if labels.len() != meta.n_train {
        return Err(corrupt(
            "LABELS",
            format!("label count {} != n_train {}", labels.len(), meta.n_train),
        ));
    }
    Ok(assemble(meta, labels, centroids, graph, data, quantized, route, drift))
}

// --- v1 (legacy) --------------------------------------------------------

/// Serialize a model in the legacy v1 single-blob layout.  Kept for
/// fixtures and migration tests; [`save`] always writes v2.
pub fn encode_v1(m: &FittedModel) -> Vec<u8> {
    let data = m.data.as_ref().map(|d| d.to_vecset());
    let mut buf = Vec::with_capacity(
        64 + m.labels.len() * 4
            + m.centroids.flat().len() * 4
            + m.graph.as_ref().map_or(0, |g| g.ids_flat().len() * 8)
            + data.as_ref().map_or(0, |d| d.flat().len() * 4),
    );
    buf.extend_from_slice(MAGIC);
    put_u32(&mut buf, V1);
    buf.push(m.method.tag());
    let mut flags = 0u8;
    if m.graph.is_some() {
        flags |= FLAG_GRAPH;
    }
    if data.is_some() {
        flags |= FLAG_DATA;
    }
    buf.push(flags);
    put_u32(&mut buf, m.threads as u32);
    put_u64(&mut buf, m.k as u64);
    put_u64(&mut buf, m.dim as u64);
    put_u64(&mut buf, m.n_train as u64);
    put_f64(&mut buf, m.total_seconds);
    put_f64(&mut buf, m.init_seconds);
    put_f64(&mut buf, m.graph_seconds);
    put_u64(&mut buf, m.history.len() as u64);
    for h in &m.history {
        put_u64(&mut buf, h.iter as u64);
        put_f64(&mut buf, h.seconds);
        put_f64(&mut buf, h.distortion);
        put_u64(&mut buf, h.moves as u64);
    }
    put_u64(&mut buf, m.labels.len() as u64);
    for &l in &m.labels {
        put_u32(&mut buf, l);
    }
    put_u64(&mut buf, m.centroids.rows() as u64);
    for &v in m.centroids.flat() {
        put_f32(&mut buf, v);
    }
    if let Some(g) = &m.graph {
        put_u64(&mut buf, g.n() as u64);
        put_u64(&mut buf, g.kappa() as u64);
        for &id in g.ids_flat() {
            put_u32(&mut buf, id);
        }
        for &d in g.dists_flat() {
            put_f32(&mut buf, d);
        }
    }
    if let Some(d) = &data {
        put_u64(&mut buf, d.rows() as u64);
        for &v in d.flat() {
            put_f32(&mut buf, v);
        }
    }
    buf
}

/// Deserialize the legacy v1 layout (whole buffer, magic included).
fn decode_v1(bytes: &[u8]) -> Result<FittedModel, String> {
    let mut r = Reader { buf: bytes, pos: 0 };
    if r.take(8)? != MAGIC {
        return Err("not a gkmeans model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version != V1 {
        return Err(format!("internal: decode_v1 on version {version}"));
    }
    let method = Method::from_tag(r.u8()?)?;
    let flags = r.u8()?;
    let threads = (r.u32()? as usize).min(MAX_THREADS);
    let k = r.len_u64("k")?;
    let dim = r.len_u64("dim")?;
    if dim == 0 {
        return Err("model dim is zero".into());
    }
    let n_train = r.len_u64("n_train")?;
    let total_seconds = r.f64()?;
    let init_seconds = r.f64()?;
    let graph_seconds = r.f64()?;
    let hist_len = r.len_u64("history length")?;
    let mut history = Vec::with_capacity(hist_len.min(1 << 20));
    for _ in 0..hist_len {
        let iter = r.len_u64("history iter")?;
        let seconds = r.f64()?;
        let distortion = r.f64()?;
        let moves = r.len_u64("history moves")?;
        history.push(IterStat { iter, seconds, distortion, moves });
    }
    let lab_len = r.len_u64("label count")?;
    let labels = r.u32_vec(lab_len)?;
    let crows = r.len_u64("centroid rows")?;
    if crows != k {
        return Err(format!("centroid rows {crows} != k {k}"));
    }
    let cflat = r.f32_vec(checked_mul(crows, dim, "centroid buffer")?)?;
    let centroids = VecSet::from_flat(dim, cflat);
    let graph = if flags & FLAG_GRAPH != 0 {
        let gn = r.len_u64("graph n")?;
        let gk = r.len_u64("graph kappa")?;
        if gn != n_train {
            return Err(format!("graph covers {gn} nodes but the model trained on {n_train}"));
        }
        let cells = checked_mul(gn, gk, "graph buffer")?;
        let ids = r.u32_vec(cells)?;
        let dists = r.f32_vec(cells)?;
        Some(KnnGraph::from_parts(gn, gk, ids, dists)?)
    } else {
        None
    };
    let data = if flags & FLAG_DATA != 0 {
        let rows = r.len_u64("data rows")?;
        if rows != n_train {
            return Err(format!("embedded {rows} vectors but the model trained on {n_train}"));
        }
        let flat = r.f32_vec(checked_mul(rows, dim, "data buffer")?)?;
        Some(ModelVectors::Ram(VecSet::from_flat(dim, flat)))
    } else {
        None
    };
    if r.pos != bytes.len() {
        return Err(format!(
            "{} trailing bytes after model payload",
            bytes.len() - r.pos
        ));
    }
    Ok(FittedModel {
        method,
        k,
        dim,
        n_train,
        threads,
        centroids,
        labels,
        history,
        total_seconds,
        init_seconds,
        graph_seconds,
        graph,
        data,
        quantized: None,
        route: None,
        route_min_k: ROUTE_MIN_K,
        drift: None,
        tombstones: Vec::new(),
    })
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn checked_mul(a: usize, b: usize, what: &str) -> Result<usize, String> {
    a.checked_mul(b).ok_or_else(|| format!("{what} size overflows"))
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| "model file offset overflows".to_string())?;
        if end > self.buf.len() {
            return Err(format!(
                "model file truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A u64 length/count field, checked to fit in usize.
    fn len_u64(&mut self, what: &str) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("{what} {v} does not fit in usize"))
    }

    fn u32_vec(&mut self, len: usize) -> Result<Vec<u32>, String> {
        let raw = self.take(checked_mul(len, 4, "u32 buffer")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32_vec(&mut self, len: usize) -> Result<Vec<f32>, String> {
        let raw = self.take(checked_mul(len, 4, "f32 buffer")?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Whole-payload sections must consume every byte.
    fn done(&mut self, what: &str) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{} trailing bytes in {what} section",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::model::{Clusterer, GkMeans, Lloyd, RunContext};
    use crate::runtime::Backend;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gkm_model_{}_{name}", std::process::id()))
    }

    fn graph_model() -> crate::model::FittedModel {
        let data = blobs(&BlobSpec::quick(250, 5, 4), 7);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(4).keep_data(true);
        GkMeans::new(4).kappa(5).tau(2).xi(25).fit(&data, &ctx)
    }

    fn assert_models_bit_identical(a: &FittedModel, b: &FittedModel) {
        assert_eq!(a.method, b.method);
        assert_eq!(a.k, b.k);
        assert_eq!(a.dim, b.dim);
        assert_eq!(a.n_train, b.n_train);
        assert_eq!(a.threads, b.threads);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.history.len(), b.history.len());
        assert_eq!(a.total_seconds.to_bits(), b.total_seconds.to_bits());
        assert_eq!(a.init_seconds.to_bits(), b.init_seconds.to_bits());
        assert_eq!(a.graph_seconds.to_bits(), b.graph_seconds.to_bits());
        assert_eq!(a.centroids.flat().len(), b.centroids.flat().len());
        for (x, y) in a.centroids.flat().iter().zip(b.centroids.flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.graph.is_some(), b.graph.is_some());
        if let (Some(ga), Some(gb)) = (&a.graph, &b.graph) {
            assert_eq!(ga.ids_flat(), gb.ids_flat());
            for (x, y) in ga.dists_flat().iter().zip(gb.dists_flat()) {
                assert_eq!(x.to_bits(), y.to_bits(), "graph distances must round-trip bitwise");
            }
        }
        assert_eq!(a.data.is_some(), b.data.is_some());
        if let (Some(da), Some(db)) = (&a.data, &b.data) {
            let (da, db) = (da.to_vecset(), db.to_vecset());
            assert_eq!(da.flat().len(), db.flat().len());
            for (x, y) in da.flat().iter().zip(db.flat()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(a.quantized.is_some(), b.quantized.is_some());
        if let (Some(qa), Some(qb)) = (&a.quantized, &b.quantized) {
            assert_eq!(qa.codes(), qb.codes(), "SQ8 codes must round-trip bytewise");
            assert_eq!(qa.quantizer(), qb.quantizer());
        }
        assert_eq!(a.route, b.route, "routing tree must round-trip exactly");
    }

    #[test]
    fn encode_decode_bit_identical() {
        let model = graph_model();
        let back = decode(&encode(&model)).unwrap();
        assert_models_bit_identical(&model, &back);
        assert!(back.data.as_ref().unwrap().is_resident(), "decode is eager");
    }

    #[test]
    fn save_load_file_roundtrip() {
        let data = blobs(&BlobSpec::quick(120, 4, 3), 8);
        let b = Backend::native();
        let model = Lloyd::new(3).fit(&data, &RunContext::new(&b).max_iters(5));
        let path = tmp("roundtrip.gkm");
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        assert_eq!(back.labels, model.labels);
        assert!(back.graph.is_none() && back.data.is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_load_pages_vectors_lazily_and_serves() {
        let model = graph_model();
        let path = tmp("lazy.gkm");
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        let vecs = back.data.as_ref().unwrap();
        assert!(!vecs.is_resident(), "v2 load must page vectors from disk");
        assert_models_bit_identical(&model, &back);
        // the paged store serves the same rows the RAM copy holds
        let ram = model.data.as_ref().unwrap().to_vecset();
        for i in (0..250).step_by(37) {
            let row = vecs.fetch_row(i);
            for (a, b) in row.iter().zip(ram.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_artifacts_still_load_and_resave_as_v2() {
        let model = graph_model();
        let v1 = encode_v1(&model);
        // v1 bytes decode
        let from_v1 = decode(&v1).unwrap();
        assert_models_bit_identical(&model, &from_v1);
        // v1 file loads, re-saves as v2, loads again — bit-exact
        let p1 = tmp("legacy.gkm");
        std::fs::write(&p1, &v1).unwrap();
        let loaded = FittedModel::load(&p1).unwrap();
        assert_models_bit_identical(&model, &loaded);
        let p2 = tmp("migrated.gkm");
        loaded.save(&p2).unwrap();
        let migrated = FittedModel::load(&p2).unwrap();
        assert_models_bit_identical(&model, &migrated);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn resave_over_own_backing_file_is_safe() {
        let model = graph_model();
        let path = tmp("self.gkm");
        model.save(&path).unwrap();
        let back = FittedModel::load(&path).unwrap();
        assert!(!back.data.as_ref().unwrap().is_resident());
        // saving the lazily-loaded model over its own backing file must
        // snapshot the vectors first, not read while truncating
        back.save(&path).unwrap();
        let again = FittedModel::load(&path).unwrap();
        assert_models_bit_identical(&model, &again);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sections_are_aligned() {
        let model = graph_model();
        let bytes = encode(&model);
        let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        assert!(count >= 4);
        for t in 0..count {
            let at = 16 + 24 * t;
            let offset = u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap());
            assert_eq!(offset % ALIGN, 0, "section {t} offset {offset} unaligned");
        }
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        let data = blobs(&BlobSpec::quick(60, 3, 2), 9);
        let b = Backend::native();
        let model = Lloyd::new(2).fit(&data, &RunContext::new(&b).max_iters(3));
        let bytes = encode(&model);
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().contains("magic"));
        // bad version
        let mut bad = bytes.clone();
        bad[8] = 99;
        assert!(decode(&bad).unwrap_err().contains("version"));
        // truncation at every eighth boundary must error, never panic
        for cut in (0..bytes.len() - 1).step_by(8) {
            assert!(decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // v1 truncation too
        let v1 = encode_v1(&model);
        for cut in (0..v1.len() - 1).step_by(8) {
            assert!(decode(&v1[..cut]).is_err(), "v1 cut at {cut}");
        }
        // v1 trailing garbage
        let mut long = v1.clone();
        long.push(0);
        assert!(decode(&long).unwrap_err().contains("trailing"));
    }

    /// The v2 table entry for `kind`: `(offset, len)`.
    fn table_entry(bytes: &[u8], kind: u32) -> (usize, usize) {
        let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        for t in 0..count {
            let at = 16 + 24 * t;
            if u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) == kind {
                let off = u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap());
                let len = u64::from_le_bytes(bytes[at + 16..at + 24].try_into().unwrap());
                return (off as usize, len as usize);
            }
        }
        panic!("no section kind {kind} in table");
    }

    #[test]
    fn crc_section_rejects_single_flipped_bytes() {
        let model = graph_model();
        let bytes = encode(&model);
        // every payload section is covered by a CRC record
        let (crc_off, crc_len) = table_entry(&bytes, SEC_CRC);
        assert_eq!(crc_len % 8, 0);
        let covered: Vec<u32> = bytes[crc_off..crc_off + crc_len]
            .chunks_exact(8)
            .map(|c| u32::from_le_bytes(c[..4].try_into().unwrap()))
            .collect();
        for kind in [SEC_META, SEC_LABELS, SEC_CENTROIDS, SEC_GRAPH, SEC_VECTORS] {
            assert!(covered.contains(&kind), "no CRC record for kind {kind}");
        }
        // a flipped byte in any eager payload fails the checksum in decode
        for kind in [SEC_META, SEC_LABELS, SEC_CENTROIDS, SEC_GRAPH] {
            let (off, len) = table_entry(&bytes, kind);
            let mut bad = bytes.clone();
            bad[off + len / 2] ^= 0xFF;
            let err = decode(&bad).unwrap_err();
            assert!(err.contains("checksum mismatch"), "kind {kind}: {err}");
        }
    }

    #[test]
    fn load_rejects_corrupt_files_with_typed_section_errors() {
        let model = graph_model();
        let path = tmp("corrupt.gkm");
        model.save(&path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // damage an eager section (CENTROIDS) and the lazily-paged
        // VECTORS payload: both must be caught at load, with the error
        // typed as corruption and naming the section.
        for (kind, name) in [(SEC_CENTROIDS, "CENTROIDS"), (SEC_VECTORS, "VECTORS")] {
            let (off, len) = table_entry(&clean, kind);
            let mut bad = clean.clone();
            bad[off + len / 2] ^= 0xFF;
            std::fs::write(&path, &bad).unwrap();
            let err = FittedModel::load(&path).unwrap_err();
            assert!(err.is_corrupt(), "{name}: {err}");
            assert!(err.to_string().contains(name), "{name}: {err}");
            assert!(err.to_string().contains("checksum mismatch"), "{name}: {err}");
        }
        // the pristine bytes still load
        std::fs::write(&path, &clean).unwrap();
        FittedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_artifacts_without_crc_section_still_load() {
        let model = graph_model();
        let bytes = encode(&model);
        // drop the trailing CRC table entry, leaving its payload as
        // ignored slack — exactly what a pre-CRC v2 writer produced
        let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let last = 16 + 24 * (count - 1);
        assert_eq!(
            u32::from_le_bytes(bytes[last..last + 4].try_into().unwrap()),
            SEC_CRC,
            "CRC section must be the last table entry"
        );
        let mut old = bytes.clone();
        old[12..16].copy_from_slice(&((count - 1) as u32).to_le_bytes());
        let back = decode(&old).unwrap();
        assert_models_bit_identical(&model, &back);
        let path = tmp("nocrc.gkm");
        std::fs::write(&path, &old).unwrap();
        let loaded = FittedModel::load(&path).unwrap();
        assert_models_bit_identical(&model, &loaded);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quantized_model_roundtrips_and_is_checksummed() {
        let mut model = graph_model();
        model.quantize_sq8(0).unwrap();
        // bytes round trip
        let back = decode(&encode(&model)).unwrap();
        assert_models_bit_identical(&model, &back);
        // file round trip: QVECTORS loads eagerly, vectors stay lazy
        let path = tmp("quant.gkm");
        model.save(&path).unwrap();
        let loaded = FittedModel::load(&path).unwrap();
        assert!(loaded.quantized.is_some());
        assert!(!loaded.data.as_ref().unwrap().is_resident());
        assert_models_bit_identical(&model, &loaded);
        // a flipped code byte is caught by the QVECTORS checksum
        let clean = std::fs::read(&path).unwrap();
        let (off, len) = table_entry(&clean, SEC_QVECTORS);
        let mut bad = clean.clone();
        bad[off + len - 1] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = FittedModel::load(&path).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
        assert!(err.to_string().contains("QVECTORS"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    /// `graph_model()` (k = 4) with a branch-2 routing tree attached —
    /// multi-level, with reps populated from the training labels.
    fn routed_model() -> crate::model::FittedModel {
        let mut model = graph_model();
        let params = crate::gkm::tree::RouteTreeParams { branch: 2, ..Default::default() };
        model.build_route(&params);
        let t = model.route.as_ref().unwrap();
        assert!(t.nodes() > 1, "branch-2 tree over k=4 must actually split");
        assert!(t.has_reps(), "labels are present, reps must be attached");
        model
    }

    #[test]
    fn routed_model_roundtrips_and_is_checksummed() {
        let model = routed_model();
        // bytes round trip (assert_models_bit_identical checks `route`)
        let back = decode(&encode(&model)).unwrap();
        assert_models_bit_identical(&model, &back);
        // file round trip: RTREE loads eagerly alongside lazy vectors
        let path = tmp("routed.gkm");
        model.save(&path).unwrap();
        let loaded = FittedModel::load(&path).unwrap();
        assert!(loaded.route.is_some());
        assert!(!loaded.data.as_ref().unwrap().is_resident());
        assert_models_bit_identical(&model, &loaded);
        // a flipped routing-vector byte is caught by the RTREE checksum
        let clean = std::fs::read(&path).unwrap();
        let (off, len) = table_entry(&clean, SEC_RTREE);
        let mut bad = clean.clone();
        bad[off + len / 2] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = FittedModel::load(&path).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
        assert!(err.to_string().contains("RTREE"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pre_routing_readers_skip_the_rtree_section() {
        // Simulate an older reader (no SEC_RTREE) on a routed artifact:
        // relabel the RTREE table entry — and its CRC record — as an
        // unknown kind.  The model must load with the tree dropped and
        // everything else intact, which is exactly what a pre-routing
        // binary does with the real kind-8 entry.
        let model = routed_model();
        let bytes = encode(&model);
        const UNKNOWN: u32 = 99;
        let mut old = bytes.clone();
        let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let mut patched = false;
        for t in 0..count {
            let at = 16 + 24 * t;
            if u32::from_le_bytes(old[at..at + 4].try_into().unwrap()) == SEC_RTREE {
                old[at..at + 4].copy_from_slice(&UNKNOWN.to_le_bytes());
                patched = true;
            }
        }
        assert!(patched, "routed artifact must carry an RTREE table entry");
        let (crc_off, crc_len) = table_entry(&old, SEC_CRC);
        for rec in 0..crc_len / 8 {
            let at = crc_off + 8 * rec;
            if u32::from_le_bytes(old[at..at + 4].try_into().unwrap()) == SEC_RTREE {
                old[at..at + 4].copy_from_slice(&UNKNOWN.to_le_bytes());
            }
        }
        let back = decode(&old).unwrap();
        assert!(back.route.is_none(), "unknown section kinds must be skipped");
        assert_eq!(back.labels, model.labels);
        assert_eq!(back.centroids.flat(), model.centroids.flat());
        let path = tmp("preroute.gkm");
        std::fs::write(&path, &old).unwrap();
        let loaded = FittedModel::load(&path).unwrap();
        assert!(loaded.route.is_none());
        assert_eq!(loaded.labels, model.labels);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rtree_parser_rejects_structurally_corrupt_trees() {
        // Strip the CRC section (count − 1: it is the last table entry)
        // so the byte flip reaches the parser, then break a leaf member
        // id: from_parts must reject it — hostile routing payloads can
        // fail to load but never mis-route.
        let model = routed_model();
        let bytes = encode(&model);
        let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let last = 16 + 24 * (count - 1);
        assert_eq!(
            u32::from_le_bytes(bytes[last..last + 4].try_into().unwrap()),
            SEC_CRC,
            "CRC section must be the last table entry"
        );
        let mut bad = bytes.clone();
        bad[12..16].copy_from_slice(&((count - 1) as u32).to_le_bytes());
        assert!(decode(&bad).is_ok(), "CRC-stripped routed artifact must still load");
        // payload tail: …, u64 mlen, k × u32 member_ids, u64 rlen,
        // k × u32 reps — poke the high byte of the last member id
        let (off, len) = table_entry(&bad, SEC_RTREE);
        let k = model.k;
        bad[off + len - 8 - 4 * k - 1] = 0xFF;
        let err = decode(&bad).unwrap_err();
        assert!(err.contains("member id"), "{err}");
    }

    #[test]
    fn rejects_out_of_bounds_sections() {
        let model = graph_model();
        let mut bytes = encode(&model);
        // corrupt the first section's length to overrun the buffer
        let len_at = 16 + 16;
        bytes[len_at..len_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("exceeds") || err.contains("overflows"), "{err}");
    }
}
