//! Incremental clustering: grow a [`FittedModel`] in place instead of
//! refitting from scratch.
//!
//! [`FittedModel::extend`] turns the fitted artifact into a *living
//! index*: new rows are assigned through the existing routed / flat
//! prediction path, appended to the labels / vectors / SQ8 codes the
//! model already carries, and stitched into the KNN graph with
//! **localized joins** — each new row enters the graph by a seeded ANN
//! search from its assigned cell's representative row (Cluster-Closure
//! style neighborhood candidates, Wang et al.), folds the exact-distance
//! candidate pool into its neighbor list with [`KnnGraph::update_pair`],
//! and then runs a bounded number of NN-Descent-style
//! neighbor-of-neighbor expansion rounds.  Nothing outside the touched
//! neighborhoods is revisited.
//!
//! A **drift trigger** keeps clustering quality honest without global
//! refits: the first drift-checked extend captures a per-cell mean
//! distortion baseline ([`DriftState`], persisted as the GKMODEL `DRIFT`
//! section); cells whose distortion rises past `baseline · (1 + T)`
//! after an extend are *dirty* and get bounded Δℐ refinement epochs
//! (the paper's Alg. 3 move rule, [`Clustering::delta_i`] /
//! [`Clustering::apply_move`]) over their members only.  Persistently
//! dirty, oversized cells split in two; the new centroid appends as a
//! routing-tree leaf with a subtree-local re-split
//! ([`RouteTree::insert_centroid`]) — never a full tree rebuild.
//!
//! Determinism contract (pinned by `tests/extend.rs`): with refinement
//! off, extending by a batch is **bit-identical** to extending
//! row-by-row — new rows are processed serially in append order, every
//! search seed is derived from the assigned cell (no RNG anywhere on
//! the path), and the graph/labels/codes a batch produces equal the
//! ones m single-row extends produce.
//!
//! [`FittedModel::remove`] tombstones rows: they vanish from search
//! results immediately and are physically compacted away by the next
//! [`FittedModel::save`] (labels / vectors / codes filtered, graph
//! remapped, reps recomputed).

use std::collections::HashSet;

use crate::data::matrix::VecSet;
use crate::data::quant::QuantizedVecStore;
use crate::data::store::VecStore;
use crate::gkm::ann;
use crate::gkm::construct;
use crate::gkm::tree;
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::Clustering;
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::model::fitted::ModelVectors;
use crate::model::FittedModel;
use crate::runtime::{Backend, RtError, RtResult};

/// Knobs for [`FittedModel::extend_with`].  The default — refinement
/// off — is the pinned-deterministic configuration.
#[derive(Debug, Clone)]
pub struct ExtendParams {
    /// Drift threshold `T`: after the append, cells whose mean
    /// distortion exceeds `baseline · (1 + T)` get Δℐ refinement.
    /// `None` (the default) disables the drift trigger entirely.
    pub refine_drift: Option<f64>,
    /// Bounded refinement epochs over dirty cells (per extend).
    pub refine_epochs: usize,
    /// NN-Descent-style neighbor-of-neighbor expansion rounds per new
    /// row during graph repair.
    pub join_rounds: usize,
    /// Candidate-pool width for the repair's seeded graph search
    /// (`0` = auto: `max(64, 4·κ)`).
    pub repair_ef: usize,
    /// A still-dirty cell with `count ≥ split_factor · n/k` (and ≥ 8
    /// members) splits into two centroids; `0.0` disables splitting.
    /// Only consulted when `refine_drift` is set.
    pub split_factor: f64,
    /// Seed for the refinement-split 2-means calls (the repair path
    /// itself draws no randomness).
    pub seed: u64,
}

impl Default for ExtendParams {
    fn default() -> ExtendParams {
        ExtendParams {
            refine_drift: None,
            refine_epochs: 2,
            join_rounds: 1,
            repair_ef: 0,
            split_factor: 2.0,
            seed: 20170707,
        }
    }
}

/// What one [`FittedModel::extend_with`] call did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExtendReport {
    /// Rows appended.
    pub added: usize,
    /// `n_train` before / after the append.
    pub n_before: usize,
    pub n_after: usize,
    /// Distinct cells the new rows were assigned to.
    pub cells_touched: usize,
    /// Graph neighbor-list updates applied during repair.
    pub graph_updates: usize,
    /// Cells the drift trigger marked dirty (0 with refinement off).
    pub dirty_cells: usize,
    /// Δℐ moves applied by the refinement epochs.
    pub refine_moves: usize,
    /// Centroids appended by oversized-dirty-cell splits.
    pub new_centroids: usize,
}

/// Per-cell mean-distortion baselines for the drift trigger.  `NaN`
/// means "not captured yet" — baselines are filled in lazily, cell by
/// cell, the first time a drift-checked extend touches the cell.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftState {
    /// `baseline[c]` = mean ‖x − C_c‖² over the cell's members at the
    /// last capture (`NaN` = unset).
    pub baseline: Vec<f64>,
}

impl DriftState {
    /// All-unset baselines for `k` cells.
    pub fn unset(k: usize) -> DriftState {
        DriftState { baseline: vec![f64::NAN; k] }
    }
}

/// Read every row of `new` into RAM, surfacing store faults as typed
/// errors instead of panics — a dying disk mid-extend must leave the
/// model (and its on-disk artifact) untouched.
fn snapshot_rows(new: &dyn VecStore) -> RtResult<VecSet> {
    let (m, d) = (new.rows(), new.dim());
    let mut flat = Vec::with_capacity(m * d);
    let mut cur = new.open();
    for i in 0..m {
        let row = cur
            .try_row(i)
            .map_err(|e| RtError::msg(format!("extend: reading new row {i}: {e}")))?;
        flat.extend_from_slice(row);
    }
    Ok(VecSet::from_flat(d, flat))
}

/// Mean squared distance of `rows` to `centroid` (f64 accumulation);
/// `NAN` for an empty member list.
fn mean_d2(
    cur: &mut crate::data::store::StoreCursor<'_>,
    rows: &[u32],
    centroid: &[f32],
) -> f64 {
    if rows.is_empty() {
        return f64::NAN;
    }
    let mut s = 0f64;
    for &i in rows {
        s += crate::core_ops::dist::d2(cur.row(i as usize), centroid) as f64;
    }
    s / rows.len() as f64
}

impl FittedModel {
    /// Append the rows of `new` to the model with the default
    /// (refinement-off, pinned-deterministic) parameters: assign via
    /// the existing predict path, append labels / vectors / SQ8 codes,
    /// and repair the KNN graph with localized joins seeded from each
    /// row's assigned cell.  See the [module docs](self) and
    /// [`FittedModel::extend_with`].
    pub fn extend(&mut self, new: &dyn VecStore) -> RtResult<ExtendReport> {
        self.extend_with(new, &ExtendParams::default())
    }

    /// [`FittedModel::extend`] with explicit [`ExtendParams`] — enables
    /// the drift trigger (`refine_drift`) and tunes the repair.
    ///
    /// The call mutates only RAM state; persisting the grown index is a
    /// separate [`FittedModel::save`] (atomic: temp sibling + fsync +
    /// rename), so a fault mid-extend leaves any on-disk artifact at
    /// its pre-extend state.  A disk-backed model's vectors are
    /// materialized into RAM on first extend (the next save streams
    /// them back out).
    pub fn extend_with(&mut self, new: &dyn VecStore, params: &ExtendParams) -> RtResult<ExtendReport> {
        let m = new.rows();
        let n0 = self.n_train;
        if new.dim() != self.dim {
            return Err(RtError::msg(format!(
                "extend: new rows have dim {} but the model has dim {}",
                new.dim(),
                self.dim
            )));
        }
        if m == 0 {
            return Ok(ExtendReport { n_before: n0, n_after: n0, ..Default::default() });
        }
        if self.labels.len() != n0 {
            return Err(RtError::msg(format!(
                "extend: model carries {} labels for {n0} training rows",
                self.labels.len()
            )));
        }
        if n0 + m > u32::MAX as usize {
            return Err(RtError::msg(format!(
                "extend: {n0} + {m} rows exceeds the u32 id space"
            )));
        }
        if self.graph.is_some() && self.data.is_none() {
            return Err(RtError::msg(
                "extend: model carries a KNN graph but no vectors; fit with \
                 RunContext::keep_data(true) to extend a graph model",
            ));
        }

        // Everything below works off a RAM snapshot of the new rows, so
        // a store fault surfaces here — once — as a typed error.
        let new_vecs = snapshot_rows(new)?;

        // 1. Assign through the existing (routed or flat) predict path:
        //    per-row deterministic at any thread count.
        let new_labels = self.predict_batch(&new_vecs);
        let affected: HashSet<u32> = new_labels.iter().copied().collect();
        let refine = params.refine_drift.is_some() && self.graph.is_some() && self.data.is_some();

        // 2. Capture pre-extend distortion baselines for cells that do
        //    not have one yet (drift trigger only).
        if refine {
            if self.drift.is_none() {
                self.drift = Some(DriftState::unset(self.k));
            }
            let need: Vec<u32> = {
                let drift = self.drift.as_ref().unwrap();
                let mut need: Vec<u32> = affected
                    .iter()
                    .copied()
                    .filter(|&c| drift.baseline[c as usize].is_nan())
                    .collect();
                need.sort_unstable();
                need
            };
            if !need.is_empty() {
                let members = members_of_cells(&self.labels, &need);
                let data = self.data.as_ref().unwrap();
                let mut cur = data.open();
                let drift = self.drift.as_mut().unwrap();
                for (slot, &c) in need.iter().enumerate() {
                    let b = mean_d2(&mut cur, &members[slot], self.centroids.row(c as usize));
                    // empty pre-extend cell: baseline 0 ⇒ any distortion
                    // the new rows bring counts as drift
                    drift.baseline[c as usize] = if b.is_nan() { 0.0 } else { b };
                }
            }
        }

        // 3. Append vectors (materializing a disk-backed store once),
        //    labels, and SQ8 codes.
        if let Some(data) = &mut self.data {
            let mut resident = match data {
                ModelVectors::Ram(v) => std::mem::replace(v, VecSet::zeros(0, 1)),
                ModelVectors::Disk(c) => crate::data::store::materialize(&*c),
            };
            for i in 0..m {
                resident.push_row(new_vecs.row(i));
            }
            *data = ModelVectors::Ram(resident);
        }
        if let Some(q) = &self.quantized {
            let quant = q.quantizer().clone();
            let mut codes = q.codes().to_vec();
            let mut row_codes = vec![0u8; self.dim];
            for i in 0..m {
                quant.encode_row(new_vecs.row(i), &mut row_codes);
                codes.extend_from_slice(&row_codes);
            }
            self.quantized = Some(
                QuantizedVecStore::from_parts(n0 + m, self.dim, codes, quant)
                    .map_err(RtError::msg)?,
            );
        }
        self.labels.extend_from_slice(&new_labels);
        self.n_train = n0 + m;

        // 4. Localized graph repair: serial, in append order, seeded
        //    from each row's assigned cell — no RNG, so batch ≡
        //    row-by-row bit-for-bit.
        let mut graph_updates = 0usize;
        if self.graph.is_some() {
            graph_updates = self.repair_graph(n0, m, &new_vecs, &new_labels, params)?;
        }

        // 5. Drift trigger + bounded Δℐ refinement over dirty cells.
        let mut dirty_cells = 0usize;
        let mut refine_moves = 0usize;
        let mut new_centroids = 0usize;
        if refine {
            let t = params.refine_drift.unwrap();
            let (d, mv, nc) = self.refine_dirty(&affected, t, params)?;
            dirty_cells = d;
            refine_moves = mv;
            new_centroids = nc;
        }

        // 6. Refresh the routed-search entry rows: new rows may be the
        //    first members of previously empty cells.
        if let Some(t) = &mut self.route {
            if t.k == self.k {
                t.set_reps(tree::reps_from_labels(&self.labels, self.k));
            }
        }

        Ok(ExtendReport {
            added: m,
            n_before: n0,
            n_after: n0 + m,
            cells_touched: affected.len(),
            graph_updates,
            dirty_cells,
            refine_moves,
            new_centroids,
        })
    }

    /// Stitch rows `n0..n0+m` into the KNN graph.  Per new row `g`
    /// (ascending): seed an exact-distance graph search at the assigned
    /// cell's representative row, fold the candidate pool into `g`'s
    /// neighbor list (symmetric updates repair the old rows' lists
    /// too), then run `join_rounds` neighbor-of-neighbor expansion
    /// rounds.  Earlier new rows are already wired when later ones
    /// search, which is exactly what makes batch ≡ row-by-row.
    fn repair_graph(
        &mut self,
        n0: usize,
        m: usize,
        new_vecs: &VecSet,
        new_labels: &[u32],
        params: &ExtendParams,
    ) -> RtResult<usize> {
        let FittedModel { graph, data, labels, k, .. } = self;
        let graph = graph.as_mut().expect("caller checked");
        let data = data.as_ref().expect("caller checked");
        graph.grow(m);
        let kappa = graph.kappa();
        let ef = if params.repair_ef == 0 { (4 * kappa).max(64) } else { params.repair_ef };
        let sp = ann::SearchParams::default().with_ef(ef).with_entries(1).with_seed(params.seed);
        // reps over the *full* post-append labels: the lowest row of a
        // cell is the same whether the batch landed at once or row by
        // row, so the seeds agree between the two schedules.
        let reps = tree::reps_from_labels(labels, *k);
        let mut scratch = ann::SearchScratch::new(n0 + m);
        let mut cur = VecStore::open(data);
        let mut updates = 0usize;
        let mut seen: HashSet<u32> = HashSet::new();
        for t in 0..m {
            let g = (n0 + t) as u32;
            if n0 + t == 0 {
                continue; // first row ever: nothing to connect to
            }
            let query = new_vecs.row(t);
            let mut seed = reps[new_labels[t] as usize];
            if seed == u32::MAX || seed == g {
                seed = if g == 0 { 1 } else { 0 };
            }
            let seeds = [seed];
            let (pool, _) = ann::search_seeded_with_scratch(
                &mut cur, graph, query, ef, &sp, &seeds, &mut scratch,
            );
            for &(dd, id) in &pool {
                if id != g && graph.update_pair(g as usize, id as usize, dd) {
                    updates += 1;
                }
            }
            // bounded neighbor-of-neighbor expansion: the NN-Descent
            // local join restricted to g's one-row neighborhood
            for _ in 0..params.join_rounds {
                let got = construct::local_join(graph, &mut cur, g as usize, &mut seen);
                updates += got;
                if got == 0 {
                    break;
                }
            }
        }
        Ok(updates)
    }

    /// Drift check + bounded Δℐ refinement + oversized-cell splits over
    /// the `affected` cells.  Returns `(dirty, moves, new_centroids)`.
    fn refine_dirty(
        &mut self,
        affected: &HashSet<u32>,
        threshold: f64,
        params: &ExtendParams,
    ) -> RtResult<(usize, usize, usize)> {
        let n = self.n_train;
        let dim = self.dim;
        let mut watch: Vec<u32> = affected.iter().copied().collect();
        watch.sort_unstable();

        // which affected cells drifted past baseline · (1 + T)?
        let mut dirty: Vec<u32> = {
            let data = self.data.as_ref().expect("caller checked");
            let mut cur = VecStore::open(data);
            let members = members_of_cells(&self.labels, &watch);
            let drift = self.drift.as_ref().expect("caller checked");
            watch
                .iter()
                .enumerate()
                .filter(|&(slot, &c)| {
                    let post = mean_d2(&mut cur, &members[slot], self.centroids.row(c as usize));
                    let base = drift.baseline[c as usize];
                    post.is_finite() && post > base * (1.0 + threshold) + 1e-12
                })
                .map(|(_, &c)| c)
                .collect()
        };
        let n_dirty = dirty.len();
        if n_dirty == 0 {
            self.update_baselines(&watch);
            return Ok((0, 0, 0));
        }

        // Approximate composite state without a full data rescan: old
        // rows contribute centroid·count (exact up to f32 rounding at
        // fit time), and refinement moves keep it incrementally exact
        // from here on.
        let mut counts = vec![0u32; self.k];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        let mut composite = vec![0f32; self.k * dim];
        for r in 0..self.k {
            let c = self.centroids.row(r);
            let nr = counts[r] as f32;
            for (dst, &v) in composite[r * dim..(r + 1) * dim].iter_mut().zip(c) {
                *dst = v * nr;
            }
        }
        let labels = std::mem::take(&mut self.labels);
        let mut clus = Clustering::from_parts(labels, composite, counts, self.k, dim)
            .map_err(RtError::msg)?;

        let mut moves = 0usize;
        let mut touched: HashSet<u32> = dirty.iter().copied().collect();
        {
            let FittedModel { graph, data, .. } = &*self;
            let graph = graph.as_ref().expect("caller checked");
            let data = data.as_ref().expect("caller checked");
            let mut cur = VecStore::open(data);
            let mut x = vec![0f32; dim];
            for _ in 0..params.refine_epochs {
                let members = members_of_cells(&clus.labels, &dirty);
                let mut epoch_moves = 0usize;
                for cell in members {
                    for &i in &cell {
                        let i = i as usize;
                        let u = clus.labels[i] as usize;
                        if clus.counts[u] <= 1 {
                            continue; // keep cells nonempty
                        }
                        x.copy_from_slice(cur.row(i));
                        // candidate targets: the labels of i's graph
                        // neighbors (the paper's cell-local move rule)
                        let mut best_v = u;
                        let mut best_delta = 0f64;
                        for &j in graph.neighbors(i) {
                            if j == u32::MAX {
                                continue;
                            }
                            let v = clus.labels[j as usize] as usize;
                            if v == u {
                                continue;
                            }
                            let d = clus.delta_i(&x, u, v);
                            if d > best_delta || (d == best_delta && d > 0.0 && v < best_v) {
                                best_delta = d;
                                best_v = v;
                            }
                        }
                        if best_delta > 0.0 {
                            clus.apply_move(i, &x, u, best_v);
                            touched.insert(u as u32);
                            touched.insert(best_v as u32);
                            epoch_moves += 1;
                        }
                    }
                }
                moves += epoch_moves;
                if epoch_moves == 0 {
                    break;
                }
            }
        }

        // refresh the centroids of every cell a move touched
        let mut touched: Vec<u32> = touched.into_iter().collect();
        touched.sort_unstable();
        for &r in &touched {
            let r = r as usize;
            if clus.counts[r] > 0 {
                let inv = 1.0 / clus.counts[r] as f32;
                let comp = clus.composite[r * dim..(r + 1) * dim].to_vec();
                for (dst, v) in self.centroids.row_mut(r).iter_mut().zip(comp) {
                    *dst = v * inv;
                }
            }
        }

        // oversized cells that are still paying for the drift split in
        // two; the new centroid appends as a routing-tree leaf.
        let mut new_centroids = 0usize;
        if params.split_factor > 0.0 {
            let quota = ((params.split_factor * n as f64 / self.k as f64).ceil() as usize).max(8);
            dirty.retain(|&c| clus.counts[c as usize] >= quota as u32);
            for c in dirty.clone() {
                if new_centroids >= 16 {
                    break; // bounded per extend
                }
                if self.split_cell(&mut clus, c as usize, params)? {
                    new_centroids += 1;
                    touched.push(c);
                    touched.push((clus.k - 1) as u32);
                }
            }
        }

        self.labels = std::mem::take(&mut clus.labels);
        self.update_baselines(&touched);
        self.update_baselines(&watch);
        Ok((n_dirty, moves, new_centroids))
    }

    /// Split cell `c` into two via a 2-means over its members; the new
    /// centroid takes id `k` and — when a routing tree is attached —
    /// appends as a leaf with a subtree-local re-split.  Returns false
    /// when the bisection degenerates (all-duplicate members).
    fn split_cell(
        &mut self,
        clus: &mut Clustering,
        c: usize,
        params: &ExtendParams,
    ) -> RtResult<bool> {
        let dim = self.dim;
        let members: Vec<u32> = clus
            .labels
            .iter()
            .enumerate()
            .filter(|&(_, &l)| l as usize == c)
            .map(|(i, _)| i as u32)
            .collect();
        if members.len() < 2 {
            return Ok(false);
        }
        let data = self.data.as_ref().expect("caller checked");
        let mut cur = VecStore::open(data);
        let mut flat = Vec::with_capacity(members.len() * dim);
        for &i in &members {
            flat.extend_from_slice(cur.row(i as usize));
        }
        let sub = VecSet::from_flat(dim, flat);
        let tm = TwoMeansParams { seed: params.seed ^ (c as u64), threads: 1, ..Default::default() };
        let side = two_means::run(&sub, 2, &tm, &Backend::Native);
        let moved: Vec<u32> = members
            .iter()
            .zip(&side)
            .filter(|&(_, &s)| s == 1)
            .map(|(&i, _)| i)
            .collect();
        if moved.is_empty() || moved.len() == members.len() {
            return Ok(false);
        }
        // grow the clustering state by one cell and move the side-1
        // members over (composites stay incrementally exact)
        let new_id = clus.k;
        clus.k += 1;
        clus.composite.extend(std::iter::repeat(0.0).take(dim));
        clus.counts.push(0);
        let mut x = vec![0f32; dim];
        for &i in &moved {
            x.copy_from_slice(cur.row(i as usize));
            clus.apply_move(i as usize, &x, c, new_id);
        }
        drop(cur);
        // both centroids refresh from their composites
        self.k += 1;
        let inv = 1.0 / clus.counts[new_id].max(1) as f32;
        let newc: Vec<f32> =
            clus.composite[new_id * dim..(new_id + 1) * dim].iter().map(|v| v * inv).collect();
        self.centroids.push_row(&newc);
        if clus.counts[c] > 0 {
            let inv = 1.0 / clus.counts[c] as f32;
            let comp = clus.composite[c * dim..(c + 1) * dim].to_vec();
            for (dst, v) in self.centroids.row_mut(c).iter_mut().zip(comp) {
                *dst = v * inv;
            }
        }
        if let Some(d) = &mut self.drift {
            d.baseline.push(f64::NAN);
        }
        if let Some(t) = &mut self.route {
            t.insert_centroid(&self.centroids, &Backend::Native);
        }
        Ok(true)
    }

    /// Recapture the distortion baselines of `cells` from current
    /// members + centroids (drift state must exist).
    fn update_baselines(&mut self, cells: &[u32]) {
        if cells.is_empty() {
            return;
        }
        let members = members_of_cells(&self.labels, cells);
        let data = self.data.as_ref().expect("caller checked");
        let mut cur = VecStore::open(data);
        let mut fresh = Vec::with_capacity(cells.len());
        for (slot, &c) in cells.iter().enumerate() {
            let b = mean_d2(&mut cur, &members[slot], self.centroids.row(c as usize));
            fresh.push(if b.is_nan() { 0.0 } else { b });
        }
        let drift = self.drift.as_mut().expect("caller checked");
        for (&c, b) in cells.iter().zip(fresh) {
            drift.baseline[c as usize] = b;
        }
    }

    /// Tombstone `ids`: the rows disappear from search results
    /// immediately and are physically removed (labels / vectors / codes
    /// filtered, graph remapped) by the next [`FittedModel::save`].
    /// Returns the number of rows newly tombstoned; unknown ids are an
    /// error, repeated ids are idempotent.
    pub fn remove(&mut self, ids: &[u32]) -> RtResult<usize> {
        for &id in ids {
            if id as usize >= self.n_train {
                return Err(RtError::msg(format!(
                    "remove: row {id} out of range (n_train = {})",
                    self.n_train
                )));
            }
        }
        let before = self.tombstones.len();
        self.tombstones.extend_from_slice(ids);
        self.tombstones.sort_unstable();
        self.tombstones.dedup();
        Ok(self.tombstones.len() - before)
    }

    /// The compacted copy [`FittedModel::save`] persists when
    /// tombstones are pending: removed rows are dropped from labels /
    /// vectors / codes, the graph is remapped (tombstoned neighbors
    /// deleted, surviving ids renumbered), reps recomputed, drift
    /// baselines kept as approximations.  Centroids are *not* refit —
    /// removal is an index operation, not a re-clustering.
    pub(crate) fn compacted(&self) -> RtResult<FittedModel> {
        if self.tombstones.is_empty() {
            return Ok(self.clone());
        }
        let n = self.n_train;
        let mut remap = vec![u32::MAX; n];
        let mut kept = 0u32;
        for i in 0..n {
            if self.tombstones.binary_search(&(i as u32)).is_err() {
                remap[i] = kept;
                kept += 1;
            }
        }
        let kept = kept as usize;
        let mut out = self.clone();
        out.tombstones.clear();
        out.n_train = kept;
        out.labels = self
            .labels
            .iter()
            .enumerate()
            .filter(|&(i, _)| remap[i] != u32::MAX)
            .map(|(_, &l)| l)
            .collect();
        if let Some(data) = &self.data {
            let resident = data.to_vecset();
            let mut flat = Vec::with_capacity(kept * self.dim);
            for i in 0..n {
                if remap[i] != u32::MAX {
                    flat.extend_from_slice(resident.row(i));
                }
            }
            out.data = Some(ModelVectors::Ram(VecSet::from_flat(self.dim, flat)));
        }
        if let Some(q) = &self.quantized {
            let mut codes = Vec::with_capacity(kept * self.dim);
            for i in 0..n {
                if remap[i] != u32::MAX {
                    codes.extend_from_slice(q.code_row(i));
                }
            }
            out.quantized = Some(
                QuantizedVecStore::from_parts(kept, self.dim, codes, q.quantizer().clone())
                    .map_err(RtError::msg)?,
            );
        }
        if let Some(g) = &self.graph {
            let kappa = g.kappa();
            let mut ids = vec![u32::MAX; kept * kappa];
            let mut dists = vec![f32::INFINITY; kept * kappa];
            for i in 0..n {
                let ni = remap[i];
                if ni == u32::MAX {
                    continue;
                }
                let base = ni as usize * kappa;
                let mut slot = 0usize;
                for (t, &j) in g.neighbors(i).iter().enumerate() {
                    if j == u32::MAX || remap[j as usize] == u32::MAX {
                        continue;
                    }
                    ids[base + slot] = remap[j as usize];
                    dists[base + slot] = g.distances(i)[t];
                    slot += 1;
                }
            }
            out.graph =
                Some(KnnGraph::from_parts(kept, kappa, ids, dists).map_err(RtError::msg)?);
        }
        if let Some(t) = &mut out.route {
            if t.has_reps() {
                t.set_reps(tree::reps_from_labels(&out.labels, out.k));
            }
        }
        Ok(out)
    }
}

/// Member lists of `cells` (ascending row order), one `Vec` per cell in
/// `cells` order.  One pass over the labels.
fn members_of_cells(labels: &[u32], cells: &[u32]) -> Vec<Vec<u32>> {
    let mut slot = std::collections::HashMap::with_capacity(cells.len());
    for (s, &c) in cells.iter().enumerate() {
        slot.insert(c, s);
    }
    let mut out = vec![Vec::new(); cells.len()];
    for (i, l) in labels.iter().enumerate() {
        if let Some(&s) = slot.get(l) {
            out[s].push(i as u32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::model::{Clusterer, GkMeans, Lloyd, RunContext};

    fn split(data: &VecSet, n0: usize) -> (VecSet, VecSet) {
        let d = data.dim();
        let old = VecSet::from_flat(d, data.flat()[..n0 * d].to_vec());
        let new = VecSet::from_flat(d, data.flat()[n0 * d..].to_vec());
        (old, new)
    }

    #[test]
    fn extend_appends_and_assigns() {
        let data = blobs(&BlobSpec::quick(260, 6, 4), 3);
        let (old, new) = split(&data, 200);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(3).keep_data(true);
        let mut model = GkMeans::new(4).kappa(6).tau(2).xi(25).fit(&old, &ctx);
        let report = model.extend(&new).unwrap();
        assert_eq!(report.added, 60);
        assert_eq!((report.n_before, report.n_after), (200, 260));
        assert_eq!(model.n_train, 260);
        assert_eq!(model.labels.len(), 260);
        assert_eq!(model.graph.as_ref().unwrap().n(), 260);
        assert!(report.graph_updates > 0, "repair must wire the new rows");
        assert!(report.cells_touched >= 1);
        // appended labels are the predict labels
        assert_eq!(&model.labels[200..], &model.predict(&new)[..]);
        model.graph.as_ref().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn extend_rejects_dim_mismatch_and_missing_data() {
        let data = blobs(&BlobSpec::quick(120, 4, 3), 5);
        let b = Backend::native();
        let mut no_data = GkMeans::new(3).kappa(4).tau(2).fit(&data, &RunContext::new(&b));
        let err = no_data.extend(&data).unwrap_err();
        assert!(err.to_string().contains("keep_data"), "{err}");
        let ctx = RunContext::new(&b).max_iters(2).keep_data(true);
        let mut model = GkMeans::new(3).kappa(4).tau(2).xi(25).fit(&data, &ctx);
        let wrong = VecSet::zeros(4, 7);
        assert!(model.extend(&wrong).unwrap_err().to_string().contains("dim"));
    }

    #[test]
    fn extend_without_graph_still_assigns() {
        let data = blobs(&BlobSpec::quick(160, 4, 3), 6);
        let (old, new) = split(&data, 120);
        let b = Backend::native();
        let mut model = Lloyd::new(3).fit(&old, &RunContext::new(&b).max_iters(3));
        let report = model.extend(&new).unwrap();
        assert_eq!(report.added, 40);
        assert_eq!(report.graph_updates, 0);
        assert_eq!(model.labels.len(), 160);
    }

    #[test]
    fn remove_tombstones_filter_search_and_compact_on_roundtrip() {
        let data = blobs(&BlobSpec::quick(220, 5, 4), 9);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(3).keep_data(true);
        let mut model = GkMeans::new(4).kappa(6).tau(2).xi(25).fit(&data, &ctx);
        // row 0's own top hit is itself; after removal it must vanish
        let hits = model.search(data.row(0), 3, &Default::default()).unwrap();
        assert_eq!(hits[0].1, 0);
        assert_eq!(model.remove(&[0, 5, 0]).unwrap(), 2, "dup ids are idempotent");
        let hits = model.search(data.row(0), 3, &Default::default()).unwrap();
        assert!(hits.iter().all(|&(_, id)| id != 0 && id != 5));
        assert!(model.remove(&[9999]).is_err());
        // compaction drops the rows and renumbers the survivors
        let compact = model.compacted().unwrap();
        assert_eq!(compact.n_train, 218);
        assert_eq!(compact.labels.len(), 218);
        assert!(compact.tombstones.is_empty());
        let g = compact.graph.as_ref().unwrap();
        assert_eq!(g.n(), 218);
        g.check_invariants().unwrap();
        assert_eq!(compact.data.as_ref().unwrap().rows(), 218);
        // old row 1 is new row 0
        let v = compact.data.as_ref().unwrap().fetch_row(0);
        assert_eq!(v, data.row(1));
    }

    #[test]
    fn drift_refinement_reduces_distortion_on_shifted_data() {
        // fit on 3 of 4 blobs, extend with the 4th: the receiving cells
        // drift and refinement must claw distortion back
        let all = blobs(&BlobSpec { sigma: 0.3, spread: 12.0, ..BlobSpec::quick(400, 6, 4) }, 11);
        let (old, new) = split(&all, 300);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(4).keep_data(true);
        let mut refined = GkMeans::new(4).kappa(8).tau(3).xi(25).fit(&old, &ctx);
        let mut plain = refined.clone();
        plain.extend(&new).unwrap();
        let params = ExtendParams { refine_drift: Some(0.05), ..Default::default() };
        let report = refined.extend_with(&new, &params).unwrap();
        assert!(refined.drift.is_some(), "drift state must be captured");
        let d_plain = crate::kmeans::common::distortion_exact(
            &all,
            &plain.labels,
            &plain.centroids,
        );
        let d_ref = crate::kmeans::common::distortion_exact(
            &all,
            &refined.labels,
            &refined.centroids,
        );
        assert!(
            d_ref <= d_plain + 1e-9,
            "refined extend must not be worse: {d_ref} vs {d_plain} (report {report:?})"
        );
    }
}
