//! [`FittedModel`] — the first-class training artifact: centroids, labels,
//! convergence history, and (for the graph methods) the KNN graph.
//!
//! A fitted model answers three questions long after the fit:
//! * [`FittedModel::predict`] — which cluster does an *unseen* vector
//!   belong to?  (blocked nearest-centroid kernels, threads-aware)
//! * [`FittedModel::search`] — which indexed vectors are closest to a
//!   query?  (greedy graph ANN over the retained training vectors)
//! * [`FittedModel::save`] / [`FittedModel::load`] — versioned binary
//!   round-trip, no external deps (see [`crate::model::serde`]).

use std::path::Path;

use crate::coordinator::job::Method;
use crate::data::matrix::VecSet;
use crate::data::quant::QuantizedVecStore;
use crate::data::store::{self, ChunkedVecStore, StoreCursor, VecStore};
use crate::gkm::ann;
use crate::gkm::tree::{self, RouteScratch, RouteTree, RouteTreeParams};
use crate::graph::knn::KnnGraph;
use crate::kmeans::common::{IterStat, KmeansOutput};
use crate::model::RunContext;
use crate::runtime::{Backend, RtError};
use crate::util::pool;
use crate::util::rng::Rng;

/// The indexed vectors a model serves ANN queries from: either embedded
/// in RAM (the classic `keep_data` path) or paged from a file region —
/// a GKMODEL v2 vectors section, or the original dataset file when the
/// fit itself streamed from disk.
#[derive(Debug, Clone)]
pub enum ModelVectors {
    /// Vectors resident in RAM (embedded in the artifact bytes).
    Ram(VecSet),
    /// Vectors paged on demand from disk through a block cache.
    Disk(ChunkedVecStore),
}

impl ModelVectors {
    pub fn rows(&self) -> usize {
        match self {
            ModelVectors::Ram(v) => v.rows(),
            ModelVectors::Disk(c) => c.rows(),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            ModelVectors::Ram(v) => v.dim(),
            ModelVectors::Disk(c) => c.dim(),
        }
    }

    /// Whether the vectors are resident in RAM.
    pub fn is_resident(&self) -> bool {
        matches!(self, ModelVectors::Ram(_))
    }

    /// Borrow the resident [`VecSet`], if any.
    pub fn as_ram(&self) -> Option<&VecSet> {
        match self {
            ModelVectors::Ram(v) => Some(v),
            ModelVectors::Disk(_) => None,
        }
    }

    /// Copy out row `i` (allocates; fine for query sampling, not for
    /// inner loops — those go through [`VecStore::open`]).
    pub fn fetch_row(&self, i: usize) -> Vec<f32> {
        match self {
            ModelVectors::Ram(v) => v.row(i).to_vec(),
            ModelVectors::Disk(c) => {
                let mut cur = VecStore::open(c);
                cur.row(i).to_vec()
            }
        }
    }

    /// Materialize into a resident [`VecSet`] (copies the Disk variant).
    pub fn to_vecset(&self) -> VecSet {
        match self {
            ModelVectors::Ram(v) => v.clone(),
            ModelVectors::Disk(c) => store::materialize(c),
        }
    }
}

impl VecStore for ModelVectors {
    fn rows(&self) -> usize {
        ModelVectors::rows(self)
    }

    fn dim(&self) -> usize {
        ModelVectors::dim(self)
    }

    fn open(&self) -> StoreCursor<'_> {
        match self {
            ModelVectors::Ram(v) => VecStore::open(v),
            ModelVectors::Disk(c) => VecStore::open(c),
        }
    }

    fn as_flat(&self) -> Option<&[f32]> {
        self.as_ram().map(|v| v.flat())
    }

    fn as_vecset(&self) -> Option<&VecSet> {
        self.as_ram()
    }

    fn disk_backing(&self) -> Option<&ChunkedVecStore> {
        match self {
            ModelVectors::Ram(_) => None,
            ModelVectors::Disk(c) => Some(c),
        }
    }

    fn scan_geometry(&self) -> Option<crate::data::plan::ScanGeometry> {
        match self {
            ModelVectors::Ram(_) => None,
            ModelVectors::Disk(c) => c.scan_geometry(),
        }
    }
}

/// The artifact a [`crate::model::Clusterer`] fit produces.
///
/// Time accounting contract (asserted by
/// [`FittedModel::check_time_accounting`]): all clocks share one origin —
/// the start of `fit`, *including* graph construction.  So
/// `graph_seconds ≤ init_seconds ≤ total_seconds`, `history` is monotone
/// in `seconds`, and the last history entry does not exceed
/// `total_seconds`.  Graph-build time is folded in exactly once.
#[derive(Debug, Clone)]
pub struct FittedModel {
    /// Which algorithm produced this model.
    pub method: Method,
    /// Cluster count.
    pub k: usize,
    /// Vector dimensionality.
    pub dim: usize,
    /// Training-set size.
    pub n_train: usize,
    /// Worker-thread preference carried over from the fit context
    /// (`predict` honors it; `1` = serial, `0` = auto).
    pub threads: usize,
    /// `k × dim` centroids (empty clusters hold zeros).
    pub centroids: VecSet,
    /// Training-set labels in `[0, k)`.
    pub labels: Vec<u32>,
    /// Per-epoch progress (index 0 records the initialization state).
    pub history: Vec<IterStat>,
    /// Total fit wall-clock, including graph build and initialization.
    pub total_seconds: f64,
    /// Initialization wall-clock (graph build + 2M-tree / seeding).
    pub init_seconds: f64,
    /// Graph-construction share of `init_seconds` (0 for non-graph methods).
    pub graph_seconds: f64,
    /// The KNN graph the fit was driven by (graph methods only).
    pub graph: Option<KnnGraph>,
    /// Retained training vectors ([`RunContext::keep_data`]) — required
    /// for [`FittedModel::search`] to serve after `save`/`load`.  A v2
    /// artifact opened with [`FittedModel::load`] pages these from disk
    /// ([`ModelVectors::Disk`]) instead of holding them in RAM.
    pub data: Option<ModelVectors>,
    /// SQ8-compressed copy of the indexed vectors
    /// ([`FittedModel::quantize_sq8`]): when present, ANN search scans
    /// these RAM-resident codes (~¼ the bytes of `data`) and re-ranks
    /// the candidate pool with exact f32 distances from `data`.
    /// Persisted as the GKMODEL `QVECTORS` section.
    pub quantized: Option<QuantizedVecStore>,
    /// Hierarchical routing tree over the centroids
    /// ([`FittedModel::build_route`]): when present and `k ≥
    /// route_min_k`, `predict`/`search` descend O(depth·branch) with a
    /// beam instead of scanning all k centroids.  Persisted as the
    /// GKMODEL `RTREE` section.
    pub route: Option<RouteTree>,
    /// Routing engages only at `k ≥ route_min_k` (default
    /// [`tree::ROUTE_MIN_K`]); runtime-only — `0` forces routing, a
    /// huge value disables it without dropping the tree.
    pub route_min_k: usize,
    /// Per-cell distortion baselines for the incremental drift trigger
    /// ([`FittedModel::extend`]); captured lazily on the first
    /// drift-checked extend and persisted as the GKMODEL `DRIFT`
    /// section.  `None` until an extend with refinement enabled runs.
    pub drift: Option<crate::model::extend::DriftState>,
    /// Rows removed by [`FittedModel::remove`] (ascending, deduplicated).
    /// Tombstoned rows are filtered out of search results immediately
    /// and physically compacted away by the next [`FittedModel::save`].
    pub tombstones: Vec<u32>,
}

/// The vectors a fitted model retains under [`RunContext::keep_data`]:
/// a disk-backed store keeps the cheap disk handle — never a 20 GB RAM
/// copy; `save` streams it into the artifact.
fn kept_data(data: &dyn VecStore, ctx: &RunContext) -> Option<ModelVectors> {
    if !ctx.keep_data {
        return None;
    }
    Some(match data.disk_backing() {
        Some(c) => ModelVectors::Disk(c.clone()),
        None => ModelVectors::Ram(store::materialize(data)),
    })
}

impl FittedModel {
    /// Assemble a model from a legacy [`KmeansOutput`], folding
    /// graph-construction time into the shared clock exactly once and
    /// emitting the history through the context's progress callback.
    pub(crate) fn from_output(
        method: Method,
        data: &dyn VecStore,
        ctx: &RunContext,
        out: KmeansOutput,
        graph: Option<KnnGraph>,
        graph_seconds: f64,
    ) -> FittedModel {
        let model = FittedModel::from_output_streamed(method, data, ctx, out, graph, graph_seconds);
        for h in &model.history {
            ctx.emit(method.name(), h);
        }
        model
    }

    /// [`FittedModel::from_output`] minus the emit loop: the hooked
    /// engines already streamed every epoch stat (folded) through the
    /// context's progress callback from inside the fit, so re-emitting
    /// here would double every entry.
    pub(crate) fn from_output_streamed(
        method: Method,
        data: &dyn VecStore,
        ctx: &RunContext,
        out: KmeansOutput,
        graph: Option<KnnGraph>,
        graph_seconds: f64,
    ) -> FittedModel {
        let KmeansOutput { clustering, mut history, total_seconds, init_seconds } = out;
        for h in history.iter_mut() {
            h.seconds += graph_seconds;
        }
        let centroids = clustering.centroids();
        FittedModel {
            method,
            k: clustering.k,
            dim: data.dim(),
            n_train: data.rows(),
            threads: ctx.threads,
            centroids,
            labels: clustering.labels,
            history,
            total_seconds: total_seconds + graph_seconds,
            init_seconds: init_seconds + graph_seconds,
            graph_seconds,
            graph,
            data: kept_data(data, ctx),
            quantized: None,
            route: None,
            route_min_k: tree::ROUTE_MIN_K,
            drift: None,
            tombstones: Vec::new(),
        }
    }

    /// Assemble a model from a *resumed* hooked run.  The checkpointed
    /// history prefix (and the `seconds_base` the engine folded into its
    /// new entries) already carries the final wall-clock values, and the
    /// init/graph split comes from the original run's checkpoint — so
    /// nothing is folded again and nothing is re-emitted.
    pub(crate) fn from_resumed(
        method: Method,
        data: &dyn VecStore,
        ctx: &RunContext,
        out: KmeansOutput,
        graph: Option<KnnGraph>,
        graph_seconds: f64,
        init_seconds: f64,
    ) -> FittedModel {
        let KmeansOutput { clustering, history, total_seconds, .. } = out;
        let centroids = clustering.centroids();
        FittedModel {
            method,
            k: clustering.k,
            dim: data.dim(),
            n_train: data.rows(),
            threads: ctx.threads,
            centroids,
            labels: clustering.labels,
            history,
            total_seconds,
            init_seconds,
            graph_seconds,
            graph,
            data: kept_data(data, ctx),
            quantized: None,
            route: None,
            route_min_k: tree::ROUTE_MIN_K,
            drift: None,
            tombstones: Vec::new(),
        }
    }

    /// Build the hierarchical routing tree over this model's centroids
    /// ([`RouteTree::build`]) and attach it: subsequent
    /// `predict`/`search` calls route coarse→fine when `k ≥
    /// route_min_k`, and [`FittedModel::save`] persists the tree as an
    /// `RTREE` section so a reloaded model routes immediately.  Also
    /// records one representative training row per cluster (from the
    /// labels) so routed ANN search can enter the graph at the routed
    /// clusters instead of random rows.
    pub fn build_route(&mut self, params: &RouteTreeParams) {
        let mut t = RouteTree::build(&self.centroids, params, &Backend::Native);
        if self.labels.len() == self.n_train && !self.labels.is_empty() {
            t.set_reps(tree::reps_from_labels(&self.labels, self.k));
        }
        self.route = Some(t);
    }

    /// The routing tree, if one is attached *and* engaged
    /// (`k ≥ route_min_k`).
    fn active_route(&self) -> Option<&RouteTree> {
        self.route.as_ref().filter(|_| self.k >= self.route_min_k)
    }

    /// Whether `predict`/`search` will route through the tree.
    pub fn routing_active(&self) -> bool {
        self.active_route().is_some()
    }

    /// Quantize the retained vectors to SQ8 ([`QuantizedVecStore`]):
    /// subsequent [`FittedModel::search`] / [`FittedModel::search_batch`]
    /// calls traverse the RAM-resident codes (~¼ the memory traffic) and
    /// re-rank the candidate pool with exact f32 distances, and
    /// [`FittedModel::save`] persists the codes as a `QVECTORS` section
    /// so a reloaded model serves quantized immediately.  `sample_rows`
    /// bounds the quantizer-training pass (`0` = scan everything); data
    /// that streams from a bvecs file is encoded losslessly through the
    /// identity quantizer.  Errors when the model retains no vectors
    /// (fit with [`RunContext::keep_data`]).
    pub fn quantize_sq8(&mut self, sample_rows: usize) -> Result<(), String> {
        let data = self.data.as_ref().ok_or_else(|| {
            "model does not embed the indexed vectors; fit with \
             RunContext::keep_data(true) before quantizing"
                .to_string()
        })?;
        self.quantized = Some(match data {
            ModelVectors::Disk(c) => c.quantize_sq8(sample_rows),
            ModelVectors::Ram(v) => QuantizedVecStore::from_store(v, sample_rows),
        });
        Ok(())
    }

    /// The chunk-cache hit/miss ledger of a disk-backed model's vectors
    /// (`None` when the vectors are resident or absent).  The serving
    /// layer ([`crate::serve`]) exports this through its `STATS` verb.
    pub fn cache_stats(&self) -> Option<&crate::data::store::CacheStats> {
        match &self.data {
            Some(ModelVectors::Disk(c)) => Some(c.cache_stats()),
            _ => None,
        }
    }

    /// Final distortion ℰ (from the last history entry).
    pub fn distortion(&self) -> f64 {
        self.history.last().map(|h| h.distortion).unwrap_or(f64::NAN)
    }

    /// Iteration wall-clock (everything after initialization).
    pub fn iter_seconds(&self) -> f64 {
        self.total_seconds - self.init_seconds
    }

    /// Out-of-sample assignment: the nearest centroid for every row of
    /// `queries`, via the blocked distance kernels, honoring the model's
    /// thread preference.  Panics if the dimensionality disagrees.
    pub fn predict(&self, queries: &VecSet) -> Vec<u32> {
        self.predict_on(queries, &Backend::Native)
    }

    /// [`FittedModel::predict`] on an explicit backend.  With more than
    /// one worker the rows are sharded and each worker runs the native
    /// kernel (PJRT dispatch is single-threaded by design); `threads = 1`
    /// routes the whole block through `backend` unchanged.
    pub fn predict_on(&self, queries: &VecSet, backend: &Backend) -> Vec<u32> {
        assert_eq!(
            queries.dim(),
            self.dim,
            "query dim {} != model dim {}",
            queries.dim(),
            self.dim
        );
        let n = queries.rows();
        if n == 0 {
            return Vec::new();
        }
        if let Some(t) = self.active_route() {
            return self.predict_routed(queries, t, backend);
        }
        let threads = pool::resolve_threads(self.threads).min(n);
        if threads <= 1 {
            return backend
                .assign_blocks(queries.flat(), self.centroids.flat(), self.dim, self.k)
                .idx;
        }
        let parts = pool::par_map_chunks(threads, n, |_, r| {
            Backend::Native
                .assign_blocks(
                    queries.rows_flat(r.start, r.end),
                    self.centroids.flat(),
                    self.dim,
                    self.k,
                )
                .idx
        });
        parts.concat()
    }

    /// Routed [`FittedModel::predict_on`]: per-query O(depth·branch)
    /// beam descent, sharded across the model's worker threads with one
    /// reusable [`RouteScratch`] per worker.  Per-query results are
    /// deterministic (no RNG in the descent), so any thread count — and
    /// [`FittedModel::predict_batch`] — returns identical labels.
    fn predict_routed(&self, queries: &VecSet, t: &RouteTree, backend: &Backend) -> Vec<u32> {
        let n = queries.rows();
        let beam = t.default_beam as usize;
        let threads = pool::resolve_threads(self.threads).min(n);
        if threads <= 1 {
            let mut s = RouteScratch::new();
            return (0..n)
                .map(|i| t.predict_one(queries.row(i), &self.centroids, beam, backend, &mut s))
                .collect();
        }
        let parts = pool::par_map_chunks(threads, n, |_, r| {
            let mut s = RouteScratch::new();
            let mut out = Vec::with_capacity(r.len());
            for i in r {
                let q = queries.row(i);
                out.push(t.predict_one(q, &self.centroids, beam, &Backend::Native, &mut s));
            }
            out
        });
        parts.concat()
    }

    /// Batched out-of-sample assignment over any [`VecStore`]: query rows
    /// are sharded across the model's worker threads, each worker opens
    /// its own cursor and streams blocks through the native kernel
    /// (`lloyd::assign_threaded` — one implementation of the sharded
    /// scan) — so a disk-backed query set never has to fit in RAM.
    /// Per-row results are independent of sharding, so any thread count
    /// (and the in-RAM [`FittedModel::predict`]) returns identical
    /// labels.
    ///
    /// ```
    /// use gkmeans::data::synth::{blobs, BlobSpec};
    /// use gkmeans::model::{Clusterer, Lloyd, RunContext};
    /// use gkmeans::runtime::Backend;
    ///
    /// let data = blobs(&BlobSpec::quick(150, 4, 3), 3);
    /// let backend = Backend::native();
    /// let model = Lloyd::new(3).fit(&data, &RunContext::new(&backend).max_iters(3));
    /// // any `VecStore` works as the query set — a resident `VecSet`
    /// // here, a disk-backed `ChunkedVecStore` in production
    /// let labels = model.predict_batch(&data);
    /// assert_eq!(labels, model.predict(&data));
    /// ```
    pub fn predict_batch(&self, queries: &dyn VecStore) -> Vec<u32> {
        assert_eq!(
            queries.dim(),
            self.dim,
            "query dim {} != model dim {}",
            queries.dim(),
            self.dim
        );
        if queries.rows() == 0 {
            return Vec::new();
        }
        if let Some(t) = self.active_route() {
            let n = queries.rows();
            let beam = t.default_beam as usize;
            let threads = pool::resolve_threads(self.threads).min(n).max(1);
            let parts = pool::par_map_chunks(threads, n, |_, r| {
                let mut cur = queries.open();
                let mut s = RouteScratch::new();
                let mut out = Vec::with_capacity(r.len());
                for i in r {
                    let q = cur.row(i);
                    out.push(t.predict_one(q, &self.centroids, beam, &Backend::Native, &mut s));
                }
                out
            });
            return parts.concat();
        }
        crate::kmeans::lloyd::assign_threaded(
            queries,
            &self.centroids,
            &Backend::Native,
            self.threads,
        )
        .idx
    }

    /// Degraded-mode [`FittedModel::predict_batch`]: per-query results,
    /// with rows the store failed to serve (mid-stream truncation, a
    /// corrupt fvecs record, an I/O error that survived the store's
    /// retry policy) reported as per-row `Err` instead of poisoning the
    /// whole batch.  Workers stream 1024-row blocks exactly like
    /// `predict_batch`; when a block read fails the worker degrades to
    /// row-at-a-time for that block, so only the rows actually hit by
    /// the fault are lost (per-row assignment is independent of
    /// blocking, so surviving rows get the exact `predict_batch`
    /// labels).  The outer `Err` is reserved for a worker dying outright
    /// ([`RtError::worker_panic`]).
    pub fn try_predict_batch(
        &self,
        queries: &dyn VecStore,
    ) -> Result<Vec<Result<u32, String>>, RtError> {
        if queries.dim() != self.dim {
            return Err(RtError::msg(format!(
                "query dim {} != model dim {}",
                queries.dim(),
                self.dim
            )));
        }
        let n = queries.rows();
        if n == 0 {
            return Ok(Vec::new());
        }
        const BLOCK: usize = 1024;
        let threads = pool::resolve_threads(self.threads).min(n);
        let route = self.active_route();
        let parts = pool::try_par_map_chunks(threads.max(1), n, |_, r| {
            let mut cur = queries.open();
            let mut rs = RouteScratch::new();
            let mut out: Vec<Result<u32, String>> = Vec::with_capacity(r.len());
            let mut lo = r.start;
            while lo < r.end {
                let hi = (lo + BLOCK).min(r.end);
                match cur.try_block(lo, hi) {
                    Ok(block) => match route {
                        Some(t) => {
                            let beam = t.default_beam as usize;
                            for row in block.chunks(self.dim) {
                                out.push(Ok(t.predict_one(
                                    row,
                                    &self.centroids,
                                    beam,
                                    &Backend::Native,
                                    &mut rs,
                                )));
                            }
                        }
                        None => {
                            let sub = Backend::Native.assign_blocks(
                                block,
                                self.centroids.flat(),
                                self.dim,
                                self.k,
                            );
                            out.extend(sub.idx.into_iter().map(Ok));
                        }
                    },
                    Err(_) => {
                        // the block spans a bad region: degrade to
                        // row-at-a-time so intact rows still get answers
                        for i in lo..hi {
                            match cur.try_row(i) {
                                Ok(row) => out.push(Ok(match route {
                                    Some(t) => t.predict_one(
                                        row,
                                        &self.centroids,
                                        t.default_beam as usize,
                                        &Backend::Native,
                                        &mut rs,
                                    ),
                                    None => {
                                        Backend::Native
                                            .assign_blocks(
                                                row,
                                                self.centroids.flat(),
                                                self.dim,
                                                self.k,
                                            )
                                            .idx[0]
                                    }
                                })),
                                Err(e) => out.push(Err(e)),
                            }
                        }
                    }
                }
                lo = hi;
            }
            out
        });
        match parts {
            Ok(parts) => Ok(parts.concat()),
            Err((payload, ctx)) => Err(RtError::worker_panic(format!(
                "{ctx}: {}",
                pool::panic_message(payload.as_ref())
            ))),
        }
    }

    /// Approximate top-`topk` nearest indexed vectors of `query`, served
    /// from the model's KNN graph.  Requires a graph method *and*
    /// [`RunContext::keep_data`] at fit time (the vectors travel with the
    /// artifact through `save`/`load`).
    pub fn search(
        &self,
        query: &[f32],
        topk: usize,
        params: &ann::SearchParams,
    ) -> Result<Vec<(f32, u32)>, String> {
        self.search_with_stats(query, topk, params).map(|(res, _)| res)
    }

    /// [`FittedModel::search`] returning the per-query [`ann::SearchStats`]
    /// (distance evaluations = the latency proxy).
    pub fn search_with_stats(
        &self,
        query: &[f32],
        topk: usize,
        params: &ann::SearchParams,
    ) -> Result<(Vec<(f32, u32)>, ann::SearchStats), String> {
        let (graph, data) = self.serving_parts()?;
        if query.len() != self.dim {
            return Err(format!("query dim {} != model dim {}", query.len(), self.dim));
        }
        // routed entry points: descend the tree to the nearest clusters
        // and enter the graph at their representative rows — O(depth·
        // branch) placement instead of random draws.  Deterministic per
        // query, so search ≡ search_batch still holds.
        if let Some(t) = self.active_route() {
            if t.has_reps() {
                let mut rs = RouteScratch::new();
                let seeds = t.seed_rows(
                    query,
                    &self.centroids,
                    t.default_beam as usize,
                    params.entries.max(1),
                    &Backend::Native,
                    &mut rs,
                );
                if !seeds.is_empty() {
                    let mut scratch = ann::SearchScratch::new(data.rows());
                    let mut cur = data.open();
                    if let Some(qs) = &self.quantized {
                        return Ok(self.filter_hits(ann::search_sq8_seeded_with_scratch(
                            qs, &mut cur, graph, query, topk, params, &seeds, &mut scratch,
                        )));
                    }
                    return Ok(self.filter_hits(ann::search_seeded_with_scratch(
                        &mut cur, graph, query, topk, params, &seeds, &mut scratch,
                    )));
                }
            }
        }
        // deterministic per-model entry points: same query, same answer
        let mut rng = Rng::new(params.seed ^ 0x00A4_45EC);
        if let Some(q) = &self.quantized {
            return Ok(self.filter_hits(ann::search_sq8(q, data, graph, query, topk, params, &mut rng)));
        }
        Ok(self.filter_hits(ann::search(data, graph, query, topk, params, &mut rng)))
    }

    /// Drop tombstoned rows ([`FittedModel::remove`]) from a result set.
    /// Tombstones are kept sorted, so each hit costs one binary search.
    #[inline]
    fn filter_hits(
        &self,
        mut res: (Vec<(f32, u32)>, ann::SearchStats),
    ) -> (Vec<(f32, u32)>, ann::SearchStats) {
        if !self.tombstones.is_empty() {
            res.0.retain(|&(_, id)| self.tombstones.binary_search(&id).is_err());
        }
        res
    }

    /// The graph + vectors a search needs, with the serving errors.
    fn serving_parts(&self) -> Result<(&KnnGraph, &ModelVectors), String> {
        let graph = self.graph.as_ref().ok_or_else(|| {
            format!(
                "{} model carries no KNN graph; ANN search needs a graph method \
                 (gkmeans / gkmeans-trad / kgraph)",
                self.method.name()
            )
        })?;
        let data = self.data.as_ref().ok_or_else(|| {
            "model does not embed the indexed vectors; fit with \
             RunContext::keep_data(true) to serve ANN queries"
                .to_string()
        })?;
        Ok((graph, data))
    }

    /// Batched ANN search: shard the query rows across the model's
    /// worker threads, each worker reusing one [`ann::SearchScratch`]
    /// (and, for disk-backed vectors, its own block-cache cursor) across
    /// its queries.  Every query derives the same deterministic entry
    /// points as [`FittedModel::search`], so the results are identical
    /// to repeated single `search` calls at any thread count.
    ///
    /// ```
    /// use gkmeans::data::synth::{blobs, BlobSpec};
    /// use gkmeans::model::{Clusterer, GkMeans, RunContext};
    /// use gkmeans::runtime::Backend;
    ///
    /// let data = blobs(&BlobSpec::quick(200, 6, 4), 5);
    /// let backend = Backend::native();
    /// // a graph method + keep_data(true) are what ANN serving needs
    /// let ctx = RunContext::new(&backend).max_iters(3).keep_data(true);
    /// let model = GkMeans::new(4).kappa(6).tau(2).xi(25).fit(&data, &ctx);
    /// let hits = model.search_batch(&data, 5, &Default::default()).unwrap();
    /// assert_eq!(hits.len(), 200);
    /// assert!(hits.iter().all(|h| !h.is_empty() && h.len() <= 5));
    /// ```
    pub fn search_batch(
        &self,
        queries: &VecSet,
        topk: usize,
        params: &ann::SearchParams,
    ) -> Result<Vec<Vec<(f32, u32)>>, String> {
        let (graph, data) = self.serving_parts()?;
        if queries.dim() != self.dim {
            return Err(format!(
                "query dim {} != model dim {}",
                queries.dim(),
                self.dim
            ));
        }
        let nq = queries.rows();
        if nq == 0 {
            return Ok(Vec::new());
        }
        let threads = pool::resolve_threads(self.threads).min(nq);
        let n = data.rows();
        let quant = self.quantized.as_ref();
        let route = self.active_route().filter(|t| t.has_reps());
        let results = pool::par_map_chunks(threads.max(1), nq, |_, r| {
            let mut scratch = ann::SearchScratch::new(n);
            let mut rs = RouteScratch::new();
            let mut cur = data.open();
            let mut out = Vec::with_capacity(r.len());
            for q in r {
                let query = queries.row(q);
                // routed seeding is deterministic per query, so batch
                // results stay equal to repeated single `search` calls
                let seeds = route
                    .map(|t| {
                        t.seed_rows(
                            query,
                            &self.centroids,
                            t.default_beam as usize,
                            params.entries.max(1),
                            &Backend::Native,
                            &mut rs,
                        )
                    })
                    .unwrap_or_default();
                let res = if !seeds.is_empty() {
                    match quant {
                        Some(qs) => ann::search_sq8_seeded_with_scratch(
                            qs,
                            &mut cur,
                            graph,
                            query,
                            topk,
                            params,
                            &seeds,
                            &mut scratch,
                        ),
                        None => ann::search_seeded_with_scratch(
                            &mut cur,
                            graph,
                            query,
                            topk,
                            params,
                            &seeds,
                            &mut scratch,
                        ),
                    }
                } else {
                    // fresh per-query RNG with the `search` derivation keeps
                    // batch results equal to repeated single calls
                    let mut rng = Rng::new(params.seed ^ 0x00A4_45EC);
                    match quant {
                        Some(qs) => ann::search_sq8_with_scratch(
                            qs,
                            &mut cur,
                            graph,
                            query,
                            topk,
                            params,
                            &mut rng,
                            &mut scratch,
                        ),
                        None => ann::search_with_scratch(
                            &mut cur,
                            graph,
                            query,
                            topk,
                            params,
                            &mut rng,
                            &mut scratch,
                        ),
                    }
                };
                out.push(self.filter_hits(res).0);
            }
            out
        });
        Ok(results.concat())
    }

    /// Degraded-mode [`FittedModel::search_batch`]: each query's search
    /// runs under a panic guard, so one query tripping over a corrupt
    /// region of the vectors file (the infallible cursor reads panic on
    /// mid-stream corruption) yields a per-query `Err` while every other
    /// query is still answered — the worker recreates its scratch and
    /// cursor after a caught panic because a mid-search unwind can leave
    /// both poisoned.  Surviving queries return exactly the
    /// `search_batch` results (same per-query RNG derivation).  The
    /// outer `Err` is a worker dying outside the per-query guard.
    pub fn try_search_batch(
        &self,
        queries: &VecSet,
        topk: usize,
        params: &ann::SearchParams,
    ) -> Result<Vec<Result<Vec<(f32, u32)>, String>>, RtError> {
        let (graph, data) = self.serving_parts().map_err(RtError::msg)?;
        if queries.dim() != self.dim {
            return Err(RtError::msg(format!(
                "query dim {} != model dim {}",
                queries.dim(),
                self.dim
            )));
        }
        let nq = queries.rows();
        if nq == 0 {
            return Ok(Vec::new());
        }
        let threads = pool::resolve_threads(self.threads).min(nq);
        let n = data.rows();
        let quant = self.quantized.as_ref();
        let route = self.active_route().filter(|t| t.has_reps());
        let parts = pool::try_par_map_chunks(threads.max(1), nq, |_, r| {
            let mut scratch: Option<ann::SearchScratch> = None;
            let mut cur: Option<crate::data::store::StoreCursor<'_>> = None;
            let mut out: Vec<Result<Vec<(f32, u32)>, String>> = Vec::with_capacity(r.len());
            for q in r {
                let mut s = scratch.take().unwrap_or_else(|| ann::SearchScratch::new(n));
                let mut c = cur.take().unwrap_or_else(|| data.open());
                let guarded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let query = queries.row(q);
                    // routing scratch stays inside the guard: a caught
                    // panic drops it with the rest of the query state
                    let seeds = route
                        .map(|t| {
                            let mut rs = RouteScratch::new();
                            t.seed_rows(
                                query,
                                &self.centroids,
                                t.default_beam as usize,
                                params.entries.max(1),
                                &Backend::Native,
                                &mut rs,
                            )
                        })
                        .unwrap_or_default();
                    let res = if !seeds.is_empty() {
                        match quant {
                            Some(qs) => ann::search_sq8_seeded_with_scratch(
                                qs, &mut c, graph, query, topk, params, &seeds, &mut s,
                            ),
                            None => ann::search_seeded_with_scratch(
                                &mut c, graph, query, topk, params, &seeds, &mut s,
                            ),
                        }
                    } else {
                        let mut rng = Rng::new(params.seed ^ 0x00A4_45EC);
                        match quant {
                            Some(qs) => ann::search_sq8_with_scratch(
                                qs,
                                &mut c,
                                graph,
                                query,
                                topk,
                                params,
                                &mut rng,
                                &mut s,
                            ),
                            None => ann::search_with_scratch(
                                &mut c,
                                graph,
                                query,
                                topk,
                                params,
                                &mut rng,
                                &mut s,
                            ),
                        }
                    };
                    self.filter_hits(res).0
                }));
                match guarded {
                    Ok(hits) => {
                        out.push(Ok(hits));
                        // reuse across queries, as search_batch does
                        scratch = Some(s);
                        cur = Some(c);
                    }
                    Err(payload) => {
                        out.push(Err(format!(
                            "query {q} failed: {}",
                            pool::panic_message(payload.as_ref())
                        )));
                        // s and c drop here: rebuilt fresh for the next query
                    }
                }
            }
            out
        });
        match parts {
            Ok(parts) => Ok(parts.concat()),
            Err((payload, ctx)) => Err(RtError::worker_panic(format!(
                "{ctx}: {}",
                pool::panic_message(payload.as_ref())
            ))),
        }
    }

    /// Save as a versioned binary artifact (see [`crate::model::serde`]):
    /// GKMODEL v2, section-offset layout, the vectors section streamed —
    /// never materialized — from wherever the model keeps them.  The
    /// write is crash-safe (temp sibling + fsync + rename) and every
    /// section carries a CRC-32 that [`FittedModel::load`] verifies.
    ///
    /// ```
    /// use gkmeans::data::synth::{blobs, BlobSpec};
    /// use gkmeans::model::{Clusterer, FittedModel, Lloyd, RunContext};
    /// use gkmeans::runtime::Backend;
    ///
    /// let data = blobs(&BlobSpec::quick(100, 4, 3), 7);
    /// let backend = Backend::native();
    /// let model = Lloyd::new(3).fit(&data, &RunContext::new(&backend).max_iters(3));
    /// let path = std::env::temp_dir().join(format!("gkm_doc_save_{}.gkm", std::process::id()));
    /// model.save(&path).unwrap();
    /// let served = FittedModel::load(&path).unwrap();
    /// assert_eq!(served.labels, model.labels);
    /// # std::fs::remove_file(&path).ok();
    /// ```
    pub fn save(&self, path: &Path) -> crate::runtime::RtResult<()> {
        crate::model::serde::save(self, path)
    }

    /// Load a model saved by [`FittedModel::save`].  Everything except
    /// the vectors section is read eagerly; the vectors page from the
    /// file on demand ([`ModelVectors::Disk`]) after a streaming
    /// checksum pass.  Corrupt artifacts are rejected with a typed
    /// [`RtError`](crate::runtime::RtError) naming the damaged section
    /// ([`is_corrupt`](crate::runtime::RtError::is_corrupt)).
    ///
    /// ```
    /// use gkmeans::data::synth::{blobs, BlobSpec};
    /// use gkmeans::model::{Clusterer, FittedModel, Lloyd, RunContext};
    /// use gkmeans::runtime::Backend;
    ///
    /// let data = blobs(&BlobSpec::quick(80, 4, 2), 9);
    /// let backend = Backend::native();
    /// let model = Lloyd::new(2).fit(&data, &RunContext::new(&backend).max_iters(2));
    /// let path = std::env::temp_dir().join(format!("gkm_doc_load_{}.gkm", std::process::id()));
    /// model.save(&path).unwrap();
    /// let served = FittedModel::load(&path).unwrap();
    /// assert_eq!((served.k, served.dim, served.n_train), (model.k, 4, 80));
    /// // a reloaded model predicts exactly like the fresh one
    /// assert_eq!(served.predict(&data), model.predict(&data));
    /// # std::fs::remove_file(&path).ok();
    /// ```
    pub fn load(path: &Path) -> crate::runtime::RtResult<FittedModel> {
        crate::model::serde::load(path)
    }

    /// Verify the time-accounting contract (see the type docs).  Tests
    /// and the pipeline assert this after every fit.
    pub fn check_time_accounting(&self) -> Result<(), String> {
        let eps = 1e-9;
        if self.graph_seconds > self.init_seconds + eps {
            return Err(format!(
                "graph_seconds {} exceeds init_seconds {}",
                self.graph_seconds, self.init_seconds
            ));
        }
        if self.init_seconds > self.total_seconds + eps {
            return Err(format!(
                "init_seconds {} exceeds total_seconds {}",
                self.init_seconds, self.total_seconds
            ));
        }
        let mut prev = 0.0f64;
        for h in &self.history {
            if h.seconds + eps < prev {
                return Err(format!(
                    "history clock went backwards: {} after {}",
                    h.seconds, prev
                ));
            }
            prev = h.seconds;
        }
        if let Some(first) = self.history.first() {
            if first.seconds + eps < self.graph_seconds {
                return Err(format!(
                    "history[0] at {}s predates the graph build ({}s): graph time \
                     not folded into the shared clock",
                    first.seconds, self.graph_seconds
                ));
            }
        }
        if let Some(last) = self.history.last() {
            if last.seconds > self.total_seconds + eps {
                return Err(format!(
                    "last history entry {}s exceeds total_seconds {}: graph time \
                     counted twice",
                    last.seconds, self.total_seconds
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::model::{Clusterer, GkMeans, Lloyd};

    #[test]
    fn predict_is_nearest_centroid() {
        let data = blobs(&BlobSpec { sigma: 0.2, spread: 40.0, ..BlobSpec::quick(300, 6, 4) }, 1);
        let b = Backend::native();
        let ctx = RunContext::new(&b);
        let model = Lloyd::new(4).fit(&data, &ctx);
        let preds = model.predict(&data);
        assert_eq!(preds.len(), 300);
        for (i, &p) in preds.iter().enumerate() {
            let mine = crate::core_ops::dist::d2(data.row(i), model.centroids.row(p as usize));
            for r in 0..model.k {
                let other = crate::core_ops::dist::d2(data.row(i), model.centroids.row(r));
                assert!(
                    mine <= other + 1e-4 * (1.0 + other),
                    "row {i}: predicted {p} at {mine} but {r} at {other}"
                );
            }
        }
    }

    #[test]
    fn predict_threaded_matches_serial() {
        let data = blobs(&BlobSpec::quick(500, 8, 6), 2);
        let b = Backend::native();
        let ctx = RunContext::new(&b);
        let mut model = Lloyd::new(6).fit(&data, &ctx);
        let serial = model.predict(&data);
        model.threads = 4;
        let par = model.predict(&data);
        assert_eq!(serial, par);
    }

    #[test]
    fn predict_empty_queries() {
        let data = blobs(&BlobSpec::quick(100, 4, 3), 3);
        let b = Backend::native();
        let model = Lloyd::new(3).fit(&data, &RunContext::new(&b));
        assert!(model.predict(&VecSet::zeros(0, 4)).is_empty());
    }

    #[test]
    fn search_requires_graph_and_data() {
        let data = blobs(&BlobSpec::quick(200, 4, 3), 4);
        let b = Backend::native();
        let no_graph = Lloyd::new(3).fit(&data, &RunContext::new(&b));
        assert!(no_graph
            .search(data.row(0), 1, &Default::default())
            .unwrap_err()
            .contains("no KNN graph"));
        let no_data = GkMeans::new(3).kappa(5).tau(2).fit(&data, &RunContext::new(&b));
        assert!(no_data
            .search(data.row(0), 1, &Default::default())
            .unwrap_err()
            .contains("keep_data"));
    }

    #[test]
    fn try_variants_match_infallible_on_clean_data() {
        let data = blobs(&BlobSpec::quick(200, 6, 4), 5);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(3).keep_data(true);
        let model = GkMeans::new(4).kappa(6).tau(2).xi(25).fit(&data, &ctx);
        let want = model.predict_batch(&data);
        let got = model.try_predict_batch(&data).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(*g.as_ref().unwrap(), *w);
        }
        let hits = model.search_batch(&data, 5, &Default::default()).unwrap();
        let try_hits = model.try_search_batch(&data, 5, &Default::default()).unwrap();
        assert_eq!(hits.len(), try_hits.len());
        for (h, t) in hits.iter().zip(&try_hits) {
            assert_eq!(h, t.as_ref().unwrap());
        }
        // serving preconditions surface as the outer typed error
        let no_graph = Lloyd::new(3).fit(&data, &RunContext::new(&b).max_iters(2));
        assert!(no_graph.try_search_batch(&data, 1, &Default::default()).is_err());
    }

    #[test]
    fn try_predict_batch_degrades_per_row_on_corruption() {
        // model over 2-d data; queries stream from a bvecs file whose
        // *middle* record header is corrupt — only that row may fail
        let data = blobs(&BlobSpec::quick(100, 2, 3), 6);
        let b = Backend::native();
        let model = Lloyd::new(3).fit(&data, &RunContext::new(&b).max_iters(3));
        let p = std::env::temp_dir().join(format!("gkm_tryq_{}.bvecs", std::process::id()));
        let mut bytes = Vec::new();
        for (hdr, row) in [(2i32, [7u8, 200u8]), (3i32, [0u8, 255u8]), (2i32, [3u8, 4u8])] {
            bytes.extend(hdr.to_le_bytes());
            bytes.extend(row);
        }
        std::fs::write(&p, &bytes).unwrap();
        let queries = crate::data::store::ChunkedVecStore::open_bvecs(&p).unwrap().chunk_rows(1);
        let out = model.try_predict_batch(&queries).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok() && out[2].is_ok(), "intact rows must be served");
        assert!(out[1].is_err(), "the corrupt row must be reported, not invented");
        // the surviving rows get the exact labels a clean predict yields
        let clean = VecSet::from_flat(2, vec![7.0, 200.0, 3.0, 4.0]);
        let want = model.predict(&clean);
        assert_eq!(*out[0].as_ref().unwrap(), want[0]);
        assert_eq!(*out[2].as_ref().unwrap(), want[1]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn accounting_contract_holds_for_graph_fit() {
        let data = blobs(&BlobSpec::quick(300, 4, 4), 5);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(4);
        let model = GkMeans::new(4).kappa(5).tau(2).xi(25).fit(&data, &ctx);
        model.check_time_accounting().unwrap();
        assert!(model.graph_seconds > 0.0);
        assert!(model.graph.is_some());
    }
}
