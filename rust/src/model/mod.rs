//! The fit → model → query surface: train a clusterer once, keep the
//! result as a first-class artifact, query it forever.
//!
//! The paper's end state is a *serving* artifact — a million centroids
//! over ten million vectors that downstream systems query — so the
//! library's public shape mirrors that:
//!
//! 1. a typed config ([`Lloyd`], [`Boost`], [`MiniBatch`],
//!    [`ClosureKmeans`], [`GkMeans`], [`GkMeansStar`], [`KGraphGkMeans`])
//!    implementing [`Clusterer`];
//! 2. [`Clusterer::fit`] over a dataset and a shared [`RunContext`]
//!    (backend + threads + seed + iteration control + progress callback);
//! 3. the returned [`FittedModel`] holds centroids, labels, history and —
//!    for the graph methods — the KNN graph, and answers
//!    [`FittedModel::predict`] (out-of-sample assignment),
//!    [`FittedModel::search`] (graph ANN), and round-trips through
//!    versioned binary [`FittedModel::save`]/[`FittedModel::load`].
//!
//! ```no_run
//! use gkmeans::model::{Clusterer, GkMeans, RunContext};
//! use gkmeans::data::synth::{blobs, BlobSpec};
//! use gkmeans::runtime::Backend;
//!
//! let data = blobs(&BlobSpec::quick(10_000, 32, 64), 42);
//! let backend = Backend::auto();
//! let ctx = RunContext::new(&backend).threads(4).keep_data(true);
//! let model = GkMeans::new(100).kappa(20).fit(&data, &ctx);
//! model.save(std::path::Path::new("vocab.gkm")).unwrap();
//! let labels = model.predict(&data);
//! let hits = model.search(data.row(0), 10, &Default::default()).unwrap();
//! # let _ = (labels, hits);
//! ```

pub mod checkpoint;
pub mod clusterer;
pub mod extend;
pub mod fitted;
pub mod serde;

pub use clusterer::{
    Boost, ClosureKmeans, Clusterer, GkMeans, GkMeansStar, KGraphGkMeans, Lloyd, MiniBatch,
};
pub use extend::{DriftState, ExtendParams, ExtendReport};
pub use fitted::{FittedModel, ModelVectors};

use crate::data::plan::ScanOrder;
use crate::kmeans::common::{IterStat, KmeansParams};
use crate::runtime::Backend;

/// Per-epoch progress callback: `(method name, epoch stat)`.
pub type ProgressFn = Box<dyn Fn(&str, &IterStat) + Sync>;

/// Everything about *how* to run a fit, shared by every [`Clusterer`]:
/// compute backend, worker threads, RNG seed, iteration control, and an
/// optional progress callback.  Built fluently:
///
/// ```
/// use gkmeans::model::RunContext;
/// use gkmeans::runtime::Backend;
///
/// let backend = Backend::native(); // or Backend::auto() for PJRT-when-available
/// let ctx = RunContext::new(&backend)
///     .threads(2)       // 1 = serial/bit-identical, 0 = auto-detect
///     .seed(7)
///     .max_iters(50)
///     .keep_data(true); // retain vectors so the model can serve ANN
/// assert_eq!((ctx.threads, ctx.seed, ctx.max_iters), (2, 7, 50));
/// assert!(ctx.keep_data);
/// ```
pub struct RunContext<'a> {
    /// Compute backend for the bulk distance math.
    pub backend: &'a Backend,
    /// Worker threads (`1` = serial/bit-identical, `0` = auto).
    pub threads: usize,
    /// RNG seed (initialization, visit order).
    pub seed: u64,
    /// Maximum epochs (full passes).
    pub max_iters: usize,
    /// Stop when the fraction of samples moved in an epoch drops below.
    pub min_move_rate: f64,
    /// Retain a copy of the training vectors inside the [`FittedModel`]
    /// so it can serve [`FittedModel::search`] after `save`/`load`.
    pub keep_data: bool,
    /// Epoch visit-order policy for the random-access scan loops (see
    /// [`crate::data::plan`]).  `Auto` (the default) shuffles within
    /// chunk-aligned super-blocks on paged stores — one chunk read per
    /// chunk per epoch instead of one per sample — and keeps the
    /// historical global shuffle, bit-identical, on resident data.
    /// `Global` forces the cache-oblivious order everywhere (exact
    /// reproduction of in-RAM scans on a paged store); `Superblock`
    /// requests locality planning explicitly.
    pub scan_order: ScanOrder,
    /// Invoked once per recorded epoch stat.  **Streaming semantics**
    /// for the hooked engines (Lloyd, Boost, GK-means, GK-means\*,
    /// KGraph+GK-means): the callback fires from inside the optimization
    /// loop, right after each epoch completes (the iteration-0
    /// initialization entry included), with `seconds` already folded to
    /// the wall-clock values the final model reports — so it works as a
    /// live heartbeat.  MiniBatch and Closure k-means still emit their
    /// whole history once, after the fit finishes (batch semantics).
    pub progress: Option<ProgressFn>,
    /// Periodic epoch-level checkpointing: `Some((dir, every))` writes a
    /// `fit.gkckpt` into `dir` after every `every`-th completed epoch
    /// (see [`checkpoint`]).  A write failure logs a warning and the fit
    /// continues — checkpointing is belt-and-braces, never the thing
    /// that kills a healthy fit.
    pub checkpoint: Option<CheckpointConfig>,
    /// Resume from `checkpoint` dir's `fit.gkckpt` if one exists (the
    /// checkpoint must match the job: method, k, dim, n, seed).  With no
    /// checkpoint file present the fit starts fresh.
    pub resume: bool,
}

/// Where and how often [`RunContext::checkpoint`] writes.
#[derive(Debug, Clone)]
pub struct CheckpointConfig {
    /// Directory holding `fit.gkckpt` (created on first write).
    pub dir: std::path::PathBuf,
    /// Write after every N completed epochs (≥ 1).
    pub every: usize,
}

impl<'a> RunContext<'a> {
    /// A context on `backend` with the library defaults (serial, seed
    /// 20170707, 30 epochs — the same defaults [`KmeansParams`] has).
    pub fn new(backend: &'a Backend) -> RunContext<'a> {
        let base = KmeansParams::default();
        RunContext {
            backend,
            threads: base.threads,
            seed: base.seed,
            max_iters: base.max_iters,
            min_move_rate: base.min_move_rate,
            keep_data: false,
            scan_order: base.scan_order,
            progress: None,
            checkpoint: None,
            resume: false,
        }
    }

    /// Set the worker-thread count (`1` = serial, `0` = auto).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the RNG seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the epoch cap.
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    /// Set the move-rate stopping threshold.
    pub fn min_move_rate(mut self, rate: f64) -> Self {
        self.min_move_rate = rate;
        self
    }

    /// Retain the training vectors in the fitted model (ANN serving).
    pub fn keep_data(mut self, keep: bool) -> Self {
        self.keep_data = keep;
        self
    }

    /// Set the epoch visit-order policy (CLI `--scan-order`).
    pub fn scan_order(mut self, order: ScanOrder) -> Self {
        self.scan_order = order;
        self
    }

    /// Install a per-epoch progress callback.
    pub fn on_progress(mut self, f: impl Fn(&str, &IterStat) + Sync + 'static) -> Self {
        self.progress = Some(Box::new(f));
        self
    }

    /// Write a `fit.gkckpt` checkpoint into `dir` after every
    /// `every_n_epochs` completed epochs (clamped to ≥ 1); see
    /// [`checkpoint`].  Combine with [`RunContext::resume`] to continue
    /// an interrupted fit.
    pub fn checkpoint(mut self, dir: impl Into<std::path::PathBuf>, every_n_epochs: usize) -> Self {
        self.checkpoint = Some(CheckpointConfig { dir: dir.into(), every: every_n_epochs.max(1) });
        self
    }

    /// Resume from the checkpoint directory's `fit.gkckpt`, if present.
    pub fn resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// The iteration-control slice of this context as the legacy
    /// [`KmeansParams`] the algorithm cores consume.
    pub fn kmeans_params(&self) -> KmeansParams {
        KmeansParams {
            max_iters: self.max_iters,
            min_move_rate: self.min_move_rate,
            seed: self.seed,
            threads: self.threads,
            scan_order: self.scan_order,
        }
    }

    /// Emit one epoch stat through the progress callback, if any.
    pub fn emit(&self, method: &str, stat: &IterStat) {
        if let Some(f) = &self.progress {
            f(method, stat);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_fields() {
        let b = Backend::native();
        let ctx = RunContext::new(&b)
            .threads(4)
            .seed(9)
            .max_iters(12)
            .min_move_rate(0.5)
            .keep_data(true)
            .scan_order(ScanOrder::Superblock);
        assert_eq!(ctx.threads, 4);
        assert_eq!(ctx.seed, 9);
        assert_eq!(ctx.max_iters, 12);
        assert_eq!(ctx.min_move_rate, 0.5);
        assert!(ctx.keep_data);
        assert_eq!(ctx.scan_order, ScanOrder::Superblock);
        let p = ctx.kmeans_params();
        assert_eq!(p.max_iters, 12);
        assert_eq!(p.seed, 9);
        assert_eq!(p.threads, 4);
        assert_eq!(p.scan_order, ScanOrder::Superblock);
    }

    #[test]
    fn defaults_match_kmeans_params() {
        let b = Backend::native();
        let ctx = RunContext::new(&b);
        let d = KmeansParams::default();
        assert_eq!(ctx.max_iters, d.max_iters);
        assert_eq!(ctx.seed, d.seed);
        assert_eq!(ctx.threads, d.threads);
        assert!(!ctx.keep_data);
    }

    #[test]
    fn progress_callback_fires() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let b = Backend::native();
        let ctx = RunContext::new(&b).on_progress(move |_, _| {
            h.fetch_add(1, Ordering::Relaxed);
        });
        let stat = IterStat { iter: 0, seconds: 0.0, distortion: 1.0, moves: 0 };
        ctx.emit("test", &stat);
        ctx.emit("test", &stat);
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }
}
