//! The approximate KNN graph: for each of `n` samples, an ascending-
//! distance list of κ (distance, id) neighbor entries.
//!
//! Updates keep the lists sorted and deduplicated; `update` is the inner
//! operation of both Alg. 3 (in-cell refinement) and NN-Descent, so it is
//! written to be branch-cheap: one threshold check rejects most
//! candidates, and insertion shifts at most κ entries.

use crate::util::rng::Rng;

/// Fixed-κ neighbor lists over `n` samples.
#[derive(Debug, Clone)]
pub struct KnnGraph {
    n: usize,
    kappa: usize,
    /// Flat `n × κ` neighbor ids (u32::MAX = empty slot).
    ids: Vec<u32>,
    /// Flat `n × κ` squared distances, ascending per row.
    dists: Vec<f32>,
}

impl KnnGraph {
    /// An empty graph (all slots vacant).
    pub fn empty(n: usize, kappa: usize) -> KnnGraph {
        assert!(kappa >= 1);
        KnnGraph {
            n,
            kappa,
            ids: vec![u32::MAX; n * kappa],
            dists: vec![f32::INFINITY; n * kappa],
        }
    }

    /// Random initialization (Alg. 3 line 4): κ distinct random neighbors
    /// per node, distances set to +∞ so any real measurement replaces them.
    ///
    /// Distances are *not* computed here: the first GK-means round treats
    /// the random lists as arbitrary candidates, exactly as the paper
    /// intends ("the clustering results are nearly random" at τ=0).
    pub fn random(n: usize, kappa: usize, rng: &mut Rng) -> KnnGraph {
        let mut g = KnnGraph::empty(n, kappa);
        for i in 0..n {
            let row = &mut g.ids[i * kappa..(i + 1) * kappa];
            for t in 0..row.len() {
                // distinct from self AND from earlier slots in the row
                // (kappa ≪ n, so rejection terminates fast; when n is tiny
                // and slots can't all be filled, leave the rest vacant)
                let mut attempts = 0;
                loop {
                    let cand = rng.below(n) as u32;
                    attempts += 1;
                    if cand as usize != i && !row[..t].contains(&cand) {
                        row[t] = cand;
                        break;
                    }
                    if attempts > 16 * n {
                        break; // leave vacant (u32::MAX)
                    }
                }
            }
        }
        g
    }

    /// Reassemble a graph from its flat parts (the [`KnnGraph::ids_flat`] /
    /// [`KnnGraph::dists_flat`] buffers a serialized model carries).
    /// Validates buffer shapes and the per-row invariants, so a corrupted
    /// artifact is an error, never a structurally-broken graph.
    pub fn from_parts(
        n: usize,
        kappa: usize,
        ids: Vec<u32>,
        dists: Vec<f32>,
    ) -> Result<KnnGraph, String> {
        if kappa == 0 {
            return Err("graph kappa must be >= 1".into());
        }
        let cells = n
            .checked_mul(kappa)
            .ok_or_else(|| "graph size overflows".to_string())?;
        if ids.len() != cells || dists.len() != cells {
            return Err(format!(
                "graph buffers have {} ids / {} dists, expected {cells}",
                ids.len(),
                dists.len()
            ));
        }
        let g = KnnGraph { n, kappa, ids, dists };
        g.check_invariants()?;
        Ok(g)
    }

    /// The flat `n × κ` neighbor-id buffer (u32::MAX = vacant slot).
    #[inline]
    pub fn ids_flat(&self) -> &[u32] {
        &self.ids
    }

    /// The flat `n × κ` squared-distance buffer (ascending per row).
    #[inline]
    pub fn dists_flat(&self) -> &[f32] {
        &self.dists
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn kappa(&self) -> usize {
        self.kappa
    }

    /// Neighbor ids of node `i` (may contain `u32::MAX` for vacant slots).
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.ids[i * self.kappa..(i + 1) * self.kappa]
    }

    /// Neighbor distances of node `i` (ascending).
    #[inline]
    pub fn distances(&self, i: usize) -> &[f32] {
        &self.dists[i * self.kappa..(i + 1) * self.kappa]
    }

    /// Current worst kept distance of node `i` (∞ if any slot vacant).
    #[inline]
    pub fn threshold(&self, i: usize) -> f32 {
        self.dists[i * self.kappa + self.kappa - 1]
    }

    /// Offer neighbor `j` at squared distance `d` to node `i`'s list.
    /// Keeps the row sorted ascending and free of duplicates.  Returns
    /// true if the list changed.
    pub fn update(&mut self, i: usize, j: u32, d: f32) -> bool {
        debug_assert_ne!(i as u32, j, "self-edge");
        let base = i * self.kappa;
        let k = self.kappa;
        let dists = &mut self.dists[base..base + k];
        let ids = &mut self.ids[base..base + k];
        if d >= dists[k - 1] {
            return false; // not better than the worst kept
        }
        // find insertion position (first index with dist > d)
        let mut pos = match dists.partition_point(|&x| x < d) {
            p => p,
        };
        // duplicate check: j could already be present (same or other dist).
        // Rows are short (κ ≤ 100) — linear scan is fastest in practice.
        if let Some(existing) = ids.iter().position(|&x| x == j) {
            if dists[existing] <= d {
                return false; // already present with a better distance
            }
            // re-position the existing entry with the improved distance
            if existing < pos {
                pos = existing;
            }
            // shift (existing..pos] right is wrong direction; remove then insert
            // remove `existing`, shift left everything after it
            for t in existing..k - 1 {
                ids[t] = ids[t + 1];
                dists[t] = dists[t + 1];
            }
            ids[k - 1] = u32::MAX;
            dists[k - 1] = f32::INFINITY;
        }
        // shift right from pos, insert
        for t in (pos..k - 1).rev() {
            ids[t + 1] = ids[t];
            dists[t + 1] = dists[t];
        }
        ids[pos] = j;
        dists[pos] = d;
        true
    }

    /// Symmetric update: offers the pair to both endpoints (Alg. 3 line 11
    /// "Update G[i] and G[j] with d(x_i, x_j)").
    pub fn update_pair(&mut self, i: usize, j: usize, d: f32) -> bool {
        let a = self.update(i, j as u32, d);
        let b = self.update(j, i as u32, d);
        a || b
    }

    /// Append `m` vacant rows (ids `u32::MAX`, distances `+∞`) — the
    /// incremental-extend path grows the graph first, then repairs the
    /// new rows with localized joins ([`crate::model::FittedModel::extend`]).
    pub fn grow(&mut self, m: usize) {
        self.n += m;
        self.ids.resize(self.n * self.kappa, u32::MAX);
        self.dists.resize(self.n * self.kappa, f32::INFINITY);
    }

    /// Move the rows of `part` into `self` starting at global row `lo`.
    /// `part`'s neighbor ids must already be global.  Row-sharded parallel
    /// builds (e.g. `graph::brute::build_threaded`) assemble their result
    /// with this.
    pub fn adopt_rows(&mut self, lo: usize, part: &KnnGraph) {
        assert_eq!(self.kappa, part.kappa, "kappa mismatch");
        assert!(lo + part.n <= self.n, "row range out of bounds");
        let k = self.kappa;
        self.ids[lo * k..(lo + part.n) * k].copy_from_slice(&part.ids);
        self.dists[lo * k..(lo + part.n) * k].copy_from_slice(&part.dists);
    }

    /// Row-invariant check (sorted, deduplicated, no self-edges, ids in
    /// bounds).  Note: row-sharded *partial* graphs (see
    /// [`KnnGraph::adopt_rows`]) hold global ids and must only be checked
    /// after assembly into the full graph.
    pub fn check_invariants(&self) -> Result<(), String> {
        for i in 0..self.n {
            let ids = self.neighbors(i);
            let dists = self.distances(i);
            let mut seen = std::collections::HashSet::new();
            for t in 0..self.kappa {
                if ids[t] == u32::MAX {
                    continue;
                }
                if ids[t] as usize >= self.n {
                    return Err(format!(
                        "neighbor id {} out of bounds (n={}) at node {i}",
                        ids[t], self.n
                    ));
                }
                if ids[t] as usize == i {
                    return Err(format!("self edge at node {i}"));
                }
                if !seen.insert(ids[t]) {
                    return Err(format!("duplicate neighbor {} at node {i}", ids[t]));
                }
                if t > 0 && dists[t] < dists[t - 1] {
                    return Err(format!("row {i} not sorted at slot {t}"));
                }
            }
        }
        Ok(())
    }

    /// Mean of the top-1 distances (a cheap graph-quality proxy).
    pub fn mean_nn_dist(&self) -> f64 {
        let mut s = 0f64;
        let mut c = 0usize;
        for i in 0..self.n {
            let d = self.dists[i * self.kappa];
            if d.is_finite() {
                s += d as f64;
                c += 1;
            }
        }
        if c == 0 {
            f64::INFINITY
        } else {
            s / c as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_keeps_sorted_topk() {
        let mut g = KnnGraph::empty(2, 3);
        assert!(g.update(0, 5, 2.0));
        assert!(g.update(0, 6, 1.0));
        assert!(g.update(0, 7, 3.0));
        assert!(!g.update(0, 8, 9.0), "worse than worst");
        assert!(g.update(0, 9, 0.5));
        assert_eq!(g.neighbors(0), &[9, 6, 5]);
        assert_eq!(g.distances(0), &[0.5, 1.0, 2.0]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_with_better_distance_repositions() {
        let mut g = KnnGraph::empty(1, 3);
        g.update(0, 5, 2.0);
        g.update(0, 6, 3.0);
        assert!(!g.update(0, 5, 2.5), "worse duplicate ignored");
        assert!(g.update(0, 6, 0.1), "better duplicate repositions");
        assert_eq!(g.neighbors(0), &[6, 5, u32::MAX]);
        assert_eq!(g.distances(0)[..2], [0.1, 2.0]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn update_pair_touches_both() {
        let mut g = KnnGraph::empty(4, 2);
        g.update_pair(1, 3, 0.7);
        assert_eq!(g.neighbors(1)[0], 3);
        assert_eq!(g.neighbors(3)[0], 1);
    }

    #[test]
    fn random_init_valid() {
        let mut rng = Rng::new(1);
        let g = KnnGraph::random(50, 5, &mut rng);
        g.check_invariants().unwrap();
        for i in 0..50 {
            assert!(g.neighbors(i).iter().all(|&j| j != u32::MAX && j < 50));
        }
    }

    #[test]
    fn threshold_reflects_worst() {
        let mut g = KnnGraph::empty(1, 2);
        assert_eq!(g.threshold(0), f32::INFINITY);
        g.update(0, 1, 5.0);
        assert_eq!(g.threshold(0), f32::INFINITY, "still a vacant slot");
        g.update(0, 2, 3.0);
        assert_eq!(g.threshold(0), 5.0);
    }

    #[test]
    fn grow_appends_vacant_rows() {
        let mut g = KnnGraph::empty(2, 3);
        g.update_pair(0, 1, 0.25);
        g.grow(2);
        assert_eq!(g.n(), 4);
        assert_eq!(g.neighbors(0)[0], 1, "existing rows untouched");
        assert_eq!(g.neighbors(2), &[u32::MAX; 3]);
        assert_eq!(g.threshold(3), f32::INFINITY);
        g.update_pair(3, 0, 0.5);
        g.check_invariants().unwrap();
    }

    #[test]
    fn adopt_rows_moves_partial_graphs() {
        let mut whole = KnnGraph::empty(4, 2);
        let mut part = KnnGraph::empty(2, 2);
        part.update(0, 3, 1.5); // global row 2's neighbor
        part.update(1, 0, 0.5); // global row 3's neighbor
        whole.adopt_rows(2, &part);
        assert_eq!(whole.neighbors(2)[0], 3);
        assert_eq!(whole.distances(2)[0], 1.5);
        assert_eq!(whole.neighbors(3)[0], 0);
        assert_eq!(whole.neighbors(0), &[u32::MAX, u32::MAX]);
        whole.check_invariants().unwrap();
    }

    #[test]
    fn from_parts_roundtrip_and_validation() {
        let mut rng = Rng::new(3);
        let g = KnnGraph::random(30, 4, &mut rng);
        let back =
            KnnGraph::from_parts(30, 4, g.ids_flat().to_vec(), g.dists_flat().to_vec()).unwrap();
        assert_eq!(back.neighbors(7), g.neighbors(7));
        assert_eq!(back.distances(7), g.distances(7));
        // wrong shape
        assert!(KnnGraph::from_parts(30, 4, vec![0; 10], vec![0.0; 10]).is_err());
        // self-edge rejected by the invariant check
        assert!(KnnGraph::from_parts(1, 1, vec![0], vec![0.5]).is_err());
    }

    #[test]
    fn randomized_update_stress_keeps_invariants() {
        let mut rng = Rng::new(2);
        let mut g = KnnGraph::empty(20, 4);
        for _ in 0..2000 {
            let i = rng.below(20);
            let mut j = rng.below(20);
            if j == i {
                j = (j + 1) % 20;
            }
            g.update(i, j as u32, rng.f32());
        }
        g.check_invariants().unwrap();
    }
}
