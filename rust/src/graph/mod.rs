//! K-nearest-neighbor graphs: the data structure GK-means is driven by.
//!
//! * [`knn`] — the fixed-κ neighbor-list graph with heap-based updates.
//! * [`brute`] — exact graph construction (ground truth for recall).
//! * [`nn_descent`] — NN-Descent/KGraph [32], the comparator graph
//!   supplier for the "KGraph+GK-means" runs.
//! * [`recall`] — recall@1 / recall@κ measurement, sampled for large n.

pub mod brute;
pub mod knn;
pub mod nn_descent;
pub mod recall;
