//! NN-Descent / KGraph [32] (Dong, Moses & Li, WWW'11).
//!
//! The comparator graph-construction algorithm for the paper's
//! "KGraph+GK-means" runs (Fig. 4, Tab. 2).  Principle: *"a neighbor of a
//! neighbor is also likely to be a neighbor"* — iterate local joins over
//! each node's neighborhood (and reverse neighborhood), keeping the best κ.
//!
//! This implementation follows the published algorithm: new/old flags per
//! entry, sampled local joins (ρ), reverse lists, termination when the
//! per-iteration update count drops below `delta · n · κ`.
//!
//! ## Parallel local joins (`threads > 1`)
//!
//! The distance evaluations of the local join dominate each round, and
//! per-node joins are independent *reads*; only the top-κ list updates
//! write.  The parallel path therefore gathers-then-merges: node ranges
//! are sharded across workers, each worker evaluates its joins against a
//! frozen snapshot of the per-node thresholds and collects the passing
//! `(u, v, d)` candidates, and a serial fold applies them through
//! `KnnGraph::update_pair` (which re-checks against the live lists, so
//! stale-threshold candidates are simply rejected).  Because thresholds
//! only tighten, the collected set is a superset of what the serial scan
//! would accept — no neighbor the serial pass would have found is ever
//! missed.  `threads = 1` keeps the historical serial loop bit-for-bit.

use crate::data::plan::{ScanOrder, ScanPlan};
use crate::data::store::VecStore;
use crate::graph::knn::KnnGraph;
use crate::util::pool;
use crate::util::rng::Rng;

/// NN-Descent parameters (defaults follow the paper [32]).
#[derive(Debug, Clone)]
pub struct NnDescentParams {
    /// Sample rate ρ for the local join.
    pub rho: f64,
    /// Termination threshold: stop when updates < delta · n · κ.
    pub delta: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
    pub seed: u64,
    /// Worker threads for the local-join phase (`1` = serial,
    /// bit-identical to the historical implementation; `0` = auto).
    pub threads: usize,
    /// Access-order policy for the local-join distance evaluations (see
    /// [`crate::data::plan`]): on paged stores the join's row pairs are
    /// grouped by chunk before evaluation; on resident data the policy
    /// is inert and the historical evaluation order is kept bit-for-bit.
    pub scan_order: ScanOrder,
}

impl Default for NnDescentParams {
    fn default() -> Self {
        NnDescentParams {
            rho: 1.0,
            delta: 0.001,
            max_iters: 12,
            seed: 20170707,
            threads: 1,
            scan_order: ScanOrder::Auto,
        }
    }
}

/// Collect one node's join pairs (new×new then new×old, the historical
/// sequence) into `pair_buf` after sorting/deduping the candidate lists.
fn collect_join_pairs(news: &mut Vec<u32>, olds: &mut Vec<u32>, pair_buf: &mut Vec<(u32, u32)>) {
    news.sort_unstable();
    news.dedup();
    olds.sort_unstable();
    olds.dedup();
    pair_buf.clear();
    for a in 0..news.len() {
        for b in (a + 1)..news.len() {
            pair_buf.push((news[a], news[b]));
        }
        for &vv in olds.iter() {
            if news[a] != vv {
                pair_buf.push((news[a], vv));
            }
        }
    }
}

/// Evaluate the local joins for one shard of nodes against a frozen
/// threshold snapshot, returning the candidate updates that pass.  The
/// pairs are gathered first and (under a super-block plan) grouped by
/// chunk before the distance evaluations; with planning off the
/// evaluation sequence is exactly the historical one.
fn join_shard(
    data: &dyn VecStore,
    g: &KnnGraph,
    plan: &ScanPlan,
    new_cand: &mut [Vec<u32>],
    old_cand: &mut [Vec<u32>],
) -> Vec<(u32, u32, f32)> {
    let mut out = Vec::new();
    let mut pair_buf: Vec<(u32, u32)> = Vec::new();
    let mut cur = data.open();
    for (news, olds) in new_cand.iter_mut().zip(old_cand.iter_mut()) {
        collect_join_pairs(news, olds, &mut pair_buf);
        plan.order_pairs(&mut pair_buf);
        for &(u, v) in pair_buf.iter() {
            let dd = cur.d2_pair(u as usize, v as usize);
            if dd < g.threshold(u as usize) || dd < g.threshold(v as usize) {
                out.push((u, v, dd));
            }
        }
    }
    out
}

/// Build an approximate κ-NN graph with NN-Descent over any [`VecStore`]
/// (the local joins read random row pairs through per-worker cursors).
pub fn build(data: &dyn VecStore, kappa: usize, params: &NnDescentParams) -> KnnGraph {
    let n = data.rows();
    let threads = pool::resolve_threads(params.threads).min(n.max(1));
    let plan = ScanPlan::new(data, params.scan_order);
    let mut rng = Rng::new(params.seed);
    let g = KnnGraph::random(n, kappa, &mut rng);
    let mut cur = data.open();
    // materialize distances for the random lists so thresholds are real
    // (vacant u32::MAX slots — tiny n, kappa ≥ n — are skipped)
    let mut g2 = KnnGraph::empty(n, kappa);
    if plan.is_superblock() {
        // Random lists scatter across the whole store: group the (i, j)
        // reads by chunk pair so each chunk pages in a bounded number of
        // times instead of once per edge.  Grouping runs one i-segment
        // (super-block of rows) at a time, so the pair buffer stays at
        // `segment × κ` entries instead of `n × κ` — the paper's 10M×50
        // scale would otherwise spike gigabytes of transient pairs.
        let seg = data
            .scan_geometry()
            .map(|geo| geo.superblock_rows())
            .unwrap_or(n)
            .max(1);
        let mut pairs: Vec<(u32, u32)> = Vec::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + seg).min(n);
            pairs.clear();
            for i in lo..hi {
                for &j in g.neighbors(i) {
                    if j != u32::MAX {
                        pairs.push((i as u32, j));
                    }
                }
            }
            plan.order_pairs(&mut pairs);
            for &(i, j) in pairs.iter() {
                let dd = cur.d2_pair(i as usize, j as usize);
                g2.update(i as usize, j, dd);
            }
            lo = hi;
        }
    } else {
        for i in 0..n {
            for &j in g.neighbors(i) {
                if j != u32::MAX {
                    let dd = cur.d2_pair(i, j as usize);
                    g2.update(i, j, dd);
                }
            }
        }
    }
    let mut g = g2;

    // "new" flags: an entry participates in a join only while new
    let mut is_new: Vec<Vec<bool>> = (0..n).map(|i| vec![true; g.neighbors(i).len()]) .collect();

    for _iter in 0..params.max_iters {
        // Build per-node join candidate sets: sampled new/old forward
        // neighbors + sampled reverse neighbors.  (Serial: the reverse
        // pushes write to arbitrary nodes, and the ρ sampling must consume
        // one shared RNG stream.)
        let mut new_cand: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_cand: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            let ids = g.neighbors(i);
            for (t, &j) in ids.iter().enumerate() {
                if j == u32::MAX {
                    continue;
                }
                let take = params.rho >= 1.0 || rng.f64() < params.rho;
                if !take {
                    continue;
                }
                if is_new[i][t] {
                    new_cand[i].push(j);
                    new_cand[j as usize].push(i as u32); // reverse
                    is_new[i][t] = false; // mark used
                } else {
                    old_cand[i].push(j);
                    old_cand[j as usize].push(i as u32);
                }
            }
        }

        let mut updates = 0usize;
        if threads <= 1 {
            // --- serial join: updates applied in place, fresh thresholds ---
            // Pairs are gathered per node (new×new then new×old — the
            // historical sequence) and, under a super-block plan, grouped
            // by chunk before evaluation; with planning off the
            // evaluate/update sequence is bit-identical to the pre-planner
            // loop.
            let mut pair_buf: Vec<(u32, u32)> = Vec::new();
            for i in 0..n {
                collect_join_pairs(&mut new_cand[i], &mut old_cand[i], &mut pair_buf);
                plan.order_pairs(&mut pair_buf);
                for &(u, v) in pair_buf.iter() {
                    let (u, v) = (u as usize, v as usize);
                    let dd = cur.d2_pair(u, v);
                    if dd < g.threshold(u) || dd < g.threshold(v) {
                        if g.update_pair(u, v, dd) {
                            updates += 1;
                        }
                    }
                }
            }
        } else {
            // --- parallel join: gather per shard, merge serially ---
            // Blocked so the gathered (u, v, d) buffers stay bounded even
            // in the first rounds (loose random-graph thresholds pass most
            // pairs); merging between blocks also refreshes the threshold
            // snapshot, so later blocks prune nearly as well as serial.
            let block = threads * 512;
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + block).min(n);
                let span = hi - lo;
                let chunk = (span + threads - 1) / threads;
                let collected: Vec<Vec<(u32, u32, f32)>> = std::thread::scope(|s| {
                    let g_ref = &g;
                    let plan_ref = &plan;
                    let handles: Vec<_> = new_cand[lo..hi]
                        .chunks_mut(chunk)
                        .zip(old_cand[lo..hi].chunks_mut(chunk))
                        .map(|(nc, oc)| s.spawn(move || join_shard(data, g_ref, plan_ref, nc, oc)))
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("nn-descent worker panicked"))
                        .collect()
                });
                for list in collected {
                    for (u, v, dd) in list {
                        if g.update_pair(u as usize, v as usize, dd) {
                            updates += 1;
                        }
                    }
                }
                lo = hi;
            }
        }

        // refresh new-flags: entries that changed since last sweep are new.
        // (approximation: rebuild flags by comparing lists — cheap enough.)
        for i in 0..n {
            let len = g.neighbors(i).len();
            if is_new[i].len() != len {
                is_new[i] = vec![true; len];
            }
        }
        // mark everything old except slots that updated this round: for
        // simplicity mark all true when many updates, else taper off.
        let frac = updates as f64 / (n as f64 * kappa as f64);
        for row in is_new.iter_mut() {
            for f in row.iter_mut() {
                *f = frac > params.delta;
            }
        }

        crate::log_debug!("nn-descent iter {_iter}: updates={updates} frac={frac:.5}");
        if (updates as f64) < params.delta * n as f64 * kappa as f64 {
            break;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core_ops::dist::d2;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::{brute, recall};
    use crate::runtime::Backend;

    #[test]
    fn converges_to_high_recall_on_blobs() {
        let data = blobs(&BlobSpec::quick(600, 8, 8), 1);
        let g = build(&data, 8, &NnDescentParams::default());
        g.check_invariants().unwrap();
        let exact = brute::build(&data, 8, &Backend::native());
        let r1 = recall::recall_at_1(&g, &exact);
        assert!(r1 > 0.80, "nn-descent recall@1 = {r1}");
    }

    #[test]
    fn distances_are_real() {
        let data = blobs(&BlobSpec::quick(100, 4, 4), 2);
        let g = build(&data, 4, &NnDescentParams::default());
        for i in 0..100 {
            for (t, &j) in g.neighbors(i).iter().enumerate() {
                if j != u32::MAX {
                    let want = d2(data.row(i), data.row(j as usize));
                    let got = g.distances(i)[t];
                    assert!((got - want).abs() < 1e-3 * (1.0 + want));
                }
            }
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let data = blobs(&BlobSpec::quick(150, 4, 3), 3);
        let a = build(&data, 4, &NnDescentParams::default());
        let b = build(&data, 4, &NnDescentParams::default());
        for i in 0..150 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
    }

    #[test]
    fn parallel_join_reaches_serial_recall() {
        let data = blobs(&BlobSpec::quick(600, 8, 8), 1);
        let serial = build(&data, 8, &NnDescentParams::default());
        let par = build(&data, 8, &NnDescentParams { threads: 4, ..Default::default() });
        par.check_invariants().unwrap();
        let exact = brute::build(&data, 8, &Backend::native());
        let rs = recall::recall_at_1(&serial, &exact);
        let rp = recall::recall_at_1(&par, &exact);
        assert!(rp > 0.80, "parallel nn-descent recall@1 = {rp}");
        assert!(rp >= rs - 0.1, "parallel recall {rp} far below serial {rs}");
    }

    #[test]
    fn parallel_join_deterministic_per_thread_count() {
        let data = blobs(&BlobSpec::quick(200, 4, 4), 6);
        let p = NnDescentParams { threads: 3, ..Default::default() };
        let a = build(&data, 4, &p);
        let b = build(&data, 4, &p);
        for i in 0..200 {
            assert_eq!(a.neighbors(i), b.neighbors(i), "row {i}");
        }
    }
}
