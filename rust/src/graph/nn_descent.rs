//! NN-Descent / KGraph [32] (Dong, Moses & Li, WWW'11).
//!
//! The comparator graph-construction algorithm for the paper's
//! "KGraph+GK-means" runs (Fig. 4, Tab. 2).  Principle: *"a neighbor of a
//! neighbor is also likely to be a neighbor"* — iterate local joins over
//! each node's neighborhood (and reverse neighborhood), keeping the best κ.
//!
//! This implementation follows the published algorithm: new/old flags per
//! entry, sampled local joins (ρ), reverse lists, termination when the
//! per-iteration update count drops below `delta · n · κ`.

use crate::core_ops::dist::d2;
use crate::data::matrix::VecSet;
use crate::graph::knn::KnnGraph;
use crate::util::rng::Rng;

/// NN-Descent parameters (defaults follow the paper [32]).
#[derive(Debug, Clone)]
pub struct NnDescentParams {
    /// Sample rate ρ for the local join.
    pub rho: f64,
    /// Termination threshold: stop when updates < delta · n · κ.
    pub delta: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for NnDescentParams {
    fn default() -> Self {
        NnDescentParams { rho: 1.0, delta: 0.001, max_iters: 12, seed: 20170707 }
    }
}

/// Build an approximate κ-NN graph with NN-Descent.
pub fn build(data: &VecSet, kappa: usize, params: &NnDescentParams) -> KnnGraph {
    let n = data.rows();
    let mut rng = Rng::new(params.seed);
    let g = KnnGraph::random(n, kappa, &mut rng);
    // materialize distances for the random lists so thresholds are real
    let ids0: Vec<(usize, Vec<u32>)> = (0..n).map(|i| (i, g.neighbors(i).to_vec())).collect();
    let mut g2 = KnnGraph::empty(n, kappa);
    for (i, ids) in ids0 {
        for j in ids {
            let dd = d2(data.row(i), data.row(j as usize));
            g2.update(i, j, dd);
        }
    }
    let mut g = g2;

    // "new" flags: an entry participates in a join only while new
    let mut is_new: Vec<Vec<bool>> = (0..n).map(|i| vec![true; g.neighbors(i).len()]) .collect();

    for _iter in 0..params.max_iters {
        // Build per-node join candidate sets: sampled new/old forward
        // neighbors + sampled reverse neighbors.
        let mut new_cand: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_cand: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            let ids = g.neighbors(i);
            for (t, &j) in ids.iter().enumerate() {
                if j == u32::MAX {
                    continue;
                }
                let take = params.rho >= 1.0 || rng.f64() < params.rho;
                if !take {
                    continue;
                }
                if is_new[i][t] {
                    new_cand[i].push(j);
                    new_cand[j as usize].push(i as u32); // reverse
                    is_new[i][t] = false; // mark used
                } else {
                    old_cand[i].push(j);
                    old_cand[j as usize].push(i as u32);
                }
            }
        }

        let mut updates = 0usize;
        for i in 0..n {
            let news = &mut new_cand[i];
            news.sort_unstable();
            news.dedup();
            let olds = &mut old_cand[i];
            olds.sort_unstable();
            olds.dedup();
            // join new × new
            for a in 0..news.len() {
                for b in (a + 1)..news.len() {
                    let (u, v) = (news[a] as usize, news[b] as usize);
                    if u == v {
                        continue;
                    }
                    let dd = d2(data.row(u), data.row(v));
                    if dd < g.threshold(u) || dd < g.threshold(v) {
                        if g.update_pair(u, v, dd) {
                            updates += 1;
                        }
                    }
                }
                // join new × old
                let u = news[a] as usize;
                for &vv in olds.iter() {
                    let v = vv as usize;
                    if u == v {
                        continue;
                    }
                    let dd = d2(data.row(u), data.row(v));
                    if dd < g.threshold(u) || dd < g.threshold(v) {
                        if g.update_pair(u, v, dd) {
                            updates += 1;
                        }
                    }
                }
            }
        }

        // refresh new-flags: entries that changed since last sweep are new.
        // (approximation: rebuild flags by comparing lists — cheap enough.)
        for i in 0..n {
            let len = g.neighbors(i).len();
            if is_new[i].len() != len {
                is_new[i] = vec![true; len];
            }
        }
        // mark everything old except slots that updated this round: for
        // simplicity mark all true when many updates, else taper off.
        let frac = updates as f64 / (n as f64 * kappa as f64);
        for row in is_new.iter_mut() {
            for f in row.iter_mut() {
                *f = frac > params.delta;
            }
        }

        crate::log_debug!("nn-descent iter {_iter}: updates={updates} frac={frac:.5}");
        if (updates as f64) < params.delta * n as f64 * kappa as f64 {
            break;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::graph::{brute, recall};
    use crate::runtime::Backend;

    #[test]
    fn converges_to_high_recall_on_blobs() {
        let data = blobs(&BlobSpec::quick(600, 8, 8), 1);
        let g = build(&data, 8, &NnDescentParams::default());
        g.check_invariants().unwrap();
        let exact = brute::build(&data, 8, &Backend::native());
        let r1 = recall::recall_at_1(&g, &exact);
        assert!(r1 > 0.80, "nn-descent recall@1 = {r1}");
    }

    #[test]
    fn distances_are_real() {
        let data = blobs(&BlobSpec::quick(100, 4, 4), 2);
        let g = build(&data, 4, &NnDescentParams::default());
        for i in 0..100 {
            for (t, &j) in g.neighbors(i).iter().enumerate() {
                if j != u32::MAX {
                    let want = d2(data.row(i), data.row(j as usize));
                    let got = g.distances(i)[t];
                    assert!((got - want).abs() < 1e-3 * (1.0 + want));
                }
            }
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let data = blobs(&BlobSpec::quick(150, 4, 3), 3);
        let a = build(&data, 4, &NnDescentParams::default());
        let b = build(&data, 4, &NnDescentParams::default());
        for i in 0..150 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
    }
}
