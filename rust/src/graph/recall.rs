//! Graph-quality measurement: recall of the approximate graph against
//! exact neighbors.
//!
//! The paper reports *top-1 average recall*: the fraction of samples whose
//! exact nearest neighbor appears in the approximate KNN list's first
//! position — §5.1 measures "only the recall of top-1"; for VLAD10M it is
//! estimated from 100 random samples.  Both modes live here.

use crate::data::store::VecStore;
use crate::graph::brute;
use crate::graph::knn::KnnGraph;
use crate::util::rng::Rng;

/// recall@1 against a precomputed exact graph: fraction of nodes whose
/// true top-1 equals the approximate top-1.
pub fn recall_at_1(approx: &KnnGraph, exact: &KnnGraph) -> f64 {
    assert_eq!(approx.n(), exact.n());
    let n = approx.n();
    let mut hit = 0usize;
    for i in 0..n {
        if approx.neighbors(i)[0] == exact.neighbors(i)[0] {
            hit += 1;
        }
    }
    hit as f64 / n.max(1) as f64
}

/// recall@κ: |approx row ∩ exact row| / κ averaged over nodes.
pub fn recall_at_k(approx: &KnnGraph, exact: &KnnGraph, kappa: usize) -> f64 {
    assert_eq!(approx.n(), exact.n());
    let n = approx.n();
    let mut total = 0f64;
    for i in 0..n {
        let truth: std::collections::HashSet<u32> =
            exact.neighbors(i).iter().copied().take(kappa).collect();
        let inter = approx
            .neighbors(i)
            .iter()
            .take(kappa)
            .filter(|j| truth.contains(j))
            .count();
        total += inter as f64 / kappa as f64;
    }
    total / n.max(1) as f64
}

/// Sampled top-1 recall for large `n` (the paper's VLAD10M protocol:
/// estimate from `samples` random nodes with exact per-query search).
pub fn sampled_recall_at_1(data: &dyn VecStore, approx: &KnnGraph, samples: usize, seed: u64) -> f64 {
    let n = data.rows();
    let mut rng = Rng::new(seed);
    let picks = rng.sample_indices(n, samples.min(n));
    let mut hit = 0usize;
    for &i in &picks {
        let truth = brute::exact_neighbors_of(data, i, 1);
        if !truth.is_empty() && approx.neighbors(i)[0] == truth[0] {
            hit += 1;
        }
    }
    hit as f64 / picks.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::runtime::Backend;

    #[test]
    fn exact_graph_has_recall_one() {
        let data = blobs(&BlobSpec::quick(120, 4, 4), 1);
        let g = brute::build(&data, 4, &Backend::native());
        assert_eq!(recall_at_1(&g, &g), 1.0);
        assert_eq!(recall_at_k(&g, &g, 4), 1.0);
        assert!(sampled_recall_at_1(&data, &g, 30, 7) > 0.999);
    }

    #[test]
    fn random_graph_has_low_recall() {
        let data = blobs(&BlobSpec::quick(300, 4, 4), 2);
        let exact = brute::build(&data, 3, &Backend::native());
        let mut rng = Rng::new(3);
        let random = KnnGraph::random(300, 3, &mut rng);
        assert!(recall_at_1(&random, &exact) < 0.05);
        assert!(recall_at_k(&random, &exact, 3) < 0.05);
    }

    #[test]
    fn partial_overlap_recall_at_k() {
        // construct graphs by hand: approx has 1 of 2 right per node
        let mut exact = KnnGraph::empty(2, 2);
        exact.update(0, 1, 1.0);
        exact.update(0, 2, 2.0);
        exact.update(1, 0, 1.0);
        exact.update(1, 2, 2.0);
        let mut approx = KnnGraph::empty(2, 2);
        approx.update(0, 1, 1.0);
        approx.update(0, 9, 1.5);
        approx.update(1, 9, 0.5);
        approx.update(1, 2, 2.0);
        assert!((recall_at_k(&approx, &exact, 2) - 0.5).abs() < 1e-9);
        assert!((recall_at_1(&approx, &exact) - 0.5).abs() < 1e-9);
    }
}
