//! Exact KNN graph by brute force — the recall ground truth.
//!
//! `O(d·n²)`: only run on the scales the paper does (SIFT100K-sized and
//! below, or the sampled-recall path in [`crate::graph::recall`]).
//!
//! [`build_threaded`] row-shards the n×n scan across workers: each worker
//! owns a contiguous stripe of query rows, tiles the full candidate range
//! against it with the native blocked kernel, and folds into a private
//! partial graph; stripes are disjoint, so the assembled result is
//! bit-identical to the serial build.

use crate::core_ops::blockdist;
use crate::data::store::VecStore;
use crate::graph::knn::KnnGraph;
use crate::runtime::Backend;
use crate::util::pool;

/// Build the exact κ-NN graph with blocked distance tiles (serial) over
/// any [`VecStore`] — two cursors stream the query-row and candidate-row
/// tiles, so the n×n scan runs out-of-core with a bounded footprint.
pub fn build(data: &dyn VecStore, kappa: usize, backend: &Backend) -> KnnGraph {
    let n = data.rows();
    let d = data.dim();
    let mut g = KnnGraph::empty(n, kappa);
    const B: usize = 256;
    let mut block = vec![0f32; B * B];
    let mut xcur = data.open();
    let mut ycur = data.open();
    let mut i0 = 0;
    while i0 < n {
        let rows = (n - i0).min(B);
        let xb = xcur.block(i0, i0 + rows);
        let mut j0 = 0;
        while j0 < n {
            let cols = (n - j0).min(B);
            let yb = ycur.block(j0, j0 + cols);
            let blk = &mut block[..rows * cols];
            backend.block_l2(xb, yb, d, blk);
            for r in 0..rows {
                let gi = i0 + r;
                let row = &blk[r * cols..(r + 1) * cols];
                for (c, &dd) in row.iter().enumerate() {
                    let gj = j0 + c;
                    if gi != gj {
                        g.update(gi, gj as u32, dd);
                    }
                }
            }
            j0 += cols;
        }
        i0 += rows;
    }
    g
}

/// Build the exact κ-NN graph with the row-sharded parallel scan.
/// `threads <= 1` (after resolution) falls back to the serial [`build`].
/// Workers always use the native kernel (PJRT dispatch is single-threaded
/// by design); against a native-backend serial build the result is
/// bit-identical, while a PJRT serial build differs only at f32 kernel
/// tolerance.
pub fn build_threaded(
    data: &dyn VecStore,
    kappa: usize,
    backend: &Backend,
    threads: usize,
) -> KnnGraph {
    let n = data.rows();
    let threads = pool::resolve_threads(threads).min(n.max(1));
    if threads <= 1 {
        return build(data, kappa, backend);
    }
    let d = data.dim();
    const B: usize = 256;
    let parts = pool::par_map_chunks(threads, n, |_, range| {
        let mut part = KnnGraph::empty(range.len(), kappa);
        let mut block = vec![0f32; B * B];
        let mut xcur = data.open();
        let mut ycur = data.open();
        let mut i0 = range.start;
        while i0 < range.end {
            let rows = (range.end - i0).min(B);
            let xb = xcur.block(i0, i0 + rows);
            let mut j0 = 0;
            while j0 < n {
                let cols = (n - j0).min(B);
                let yb = ycur.block(j0, j0 + cols);
                let blk = &mut block[..rows * cols];
                blockdist::block_l2(xb, yb, d, blk);
                for r in 0..rows {
                    let gi = i0 + r;
                    let row = &blk[r * cols..(r + 1) * cols];
                    for (c, &dd) in row.iter().enumerate() {
                        let gj = j0 + c;
                        if gi != gj {
                            part.update(gi - range.start, gj as u32, dd);
                        }
                    }
                }
                j0 += cols;
            }
            i0 += rows;
        }
        (range.start, part)
    });
    let mut g = KnnGraph::empty(n, kappa);
    for (lo, part) in &parts {
        g.adopt_rows(*lo, part);
    }
    g
}

/// Exact κ nearest neighbors of one query row index (used by sampled
/// recall on sets too large for the full graph).
pub fn exact_neighbors_of(data: &dyn VecStore, i: usize, kappa: usize) -> Vec<u32> {
    use crate::core_ops::topk::TopK;
    let mut t = TopK::new(kappa);
    let mut cur = data.open();
    let q = cur.row(i).to_vec();
    for j in 0..data.rows() {
        if j != i {
            t.push(crate::core_ops::dist::d2(&q, cur.row(j)), j as u32);
        }
    }
    t.into_sorted().into_iter().map(|n| n.id).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};

    #[test]
    fn brute_graph_matches_per_query_search() {
        let data = blobs(&BlobSpec::quick(150, 6, 4), 1);
        let g = build(&data, 5, &Backend::native());
        g.check_invariants().unwrap();
        for i in (0..150).step_by(17) {
            let want = exact_neighbors_of(&data, i, 5);
            assert_eq!(g.neighbors(i), &want[..], "row {i}");
        }
    }

    #[test]
    fn neighbors_are_sorted_and_distances_exact() {
        let data = blobs(&BlobSpec::quick(80, 3, 3), 2);
        let g = build(&data, 3, &Backend::native());
        for i in 0..80 {
            let ids = g.neighbors(i);
            let ds = g.distances(i);
            for t in 0..3 {
                let want = crate::core_ops::dist::d2(data.row(i), data.row(ids[t] as usize));
                assert!((ds[t] - want).abs() < 1e-3 * (1.0 + want));
            }
            assert!(ds.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn kappa_larger_than_n_minus_one() {
        let data = blobs(&BlobSpec::quick(5, 2, 1), 3);
        let g = build(&data, 10, &Backend::native());
        for i in 0..5 {
            let real: Vec<u32> = g.neighbors(i).iter().copied().filter(|&j| j != u32::MAX).collect();
            assert_eq!(real.len(), 4, "only n-1 neighbors exist");
        }
    }

    #[test]
    fn threaded_build_is_bit_identical() {
        let data = blobs(&BlobSpec::quick(300, 6, 5), 4);
        let serial = build(&data, 6, &Backend::native());
        for threads in [2usize, 3, 8] {
            let par = build_threaded(&data, 6, &Backend::native(), threads);
            for i in 0..300 {
                assert_eq!(serial.neighbors(i), par.neighbors(i), "row {i} threads={threads}");
                assert_eq!(serial.distances(i), par.distances(i), "row {i} threads={threads}");
            }
        }
    }

    #[test]
    fn threaded_build_handles_more_threads_than_rows() {
        let data = blobs(&BlobSpec::quick(7, 3, 2), 5);
        let par = build_threaded(&data, 3, &Backend::native(), 16);
        let serial = build(&data, 3, &Backend::native());
        for i in 0..7 {
            assert_eq!(serial.neighbors(i), par.neighbors(i));
        }
    }
}
