//! `gkm-serve` — serve one or more GKMODEL artifacts over TCP.
//!
//! ```text
//! gkm-serve model.gkm [more-shards.gkm ...] \
//!     [--addr 127.0.0.1:7070] [--batch-window-us 200] [--max-batch 64] \
//!     [--ef 64] [--threads 0] [--max-conns 256] [--heartbeat-s 10] \
//!     [--resident] [--quantize]
//! ```
//!
//! Several model paths shard one logical index: global ids are assigned
//! in argument order (shard 0's rows first).  Vectors page from disk by
//! default (GKMODEL v2 lazy loading); `--resident` materializes them
//! into RAM at startup, and `--quantize` trains an SQ8 code store per
//! shard so searches traverse RAM-resident u8 codes (exact f32 re-rank
//! pages only the `ef` surviving rows) — a no-op for artifacts that
//! already carry a QVECTORS section.  The process exits cleanly on
//! SIGTERM/SIGINT or a protocol SHUTDOWN frame.

use std::time::Duration;

use gkmeans::model::{FittedModel, ModelVectors};
use gkmeans::serve::{install_termination_handler, ServeConfig, Server, ShardedIndex};
use gkmeans::util::cli;

fn usage() -> ! {
    eprintln!(
        "usage: gkm-serve MODEL.gkm [SHARD2.gkm ...] [--addr HOST:PORT] \
         [--batch-window-us N] [--max-batch N] [--ef N] [--threads N] \
         [--max-conns N] [--heartbeat-s N] [--resident] [--quantize]"
    );
    std::process::exit(2);
}

fn main() {
    let args = cli::parse_env(&[
        "addr",
        "model",
        "batch-window-us",
        "max-batch",
        "ef",
        "threads",
        "max-conns",
        "heartbeat-s",
    ]);
    // model paths: positionals (plus the subcommand slot, which the
    // parser claims for a bare first path) and an optional --model
    let mut paths: Vec<String> = Vec::new();
    if let Some(sub) = &args.subcommand {
        paths.push(sub.clone());
    }
    paths.extend(args.positionals.iter().cloned());
    if let Some(m) = args.get("model") {
        paths.push(m.to_string());
    }
    if paths.is_empty() {
        usage();
    }
    let resident = args.flag("resident");
    let quantize = args.flag("quantize");

    let mut shards = Vec::with_capacity(paths.len());
    for p in &paths {
        let mut model = match FittedModel::load(std::path::Path::new(p)) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("gkm-serve: cannot load {p}: {e}");
                std::process::exit(1);
            }
        };
        if resident {
            if let Some(data) = &model.data {
                model.data = Some(ModelVectors::Ram(data.to_vecset()));
            }
        }
        // artifacts saved with `cluster --quantize sq8` already carry
        // codes; otherwise train a quantizer here (one streaming pass)
        if quantize && model.quantized.is_none() {
            if let Err(e) = model.quantize_sq8(0) {
                eprintln!("gkm-serve: cannot quantize {p}: {e}");
                std::process::exit(1);
            }
        }
        let backing = match &model.data {
            Some(d) if d.is_resident() => "resident",
            Some(_) => "disk",
            None => "no-vectors (predict only)",
        };
        let codes = match &model.quantized {
            Some(q) => format!(", sq8 codes {} bytes", q.resident_bytes()),
            None => String::new(),
        };
        eprintln!(
            "[gkm-serve] loaded {p}: {} n={} dim={} k={} [{backing}{codes}]",
            model.method.name(),
            model.n_train,
            model.dim,
            model.k
        );
        shards.push(model);
    }

    let cfg = ServeConfig {
        addr: args.get_or("addr", "127.0.0.1:7070").to_string(),
        batch_window: Duration::from_micros(args.u64_or("batch-window-us", 200)),
        max_batch: args.usize_or("max-batch", 64),
        default_ef: args.usize_or("ef", 64),
        threads: args.usize_or("threads", 0),
        max_conns: args.usize_or("max-conns", 256),
        heartbeat: match args.u64_or("heartbeat-s", 10) {
            0 => None,
            s => Some(Duration::from_secs(s)),
        },
    };

    let index = match ShardedIndex::new(shards) {
        Ok(i) => i,
        Err(e) => {
            eprintln!("gkm-serve: {e}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "[gkm-serve] index: {} shards, {} rows, dim {}",
        index.num_shards(),
        index.total_rows(),
        index.dim()
    );

    install_termination_handler();
    let handle = match Server::start(index, &cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("gkm-serve: {e}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "[gkm-serve] listening on {} (window {}us, max-batch {})",
        handle.addr(),
        cfg.batch_window.as_micros(),
        cfg.max_batch
    );
    handle.wait();
    eprintln!("[gkm-serve] shutdown complete");
}
