//! Closure k-means [27] (Wang et al., CVPR'12) — the strongest fast
//! baseline the paper compares against (Figs. 5–7, Tab. 2).
//!
//! Idea: each iteration, a sample only needs to be compared against the
//! centroids of clusters in its *closure* — the clusters owning points
//! that fall into the same cell of a random spatial partition as the
//! sample.  We realize the partitions as random-projection bisection trees
//! (the paper's own construction): `trees` independent RP-trees with
//! leaves of ≤ `leaf_max` points; a sample's candidate set is the set of
//! cluster labels present in its leaves, plus its current cluster.  Per-
//! iteration cost is `O(n · d · |candidates|)` — near-constant in k, which
//! is exactly the behaviour Fig. 6(b) shows.
//!
//! The restricted assignment scan is sharded over the worker pool
//! (`assign_restricted`): per-worker cursors walk contiguous stripes of
//! the sequential scan order, and since each sample's result depends only
//! on frozen state, any thread count reproduces the serial labels
//! bit-for-bit (the gather-then-merge discipline of
//! [`crate::util::pool`]).

use crate::core_ops::dist::d2;
use crate::data::matrix::VecSet;
use crate::data::plan::ScanPlan;
use crate::data::store::VecStore;
use crate::kmeans::common::{Clustering, IterStat, KmeansOutput, KmeansParams};
use crate::kmeans::two_means::{self, TwoMeansParams};
use crate::runtime::Backend;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Closure k-means knobs.
#[derive(Debug, Clone)]
pub struct ClosureParams {
    /// Number of independent random-partition trees.
    pub trees: usize,
    /// Maximum leaf size of each tree.
    pub leaf_max: usize,
    pub base: KmeansParams,
}

impl Default for ClosureParams {
    fn default() -> Self {
        ClosureParams { trees: 3, leaf_max: 30, base: KmeansParams::default() }
    }
}

/// Leaves of one random-projection bisection tree: a permutation of sample
/// ids plus `[start, end)` ranges, built iteratively to avoid recursion
/// depth issues.  Streams over any [`VecStore`]: each split's projections
/// are evaluated through a cursor — in chunk-grouped order under a
/// super-block plan (a row's projection is independent of read order), in
/// the historical permutation order otherwise.
fn rp_tree_leaves(
    data: &dyn VecStore,
    plan: &ScanPlan,
    leaf_max: usize,
    rng: &mut Rng,
) -> (Vec<u32>, Vec<(u32, u32)>) {
    let n = data.rows();
    let d = data.dim();
    let mut cur = data.open();
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut leaves = Vec::new();
    let mut stack = vec![(0usize, n)];
    let mut read_order: Vec<u32> = Vec::new();
    while let Some((lo, hi)) = stack.pop() {
        if hi - lo <= leaf_max.max(2) {
            leaves.push((lo as u32, hi as u32));
            continue;
        }
        // random direction; median split on the projection
        let dir: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let members: &[u32] = if plan.is_superblock() {
            read_order.clear();
            read_order.extend_from_slice(&perm[lo..hi]);
            plan.order_subset(&mut read_order);
            &read_order
        } else {
            &perm[lo..hi]
        };
        let mut pairs: Vec<(f32, u32)> = members
            .iter()
            .map(|&id| (crate::core_ops::dist::dot(cur.row(id as usize), &dir), id))
            .collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (off, (_, id)) in pairs.into_iter().enumerate() {
            perm[lo + off] = id;
        }
        let mid = lo + (hi - lo) / 2;
        stack.push((lo, mid));
        stack.push((mid, hi));
    }
    (perm, leaves)
}

/// The restricted assignment scan of one closure iteration: every sample
/// is compared only against the centroids of its closure candidate set
/// (plus its current cluster).  Returns the new labels and the move
/// count.
///
/// Sharded over [`util::pool`](crate::util::pool) with a per-worker
/// cursor walking a contiguous stripe of the sequential scan order — the
/// order the planner already considers chunk-friendly, so a streamed
/// store reads each chunk once per stripe.  Per-sample results depend
/// only on that sample's candidates and the frozen
/// `labels`/`centroids`, and stripes fold back in index order, so any
/// thread count (including 1, which runs on the caller's thread without
/// spawning) produces labels **bit-identical** to the historical serial
/// loop.
fn assign_restricted(
    data: &dyn VecStore,
    candidates: &[Vec<u32>],
    labels: &[u32],
    centroids: &VecSet,
    threads: usize,
) -> (Vec<u32>, usize) {
    let n = data.rows();
    let threads = pool::resolve_threads(threads).min(n.max(1));
    let parts = pool::par_map_chunks(threads, n, |_, r| {
        let mut cur = data.open();
        let mut cand: Vec<u32> = Vec::new();
        let mut local = Vec::with_capacity(r.len());
        let mut moves = 0usize;
        for i in r {
            cand.clear();
            cand.extend_from_slice(&candidates[i]);
            cand.push(labels[i]);
            cand.sort_unstable();
            cand.dedup();
            let row = cur.row(i);
            let mut best = f32::INFINITY;
            let mut best_c = labels[i];
            for &c in cand.iter() {
                let dd = d2(row, centroids.row(c as usize));
                if dd < best {
                    best = dd;
                    best_c = c;
                }
            }
            if best_c != labels[i] {
                moves += 1;
            }
            local.push(best_c);
        }
        (local, moves)
    });
    let mut new_labels: Vec<u32> = Vec::with_capacity(n);
    let mut moves = 0usize;
    for (part, m) in parts {
        new_labels.extend_from_slice(&part);
        moves += m;
    }
    (new_labels, moves)
}

/// Deprecated shim over [`run_core`] — the pre-`Clusterer` entry point.
#[deprecated(
    note = "use `model::ClosureKmeans::new(k).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data)"
)]
pub fn run(data: &VecSet, k: usize, params: &ClosureParams, backend: &Backend) -> KmeansOutput {
    run_core(data, k, params, backend)
}

/// The closure k-means engine ([`crate::model::ClosureKmeans`] executes
/// this).  Initialization follows the paper's fast variants: a 2M-tree
/// partition (cheap, balanced) provides the starting clusters.  Runs
/// over any [`VecStore`]: the tree builds, the restricted assignment
/// scan, and the centroid updates all stream through cursors (the
/// assignment scan is sequential by construction, so it is already the
/// chunk-friendly order).
pub fn run_core(
    data: &dyn VecStore,
    k: usize,
    params: &ClosureParams,
    backend: &Backend,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();
    let plan = ScanPlan::new(data, params.base.scan_order);
    let mut rng = Rng::new(params.base.seed ^ 0xC105_0513);

    // --- init: 2M-tree labels + centroids ---
    let labels = two_means::run(
        data,
        k,
        &TwoMeansParams {
            seed: params.base.seed,
            threads: params.base.threads,
            scan_order: params.base.scan_order,
            ..Default::default()
        },
        backend,
    );
    let mut clustering = Clustering::from_labels(data, labels, k);
    let mut centroids = clustering.centroids();
    let init_seconds = timer.elapsed_s();

    // --- random partitions (closures), built once ---
    let trees: Vec<(Vec<u32>, Vec<(u32, u32)>)> = (0..params.trees.max(1))
        .map(|_| rp_tree_leaves(data, &plan, params.leaf_max, &mut rng))
        .collect();

    let mut cur = data.open();
    let total_norm: f64 = (0..n)
        .map(|i| crate::core_ops::dist::norm2(cur.row(i)) as f64)
        .sum();
    let mut history = vec![IterStat {
        iter: 0,
        seconds: timer.elapsed_s(),
        distortion: (total_norm - clustering.objective()) / n as f64,
        moves: 0,
    }];

    // scratch: candidate labels per sample, rebuilt each iteration
    let mut candidates: Vec<Vec<u32>> = vec![Vec::new(); n];

    for iter in 1..=params.base.max_iters {
        // 1) closure candidate sets from the leaf groups
        for c in candidates.iter_mut() {
            c.clear();
        }
        for (perm, leaves) in &trees {
            for &(lo, hi) in leaves {
                let members = &perm[lo as usize..hi as usize];
                // labels present in this leaf
                let mut present: Vec<u32> = members
                    .iter()
                    .map(|&i| clustering.labels[i as usize])
                    .collect();
                present.sort_unstable();
                present.dedup();
                for &i in members {
                    candidates[i as usize].extend_from_slice(&present);
                }
            }
        }

        // 2) restricted assignment, sharded over the worker pool (the
        //    last "not yet parallel" fit — per-worker cursors on
        //    contiguous stripes of the sequential scan order; results
        //    are bit-identical to the serial loop at any thread count)
        let (new_labels, moves) = assign_restricted(
            data,
            &candidates,
            &clustering.labels,
            &centroids,
            params.base.threads,
        );

        // 3) Lloyd-style update, fused with the state rebuild so a
        // streamed store is scanned once here instead of twice
        let (next, next_centroids) =
            Clustering::from_labels_with_centroids(data, new_labels, k, &centroids);
        clustering = next;
        centroids = next_centroids;

        history.push(IterStat {
            iter,
            seconds: timer.elapsed_s(),
            distortion: (total_norm - clustering.objective()) / n as f64,
            moves,
        });
        if (moves as f64) < params.base.min_move_rate * n as f64 {
            break;
        }
    }

    KmeansOutput {
        clustering,
        history,
        total_seconds: timer.elapsed_s(),
        init_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};

    #[test]
    fn rp_tree_leaves_partition_everything() {
        let data = blobs(&BlobSpec::quick(500, 6, 5), 1);
        let mut rng = Rng::new(2);
        let (perm, leaves) = rp_tree_leaves(&data, &ScanPlan::global(), 30, &mut rng);
        let mut seen = vec![false; 500];
        let mut total = 0;
        for &(lo, hi) in &leaves {
            assert!(hi - lo <= 32);
            for &i in &perm[lo as usize..hi as usize] {
                assert!(!seen[i as usize], "duplicate sample in leaves");
                seen[i as usize] = true;
                total += 1;
            }
        }
        assert_eq!(total, 500);
    }

    #[test]
    fn improves_over_init_and_valid() {
        let data = blobs(&BlobSpec::quick(800, 8, 10), 3);
        let out = run_core(&data, 10, &ClosureParams::default(), &Backend::native());
        out.clustering.check_invariants(&data).unwrap();
        let first = out.history.first().unwrap().distortion;
        let last = out.history.last().unwrap().distortion;
        assert!(last <= first + 1e-9, "{first} -> {last}");
    }

    #[test]
    fn parallel_assignment_bit_identical_to_serial() {
        // the closure hot loop: same candidates, same frozen state —
        // sharding must not move a single label or the move count
        let data = blobs(&BlobSpec::quick(600, 6, 8), 9);
        let mut rng = Rng::new(5);
        let labels: Vec<u32> = (0..600).map(|_| rng.below(8) as u32).collect();
        let clustering = Clustering::from_labels(&data, labels, 8);
        let centroids = clustering.centroids();
        let mut candidates: Vec<Vec<u32>> = vec![Vec::new(); 600];
        for (i, c) in candidates.iter_mut().enumerate() {
            let w = 1 + (i % 4);
            for t in 0..w {
                c.push(((i * 7 + t * 3) % 8) as u32);
            }
        }
        let (serial_labels, serial_moves) =
            assign_restricted(&data, &candidates, &clustering.labels, &centroids, 1);
        for threads in [2usize, 3, 8] {
            let (par_labels, par_moves) =
                assign_restricted(&data, &candidates, &clustering.labels, &centroids, threads);
            assert_eq!(serial_labels, par_labels, "threads={threads}");
            assert_eq!(serial_moves, par_moves, "threads={threads}");
        }
    }

    #[test]
    fn near_constant_cost_in_k() {
        // candidate sets depend on leaf contents, not on k; check the
        // candidate count doesn't scale with k.
        let data = blobs(&BlobSpec::quick(1000, 8, 16), 4);
        let p = ClosureParams { base: KmeansParams { max_iters: 3, ..Default::default() }, ..Default::default() };
        let t_small = crate::util::timer::timed(|| run_core(&data, 8, &p, &Backend::native())).1;
        let t_big = crate::util::timer::timed(|| run_core(&data, 64, &p, &Backend::native())).1;
        // 8x more clusters should cost far less than 8x the time; allow 3x
        // for init + noise on a loaded box.
        assert!(t_big < t_small * 4.0, "t_small={t_small} t_big={t_big}");
    }
}
