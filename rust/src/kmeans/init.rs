//! Centroid seeding: uniform random and k-means++ [14].

use crate::core_ops::dist::d2;
use crate::data::matrix::VecSet;
use crate::data::store::{self, VecStore};
use crate::util::rng::Rng;

/// k distinct data points chosen uniformly at random.
///
/// The sampled indices are scattered uniformly over the store;
/// [`store::gather`] reads them in ascending-row (chunk-grouped) order
/// and scatters back, so a paged store loads each chunk at most once and
/// the returned seeds are bit-identical to a naive in-order gather.
pub fn random_init(data: &dyn VecStore, k: usize, rng: &mut Rng) -> VecSet {
    assert!(k <= data.rows(), "k={k} > n={}", data.rows());
    let idx = rng.sample_indices(data.rows(), k);
    store::gather(data, &idx)
}

/// k-means++ seeding: each next seed drawn ∝ D²(x) to the nearest chosen
/// seed.  O(n·k·d); used by the Lloyd / Mini-Batch baselines.  Each
/// round is one sequential scan of the store, so it runs out-of-core.
pub fn kmeanspp_init(data: &dyn VecStore, k: usize, rng: &mut Rng) -> VecSet {
    let n = data.rows();
    assert!(k <= n, "k={k} > n={n}");
    let mut cur = data.open();
    let mut centers = VecSet::zeros(0, data.dim());
    let first = rng.below(n);
    let c0 = cur.row(first).to_vec();
    centers.push_row(&c0);

    let mut best_d2: Vec<f64> = (0..n).map(|i| d2(cur.row(i), &c0) as f64).collect();

    for _ in 1..k {
        let total: f64 = best_d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n) // all points identical to chosen seeds
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &w) in best_d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        };
        let c = cur.row(pick).to_vec();
        centers.push_row(&c);
        for (i, best) in best_d2.iter_mut().enumerate() {
            let dd = d2(cur.row(i), &c) as f64;
            if dd < *best {
                *best = dd;
            }
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_data() -> VecSet {
        // 4 tight groups at corners of a square
        let mut flat = Vec::new();
        for (cx, cy) in [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0), (10.0, 10.0)] {
            for i in 0..10 {
                flat.push(cx + 0.01 * i as f32);
                flat.push(cy);
            }
        }
        VecSet::from_flat(2, flat)
    }

    #[test]
    fn random_init_rows_are_data_points() {
        let data = grid_data();
        let mut rng = Rng::new(1);
        let c = random_init(&data, 4, &mut rng);
        assert_eq!(c.rows(), 4);
        for i in 0..4 {
            assert!(
                (0..data.rows()).any(|j| data.row(j) == c.row(i)),
                "seed {i} not a data point"
            );
        }
    }

    #[test]
    fn kmeanspp_spreads_across_groups() {
        let data = grid_data();
        // over several seeds, ++ should nearly always hit all 4 corners
        let mut hits = 0;
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let c = kmeanspp_init(&data, 4, &mut rng);
            let mut corners = std::collections::HashSet::new();
            for i in 0..4 {
                let r = c.row(i);
                corners.insert(((r[0] / 5.0) as i32, (r[1] / 5.0) as i32));
            }
            if corners.len() == 4 {
                hits += 1;
            }
        }
        assert!(hits >= 8, "k-means++ hit all corners only {hits}/10 times");
    }

    #[test]
    fn kmeanspp_handles_duplicates() {
        let data = VecSet::from_flat(1, vec![1.0; 20]);
        let mut rng = Rng::new(2);
        let c = kmeanspp_init(&data, 3, &mut rng);
        assert_eq!(c.rows(), 3);
    }

    #[test]
    #[should_panic(expected = "k=5 > n=2")]
    fn k_larger_than_n_panics() {
        let data = VecSet::from_flat(1, vec![0.0, 1.0]);
        random_init(&data, 5, &mut Rng::new(3));
    }
}
