//! Mini-Batch k-means [20] (Sculley, WWW'10) — the web-scale baseline.
//!
//! Each step samples a batch, assigns it to the nearest current centroid,
//! and applies per-center SGD updates with learning rate 1/c_t (c_t =
//! cumulative assignment count of the center).  Fast, but the paper's
//! Figs. 5–7 show notably worse distortion — which this implementation
//! reproduces.

use crate::core_ops::argmin::ArgminAcc;
use crate::data::matrix::VecSet;
use crate::data::store::{self, VecStore};
use crate::kmeans::common::{Clustering, IterStat, KmeansOutput, KmeansParams};
use crate::kmeans::init::kmeanspp_init;
use crate::kmeans::lloyd::assign_threaded;
use crate::runtime::Backend;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Mini-Batch specific knobs.
#[derive(Debug, Clone)]
pub struct MiniBatchParams {
    /// Samples per batch (Sculley's b).
    pub batch: usize,
    pub base: KmeansParams,
}

impl Default for MiniBatchParams {
    fn default() -> Self {
        MiniBatchParams { batch: 1024, base: KmeansParams::default() }
    }
}

/// Deprecated shim over [`run_core`] — the pre-`Clusterer` entry point.
#[deprecated(
    note = "use `model::MiniBatch::new(k).batch(b).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data)"
)]
pub fn run(data: &VecSet, k: usize, params: &MiniBatchParams, backend: &Backend) -> KmeansOutput {
    run_core(data, k, params, backend)
}

/// The Mini-Batch engine ([`crate::model::MiniBatch`] executes this).
/// One "iteration" in the history = one batch step; `base.max_iters`
/// counts batch steps (matching how the paper plots it against
/// wall-clock, where Mini-Batch may terminate before one full data pass).
/// Runs over any [`VecStore`]: batches are gathered through a cursor and
/// the full-dataset distortion/assignment passes stream in blocks,
/// sharded over `base.threads` workers.
pub fn run_core(
    data: &dyn VecStore,
    k: usize,
    params: &MiniBatchParams,
    backend: &Backend,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();
    let b = params.batch.min(n);
    let threads = params.base.threads;
    let mut rng = Rng::new(params.base.seed);

    let mut centroids = kmeanspp_init(data, k, &mut rng);
    let init_seconds = timer.elapsed_s();
    let mut counts = vec![0u64; k];
    let mut history = Vec::new();

    for iter in 0..params.base.max_iters {
        let batch_idx = rng.sample_indices(n, b);
        let batch = store::gather(data, &batch_idx);
        let acc: ArgminAcc = assign_threaded(&batch, &centroids, backend, threads);
        let mut moved = 0usize;
        for (t, &_i) in batch_idx.iter().enumerate() {
            let c = acc.idx[t] as usize;
            counts[c] += 1;
            let lr = 1.0 / counts[c] as f32;
            let row = batch.row(t);
            let ctr = centroids.row_mut(c);
            for (cv, xv) in ctr.iter_mut().zip(row) {
                *cv += lr * (xv - *cv);
            }
            moved += 1;
        }
        // Distortion here is measured on the *batch* (cheap proxy) except
        // every 10th step + last, where we pay for the real number so the
        // Fig. 5 curves are honest.
        let full = iter % 10 == 9 || iter + 1 == params.base.max_iters;
        let distortion = if full {
            let acc_all = assign_threaded(data, &centroids, backend, threads);
            acc_all.best.iter().map(|&v| v as f64).sum::<f64>() / n as f64
        } else {
            acc.best.iter().map(|&v| v as f64).sum::<f64>() / b as f64
        };
        history.push(IterStat { iter, seconds: timer.elapsed_s(), distortion, moves: moved });
    }

    // Final full assignment for the returned clustering.
    let acc = assign_threaded(data, &centroids, backend, threads);
    let clustering = Clustering::from_labels(data, acc.idx.clone(), k);
    KmeansOutput { clustering, history, total_seconds: timer.elapsed_s(), init_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};

    #[test]
    fn runs_and_improves_over_init() {
        let data = blobs(&BlobSpec::quick(2000, 8, 16), 1);
        let params = MiniBatchParams {
            batch: 256,
            base: KmeansParams { max_iters: 40, ..Default::default() },
        };
        let out = run_core(&data, 16, &params, &Backend::native());
        assert_eq!(out.history.len(), 40);
        out.clustering.check_invariants(&data).unwrap();
        // mini-batch should still find blob structure on easy data
        let first = out.history.first().unwrap().distortion;
        let last = out.history.last().unwrap().distortion;
        assert!(last <= first, "no improvement: {first} -> {last}");
    }

    #[test]
    fn worse_than_lloyd_typically() {
        // The paper's core observation about Mini-Batch: fast but higher
        // distortion. Verify the ordering on overlapping blobs.
        let data = blobs(&BlobSpec { sigma: 2.0, ..BlobSpec::quick(1500, 8, 24) }, 2);
        let k = 24;
        let mb = run_core(
            &data,
            k,
            &MiniBatchParams { batch: 128, base: KmeansParams { max_iters: 15, ..Default::default() } },
            &Backend::native(),
        );
        let lloyd = crate::kmeans::lloyd::run_core(&data, k, &KmeansParams::default(), &Backend::native());
        assert!(
            mb.clustering.distortion(&data) >= lloyd.clustering.distortion(&data) * 0.98,
            "mini-batch unexpectedly beat lloyd: {} vs {}",
            mb.clustering.distortion(&data),
            lloyd.clustering.distortion(&data)
        );
    }

    #[test]
    fn batch_larger_than_n_is_clamped() {
        let data = blobs(&BlobSpec::quick(100, 4, 4), 3);
        let out = run_core(
            &data,
            4,
            &MiniBatchParams { batch: 10_000, base: KmeansParams { max_iters: 3, ..Default::default() } },
            &Backend::native(),
        );
        assert_eq!(out.history.len(), 3);
    }
}
