//! Traditional k-means (Lloyd's algorithm) — the paper's primary baseline.
//!
//! Assignment is the `O(n·d·k)` bottleneck the paper attacks; here it runs
//! through [`Backend::assign_blocks`], i.e. blocked distance tiles on
//! either the native mini-GEMM or the AOT-compiled Pallas kernel via PJRT.

use crate::core_ops::argmin::ArgminAcc;
use crate::data::matrix::VecSet;
use crate::data::store::{StoreCursor, VecStore};
use crate::kmeans::common::{Clustering, EpochState, FitHooks, IterStat, KmeansOutput, KmeansParams};
use crate::kmeans::init::kmeanspp_init;
use crate::runtime::Backend;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Deprecated shim over [`run_core`] — the pre-`Clusterer` entry point.
#[deprecated(
    note = "use `model::Lloyd::new(k).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data)"
)]
pub fn run(data: &VecSet, k: usize, params: &KmeansParams, backend: &Backend) -> KmeansOutput {
    run_core(data, k, params, backend)
}

/// The Lloyd engine ([`crate::model::Lloyd`] executes this) — runs over
/// any [`VecStore`], so a disk-backed dataset streams through the
/// assignment and update scans block by block.
pub fn run_core(
    data: &dyn VecStore,
    k: usize,
    params: &KmeansParams,
    backend: &Backend,
) -> KmeansOutput {
    run_core_hooked(data, k, params, backend, &mut FitHooks::none())
}

/// [`run_core`] with fit instrumentation (per-epoch hook + resume).  A
/// resume point skips the k-means++ seeding and restores the checkpointed
/// labels + centroids; Lloyd's epochs consume no randomness, so restoring
/// those two arrays makes the continued fit bit-identical to the
/// uninterrupted one at any thread count (assignment is row-independent).
pub fn run_core_hooked(
    data: &dyn VecStore,
    k: usize,
    params: &KmeansParams,
    backend: &Backend,
    hooks: &mut FitHooks<'_>,
) -> KmeansOutput {
    let timer = Timer::start();
    let n = data.rows();

    let (mut centroids, mut labels, mut history, start_iter, seconds_base, init_seconds) =
        match hooks.resume.take() {
            Some(r) => {
                let centroids = VecSet::from_flat(
                    data.dim(),
                    r.centroids.expect("Lloyd checkpoint carries centroids"),
                );
                let base = r.history.last().map(|h| h.seconds).unwrap_or(0.0);
                (centroids, r.labels, r.history, r.next_iter, base, 0.0)
            }
            None => {
                let mut rng = Rng::new(params.seed);
                let centroids = kmeanspp_init(data, k, &mut rng);
                let init_seconds = timer.elapsed_s();
                hooks.init_seconds = init_seconds;
                (centroids, vec![u32::MAX; n], Vec::new(), 0, 0.0, init_seconds)
            }
        };

    for iter in start_iter..params.max_iters {
        // --- assignment (the bottleneck) ---
        let acc = assign_threaded(data, &centroids, backend, params.threads);
        let mut moves = 0usize;
        for i in 0..n {
            if labels[i] != acc.idx[i] {
                moves += 1;
                labels[i] = acc.idx[i];
            }
        }
        let distortion = acc.best.iter().map(|&v| v as f64).sum::<f64>() / n as f64;

        // --- update ---
        centroids = update_centroids(data, &labels, k, &centroids);

        history.push(IterStat { iter, seconds: seconds_base + timer.elapsed_s(), distortion, moves });
        if hooks.on_epoch.is_some() {
            let seconds_offset = hooks.seconds_offset;
            let hook_init = hooks.init_seconds;
            let stat = history.last().expect("entry just pushed");
            hooks.fire(&EpochState {
                completed_epoch: iter,
                // Lloyd's epochs draw no randomness; seeding consumed the
                // RNG before the first epoch
                rng: [0; 4],
                stat,
                history: &history,
                seconds_offset,
                init_seconds: hook_init,
                labels: &labels,
                composite: None,
                counts: None,
                comp_norm2: None,
                centroids: Some(centroids.flat()),
            });
        }
        if (moves as f64) < params.min_move_rate * n as f64 {
            break;
        }
    }

    let clustering = Clustering::from_labels(data, labels, k);
    KmeansOutput {
        clustering,
        history,
        total_seconds: seconds_base + timer.elapsed_s(),
        init_seconds,
    }
}

/// Rows streamed per `assign_blocks` call on the cursor path.
const STREAM_ROWS: usize = 1024;

/// Assign store rows `[lo, hi)` to their closest centroid, streaming
/// blocks through the cursor.  Each row's result depends only on that
/// row and the centroids, so the block boundaries do not affect values.
fn assign_stream(
    cur: &mut StoreCursor<'_>,
    lo: usize,
    hi: usize,
    centroids: &VecSet,
    backend: &Backend,
    d: usize,
) -> ArgminAcc {
    let k = centroids.rows();
    let mut acc = ArgminAcc::new(hi - lo);
    let mut r = lo;
    while r < hi {
        let r2 = (r + STREAM_ROWS).min(hi);
        let sub = backend.assign_blocks(cur.block(r, r2), centroids.flat(), d, k);
        acc.best[r - lo..r2 - lo].copy_from_slice(&sub.best);
        acc.idx[r - lo..r2 - lo].copy_from_slice(&sub.idx);
        r = r2;
    }
    acc
}

/// Full closest-centroid assignment via blocked distance tiles.  A
/// resident store routes its whole flat buffer through the backend in
/// one call (the historical path, bit-identical); a chunked store
/// streams fixed-size row blocks.
pub fn assign(data: &dyn VecStore, centroids: &VecSet, backend: &Backend) -> ArgminAcc {
    if let Some(flat) = data.as_flat() {
        return backend.assign_blocks(flat, centroids.flat(), data.dim(), centroids.rows());
    }
    assign_stream(&mut data.open(), 0, data.rows(), centroids, backend, data.dim())
}

/// Row-sharded multi-threaded [`assign`] over `util::pool`: each worker
/// opens its own cursor and runs the native kernel on its stripe.
/// Stripes are disjoint and per-row results are independent, so the
/// result is identical to the serial assignment; `threads <= 1` falls
/// through to [`assign`] (bit-identical to the historical path).
pub fn assign_threaded(
    data: &dyn VecStore,
    centroids: &VecSet,
    backend: &Backend,
    threads: usize,
) -> ArgminAcc {
    let n = data.rows();
    let threads = pool::resolve_threads(threads).min(n.max(1));
    if threads <= 1 {
        return assign(data, centroids, backend);
    }
    let d = data.dim();
    let parts = pool::par_map_chunks(threads, n, |_, r| {
        let mut cur = data.open();
        assign_stream(&mut cur, r.start, r.end, centroids, &Backend::Native, d)
    });
    let mut acc = ArgminAcc::new(n);
    let mut off = 0;
    for p in parts {
        let m = p.idx.len();
        acc.best[off..off + m].copy_from_slice(&p.best);
        acc.idx[off..off + m].copy_from_slice(&p.idx);
        off += m;
    }
    acc
}

/// Mean update; empty clusters keep their previous centroid (standard
/// empty-cluster guard, keeps k constant like the paper's implementations).
pub fn update_centroids(data: &dyn VecStore, labels: &[u32], k: usize, prev: &VecSet) -> VecSet {
    let d = data.dim();
    let mut cur = data.open();
    let mut sums = vec![0f64; k * d];
    let mut counts = vec![0u64; k];
    for (i, &l) in labels.iter().enumerate() {
        let l = l as usize;
        counts[l] += 1;
        let row = cur.row(i);
        let dst = &mut sums[l * d..(l + 1) * d];
        for (a, v) in dst.iter_mut().zip(row) {
            *a += *v as f64;
        }
    }
    let mut out = Vec::with_capacity(k * d);
    for r in 0..k {
        if counts[r] == 0 {
            out.extend_from_slice(prev.row(r));
        } else {
            let c = counts[r] as f64;
            out.extend(sums[r * d..(r + 1) * d].iter().map(|s| (*s / c) as f32));
        }
    }
    VecSet::from_flat(d, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};

    #[test]
    fn recovers_separated_blobs() {
        let data = blobs(&BlobSpec { sigma: 0.2, spread: 50.0, ..BlobSpec::quick(300, 4, 3) }, 1);
        let out = run_core(&data, 3, &KmeansParams::default(), &Backend::native());
        // well-separated: distortion should be tiny relative to spread
        assert!(out.distortion() < 1.0, "distortion={}", out.distortion());
        out.clustering.check_invariants(&data).unwrap();
    }

    #[test]
    fn distortion_non_increasing() {
        let data = blobs(&BlobSpec::quick(500, 8, 10), 2);
        let out = run_core(&data, 10, &KmeansParams::default(), &Backend::native());
        for w in out.history.windows(2) {
            assert!(
                w[1].distortion <= w[0].distortion + 1e-6,
                "distortion rose: {} -> {}",
                w[0].distortion,
                w[1].distortion
            );
        }
    }

    #[test]
    fn history_and_convergence() {
        let data = blobs(&BlobSpec::quick(200, 4, 4), 3);
        let out = run_core(&data, 4, &KmeansParams { max_iters: 50, ..Default::default() }, &Backend::native());
        assert!(!out.history.is_empty());
        assert!(out.history.len() <= 50);
        // converged well before 50 iterations on blobs
        assert!(out.history.last().unwrap().moves <= data.rows() / 100 + 1);
    }

    #[test]
    fn update_keeps_empty_cluster_centroid() {
        let data = VecSet::from_flat(1, vec![0.0, 1.0]);
        let prev = VecSet::from_flat(1, vec![5.0, 6.0, 7.0]);
        let labels = vec![0, 0];
        let c = update_centroids(&data, &labels, 3, &prev);
        assert_eq!(c.row(0), &[0.5]);
        assert_eq!(c.row(1), &[6.0]);
        assert_eq!(c.row(2), &[7.0]);
    }

    #[test]
    fn threaded_assignment_matches_serial_exactly() {
        let data = blobs(&BlobSpec::quick(700, 6, 9), 6);
        let mut rng = Rng::new(8);
        let centroids = crate::kmeans::init::kmeanspp_init(&data, 9, &mut rng);
        let serial = assign(&data, &centroids, &Backend::native());
        for threads in [2usize, 3, 8] {
            let par = assign_threaded(&data, &centroids, &Backend::native(), threads);
            assert_eq!(serial.idx, par.idx, "threads={threads}");
            assert_eq!(serial.best, par.best, "threads={threads}");
        }
    }

    #[test]
    fn threaded_run_matches_serial_exactly() {
        let data = blobs(&BlobSpec::quick(400, 5, 6), 7);
        let serial = run_core(&data, 6, &KmeansParams::default(), &Backend::native());
        let par = run_core(
            &data,
            6,
            &KmeansParams { threads: 4, ..Default::default() },
            &Backend::native(),
        );
        assert_eq!(serial.clustering.labels, par.clustering.labels);
        for (a, b) in serial.history.iter().zip(&par.history) {
            assert_eq!(a.distortion.to_bits(), b.distortion.to_bits());
            assert_eq!(a.moves, b.moves);
        }
    }

    use crate::util::rng::Rng;

    #[test]
    fn k_equals_n_zero_distortion() {
        let data = blobs(&BlobSpec::quick(20, 3, 2), 4);
        let out = run_core(&data, 20, &KmeansParams::default(), &Backend::native());
        assert!(out.distortion() < 1e-6);
    }
}
