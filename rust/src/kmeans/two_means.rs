//! Alg. 1 — Two-means (2M) tree [31]: recursive equal-size bisection.
//!
//! Bisecting k-means with one extra step: after each bisection the two
//! children are adjusted to equal size (split at the median of the margin
//! d(x,c₀) − d(x,c₁)).  Following the paper (§3.2), the bisection itself
//! is refined with a few boost-k-means sweeps (k = 2).  Complexity
//! `O(d·n·log k)` — cheaper than one full k-means iteration; GK-means uses
//! it to produce its initial partition.

use crate::core_ops::dist::d2;
use crate::data::plan::{ScanOrder, ScanPlan};
use crate::data::store::{StoreCursor, VecStore};
use crate::kmeans::common::Clustering;
use crate::runtime::Backend;
use crate::util::rng::Rng;

/// Parameters for a 2M-tree build.
#[derive(Debug, Clone)]
pub struct TwoMeansParams {
    /// Lloyd-style refinement sweeps per bisection.
    pub bisect_iters: usize,
    /// BKM refinement sweeps per bisection (paper integrates BKM at step 8).
    pub boost_iters: usize,
    pub seed: u64,
    /// Worker threads (`1` = the historical serial build, bit-identical;
    /// `0` = auto).  With `threads > 1` independent subtree splits run
    /// concurrently: each split draws from its own deterministically
    /// derived RNG stream, so results are reproducible per `(seed,
    /// threads)` but differ from the serial split order.
    pub threads: usize,
    /// Access-order policy for the per-bisection subset reads (see
    /// [`crate::data::plan`]): on paged stores each bisected subset is
    /// visited in chunk-grouped order (and the BKM polish shuffles
    /// within super-blocks); resident data keeps the historical order
    /// bit-for-bit.
    pub scan_order: ScanOrder,
}

impl Default for TwoMeansParams {
    fn default() -> Self {
        TwoMeansParams {
            bisect_iters: 4,
            boost_iters: 2,
            seed: 20170707,
            threads: 1,
            scan_order: ScanOrder::Auto,
        }
    }
}

/// Run Alg. 1: partition `data` into exactly `k` clusters of near-equal
/// size.  Returns per-sample labels in `[0, k)`.
pub fn run(data: &dyn VecStore, k: usize, params: &TwoMeansParams, backend: &Backend) -> Vec<u32> {
    let threads = crate::util::pool::resolve_threads(params.threads);
    if threads > 1 {
        return run_parallel(data, k, params, threads);
    }
    let n = data.rows();
    assert!(k >= 1 && k <= n, "k={k} n={n}");
    let plan = ScanPlan::new(data, params.scan_order);
    let mut rng = Rng::new(params.seed);

    // Cluster store: Vec of member-index lists; a simple binary max-heap of
    // (size, cluster-id) drives "pop largest".
    let mut members: Vec<Vec<u32>> = Vec::with_capacity(k);
    members.push((0..n as u32).collect());
    let mut heap: std::collections::BinaryHeap<(usize, usize)> =
        std::collections::BinaryHeap::new();
    heap.push((n, 0));

    while members.len() < k {
        let (_, id) = heap.pop().expect("heap nonempty while members < k");
        let subset = std::mem::take(&mut members[id]);
        if subset.len() < 2 {
            // Unsplittable singleton: put it back and pick another.  (With
            // k <= n there is always a splittable cluster remaining.)
            members[id] = subset;
            continue;
        }
        let (left, right) = bisect_equal(data, &subset, params, &plan, &mut rng, backend);
        let new_id = members.len();
        heap.push((left.len(), id));
        heap.push((right.len(), new_id));
        members[id] = left;
        members.push(right);
    }

    let mut labels = vec![0u32; n];
    for (cid, mem) in members.iter().enumerate() {
        for &i in mem {
            labels[i as usize] = cid as u32;
        }
    }
    labels
}

/// Convenience: run Alg. 1 and wrap into a [`Clustering`].
pub fn cluster(
    data: &dyn VecStore,
    k: usize,
    params: &TwoMeansParams,
    backend: &Backend,
) -> Clustering {
    Clustering::from_labels(data, run(data, k, params, backend), k)
}

/// Parallel 2M-tree build: each round pops the `min(threads, k - built)`
/// largest clusters off the size heap and bisects them concurrently —
/// subtree splits are fully independent.  Every split gets its own RNG
/// stream derived from `(seed, round, cluster id)`, so the build is
/// deterministic for a fixed `(seed, threads)`.  Workers use the native
/// margin path (`prefers_blocked` would only route subsets ≥ 200K through
/// PJRT, and PJRT dispatch is not shared across threads).
fn run_parallel(
    data: &dyn VecStore,
    k: usize,
    params: &TwoMeansParams,
    threads: usize,
) -> Vec<u32> {
    let n = data.rows();
    assert!(k >= 1 && k <= n, "k={k} n={n}");
    let plan = ScanPlan::new(data, params.scan_order);
    let mut members: Vec<Vec<u32>> = Vec::with_capacity(k);
    members.push((0..n as u32).collect());
    let mut heap: std::collections::BinaryHeap<(usize, usize)> =
        std::collections::BinaryHeap::new();
    heap.push((n, 0));
    let mut round: u64 = 0;

    while members.len() < k {
        let need = k - members.len();
        // pop up to `threads` splittable clusters, largest first
        let mut tasks: Vec<(usize, Vec<u32>)> = Vec::new();
        let mut stash: Vec<(usize, usize)> = Vec::new();
        while tasks.len() < threads.min(need) {
            match heap.pop() {
                Some((sz, id)) if sz < 2 => stash.push((sz, id)),
                Some((_, id)) => tasks.push((id, std::mem::take(&mut members[id]))),
                None => break,
            }
        }
        for e in stash {
            heap.push(e);
        }
        assert!(
            !tasks.is_empty(),
            "no splittable cluster left with {} < k={k} (n={n})",
            members.len()
        );
        round += 1;

        let results: Vec<(Vec<u32>, Vec<u32>)> = std::thread::scope(|s| {
            let plan_ref = &plan;
            let handles: Vec<_> = tasks
                .iter()
                .map(|(id, subset)| {
                    let task_seed = params
                        .seed
                        .wrapping_add(round.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                        ^ (*id as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
                    let subset: &[u32] = subset;
                    s.spawn(move || {
                        let mut rng = Rng::new(task_seed);
                        let backend = Backend::native();
                        bisect_equal(data, subset, params, plan_ref, &mut rng, &backend)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("2M-tree worker panicked"))
                .collect()
        });

        for ((id, _), (left, right)) in tasks.iter().zip(results) {
            let new_id = members.len();
            heap.push((left.len(), *id));
            heap.push((right.len(), new_id));
            members[*id] = left;
            members.push(right);
        }
    }

    let mut labels = vec![0u32; n];
    for (cid, mem) in members.iter().enumerate() {
        for &i in mem {
            labels[i as usize] = cid as u32;
        }
    }
    labels
}

/// Bisect one subset into two equal halves (Alg. 1 steps 8–9).
fn bisect_equal(
    data: &dyn VecStore,
    subset: &[u32],
    params: &TwoMeansParams,
    plan: &ScanPlan,
    rng: &mut Rng,
    backend: &Backend,
) -> (Vec<u32>, Vec<u32>) {
    // Under a super-block plan, visit the subset in chunk-grouped order:
    // every margin/centroid sweep below then reads each chunk at most
    // once however the parent splits scattered the ids.  (The returned
    // halves are id *sets*; their order is irrelevant to the tree.)
    let mut planned: Vec<u32>;
    let subset: &[u32] = if plan.is_superblock() {
        planned = subset.to_vec();
        plan.order_subset(&mut planned);
        &planned
    } else {
        subset
    };
    let m = subset.len();
    let d = data.dim();
    let mut cur = data.open();

    // --- 2-means on the subset ---
    let mut c0 = cur.row(subset[rng.below(m)] as usize).to_vec();
    let mut c1 = cur.row(subset[rng.below(m)] as usize).to_vec();
    if c0 == c1 {
        // nudge to break ties on duplicate draws
        for v in c1.iter_mut() {
            *v += 1e-4;
        }
    }
    let mut margins = vec![0f32; m];

    for _ in 0..params.bisect_iters.max(1) {
        // assignment by margin sign; margins via the backend for big subsets
        compute_margins(data, &mut cur, subset, &c0, &c1, backend, &mut margins);
        let (mut s0, mut s1) = (vec![0f64; d], vec![0f64; d]);
        let (mut n0, mut n1) = (0u32, 0u32);
        for (t, &i) in subset.iter().enumerate() {
            let row = cur.row(i as usize);
            if margins[t] <= 0.0 {
                for (a, v) in s0.iter_mut().zip(row) {
                    *a += *v as f64;
                }
                n0 += 1;
            } else {
                for (a, v) in s1.iter_mut().zip(row) {
                    *a += *v as f64;
                }
                n1 += 1;
            }
        }
        if n0 == 0 || n1 == 0 {
            // degenerate split: re-seed the empty side and retry next sweep
            let pick = subset[rng.below(m)] as usize;
            if n0 == 0 {
                c0 = cur.row(pick).to_vec();
            } else {
                c1 = cur.row(pick).to_vec();
            }
            continue;
        }
        for (t, a) in c0.iter_mut().enumerate() {
            *a = (s0[t] / n0 as f64) as f32;
        }
        for (t, a) in c1.iter_mut().enumerate() {
            *a = (s1[t] / n1 as f64) as f32;
        }
    }

    // --- BKM polish with k=2 on the subset (paper step 8) ---
    if params.boost_iters > 0 {
        boost_polish(
            &mut cur,
            subset,
            plan,
            &mut c0,
            &mut c1,
            params.boost_iters,
            rng,
            &mut margins,
        );
    }

    // --- equal-size adjustment (step 9): median split on the margin ---
    compute_margins(data, &mut cur, subset, &c0, &c1, backend, &mut margins);
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| margins[a].partial_cmp(&margins[b]).unwrap());
    let half = m / 2;
    let mut left = Vec::with_capacity(half.max(1));
    let mut right = Vec::with_capacity(m - half);
    for (rank, &t) in order.iter().enumerate() {
        if rank < half {
            left.push(subset[t]); // most-negative margins: closest to c0
        } else {
            right.push(subset[t]);
        }
    }
    if left.is_empty() {
        left.push(right.pop().unwrap());
    }
    (left, right)
}

/// margin[t] = d(x_t, c0) − d(x_t, c1); routed through the backend's
/// bisect entry when the subset is large enough to amortize.
fn compute_margins(
    data: &dyn VecStore,
    cur: &mut StoreCursor<'_>,
    subset: &[u32],
    c0: &[f32],
    c1: &[f32],
    backend: &Backend,
    out: &mut [f32],
) {
    if backend.prefers_blocked(subset.len()) {
        backend.bisect_margins(data, subset, c0, c1, out);
    } else {
        for (t, &i) in subset.iter().enumerate() {
            let row = cur.row(i as usize);
            out[t] = d2(row, c0) - d2(row, c1);
        }
    }
}

/// A few BKM sweeps on the 2-cluster subproblem (incremental, Eqn. 3).
#[allow(clippy::too_many_arguments)]
fn boost_polish(
    cur: &mut StoreCursor<'_>,
    subset: &[u32],
    plan: &ScanPlan,
    c0: &mut Vec<f32>,
    c1: &mut Vec<f32>,
    iters: usize,
    rng: &mut Rng,
    margins: &mut [f32],
) {
    use crate::core_ops::dist::norm2;
    let d = c0.len();
    let m = subset.len();
    // composite vectors from the current margin assignment
    for (t, &i) in subset.iter().enumerate() {
        let row = cur.row(i as usize);
        margins[t] = d2(row, c0) - d2(row, c1);
    }
    let mut comp = vec![0f64; 2 * d];
    let mut cnt = [0f64; 2];
    let mut side: Vec<u8> = vec![0; m];
    for (t, &i) in subset.iter().enumerate() {
        let s = (margins[t] > 0.0) as usize;
        side[t] = s as u8;
        cnt[s] += 1.0;
        for (a, v) in comp[s * d..(s + 1) * d].iter_mut().zip(cur.row(i as usize)) {
            *a += *v as f64;
        }
    }
    // §Perf: cached ‖D‖² + allocation-free f64 dots (the first version
    // materialized two Vec<f32> copies of the composites per visit, which
    // dominated the 2M-tree profile).
    #[inline]
    fn dot64(a: &[f64], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * *y as f64).sum()
    }
    let mut norm2_64 = [0f64; 2];
    for s in 0..2 {
        norm2_64[s] = comp[s * d..(s + 1) * d].iter().map(|a| a * a).sum();
    }
    let mut order: Vec<usize> = (0..m).collect();
    for _ in 0..iters {
        // planned: shuffle within super-blocks of the underlying rows
        // (plain shuffle — bit-identical RNG use — when planning is off)
        plan.shuffle_positions(&mut order, |t| subset[t] as usize, rng);
        let mut moves = 0;
        for &t in &order {
            let x = cur.row(subset[t] as usize);
            let u = side[t] as usize;
            let v = 1 - u;
            if cnt[u] <= 1.0 {
                continue;
            }
            let xx = norm2(x) as f64;
            let dux = dot64(&comp[u * d..(u + 1) * d], x);
            let dvx = dot64(&comp[v * d..(v + 1) * d], x);
            let duu = norm2_64[u];
            let dvv = norm2_64[v];
            let delta = (dvv + 2.0 * dvx + xx) / (cnt[v] + 1.0) - dvv / cnt[v]
                + (duu - 2.0 * dux + xx) / (cnt[u] - 1.0)
                - duu / cnt[u];
            if delta > 0.0 {
                // keep cached norms in sync: ‖D∓x‖² = ‖D‖² ∓ 2⟨D,x⟩ + ‖x‖²
                norm2_64[u] += -2.0 * dux + xx;
                norm2_64[v] += 2.0 * dvx + xx;
                for (a, xv) in comp[u * d..(u + 1) * d].iter_mut().zip(x) {
                    *a -= *xv as f64;
                }
                for (a, xv) in comp[v * d..(v + 1) * d].iter_mut().zip(x) {
                    *a += *xv as f64;
                }
                cnt[u] -= 1.0;
                cnt[v] += 1.0;
                side[t] = v as u8;
                moves += 1;
            }
        }
        if moves == 0 {
            break;
        }
    }
    for t in 0..d {
        c0[t] = (comp[t] / cnt[0].max(1.0)) as f32;
        c1[t] = (comp[d + t] / cnt[1].max(1.0)) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::VecSet;
    use crate::data::synth::{blobs, BlobSpec};

    #[test]
    fn produces_k_equalish_clusters() {
        let data = blobs(&BlobSpec::quick(1000, 8, 16), 1);
        for k in [2, 7, 16, 20] {
            let labels = run(&data, k, &TwoMeansParams::default(), &Backend::native());
            let mut counts = vec![0usize; k];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c > 0), "k={k}: empty cluster");
            let (mx, mn) = (*counts.iter().max().unwrap(), *counts.iter().min().unwrap());
            // equal-size bisection keeps sizes within ~2x of each other
            assert!(mx <= mn * 2 + 2, "k={k}: sizes {mn}..{mx} too skewed");
        }
    }

    #[test]
    fn all_samples_labeled_once() {
        let data = blobs(&BlobSpec::quick(333, 4, 4), 2);
        let labels = run(&data, 10, &TwoMeansParams::default(), &Backend::native());
        assert_eq!(labels.len(), 333);
        assert!(labels.iter().all(|&l| (l as usize) < 10));
    }

    #[test]
    fn k_one_and_k_n() {
        let data = blobs(&BlobSpec::quick(16, 3, 2), 3);
        assert!(run(&data, 1, &TwoMeansParams::default(), &Backend::native())
            .iter()
            .all(|&l| l == 0));
        let labels = run(&data, 16, &TwoMeansParams::default(), &Backend::native());
        let set: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(set.len(), 16, "k=n must give singletons");
    }

    #[test]
    fn better_than_random_partition() {
        let data = blobs(&BlobSpec::quick(600, 6, 8), 4);
        let c = cluster(&data, 8, &TwoMeansParams::default(), &Backend::native());
        let random_labels: Vec<u32> = (0..600).map(|i| (i % 8) as u32).collect();
        let r = Clustering::from_labels(&data, random_labels, 8);
        assert!(
            c.distortion(&data) < r.distortion(&data) * 0.9,
            "2M {} vs random {}",
            c.distortion(&data),
            r.distortion(&data)
        );
    }

    #[test]
    fn parallel_build_valid_and_balanced() {
        let data = blobs(&BlobSpec::quick(1000, 8, 16), 1);
        for k in [2usize, 7, 16, 20] {
            let params = TwoMeansParams { threads: 4, ..Default::default() };
            let labels = run(&data, k, &params, &Backend::native());
            assert_eq!(labels.len(), 1000);
            let mut counts = vec![0usize; k];
            for &l in &labels {
                assert!((l as usize) < k);
                counts[l as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c > 0), "k={k}: empty cluster");
            let (mx, mn) = (*counts.iter().max().unwrap(), *counts.iter().min().unwrap());
            // batched largest-first splitting keeps near-equal sizes, but
            // the split tree differs from serial; allow a looser bound
            assert!(mx <= mn * 3 + 3, "k={k}: sizes {mn}..{mx} too skewed");
        }
    }

    #[test]
    fn parallel_build_is_deterministic_per_thread_count() {
        let data = blobs(&BlobSpec::quick(400, 6, 8), 2);
        let params = TwoMeansParams { threads: 3, ..Default::default() };
        let a = run(&data, 9, &params, &Backend::native());
        let b = run(&data, 9, &params, &Backend::native());
        assert_eq!(a, b);
    }

    #[test]
    fn duplicate_points_dont_crash() {
        let data = VecSet::from_flat(2, vec![1.0; 40]); // 20 identical points
        let labels = run(&data, 4, &TwoMeansParams::default(), &Backend::native());
        let mut counts = vec![0; 4];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 20);
        assert!(counts.iter().all(|&c| c > 0));
    }
}
