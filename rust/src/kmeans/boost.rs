//! Boost k-means (BKM) [16] — the quality reference GK-means builds on.
//!
//! The "egg-chicken" Lloyd loop is replaced by stochastic incremental
//! optimization of ℐ = Σ_r ‖D_r‖²/n_r (Eqn. 2): samples are visited in
//! random order; each is moved to the cluster maximizing Δℐ (Eqn. 3) as
//! soon as the improving move is found to be the best one.  Cost per visit
//! is a full scan over k clusters (one ⟨D_v, x⟩ each) — the same
//! complexity level as a Lloyd assignment, which is exactly the cost
//! GK-means later prunes with the KNN graph.
//!
//! Implementation notes: per-cluster ‖D_r‖² is cached and updated on every
//! move, so evaluating one candidate cluster costs a single O(d) dot.
//!
//! Runs over any [`VecStore`]: the epoch scan reads rows through a
//! cursor, with the visit order coming from the locality-aware scan
//! planner ([`crate::data::plan`]) — a disk-backed fit streams instead of
//! materializing, and a resident fit keeps the historical global shuffle
//! bit-for-bit.

use crate::core_ops::dist::{batch_eligible, dot, dot_batch, norm2};
use crate::data::matrix::VecSet;
use crate::data::plan::ScanPlan;
use crate::data::store::VecStore;
use crate::kmeans::common::{Clustering, EpochState, FitHooks, IterStat, KmeansOutput, KmeansParams};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Columns per [`dot_batch`] call in the k-wide candidate scan: bounds
/// the dots scratch while keeping each call far above the batch
/// kernels' minimum width.
const SCAN_TILE: usize = 512;

/// Per-cluster cached state for fast Δℐ evaluation: the composite-norm
/// cache `‖D_r‖²` the batched candidate kernels rely on.
///
/// Every Δℐ term needs the norm of a candidate's composite vector; with
/// the cache, evaluating one candidate costs a single O(d) cross dot —
/// and once the dots themselves come batched from
/// [`crate::core_ops::dist::dot_batch`] over a gathered composite block,
/// the whole candidate set is one tiled mini-GEMM pass plus O(κ̃) cached
/// lookups.  [`DeltaCache::commit_move`] is the sole maintenance point:
/// it updates the norms from the *pre-move* composites and applies the
/// move as one operation, so the cache can never drift from the
/// [`Clustering`] it summarizes.
pub(crate) struct DeltaCache {
    /// ‖D_r‖² per cluster.
    pub comp_norm2: Vec<f64>,
}

impl DeltaCache {
    pub fn new(c: &Clustering) -> DeltaCache {
        DeltaCache {
            comp_norm2: (0..c.k).map(|r| norm2(c.composite_of(r)) as f64).collect(),
        }
    }

    /// Δℐ of moving `x` (‖x‖² = xx) from `u` into candidate `v`, given the
    /// *loss* part of leaving `u` was precomputed (`leave_u`).
    #[inline]
    pub fn gain(&self, c: &Clustering, x: &[f32], xx: f64, v: usize) -> f64 {
        self.gain_from_dot(c, xx, v, dot(c.composite_of(v), x) as f64)
    }

    /// [`DeltaCache::gain`] with the cross dot `⟨D_v, x⟩` supplied by the
    /// caller — the batched candidate path computes every candidate's dot
    /// in one `dot_batch` call over a gathered composite block.  The
    /// arithmetic is identical to the scalar entry point (same cached
    /// norms, same expression), so batched and scalar evaluation agree to
    /// the bit whenever the dots do.
    #[inline]
    pub fn gain_from_dot(&self, c: &Clustering, xx: f64, v: usize, dvx: f64) -> f64 {
        let nv = c.counts[v] as f64;
        let dvdv = self.comp_norm2[v];
        if nv == 0.0 {
            return xx; // moving into an empty cluster contributes ‖x‖²
        }
        (dvdv + 2.0 * dvx + xx) / (nv + 1.0) - dvdv / nv
    }

    /// The ℐ change contributed by removing `x` from its cluster `u`.
    #[inline]
    pub fn leave(&self, c: &Clustering, x: &[f32], xx: f64, u: usize) -> f64 {
        self.leave_from_dot(c, xx, u, dot(c.composite_of(u), x) as f64)
    }

    /// [`DeltaCache::leave`] with the cross dot `⟨D_u, x⟩` supplied by
    /// the caller (see [`DeltaCache::gain_from_dot`]).
    #[inline]
    pub fn leave_from_dot(&self, c: &Clustering, xx: f64, u: usize, dux: f64) -> f64 {
        let nu = c.counts[u] as f64;
        let dudu = self.comp_norm2[u];
        let after = if nu <= 1.0 { 0.0 } else { (dudu - 2.0 * dux + xx) / (nu - 1.0) };
        after - dudu / nu.max(1.0)
    }

    /// Update cached norms for moving `x`: ‖D∓x‖² = ‖D‖² ∓ 2⟨D,x⟩ + ‖x‖².
    ///
    /// Private on purpose: it reads the *pre-move* composites, so it is
    /// only correct when called before `Clustering::apply_move`.  The
    /// ordering used to be the caller's responsibility (and was fragile);
    /// [`DeltaCache::commit_move`] is now the single entry point that
    /// performs both updates in the right order.
    #[inline]
    fn on_move(&mut self, c: &Clustering, x: &[f32], xx: f64, u: usize, v: usize) {
        let du = c.composite_of(u);
        let dv = c.composite_of(v);
        self.comp_norm2[u] += -2.0 * dot(du, x) as f64 + xx;
        self.comp_norm2[v] += 2.0 * dot(dv, x) as f64 + xx;
    }

    /// Move sample `i` (vector `x`, ‖x‖² = `xx`) from cluster `u` to `v`,
    /// updating the cached composite norms and the clustering state as one
    /// operation.  This is the only way to apply a move while a
    /// `DeltaCache` is live — it guarantees the cache update sees the
    /// pre-move composites and can never be reordered against
    /// `Clustering::apply_move`.
    #[inline]
    pub fn commit_move(&mut self, c: &mut Clustering, i: usize, x: &[f32], xx: f64, u: usize, v: usize) {
        debug_assert_eq!(
            c.labels[i] as usize, u,
            "commit_move: sample {i} is not currently in cluster {u}"
        );
        debug_assert_ne!(u, v, "commit_move: source == destination");
        self.on_move(c, x, xx, u, v);
        c.apply_move(i, x, u, v);
    }
}

/// Fire the per-epoch hook for a composite-maintaining engine (BKM and
/// GK-means share the `Clustering` + `DeltaCache` state shape).  Reads
/// the entry just pushed onto `history`.
pub(crate) fn fire_epoch(
    hooks: &mut FitHooks<'_>,
    history: &[IterStat],
    rng: &Rng,
    c: &Clustering,
    cache: &DeltaCache,
) {
    if hooks.on_epoch.is_none() {
        return;
    }
    let seconds_offset = hooks.seconds_offset;
    let init_seconds = hooks.init_seconds;
    let stat = history.last().expect("fire_epoch: history has the entry just pushed");
    let state = EpochState {
        completed_epoch: stat.iter,
        rng: rng.state(),
        stat,
        history,
        seconds_offset,
        init_seconds,
        labels: &c.labels,
        composite: Some(&c.composite),
        counts: Some(&c.counts),
        comp_norm2: Some(&cache.comp_norm2),
        centroids: None,
    };
    hooks.fire(&state);
}

/// Deprecated shim over [`run_core`] — the pre-`Clusterer` entry point.
#[deprecated(
    note = "use `model::Boost::new(k).fit(&data, &RunContext::new(&backend))` \
            (or `fit_store` for disk-backed data)"
)]
pub fn run(data: &VecSet, k: usize, params: &KmeansParams, backend: &crate::runtime::Backend) -> KmeansOutput {
    run_core(data, k, params, backend)
}

/// The BKM engine ([`crate::model::Boost`] executes this): random
/// balanced start, then [`run_from`].  Runs over any [`VecStore`].
pub fn run_core(
    data: &dyn VecStore,
    k: usize,
    params: &KmeansParams,
    backend: &crate::runtime::Backend,
) -> KmeansOutput {
    run_core_hooked(data, k, params, backend, &mut FitHooks::none())
}

/// [`run_core`] with fit instrumentation: a resume point skips the random
/// balanced start entirely (the mid-fit state comes from the checkpoint).
pub fn run_core_hooked(
    data: &dyn VecStore,
    k: usize,
    params: &KmeansParams,
    _backend: &crate::runtime::Backend,
    hooks: &mut FitHooks<'_>,
) -> KmeansOutput {
    if hooks.resume.is_some() {
        let placeholder = Clustering {
            labels: Vec::new(),
            composite: Vec::new(),
            counts: Vec::new(),
            k,
            dim: data.dim(),
        };
        return run_from_hooked(data, placeholder, params, hooks);
    }
    let mut rng = Rng::new(params.seed);
    let labels: Vec<u32> = (0..data.rows()).map(|i| (i % k) as u32).collect();
    let mut shuffled = labels;
    rng.shuffle(&mut shuffled);
    run_from_hooked(data, Clustering::from_labels(data, shuffled, k), params, hooks)
}

/// Run BKM starting from an existing clustering.
pub fn run_from(data: &dyn VecStore, c: Clustering, params: &KmeansParams) -> KmeansOutput {
    run_from_hooked(data, c, params, &mut FitHooks::none())
}

/// [`run_from`] with fit instrumentation (per-epoch hook + resume).  With
/// [`FitHooks::none`] this IS the historical `run_from`: same RNG stream,
/// same visit order, same arithmetic — bit-identical output.
pub fn run_from_hooked(
    data: &dyn VecStore,
    mut c: Clustering,
    params: &KmeansParams,
    hooks: &mut FitHooks<'_>,
) -> KmeansOutput {
    let timer = Timer::start();
    let init_seconds = 0.0;
    let n = data.rows();
    let plan = ScanPlan::new(data, params.scan_order);
    let mut cur = data.open();
    let total_norm: f64 = (0..n).map(|i| norm2(cur.row(i)) as f64).sum();
    let mut rng = Rng::new(params.seed ^ 0xB005_7133);
    let mut order: Vec<usize> = (0..n).collect();

    let (mut cache, mut history, start_iter, seconds_base) = match hooks.resume.take() {
        Some(r) => {
            // Restore the exact mid-fit state (labels, composites, counts
            // and cached norms are raw checkpointed bits — rebuilding any
            // of them would perturb the last ulp), then replay the epoch
            // shuffles so the visit-order permutation and the RNG stream
            // both match the uninterrupted run.
            c = Clustering {
                labels: r.labels,
                composite: r.composite.expect("BKM checkpoint carries composite vectors"),
                counts: r.counts.expect("BKM checkpoint carries cluster counts"),
                k: c.k,
                dim: c.dim,
            };
            let cache =
                DeltaCache { comp_norm2: r.comp_norm2.expect("BKM checkpoint carries ‖D_r‖²") };
            for _ in 1..r.next_iter {
                plan.shuffle_epoch(&mut order, &mut rng);
            }
            debug_assert_eq!(rng.state(), r.rng, "resume RNG replay diverged from the checkpoint");
            let base = r.history.last().map(|h| h.seconds).unwrap_or(0.0);
            (cache, r.history, r.next_iter, base)
        }
        None => {
            let cache = DeltaCache::new(&c);
            let history = vec![IterStat {
                iter: 0,
                seconds: timer.elapsed_s(),
                distortion: (total_norm - c.objective()) / n as f64,
                moves: 0,
            }];
            fire_epoch(hooks, &history, &rng, &c, &cache);
            (cache, history, 1, 0.0)
        }
    };

    // The composite block is already flat k × dim, so the k-wide scan's
    // dots come from SCAN_TILE-column dot_batch passes — one mini-GEMM
    // tile at a time, with a bounded scratch — instead of k strided
    // scalar dots.  dot_batch is pinned bit-identical per column to
    // `dot` (and gain_from_dot/leave_from_dot to their scalar entry
    // points), so the epoch is bit-for-bit the historical scan.
    // Narrow geometries (d < BATCH_MIN_DIM, or a ragged tail tile under
    // BATCH_TILE columns) keep the scalar dots.  Note bound-based
    // candidate pruning (the d2_bounded idiom from the graph-refinement
    // tails) is deliberately NOT applied here: Δℐ compares sign-
    // indefinite dots against per-cluster counts and *incrementally
    // maintained* norms, so a Cauchy–Schwarz skip is not exact the way
    // a monotone partial-distance bound is.
    let mut dots = vec![0f32; SCAN_TILE.min(c.k)];
    for iter in start_iter..=params.max_iters {
        plan.shuffle_epoch(&mut order, &mut rng);
        let mut moves = 0usize;
        for &i in &order {
            let x = cur.row(i);
            let u = c.labels[i] as usize;
            let xx = norm2(x) as f64;
            let leave = cache.leave(&c, x, xx, u);
            // full scan over clusters: the BKM bottleneck
            let mut best_v = u;
            let mut best_delta = 0f64;
            let mut lo = 0usize;
            while lo < c.k {
                let hi = (lo + SCAN_TILE).min(c.k);
                if batch_eligible(c.dim, hi - lo) {
                    let tile = &c.composite[lo * c.dim..hi * c.dim];
                    dot_batch(x, tile, c.dim, &mut dots[..hi - lo]);
                    for v in lo..hi {
                        if v == u {
                            continue;
                        }
                        let delta = cache.gain_from_dot(&c, xx, v, dots[v - lo] as f64) + leave;
                        if delta > best_delta {
                            best_delta = delta;
                            best_v = v;
                        }
                    }
                } else {
                    for v in lo..hi {
                        if v == u {
                            continue;
                        }
                        let delta = cache.gain(&c, x, xx, v) + leave;
                        if delta > best_delta {
                            best_delta = delta;
                            best_v = v;
                        }
                    }
                }
                lo = hi;
            }
            if best_v != u && best_delta > 0.0 {
                cache.commit_move(&mut c, i, x, xx, u, best_v);
                moves += 1;
            }
        }
        history.push(IterStat {
            iter,
            seconds: seconds_base + timer.elapsed_s(),
            distortion: (total_norm - c.objective()) / n as f64,
            moves,
        });
        fire_epoch(hooks, &history, &rng, &c, &cache);
        if (moves as f64) < params.min_move_rate * n as f64 {
            break;
        }
    }

    KmeansOutput {
        clustering: c,
        history,
        total_seconds: seconds_base + timer.elapsed_s(),
        init_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::runtime::Backend;

    #[test]
    fn objective_monotone_nondecreasing() {
        let data = blobs(&BlobSpec::quick(300, 6, 5), 3);
        let out = run_core(&data, 5, &KmeansParams::default(), &Backend::native());
        for w in out.history.windows(2) {
            assert!(
                w[1].distortion <= w[0].distortion + 1e-9,
                "ΔI-driven moves must not increase distortion"
            );
        }
    }

    #[test]
    fn beats_or_matches_lloyd_on_blobs() {
        let data = blobs(&BlobSpec::quick(600, 8, 12), 4);
        let p = KmeansParams::default();
        let b = Backend::native();
        let bkm = run_core(&data, 12, &p, &b);
        let lloyd = crate::kmeans::lloyd::run_core(&data, 12, &p, &b);
        // paper: BKM converges to considerably better local optima; allow
        // small slack for randomness.
        assert!(
            bkm.distortion() <= lloyd.distortion() * 1.10,
            "bkm={} lloyd={}",
            bkm.distortion(),
            lloyd.distortion()
        );
    }

    #[test]
    fn cached_norms_stay_consistent() {
        let data = blobs(&BlobSpec::quick(120, 4, 4), 5);
        let out = run_core(&data, 4, &KmeansParams { max_iters: 5, ..Default::default() }, &Backend::native());
        let c = &out.clustering;
        let cache = DeltaCache::new(c);
        for r in 0..c.k {
            let direct = norm2(c.composite_of(r)) as f64;
            assert!(
                (cache.comp_norm2[r] - direct).abs() < 1e-3 * (1.0 + direct),
                "cluster {r}"
            );
        }
        c.check_invariants(&data).unwrap();
    }

    #[test]
    fn commit_move_keeps_cache_and_clustering_in_sync() {
        // Regression for the on_move/apply_move ordering hazard: drive a
        // random sequence of commits through the single entry point and
        // verify the cached ‖D_r‖² always matches a fresh recomputation.
        let mut rng = Rng::new(9);
        let data = blobs(&BlobSpec::quick(150, 5, 4), 7);
        let labels: Vec<u32> = (0..150).map(|_| rng.below(4) as u32).collect();
        let mut c = Clustering::from_labels(&data, labels, 4);
        let mut cache = DeltaCache::new(&c);
        for step in 0..200 {
            let i = rng.below(150);
            let u = c.labels[i] as usize;
            let v = rng.below(4);
            if u == v || c.counts[u] <= 1 {
                continue;
            }
            let x = data.row(i);
            let xx = norm2(x) as f64;
            cache.commit_move(&mut c, i, x, xx, u, v);
            if step % 40 == 0 {
                for r in 0..c.k {
                    let direct = norm2(c.composite_of(r)) as f64;
                    assert!(
                        (cache.comp_norm2[r] - direct).abs() < 1e-3 * (1.0 + direct),
                        "step {step} cluster {r}: cached {} vs direct {direct}",
                        cache.comp_norm2[r]
                    );
                }
                c.check_invariants(&data).unwrap();
            }
        }
    }

    #[test]
    fn from_dot_variants_match_scalar_entry_points_exactly() {
        // the batched candidate path feeds precomputed dots into
        // gain_from_dot / leave_from_dot; with the same dot they must
        // reproduce the scalar entry points to the bit
        let mut rng = Rng::new(21);
        let data = blobs(&BlobSpec::quick(120, 6, 5), 11);
        let labels: Vec<u32> = (0..120).map(|_| rng.below(5) as u32).collect();
        let c = Clustering::from_labels(&data, labels, 5);
        let cache = DeltaCache::new(&c);
        for _ in 0..100 {
            let i = rng.below(120);
            let x = data.row(i);
            let xx = norm2(x) as f64;
            let u = c.labels[i] as usize;
            let v = rng.below(5);
            let dvx = dot(c.composite_of(v), x) as f64;
            let dux = dot(c.composite_of(u), x) as f64;
            assert_eq!(
                cache.gain(&c, x, xx, v).to_bits(),
                cache.gain_from_dot(&c, xx, v, dvx).to_bits()
            );
            assert_eq!(
                cache.leave(&c, x, xx, u).to_bits(),
                cache.leave_from_dot(&c, xx, u, dux).to_bits()
            );
        }
    }

    #[test]
    fn batched_scan_selects_the_same_move_as_the_scalar_scan() {
        // the epoch loop's tiled dot_batch scan must pick the identical
        // (best_v, best_delta) the historical scalar scan picked —
        // dot_batch is bit-identical per column to `dot`, and the
        // *_from_dot entry points are bit-identical to their scalar
        // counterparts, so the selection can never diverge
        let mut rng = Rng::new(31);
        let data = blobs(&BlobSpec::quick(200, 32, 24), 13);
        let labels: Vec<u32> = (0..200).map(|_| rng.below(24) as u32).collect();
        let c = Clustering::from_labels(&data, labels, 24);
        let cache = DeltaCache::new(&c);
        assert!(batch_eligible(c.dim, c.k));
        let mut dots = vec![0f32; c.k];
        for i in (0..200).step_by(7) {
            let x = data.row(i);
            let u = c.labels[i] as usize;
            let xx = norm2(x) as f64;
            let leave = cache.leave(&c, x, xx, u);
            let (mut sv, mut sd) = (u, 0f64);
            for v in 0..c.k {
                if v == u {
                    continue;
                }
                let delta = cache.gain(&c, x, xx, v) + leave;
                if delta > sd {
                    sd = delta;
                    sv = v;
                }
            }
            dot_batch(x, &c.composite, c.dim, &mut dots);
            let (mut bv, mut bd) = (u, 0f64);
            for v in 0..c.k {
                if v == u {
                    continue;
                }
                let delta = cache.gain_from_dot(&c, xx, v, dots[v] as f64) + leave;
                if delta > bd {
                    bd = delta;
                    bv = v;
                }
            }
            assert_eq!(sv, bv, "sample {i}");
            assert_eq!(sd.to_bits(), bd.to_bits(), "sample {i}");
        }
    }

    #[test]
    fn clusters_stay_nonempty_enough() {
        // BKM must not collapse everything into one cluster on blob data.
        let data = blobs(&BlobSpec::quick(200, 4, 8), 6);
        let out = run_core(&data, 8, &KmeansParams::default(), &Backend::native());
        let nonempty = out.clustering.counts.iter().filter(|&&c| c > 0).count();
        assert!(nonempty >= 6, "only {nonempty}/8 clusters nonempty");
    }
}
