//! Shared clustering state: assignments, composite vectors, the boost
//! k-means objective ℐ (Eqn. 2) and its increment Δℐ (Eqn. 3), distortion
//! (Eqn. 4).
//!
//! The central data structure is [`Clustering`]: the label array plus the
//! per-cluster *composite vectors* `D_r = Σ_{x_i ∈ S_r} x_i` and counts
//! `n_r`.  BKM-style moves are O(d) updates of two composite vectors, and
//! the objective ℐ = Σ_r ⟨D_r, D_r⟩ / n_r is maintained incrementally.

use crate::core_ops::dist::{dot, norm2};
use crate::data::matrix::VecSet;
use crate::data::plan::ScanOrder;
use crate::data::store::VecStore;

/// Common iteration-control parameters shared by the k-means variants.
#[derive(Debug, Clone)]
pub struct KmeansParams {
    /// Maximum number of epochs (full passes).
    pub max_iters: usize,
    /// Stop when the fraction of samples moved in an epoch drops below this.
    pub min_move_rate: f64,
    /// RNG seed (visit order, initialization).
    pub seed: u64,
    /// Worker threads for the parallel execution layer (`util::pool`).
    /// `1` = serial, bit-identical to the pre-parallel implementation;
    /// `0` = auto (env `GKMEANS_THREADS`, else available parallelism).
    pub threads: usize,
    /// Epoch visit-order policy (see [`crate::data::plan`]): `Auto` uses
    /// chunk-aligned super-block shuffles on paged stores and the
    /// historical global shuffle (bit-identical) on resident data.
    pub scan_order: ScanOrder,
}

impl Default for KmeansParams {
    fn default() -> Self {
        KmeansParams {
            max_iters: 30,
            min_move_rate: 1e-3,
            seed: 20170707,
            threads: 1,
            scan_order: ScanOrder::Auto,
        }
    }
}

/// Cluster state over a borrowed dataset.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Cluster label per sample.
    pub labels: Vec<u32>,
    /// Flat `k × d` composite vectors `D_r`.
    pub composite: Vec<f32>,
    /// Cluster sizes `n_r`.
    pub counts: Vec<u32>,
    /// Number of clusters k.
    pub k: usize,
    /// Dimensionality d (cached from the dataset).
    pub dim: usize,
}

impl Clustering {
    /// Build state from a label array (recomputes composites/counts).
    pub fn from_labels(data: &dyn VecStore, labels: Vec<u32>, k: usize) -> Clustering {
        assert_eq!(labels.len(), data.rows());
        let dim = data.dim();
        let mut c = Clustering {
            labels,
            composite: vec![0.0; k * dim],
            counts: vec![0; k],
            k,
            dim,
        };
        c.rebuild(data);
        c
    }

    /// [`Clustering::from_labels`] fused with the Lloyd mean update:
    /// one sequential store scan produces both the clustering state and
    /// the new centroids (empty clusters keep their `prev` row).  The
    /// results are bit-identical to `from_labels` +
    /// [`crate::kmeans::lloyd::update_centroids`] run separately — the
    /// f32 composite and f64 mean accumulators see the same values in
    /// the same order — but a disk-backed store is read once instead of
    /// twice per iteration (the Closure / GK-means* update step).
    pub fn from_labels_with_centroids(
        data: &dyn VecStore,
        labels: Vec<u32>,
        k: usize,
        prev: &VecSet,
    ) -> (Clustering, VecSet) {
        assert_eq!(labels.len(), data.rows());
        let dim = data.dim();
        let mut c = Clustering {
            labels,
            composite: vec![0.0; k * dim],
            counts: vec![0; k],
            k,
            dim,
        };
        let mut sums = vec![0f64; k * dim];
        let mut cur = data.open();
        for (i, &l) in c.labels.iter().enumerate() {
            let l = l as usize;
            debug_assert!(l < k, "label {l} out of range k={k}");
            let row = cur.row(i);
            let comp = &mut c.composite[l * dim..(l + 1) * dim];
            let sum = &mut sums[l * dim..(l + 1) * dim];
            for ((dv, sv), xv) in comp.iter_mut().zip(sum.iter_mut()).zip(row) {
                *dv += xv;
                *sv += *xv as f64;
            }
            c.counts[l] += 1;
        }
        let mut out = Vec::with_capacity(k * dim);
        for r in 0..k {
            if c.counts[r] == 0 {
                out.extend_from_slice(prev.row(r));
            } else {
                let cnt = c.counts[r] as f64;
                out.extend(sums[r * dim..(r + 1) * dim].iter().map(|s| (*s / cnt) as f32));
            }
        }
        let centroids = VecSet::from_flat(dim, out);
        (c, centroids)
    }

    /// Reassemble clustering state from parts the caller already holds
    /// — validates shapes only, trusts the composites.  The incremental
    /// extend path ([`crate::model::FittedModel::extend_with`]) uses
    /// this with composites approximated as `centroid · count`, which
    /// [`Clustering::apply_move`] then keeps incrementally exact,
    /// without ever rescanning the full store.
    pub fn from_parts(
        labels: Vec<u32>,
        composite: Vec<f32>,
        counts: Vec<u32>,
        k: usize,
        dim: usize,
    ) -> Result<Clustering, String> {
        if composite.len() != k * dim {
            return Err(format!("composite len {} != k*dim {}", composite.len(), k * dim));
        }
        if counts.len() != k {
            return Err(format!("counts len {} != k {k}", counts.len()));
        }
        let total: u64 = counts.iter().map(|&c| c as u64).sum();
        if total != labels.len() as u64 {
            return Err(format!("counts sum {total} != {} labels", labels.len()));
        }
        if let Some(&l) = labels.iter().find(|&&l| l as usize >= k) {
            return Err(format!("label {l} out of range k={k}"));
        }
        Ok(Clustering { labels, composite, counts, k, dim })
    }

    /// Recompute composite vectors and counts from labels (one
    /// sequential pass over the store).
    pub fn rebuild(&mut self, data: &dyn VecStore) {
        self.composite.iter_mut().for_each(|v| *v = 0.0);
        self.counts.iter_mut().for_each(|v| *v = 0);
        let mut cur = data.open();
        for (i, &l) in self.labels.iter().enumerate() {
            let l = l as usize;
            debug_assert!(l < self.k, "label {l} out of range k={}", self.k);
            let row = cur.row(i);
            let dst = &mut self.composite[l * self.dim..(l + 1) * self.dim];
            for (dv, xv) in dst.iter_mut().zip(row) {
                *dv += xv;
            }
            self.counts[l] += 1;
        }
    }

    /// Composite vector of cluster `r`.
    #[inline]
    pub fn composite_of(&self, r: usize) -> &[f32] {
        &self.composite[r * self.dim..(r + 1) * self.dim]
    }

    /// Centroid of cluster `r` (allocates; `C_r = D_r / n_r`).
    pub fn centroid_of(&self, r: usize) -> Vec<f32> {
        let n = self.counts[r].max(1) as f32;
        self.composite_of(r).iter().map(|v| v / n).collect()
    }

    /// All centroids as a `k × d` VecSet (empty clusters get zeros).
    pub fn centroids(&self) -> VecSet {
        let mut out = Vec::with_capacity(self.k * self.dim);
        for r in 0..self.k {
            let n = self.counts[r] as f32;
            let comp = self.composite_of(r);
            if n > 0.0 {
                out.extend(comp.iter().map(|v| v / n));
            } else {
                out.extend(std::iter::repeat(0.0).take(self.dim));
            }
        }
        VecSet::from_flat(self.dim, out)
    }

    /// The boost k-means objective ℐ = Σ_r ⟨D_r, D_r⟩ / n_r (Eqn. 2).
    pub fn objective(&self) -> f64 {
        let mut s = 0f64;
        for r in 0..self.k {
            if self.counts[r] > 0 {
                s += norm2(self.composite_of(r)) as f64 / self.counts[r] as f64;
            }
        }
        s
    }

    /// Average distortion ℰ (Eqn. 4) = (Σ‖x‖² − ℐ) / n.
    ///
    /// Identity: Σ_i ‖x_i − C_{q(i)}‖² = Σ_i ‖x_i‖² − Σ_r ‖D_r‖²/n_r,
    /// so distortion falls exactly as ℐ rises — both views are used by the
    /// eval code; this one is O(n·d) only in the Σ‖x‖² term.
    pub fn distortion(&self, data: &dyn VecStore) -> f64 {
        let mut cur = data.open();
        let total: f64 = (0..data.rows()).map(|i| norm2(cur.row(i)) as f64).sum();
        (total - self.objective()) / data.rows().max(1) as f64
    }

    /// Δℐ for moving sample `x` from its current cluster `u` to `v`
    /// (Eqn. 3).  Positive = improvement.  `u == v` returns 0.
    ///
    /// Expanded form used here (avoids materializing `D ± x`):
    ///   gain_v = (‖D_v‖² + 2⟨D_v,x⟩ + ‖x‖²)/(n_v+1) − ‖D_v‖²/n_v
    ///   loss_u = (‖D_u‖² − 2⟨D_u,x⟩ + ‖x‖²)/(n_u−1) − ‖D_u‖²/n_u
    ///   Δℐ = gain_v + loss_u
    /// Singleton guard: if `n_u == 1`, removing `x` empties `u`; the
    /// `(n_u − 1)` term is defined as 0 (the paper keeps clusters nonempty
    /// by never making such moves profitable unless v gains more).
    pub fn delta_i(&self, x: &[f32], u: usize, v: usize) -> f64 {
        if u == v {
            return 0.0;
        }
        let nu = self.counts[u] as f64;
        let nv = self.counts[v] as f64;
        let xx = norm2(x) as f64;
        let dv = self.composite_of(v);
        let dvdv = norm2(dv) as f64;
        let dvx = dot(dv, x) as f64;
        let gain_v = (dvdv + 2.0 * dvx + xx) / (nv + 1.0) - dvdv / nv.max(1.0);
        let du = self.composite_of(u);
        let dudu = norm2(du) as f64;
        let dux = dot(du, x) as f64;
        let after_u = if nu <= 1.0 {
            0.0
        } else {
            (dudu - 2.0 * dux + xx) / (nu - 1.0)
        };
        let loss_u = after_u - dudu / nu.max(1.0);
        gain_v + loss_u
    }

    /// Apply the move of sample `i` (vector `x`) from cluster `u` to `v`.
    pub fn apply_move(&mut self, i: usize, x: &[f32], u: usize, v: usize) {
        debug_assert_eq!(self.labels[i] as usize, u);
        debug_assert_ne!(u, v);
        let d = self.dim;
        {
            let du = &mut self.composite[u * d..(u + 1) * d];
            for (dv, xv) in du.iter_mut().zip(x) {
                *dv -= xv;
            }
        }
        {
            let dvv = &mut self.composite[v * d..(v + 1) * d];
            for (dv, xv) in dvv.iter_mut().zip(x) {
                *dv += xv;
            }
        }
        self.counts[u] -= 1;
        self.counts[v] += 1;
        self.labels[i] = v as u32;
    }

    /// Structural invariants; used by tests and the property framework.
    pub fn check_invariants(&self, data: &dyn VecStore) -> Result<(), String> {
        if self.labels.len() != data.rows() {
            return Err("label count != rows".into());
        }
        let mut counts = vec![0u32; self.k];
        for &l in &self.labels {
            if l as usize >= self.k {
                return Err(format!("label {l} >= k {}", self.k));
            }
            counts[l as usize] += 1;
        }
        if counts != self.counts {
            return Err("cached counts out of sync".into());
        }
        // composite check on a few clusters (full check is O(n·d))
        let mut cur = data.open();
        let mut comp = vec![0f64; self.k.min(8) * self.dim];
        for (i, &l) in self.labels.iter().enumerate() {
            let l = l as usize;
            if l < self.k.min(8) {
                for (a, v) in comp[l * self.dim..(l + 1) * self.dim]
                    .iter_mut()
                    .zip(cur.row(i))
                {
                    *a += *v as f64;
                }
            }
        }
        for r in 0..self.k.min(8) {
            for (a, b) in comp[r * self.dim..(r + 1) * self.dim]
                .iter()
                .zip(self.composite_of(r))
            {
                if (*a - *b as f64).abs() > 1e-2 * (1.0 + a.abs()) {
                    return Err(format!("composite drift in cluster {r}: {a} vs {b}"));
                }
            }
        }
        Ok(())
    }
}

/// Per-epoch progress record emitted by every k-means variant; the bench
/// harnesses plot these (Fig. 5 distortion-vs-iteration / vs-time curves).
#[derive(Debug, Clone)]
pub struct IterStat {
    /// Epoch index (0 = after initialization).
    pub iter: usize,
    /// Cumulative wall-clock seconds since the algorithm started
    /// (including initialization).
    pub seconds: f64,
    /// Average distortion ℰ after this epoch.
    pub distortion: f64,
    /// Samples that changed cluster this epoch.
    pub moves: usize,
}

/// Common output of every clustering variant.
#[derive(Debug, Clone)]
pub struct KmeansOutput {
    pub clustering: Clustering,
    /// Per-epoch progress (index 0 records the initialization state).
    pub history: Vec<IterStat>,
    /// Total wall-clock seconds.
    pub total_seconds: f64,
    /// Seconds spent in initialization (2M-tree / seeding).
    pub init_seconds: f64,
}

impl KmeansOutput {
    /// Final distortion (from the last history entry).
    pub fn distortion(&self) -> f64 {
        self.history.last().map(|h| h.distortion).unwrap_or(f64::NAN)
    }
}

/// Mid-fit snapshot handed to [`FitHooks::on_epoch`] after every recorded
/// epoch (including the iteration-0 initialization entry of the engines
/// that record one).  Borrows live engine state — the callback must copy
/// whatever it wants to keep.
///
/// `stat`/`history` carry the engine's *raw* seconds (its own timer);
/// callers that need wall-clock-consistent values fold in
/// `seconds_offset` (graph construction + any engine initialization the
/// engine accounts for separately).
pub struct EpochState<'a> {
    /// Epoch just finished (matches `stat.iter`).
    pub completed_epoch: usize,
    /// Engine RNG state *after* this epoch's draws (`[0; 4]` for engines
    /// with no per-epoch randomness, e.g. Lloyd).
    pub rng: [u64; 4],
    /// The history entry just recorded (raw engine seconds).
    pub stat: &'a IterStat,
    /// Full history so far, including `stat` (raw engine seconds).
    pub history: &'a [IterStat],
    /// Seconds to add to raw history seconds for wall-clock consistency
    /// with the final fitted model (graph construction, and for engines
    /// that fold initialization into history post-hoc, that too).
    pub seconds_offset: f64,
    /// Engine-side initialization seconds (what the engine will report
    /// as `KmeansOutput::init_seconds`); 0 while resuming.
    pub init_seconds: f64,
    /// Current labels.
    pub labels: &'a [u32],
    /// Flat `k × d` composite vectors (composite-maintaining engines).
    pub composite: Option<&'a [f32]>,
    /// Cluster sizes (composite-maintaining engines).
    pub counts: Option<&'a [u32]>,
    /// Cached `‖D_r‖²` (engines carrying a `DeltaCache`).
    pub comp_norm2: Option<&'a [f64]>,
    /// Flat `k × d` centroids (centroid-maintaining engines).
    pub centroids: Option<&'a [f32]>,
}

/// Mid-fit state to restart an engine from — the deserialized form of a
/// GKCKPT checkpoint (see [`crate::model::checkpoint`]).  The engine
/// consumes this instead of running its initialization; at `threads = 1`
/// the continued fit is bit-identical to the uninterrupted one.
#[derive(Debug, Clone)]
pub struct ResumePoint {
    /// First epoch to run (`last completed + 1`).
    pub next_iter: usize,
    /// Engine RNG state at the checkpoint (consistency guard: the engine
    /// replays its epoch shuffles and asserts it lands on this state).
    pub rng: [u64; 4],
    /// History up to the checkpoint, with *folded* (wall-clock) seconds;
    /// new entries continue from the last folded value.
    pub history: Vec<IterStat>,
    /// Labels at the checkpoint.
    pub labels: Vec<u32>,
    /// Composite vectors at the checkpoint (raw f32 bits — an
    /// incrementally maintained composite differs in the last ulp from a
    /// rebuilt one, so it must be restored, not recomputed).
    pub composite: Option<Vec<f32>>,
    /// Cluster sizes at the checkpoint.
    pub counts: Option<Vec<u32>>,
    /// Cached `‖D_r‖²` at the checkpoint (raw f64 bits, same reasoning).
    pub comp_norm2: Option<Vec<f64>>,
    /// Centroids at the checkpoint (centroid-maintaining engines).
    pub centroids: Option<Vec<f32>>,
}

/// Optional fit instrumentation threaded through the `*_hooked` engine
/// entry points: a per-epoch callback (streaming progress + periodic
/// checkpoints) and an optional [`ResumePoint`] to continue from.
/// [`FitHooks::none`] is the inert default the plain entry points use —
/// with it, the hooked engines run the historical code path unchanged.
pub struct FitHooks<'a> {
    /// Fires after every recorded epoch, including the iteration-0
    /// initialization entry of the engines that record one.
    pub on_epoch: Option<&'a mut dyn FnMut(&EpochState<'_>)>,
    /// Seconds the caller wants folded into emitted/persisted history
    /// (graph construction); engines that account initialization
    /// separately add their share before the first fire.
    pub seconds_offset: f64,
    /// Set by the engine: its `KmeansOutput::init_seconds` share, so the
    /// hook can persist model-consistent time accounting.
    pub init_seconds: f64,
    /// Consumed (`Option::take`) by the engine to skip initialization
    /// and continue a checkpointed fit.
    pub resume: Option<ResumePoint>,
}

impl<'a> FitHooks<'a> {
    /// No callback, no resume — the hooked engines behave exactly like
    /// their historical entry points.
    pub fn none() -> FitHooks<'a> {
        FitHooks { on_epoch: None, seconds_offset: 0.0, init_seconds: 0.0, resume: None }
    }

    /// Invoke the callback, if any.
    pub fn fire(&mut self, state: &EpochState<'_>) {
        if let Some(f) = self.on_epoch.as_mut() {
            f(state);
        }
    }
}

/// Exact distortion computed from scratch (O(n·d), reference for tests).
pub fn distortion_exact(data: &dyn VecStore, labels: &[u32], centroids: &VecSet) -> f64 {
    let mut cur = data.open();
    let mut s = 0f64;
    for (i, &l) in labels.iter().enumerate() {
        s += crate::core_ops::dist::d2(cur.row(i), centroids.row(l as usize)) as f64;
    }
    s / data.rows().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy() -> (VecSet, Clustering) {
        // two well-separated 1-d clusters
        let data = VecSet::from_flat(1, vec![0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let labels = vec![0, 0, 0, 1, 1, 1];
        let c = Clustering::from_labels(&data, labels, 2);
        (data, c)
    }

    #[test]
    fn composite_and_counts() {
        let (_, c) = toy();
        assert_eq!(c.counts, vec![3, 3]);
        assert_eq!(c.composite_of(0), &[3.0]);
        assert_eq!(c.composite_of(1), &[33.0]);
        assert_eq!(c.centroid_of(0), vec![1.0]);
        assert_eq!(c.centroid_of(1), vec![11.0]);
    }

    #[test]
    fn distortion_matches_exact() {
        let (data, c) = toy();
        let exact = distortion_exact(&data, &c.labels, &c.centroids());
        assert!((c.distortion(&data) - exact).abs() < 1e-9, "{} vs {exact}", c.distortion(&data));
    }

    #[test]
    fn delta_i_matches_brute_force() {
        // Move x=2.0 (index 2) from cluster 0 to 1 and compare ΔI against
        // recomputed objectives.
        let (data, mut c) = toy();
        let before = c.objective();
        let predicted = c.delta_i(data.row(2), 0, 1);
        c.apply_move(2, data.row(2), 0, 1);
        let after = c.objective();
        assert!(
            (after - before - predicted).abs() < 1e-9,
            "predicted {predicted}, actual {}",
            after - before
        );
        // moving an interior point to the far cluster should hurt
        assert!(predicted < 0.0);
    }

    #[test]
    fn delta_i_self_move_is_zero() {
        let (data, c) = toy();
        assert_eq!(c.delta_i(data.row(0), 0, 0), 0.0);
    }

    #[test]
    fn randomized_delta_consistency() {
        let mut rng = Rng::new(11);
        let n = 60;
        let d = 5;
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let data = VecSet::from_flat(d, flat);
        let labels: Vec<u32> = (0..n).map(|_| rng.below(4) as u32).collect();
        let mut c = Clustering::from_labels(&data, labels, 4);
        for _ in 0..50 {
            let i = rng.below(n);
            let u = c.labels[i] as usize;
            let v = rng.below(4);
            if u == v || c.counts[u] <= 1 {
                continue;
            }
            let before = c.objective();
            let pred = c.delta_i(data.row(i), u, v);
            c.apply_move(i, data.row(i), u, v);
            let actual = c.objective() - before;
            assert!(
                (pred - actual).abs() < 1e-6 * (1.0 + actual.abs()),
                "pred={pred} actual={actual}"
            );
            c.check_invariants(&data).unwrap();
        }
    }

    #[test]
    fn objective_distortion_duality() {
        // maximizing I == minimizing distortion: check the identity holds
        let mut rng = Rng::new(12);
        let n = 40;
        let flat: Vec<f32> = (0..n * 3).map(|_| rng.normal()).collect();
        let data = VecSet::from_flat(3, flat);
        let labels: Vec<u32> = (0..n).map(|_| rng.below(5) as u32).collect();
        let c = Clustering::from_labels(&data, labels, 5);
        let exact = distortion_exact(&data, &c.labels, &c.centroids());
        assert!((c.distortion(&data) - exact).abs() < 1e-6);
    }

    #[test]
    fn fused_rebuild_matches_two_pass_bit_for_bit() {
        // from_labels_with_centroids must reproduce from_labels +
        // lloyd::update_centroids exactly (same accumulators, same
        // order) — it only fuses the two store scans into one.
        let mut rng = Rng::new(13);
        let n = 80;
        let d = 4;
        let k = 5;
        let data = VecSet::from_flat(d, (0..n * d).map(|_| rng.normal()).collect());
        // label 4 left empty to exercise the prev-centroid fallback
        let labels: Vec<u32> = (0..n).map(|_| rng.below(k - 1) as u32).collect();
        let prev = VecSet::from_flat(d, (0..k * d).map(|_| rng.normal()).collect());
        let two_pass_c = Clustering::from_labels(&data, labels.clone(), k);
        let two_pass_cent = crate::kmeans::lloyd::update_centroids(&data, &labels, k, &prev);
        let (fused_c, fused_cent) = Clustering::from_labels_with_centroids(&data, labels, k, &prev);
        assert_eq!(fused_c.labels, two_pass_c.labels);
        assert_eq!(fused_c.counts, two_pass_c.counts);
        for (a, b) in fused_c.composite.iter().zip(&two_pass_c.composite) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in fused_cent.flat().iter().zip(two_pass_cent.flat()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(fused_cent.row(k - 1), prev.row(k - 1), "empty cluster keeps prev");
    }

    #[test]
    fn invariants_catch_corruption() {
        let (data, mut c) = toy();
        c.counts[0] = 99;
        assert!(c.check_invariants(&data).is_err());
    }
}
