//! k-means variants: the baselines the paper compares against, plus the
//! shared clustering state they all operate on.
//!
//! * [`lloyd`] — traditional k-means [5], [6].
//! * [`boost`] — boost k-means (BKM) [16]: incremental Δℐ optimization;
//!   the quality reference and the base GK-means builds on.
//! * [`minibatch`] — Mini-Batch k-means [20] (web-scale baseline).
//! * [`closure`] — closure k-means [27] (the strongest fast baseline).
//! * [`two_means`] — Alg. 1: 2M-tree equal-size recursive bisection, used
//!   to initialize GK-means and the graph construction.
//! * [`init`] — random and k-means++ seeding for the centroid-based
//!   variants.

pub mod boost;
pub mod closure;
pub mod common;
pub mod init;
pub mod lloyd;
pub mod minibatch;
pub mod two_means;
