//! # GK-means — fast k-means driven by an approximate KNN graph
//!
//! Production-quality reproduction of Deng & Zhao, *"Fast k-means based on
//! KNN Graph"* (2017), as a three-layer Rust + JAX/Pallas + PJRT system.
//!
//! The headline idea: the per-iteration bottleneck of k-means is the
//! `O(n·d·k)` closest-centroid search.  A sample and its κ nearest
//! neighbors live in the same cluster with high probability, so — given an
//! approximate KNN graph — each sample only needs to be compared against
//! the clusters its κ neighbors currently reside in.  Cost per iteration
//! drops to `O(n·d·κ)`, independent of `k`.  The graph itself is built by
//! iteratively calling the fast k-means (cluster into fixed-size cells,
//! refine neighbor lists within each cell, repeat): clustering structure
//! and graph quality co-evolve.
//!
//! ## The public surface: fit → model → query
//!
//! The library is organized around the [`model::Clusterer`] trait.  A
//! typed config ([`model::GkMeans`], [`model::Lloyd`], …) is fitted over
//! a dataset under a shared [`model::RunContext`] (backend + threads +
//! seed + progress), producing a [`model::FittedModel`] — a first-class
//! artifact holding centroids, labels, convergence history, and (for the
//! graph methods) the KNN graph.  The model answers
//! [`predict`](model::FittedModel::predict) for out-of-sample vectors,
//! serves graph ANN via [`search`](model::FittedModel::search), and
//! round-trips through versioned binary
//! [`save`](model::FittedModel::save)/[`load`](model::FittedModel::load):
//!
//! ```no_run
//! use gkmeans::prelude::*;
//!
//! let data = blobs(&BlobSpec::quick(10_000, 32, 64), 42);
//! let backend = Backend::auto();
//! let ctx = RunContext::new(&backend).threads(0).keep_data(true);
//! let model = GkMeans::new(100).kappa(20).fit(&data, &ctx);
//! model.save(std::path::Path::new("vocab.gkm")).unwrap();
//!
//! let served = FittedModel::load(std::path::Path::new("vocab.gkm")).unwrap();
//! let labels = served.predict(&data);                     // out-of-sample assignment
//! let near = served.search(data.row(7), 10, &Default::default()).unwrap(); // graph ANN
//! # let _ = (labels, near);
//! ```
//!
//! The pre-model `run(data, k, &params, backend)` free functions still
//! compile as deprecated shims over the same engines.
//!
//! ## Layout
//!
//! * [`model`] — **the public API**: [`model::Clusterer`],
//!   [`model::RunContext`], [`model::FittedModel`], binary model
//!   serialization.
//! * [`util`] — RNG, CLI/config parsing, timers, logging, and the
//!   scoped-thread parallel execution layer ([`util::pool`]) — all with no
//!   external deps.
//! * [`data`] — dataset container, the [`data::store::VecStore`] storage
//!   abstraction (in-RAM [`data::matrix::VecSet`] or the out-of-core
//!   [`data::store::ChunkedVecStore`] streaming fixed-size row blocks
//!   from disk), synthetic generators for the paper's four datasets,
//!   fvecs/bvecs I/O.
//! * [`core_ops`] — scalar & blocked distance math, top-κ selection.
//! * [`kmeans`] — the engines for Lloyd, boost k-means (BKM), Mini-Batch,
//!   closure k-means, and the 2M-tree initializer (Alg. 1).
//! * [`graph`] — KNN-graph structure, brute-force ground truth, NN-Descent.
//! * [`gkm`] — the paper's contribution: graph-driven k-means (Alg. 2) and
//!   the intertwined graph construction (Alg. 3), plus graph-based ANN
//!   search.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts;
//!   the [`runtime::Backend`] enum lets every bulk op run Native or PJRT.
//! * [`coordinator`] — job specs, the end-to-end pipeline, metrics.
//! * [`serve`] — production ANN serving: the `gkm-serve` TCP front door
//!   with micro-batching ([`serve::Batcher`]), shard scatter-gather
//!   ([`serve::ShardedIndex`]), a dependency-free wire protocol
//!   ([`serve::proto`]) and live metrics ([`serve::ServeMetrics`]).
//! * [`eval`] — distortion (Eqn. 4), recall, co-occurrence statistics.
//! * [`testing`] — in-tree property-based testing mini-framework.

pub mod bench_util;
pub mod coordinator;
pub mod core_ops;
pub mod data;
pub mod eval;
pub mod gkm;
pub mod graph;
pub mod kmeans;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod util;

/// Convenience re-exports for downstream users: everything the
/// fit → model → query flow needs, plus the structural types the model
/// exposes.
pub mod prelude {
    pub use crate::coordinator::job::{ClusterJob, JobResult, Method};
    pub use crate::data::matrix::VecSet;
    pub use crate::data::plan::{ScanOrder, ScanPlan};
    pub use crate::data::store::{ChunkedVecStore, VecStore};
    pub use crate::data::synth::{blobs, BlobSpec};
    pub use crate::data::DatasetSpec;
    pub use crate::gkm::ann::SearchParams;
    pub use crate::gkm::tree::{RouteScratch, RouteTree, RouteTreeParams};
    pub use crate::graph::knn::KnnGraph;
    pub use crate::kmeans::common::{Clustering, IterStat};
    pub use crate::model::{
        Boost, ClosureKmeans, Clusterer, FittedModel, GkMeans, GkMeansStar, KGraphGkMeans,
        Lloyd, MiniBatch, ModelVectors, RunContext,
    };
    pub use crate::runtime::Backend;
    pub use crate::serve::{Client, ServeConfig, Server, ServerHandle, ShardedIndex};
    pub use crate::util::rng::Rng;
}
