//! # GK-means — fast k-means driven by an approximate KNN graph
//!
//! Production-quality reproduction of Deng & Zhao, *"Fast k-means based on
//! KNN Graph"* (2017), as a three-layer Rust + JAX/Pallas + PJRT system.
//!
//! The headline idea: the per-iteration bottleneck of k-means is the
//! `O(n·d·k)` closest-centroid search.  A sample and its κ nearest
//! neighbors live in the same cluster with high probability, so — given an
//! approximate KNN graph — each sample only needs to be compared against
//! the clusters its κ neighbors currently reside in.  Cost per iteration
//! drops to `O(n·d·κ)`, independent of `k`.  The graph itself is built by
//! iteratively calling the fast k-means (cluster into fixed-size cells,
//! refine neighbor lists within each cell, repeat): clustering structure
//! and graph quality co-evolve.
//!
//! ## Layout
//!
//! * [`util`] — RNG, CLI/config parsing, timers, logging, and the
//!   scoped-thread parallel execution layer ([`util::pool`]) — all with no
//!   external deps.
//! * [`data`] — dataset container, synthetic generators for the paper's
//!   four datasets, fvecs/bvecs I/O.
//! * [`core_ops`] — scalar & blocked distance math, top-κ selection.
//! * [`kmeans`] — Lloyd, boost k-means (BKM), Mini-Batch, closure k-means,
//!   and the 2M-tree initializer (Alg. 1).
//! * [`graph`] — KNN-graph structure, brute-force ground truth, NN-Descent.
//! * [`gkm`] — the paper's contribution: graph-driven k-means (Alg. 2) and
//!   the intertwined graph construction (Alg. 3), plus graph-based ANN
//!   search.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts;
//!   the [`runtime::Backend`] enum lets every bulk op run Native or PJRT.
//! * [`coordinator`] — job specs, the end-to-end pipeline, metrics.
//! * [`eval`] — distortion (Eqn. 4), recall, co-occurrence statistics.
//! * [`testing`] — in-tree property-based testing mini-framework.

pub mod bench_util;
pub mod coordinator;
pub mod core_ops;
pub mod data;
pub mod eval;
pub mod gkm;
pub mod graph;
pub mod kmeans;
pub mod runtime;
pub mod testing;
pub mod util;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::coordinator::job::{ClusterJob, JobResult, Method};
    pub use crate::data::matrix::VecSet;
    pub use crate::data::synth::BlobSpec;
    pub use crate::data::DatasetSpec;
    pub use crate::gkm::construct::{ConstructParams, GraphBuildOutput};
    pub use crate::gkm::gkmeans::GkMeansParams;
    pub use crate::graph::knn::KnnGraph;
    pub use crate::kmeans::common::{Clustering, KmeansParams};
    pub use crate::runtime::Backend;
    pub use crate::util::rng::Rng;
}
