//! `VecSet`: the flat row-major `f32` matrix every algorithm operates on.

/// An `n × d` matrix of `f32`, row-major, contiguous.
///
/// All clustering structures index into one shared `VecSet`; rows are
/// sample vectors.  Invariant: `data.len() == rows * dim`.
#[derive(Debug, Clone, PartialEq)]
pub struct VecSet {
    dim: usize,
    data: Vec<f32>,
}

impl VecSet {
    /// Build from a flat buffer; `data.len()` must be a multiple of `dim`.
    pub fn from_flat(dim: usize, data: Vec<f32>) -> VecSet {
        assert!(dim > 0, "dim must be positive");
        assert!(
            data.len() % dim == 0,
            "flat length {} not a multiple of dim {}",
            data.len(),
            dim
        );
        VecSet { dim, data }
    }

    /// An all-zeros `n × d` matrix.
    pub fn zeros(rows: usize, dim: usize) -> VecSet {
        VecSet::from_flat(dim, vec![0.0; rows * dim])
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.data.len() / self.dim
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.dim;
        &self.data[i * d..(i + 1) * d]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.dim;
        &mut self.data[i * d..(i + 1) * d]
    }

    /// The whole flat buffer.
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Copy out the rows at `idx` into a new `VecSet` (gather).
    pub fn gather(&self, idx: &[usize]) -> VecSet {
        let mut out = Vec::with_capacity(idx.len() * self.dim);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        VecSet::from_flat(self.dim, out)
    }

    /// Append a row.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        self.data.extend_from_slice(row);
    }

    /// Contiguous sub-range of rows `[lo, hi)` as a flat slice.
    #[inline]
    pub fn rows_flat(&self, lo: usize, hi: usize) -> &[f32] {
        &self.data[lo * self.dim..hi * self.dim]
    }

    /// ℓ2-normalize every row in place (zero rows left untouched).
    pub fn l2_normalize(&mut self) {
        let d = self.dim;
        for r in self.data.chunks_mut(d) {
            let norm = r.iter().map(|v| v * v).sum::<f32>().sqrt();
            if norm > 0.0 {
                for v in r.iter_mut() {
                    *v /= norm;
                }
            }
        }
    }

    /// Per-matrix mean vector (f64 accumulation).
    pub fn mean(&self) -> Vec<f32> {
        let n = self.rows();
        let mut acc = vec![0f64; self.dim];
        for r in self.data.chunks(self.dim) {
            for (a, v) in acc.iter_mut().zip(r) {
                *a += *v as f64;
            }
        }
        acc.iter().map(|a| (*a / n.max(1) as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = VecSet::from_flat(2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.rows_flat(1, 3), &[3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn bad_flat_length_panics() {
        VecSet::from_flat(3, vec![0.0; 4]);
    }

    #[test]
    fn gather_rows() {
        let m = VecSet::from_flat(1, vec![10.0, 11.0, 12.0, 13.0]);
        let g = m.gather(&[3, 0, 3]);
        assert_eq!(g.flat(), &[13.0, 10.0, 13.0]);
    }

    #[test]
    fn row_mut_and_push() {
        let mut m = VecSet::zeros(1, 2);
        m.row_mut(0)[1] = 5.0;
        m.push_row(&[7.0, 8.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(0), &[0.0, 5.0]);
        assert_eq!(m.row(1), &[7.0, 8.0]);
    }

    #[test]
    fn l2_normalize_rows() {
        let mut m = VecSet::from_flat(2, vec![3.0, 4.0, 0.0, 0.0]);
        m.l2_normalize();
        assert!((m.row(0)[0] - 0.6).abs() < 1e-6);
        assert!((m.row(0)[1] - 0.8).abs() < 1e-6);
        assert_eq!(m.row(1), &[0.0, 0.0], "zero row untouched");
    }

    #[test]
    fn mean_vector() {
        let m = VecSet::from_flat(2, vec![1.0, 0.0, 3.0, 2.0]);
        assert_eq!(m.mean(), vec![2.0, 1.0]);
    }
}
