//! Datasets: container, the out-of-core storage layer ([`store`]) and
//! its locality-aware scan planner ([`plan`]), SQ8 scalar quantization
//! ([`quant`]), synthetic generators for the paper's four benchmark
//! sets, and fvecs/bvecs interchange I/O.

pub mod io;
pub mod matrix;
pub mod plan;
pub mod quant;
pub mod store;
pub mod synth;

use crate::data::matrix::VecSet;
use crate::data::store::{ChunkedVecStore, VecStore};

/// A named dataset request: either one of the paper's four synthetic
/// stand-ins at a given scale, or a file on disk.
///
/// The paper evaluates on SIFT1M (128-d), VLAD10M (512-d), GloVe1M (100-d)
/// and GIST1M (960-d); none are redistributable here, so `synth` builds
/// geometry-matched stand-ins (see DESIGN.md §Substitutions).  If you have
/// the real `.fvecs`/`.bvecs` files, `DatasetSpec::File` drops them in.
#[derive(Debug, Clone)]
pub enum DatasetSpec {
    /// `kind` ∈ {sift, vlad, glove, gist, blobs}; `n` rows; `seed`.
    Synth { kind: String, n: usize, seed: u64 },
    /// fvecs/bvecs file path (format inferred from extension).
    File { path: String },
}

impl DatasetSpec {
    /// Parse `"sift:100000"`, `"vlad:1000000:seed=7"`, or a file path.
    pub fn parse(s: &str) -> Result<DatasetSpec, String> {
        if s.contains('/') || s.ends_with(".fvecs") || s.ends_with(".bvecs") {
            return Ok(DatasetSpec::File { path: s.to_string() });
        }
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("").to_string();
        let n: usize = parts
            .next()
            .ok_or_else(|| format!("dataset spec {s:?}: expected kind:n"))?
            .parse()
            .map_err(|e| format!("dataset spec {s:?}: bad n ({e})"))?;
        let mut seed = 20170707;
        for extra in parts {
            if let Some(v) = extra.strip_prefix("seed=") {
                seed = v.parse().map_err(|e| format!("bad seed ({e})"))?;
            }
        }
        Ok(DatasetSpec::Synth { kind, n, seed })
    }

    /// Materialize the dataset in RAM.
    pub fn load(&self) -> Result<VecSet, String> {
        match self {
            DatasetSpec::Synth { kind, n, seed } => synth::by_name(kind, *n, *seed),
            DatasetSpec::File { path } => io::read_auto(std::path::Path::new(path)),
        }
    }

    /// Open the dataset as a [`VecStore`] without materializing it:
    /// file-backed specs stream through a [`ChunkedVecStore`] (out-of-core
    /// clustering / serving), synthetic specs are generated in RAM.
    pub fn open_store(&self) -> Result<Box<dyn VecStore>, String> {
        match self {
            DatasetSpec::Synth { kind, n, seed } => {
                Ok(Box::new(synth::by_name(kind, *n, *seed)?))
            }
            DatasetSpec::File { path } => {
                Ok(Box::new(ChunkedVecStore::open_auto(std::path::Path::new(path))?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synth_spec() {
        match DatasetSpec::parse("sift:1000").unwrap() {
            DatasetSpec::Synth { kind, n, seed } => {
                assert_eq!(kind, "sift");
                assert_eq!(n, 1000);
                assert_eq!(seed, 20170707);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_seed_override() {
        match DatasetSpec::parse("glove:50:seed=9").unwrap() {
            DatasetSpec::Synth { seed, .. } => assert_eq!(seed, 9),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_file_spec() {
        assert!(matches!(
            DatasetSpec::parse("/data/sift.fvecs").unwrap(),
            DatasetSpec::File { .. }
        ));
    }

    #[test]
    fn parse_errors() {
        assert!(DatasetSpec::parse("sift").is_err());
        assert!(DatasetSpec::parse("sift:notanum").is_err());
    }

    #[test]
    fn load_synth_dispatch() {
        let v = DatasetSpec::parse("sift:200").unwrap().load().unwrap();
        assert_eq!(v.rows(), 200);
        assert_eq!(v.dim(), 128);
    }
}
