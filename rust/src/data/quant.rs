//! SQ8 scalar quantization: u8 codes + a per-dimension affine, the ~4×
//! working-set shrink that makes the paper's 10M × 512-d regime
//! RAM-resident (~5 GB of codes vs ~20 GB of f32).
//!
//! ## Model
//!
//! A trained [`Sq8Quantizer`] holds per-dimension `min[t]` and `scale[t]`
//! (the step per code unit, `(max − min) / 255` over a training sample);
//! a vector quantizes as `code[t] = round((v[t] − min[t]) / scale[t])`
//! clamped to `[0, 255]`, and decodes as `min[t] + scale[t] · code[t]`.
//! Data that is already u8 (bvecs) round-trips **losslessly** through
//! the identity quantizer (`min = 0`, `scale = 1`) — undoing the 4×
//! inflation `ChunkedVecStore` pays when it promotes bvecs rows to f32.
//!
//! ## Serving contract
//!
//! Distances against codes are **asymmetric** (f32 query × u8 base,
//! [`crate::core_ops::dist::d2_batch_sq8`]) and carry the quantization
//! error, which is bounded per dimension by `scale[t] / 2`.  Candidate
//! *selection* over codes is therefore approximate; callers that promise
//! exact-distance results (ANN serving) re-rank the surviving candidates
//! with the exact f32 kernel — see `gkm::ann::search_sq8`, which re-ranks
//! the whole `ef` pool so the returned distances are true f32 `d²`.
//!
//! A [`QuantizedVecStore`] implements [`VecStore`], so every scan loop
//! (fit, predict, refinement) can also run directly over codes: cursors
//! decode rows on the fly into per-cursor scratch (tolerance-class
//! results — the decoded value is the quantizer's reconstruction).

use crate::core_ops::dist;
use crate::data::store::{StoreCursor, VecStore};

/// Per-dimension affine scalar quantizer (`f32 → u8`).
#[derive(Debug, Clone, PartialEq)]
pub struct Sq8Quantizer {
    min: Vec<f32>,
    /// Step per code unit; `0` for dimensions that were constant in the
    /// training sample (those encode to 0 and decode back to `min`).
    scale: Vec<f32>,
    /// Precomputed `1 / scale` (`0` where `scale == 0`).
    inv_scale: Vec<f32>,
}

impl Sq8Quantizer {
    /// Quantizer from explicit per-dimension parameters (the serde load
    /// path).  `scale` entries must be finite and non-negative.
    pub fn from_parts(min: Vec<f32>, scale: Vec<f32>) -> Result<Sq8Quantizer, String> {
        if min.len() != scale.len() {
            return Err(format!(
                "quantizer min/scale length mismatch: {} vs {}",
                min.len(),
                scale.len()
            ));
        }
        if min.iter().any(|v| !v.is_finite()) || scale.iter().any(|v| !v.is_finite() || *v < 0.0) {
            return Err("quantizer parameters must be finite (scale non-negative)".to_string());
        }
        let inv_scale = scale.iter().map(|&s| if s > 0.0 { 1.0 / s } else { 0.0 }).collect();
        Ok(Sq8Quantizer { min, scale, inv_scale })
    }

    /// The lossless passthrough for data that is already u8 (bvecs):
    /// `min = 0`, `scale = 1`, so `encode(decode(c)) == c` exactly.
    pub fn identity(dim: usize) -> Sq8Quantizer {
        Sq8Quantizer { min: vec![0.0; dim], scale: vec![1.0; dim], inv_scale: vec![1.0; dim] }
    }

    /// Train on a deterministic sample of `store`: per-dimension min/max
    /// over up to `sample_rows` rows taken at an even stride (no RNG —
    /// the same store always yields the same quantizer).  `sample_rows =
    /// 0` means the full pass.
    pub fn train(store: &dyn VecStore, sample_rows: usize) -> Sq8Quantizer {
        let (n, d) = (store.rows(), store.dim());
        assert!(n > 0, "cannot train a quantizer on an empty store");
        let take = if sample_rows == 0 { n } else { sample_rows.min(n) };
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        let mut cur = store.open();
        for s in 0..take {
            // even-stride sample: rows 0, n/take, 2n/take, …
            let i = s * n / take;
            let row = cur.row(i);
            for (t, &v) in row.iter().enumerate() {
                lo[t] = lo[t].min(v);
                hi[t] = hi[t].max(v);
            }
        }
        let scale: Vec<f32> = lo.iter().zip(&hi).map(|(&l, &h)| (h - l) / 255.0).collect();
        let inv_scale = scale.iter().map(|&s| if s > 0.0 { 1.0 / s } else { 0.0 }).collect();
        Sq8Quantizer { min: lo, scale, inv_scale }
    }

    /// Dimensionality this quantizer was trained for.
    pub fn dim(&self) -> usize {
        self.min.len()
    }

    /// Per-dimension minima (the affine offset).
    pub fn min(&self) -> &[f32] {
        &self.min
    }

    /// Per-dimension step sizes (the affine scale per code unit).
    pub fn scale(&self) -> &[f32] {
        &self.scale
    }

    /// Whether this is the lossless u8 passthrough.
    pub fn is_identity(&self) -> bool {
        self.min.iter().all(|&v| v == 0.0) && self.scale.iter().all(|&v| v == 1.0)
    }

    /// Encode one f32 row (`row.len() == dim`) into codes.  Values
    /// outside the trained range clamp to the nearest code.
    pub fn encode_row(&self, row: &[f32], out: &mut [u8]) {
        assert_eq!(row.len(), self.dim(), "row/quantizer dim mismatch");
        assert_eq!(out.len(), self.dim(), "out/quantizer dim mismatch");
        for (t, (&v, o)) in row.iter().zip(out.iter_mut()).enumerate() {
            let q = (v - self.min[t]) * self.inv_scale[t];
            *o = q.round().clamp(0.0, 255.0) as u8;
        }
    }

    /// Decode codes back to the f32 reconstruction.
    pub fn decode_row(&self, codes: &[u8], out: &mut [f32]) {
        assert_eq!(codes.len(), self.dim(), "codes/quantizer dim mismatch");
        assert_eq!(out.len(), self.dim(), "out/quantizer dim mismatch");
        for (t, (&c, o)) in codes.iter().zip(out.iter_mut()).enumerate() {
            *o = self.min[t] + self.scale[t] * f32::from(c);
        }
    }

    /// Worst-case per-dimension reconstruction error: half the largest
    /// step (quantize → dequantize moves a value at most `scale[t]/2`
    /// when it was inside the trained range).
    pub fn max_step(&self) -> f32 {
        self.scale.iter().fold(0f32, |a, &s| a.max(s))
    }
}

/// A RAM-resident SQ8-quantized vector store: `rows × dim` u8 codes plus
/// the [`Sq8Quantizer`] that produced them — one quarter the bytes of
/// the f32 original.  Implements [`VecStore`] (cursors decode on the
/// fly); the fast serving path skips decoding entirely via
/// [`QuantizedVecStore::d2_gather`].
#[derive(Debug, Clone)]
pub struct QuantizedVecStore {
    rows: usize,
    dim: usize,
    codes: Vec<u8>,
    quant: Sq8Quantizer,
}

impl QuantizedVecStore {
    /// Quantize every row of `store`: train on an even-stride sample of
    /// up to `sample_rows` rows (0 = full pass), then encode all rows.
    pub fn from_store(store: &dyn VecStore, sample_rows: usize) -> QuantizedVecStore {
        let quant = Sq8Quantizer::train(store, sample_rows);
        Self::encode_with(store, quant)
    }

    /// Encode every row of `store` with a caller-supplied quantizer
    /// (bvecs passthrough uses [`Sq8Quantizer::identity`]).
    pub fn encode_with(store: &dyn VecStore, quant: Sq8Quantizer) -> QuantizedVecStore {
        let (n, d) = (store.rows(), store.dim());
        assert_eq!(quant.dim(), d, "quantizer/store dim mismatch");
        let mut codes = vec![0u8; n * d];
        let mut cur = store.open();
        for i in 0..n {
            quant.encode_row(cur.row(i), &mut codes[i * d..(i + 1) * d]);
        }
        QuantizedVecStore { rows: n, dim: d, codes, quant }
    }

    /// Reassemble from persisted parts (the GKMODEL `QVECTORS` loader).
    pub fn from_parts(
        rows: usize,
        dim: usize,
        codes: Vec<u8>,
        quant: Sq8Quantizer,
    ) -> Result<QuantizedVecStore, String> {
        if quant.dim() != dim {
            return Err(format!("quantizer dim {} != store dim {dim}", quant.dim()));
        }
        if codes.len() != rows * dim {
            return Err(format!(
                "code buffer holds {} bytes, want rows·dim = {}",
                codes.len(),
                rows * dim
            ));
        }
        Ok(QuantizedVecStore { rows, dim, codes, quant })
    }

    /// Number of code rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The quantizer (persisted alongside the codes).
    pub fn quantizer(&self) -> &Sq8Quantizer {
        &self.quant
    }

    /// The raw `rows · dim` code buffer (persisted by model save).
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Resident bytes of the code matrix — the working set the 4×
    /// shrink claim is about (quantizer parameters add `8·dim` bytes).
    pub fn resident_bytes(&self) -> usize {
        self.codes.len()
    }

    /// Code row `i`.
    pub fn code_row(&self, i: usize) -> &[u8] {
        &self.codes[i * self.dim..(i + 1) * self.dim]
    }

    /// Decode row `i` into `out` (`out.len() == dim`).
    pub fn decode_into(&self, i: usize, out: &mut [f32]) {
        self.quant.decode_row(self.code_row(i), out);
    }

    /// Asymmetric distances from f32 query `x` to the (non-contiguous)
    /// code rows `ids`: gathers the u8 rows into `buf` (reused scratch)
    /// and runs one [`dist::d2_batch_sq8`] over the gathered block.
    /// `out.len() == ids.len()`.
    pub fn d2_gather(&self, x: &[f32], ids: &[u32], buf: &mut Vec<u8>, out: &mut [f32]) {
        assert_eq!(x.len(), self.dim, "query/store dim mismatch");
        assert_eq!(ids.len(), out.len(), "one output per candidate");
        buf.clear();
        for &id in ids {
            buf.extend_from_slice(self.code_row(id as usize));
        }
        dist::d2_batch_sq8(x, buf, self.quant.min(), self.quant.scale(), self.dim, out);
    }
}

impl VecStore for QuantizedVecStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn open(&self) -> StoreCursor<'_> {
        StoreCursor::Quant(QuantCursor {
            store: self,
            row_buf: vec![0f32; self.dim],
            pair_buf: vec![0f32; self.dim],
            block_buf: Vec::new(),
        })
    }
}

/// Decoding cursor over a [`QuantizedVecStore`]: rows and blocks are
/// reconstructed into per-cursor scratch on each access (the store stays
/// u8-resident; only the working row/block is ever f32).
pub struct QuantCursor<'a> {
    store: &'a QuantizedVecStore,
    row_buf: Vec<f32>,
    pair_buf: Vec<f32>,
    block_buf: Vec<f32>,
}

impl QuantCursor<'_> {
    /// Decode row `i` into the cursor's row scratch.
    pub fn row(&mut self, i: usize) -> &[f32] {
        self.store.decode_into(i, &mut self.row_buf);
        &self.row_buf
    }

    /// Decode rows `[lo, hi)` into the cursor's block scratch.
    pub fn block(&mut self, lo: usize, hi: usize) -> &[f32] {
        let d = self.store.dim;
        self.block_buf.resize((hi - lo) * d, 0.0);
        for (s, i) in (lo..hi).enumerate() {
            let dst = &mut self.block_buf[s * d..(s + 1) * d];
            self.store.decode_into(i, dst);
        }
        &self.block_buf
    }

    /// Squared distance between decoded rows `i` and `j`.
    pub fn d2_pair(&mut self, i: usize, j: usize) -> f32 {
        self.store.decode_into(i, &mut self.row_buf);
        self.store.decode_into(j, &mut self.pair_buf);
        dist::d2(&self.row_buf, &self.pair_buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::VecSet;
    use crate::util::rng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> VecSet {
        let mut rng = Rng::new(seed);
        VecSet::from_flat(d, (0..n * d).map(|_| rng.normal() * 3.0).collect())
    }

    #[test]
    fn roundtrip_error_is_bounded_by_half_a_step() {
        let data = random_set(200, 24, 1);
        let q = Sq8Quantizer::train(&data, 0);
        let mut codes = vec![0u8; 24];
        let mut back = vec![0f32; 24];
        for i in 0..200 {
            let row = data.row(i);
            q.encode_row(row, &mut codes);
            q.decode_row(&codes, &mut back);
            for t in 0..24 {
                let err = (row[t] - back[t]).abs();
                // in-range values land within half a quantization step
                // (+ f32 slack for the affine arithmetic)
                assert!(
                    err <= 0.5 * q.scale()[t] + 1e-5,
                    "row {i} dim {t}: err {err} > step/2 {}",
                    0.5 * q.scale()[t]
                );
            }
        }
    }

    #[test]
    fn identity_quantizer_is_lossless_on_u8_data() {
        let mut rng = Rng::new(2);
        let d = 16;
        let flat: Vec<f32> = (0..50 * d).map(|_| rng.below(256) as f32).collect();
        let data = VecSet::from_flat(d, flat.clone());
        let q = Sq8Quantizer::identity(d);
        assert!(q.is_identity());
        let store = QuantizedVecStore::encode_with(&data, q);
        let mut back = vec![0f32; d];
        for i in 0..50 {
            store.decode_into(i, &mut back);
            assert_eq!(back, data.row(i), "row {i}");
        }
    }

    #[test]
    fn trained_quantizer_beats_constant_dims_and_outliers() {
        // constant dimension -> scale 0 -> decodes exactly to min;
        // out-of-range values clamp instead of wrapping
        let d = 3;
        let flat = vec![1.0f32, -2.0, 7.5, 1.0, 3.0, 7.5, 1.0, 0.5, 7.5];
        let data = VecSet::from_flat(d, flat);
        let q = Sq8Quantizer::train(&data, 0);
        assert_eq!(q.scale()[0], 0.0);
        assert_eq!(q.scale()[2], 0.0);
        let mut codes = vec![0u8; d];
        let mut back = vec![0f32; d];
        q.encode_row(&[1.0, 100.0, 7.5], &mut codes);
        assert_eq!(codes[1], 255, "out-of-range clamps to the top code");
        q.decode_row(&codes, &mut back);
        assert_eq!(back[0], 1.0);
        assert_eq!(back[2], 7.5);
    }

    #[test]
    fn quantized_store_cursor_matches_explicit_decode() {
        let data = random_set(60, 10, 3);
        let store = QuantizedVecStore::from_store(&data, 0);
        assert_eq!(VecStore::rows(&store), 60);
        assert_eq!(VecStore::dim(&store), 10);
        assert_eq!(store.resident_bytes(), 600);
        let mut cur = store.open();
        let mut want = vec![0f32; 10];
        for i in [0usize, 7, 31, 59] {
            store.decode_into(i, &mut want);
            assert_eq!(cur.row(i), &want[..], "row {i}");
        }
        // block = the concatenation of decoded rows
        let blk = cur.block(5, 9).to_vec();
        for (s, i) in (5..9).enumerate() {
            store.decode_into(i, &mut want);
            assert_eq!(&blk[s * 10..(s + 1) * 10], &want[..], "block row {i}");
        }
        // d2_pair = d2 over decoded rows
        let mut a = vec![0f32; 10];
        let mut b = vec![0f32; 10];
        store.decode_into(2, &mut a);
        store.decode_into(40, &mut b);
        assert_eq!(cur.d2_pair(2, 40).to_bits(), dist::d2(&a, &b).to_bits());
    }

    #[test]
    fn d2_gather_matches_per_row_asymmetric_kernel() {
        let data = random_set(80, 32, 4);
        let store = QuantizedVecStore::from_store(&data, 20);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let ids: Vec<u32> = vec![3, 77, 0, 41, 41, 12];
        let mut buf = Vec::new();
        let mut out = vec![0f32; ids.len()];
        store.d2_gather(&x, &ids, &mut buf, &mut out);
        for (t, &id) in ids.iter().enumerate() {
            let mut one = [0f32; 1];
            dist::d2_batch_sq8(
                &x,
                store.code_row(id as usize),
                store.quantizer().min(),
                store.quantizer().scale(),
                32,
                &mut one,
            );
            assert_eq!(out[t].to_bits(), one[0].to_bits(), "candidate {t} (row {id})");
        }
    }

    #[test]
    fn from_parts_validates_geometry() {
        let q = Sq8Quantizer::identity(4);
        assert!(QuantizedVecStore::from_parts(2, 4, vec![0; 8], q.clone()).is_ok());
        assert!(QuantizedVecStore::from_parts(2, 4, vec![0; 7], q.clone()).is_err());
        assert!(QuantizedVecStore::from_parts(2, 3, vec![0; 6], q).is_err());
        assert!(Sq8Quantizer::from_parts(vec![0.0; 3], vec![1.0; 2]).is_err());
        assert!(Sq8Quantizer::from_parts(vec![0.0; 2], vec![f32::NAN, 1.0]).is_err());
        assert!(Sq8Quantizer::from_parts(vec![0.0; 2], vec![-1.0, 1.0]).is_err());
    }
}
