//! Synthetic stand-ins for the paper's four benchmark datasets.
//!
//! The real sets (SIFT1M, VLAD10M from YFCC, GloVe1M, GIST1M) are not
//! redistributable in this environment.  GK-means' behaviour depends on the
//! *local neighborhood → cluster co-occurrence* statistic (paper Fig. 1),
//! which is a property of clustered data, not of SIFT specifically; each
//! generator below reproduces the geometry that matters for its dataset:
//!
//! * `sift_like`  — 128-d mixture of anisotropic Gaussian blobs, components
//!   clipped to `[0, 255]` (SIFT is a non-negative quantized histogram).
//! * `vlad_like`  — 512-d mixture with heavy-tailed (Zipf) cluster sizes,
//!   ℓ2-normalized rows (VLAD vectors are ℓ2-normalized aggregates).
//! * `glove_like` — 100-d, broad overlapping mixture + correlated dims
//!   (word embeddings cluster weakly — the paper's hardest graph case).
//! * `gist_like`  — 960-d with *low intrinsic dimension* (~24): blobs are
//!   generated in a low-d latent space and embedded by a fixed random
//!   linear map + small ambient noise.

use crate::data::matrix::VecSet;
use crate::util::rng::Rng;

/// Parameters for the generic blob generator all four stand-ins reuse.
#[derive(Debug, Clone)]
pub struct BlobSpec {
    /// Number of samples.
    pub n: usize,
    /// Ambient dimensionality.
    pub dim: usize,
    /// Number of mixture components.
    pub components: usize,
    /// Component centers are drawn uniform in `[0, spread]^dim`.
    pub spread: f32,
    /// Base within-component standard deviation.
    pub sigma: f32,
    /// Per-component sigma multiplier is drawn in `[1-aniso, 1+aniso]`
    /// per *dimension* (anisotropy).
    pub aniso: f32,
    /// Zipf exponent for component sizes (0 = uniform sizes).
    pub zipf: f64,
    /// If `Some(ld)`, generate in `ld` latent dims and embed (GIST-like).
    pub latent_dim: Option<usize>,
    /// Clip components to `[0, clip]` after generation (SIFT-like).
    pub clip: Option<f32>,
    /// ℓ2-normalize rows at the end (VLAD-like).
    pub normalize: bool,
}

impl BlobSpec {
    /// Small, quick spec used by tests and the quickstart example.
    pub fn quick(n: usize, dim: usize, components: usize) -> BlobSpec {
        BlobSpec {
            n,
            dim,
            components,
            spread: 10.0,
            sigma: 1.0,
            aniso: 0.3,
            zipf: 0.0,
            latent_dim: None,
            clip: None,
            normalize: false,
        }
    }
}

/// Draw component sizes: uniform, or Zipf-tailed when `zipf > 0`.
fn component_sizes(n: usize, k: usize, zipf: f64, rng: &mut Rng) -> Vec<usize> {
    let mut weights: Vec<f64> = (1..=k).map(|r| 1.0 / (r as f64).powf(zipf)).collect();
    rng.shuffle(&mut weights);
    let total: f64 = weights.iter().sum();
    let mut sizes: Vec<usize> = weights.iter().map(|w| (w / total * n as f64) as usize).collect();
    // distribute the rounding remainder
    let mut assigned: usize = sizes.iter().sum();
    let mut i = 0;
    while assigned < n {
        sizes[i % k] += 1;
        assigned += 1;
        i += 1;
    }
    sizes
}

/// Generic mixture-of-blobs generator; all dataset stand-ins call this.
pub fn blobs(spec: &BlobSpec, seed: u64) -> VecSet {
    let mut rng = Rng::new(seed);
    let gen_dim = spec.latent_dim.unwrap_or(spec.dim);
    let k = spec.components.max(1);

    // Component centers + per-dimension sigmas.
    let mut centers = Vec::with_capacity(k * gen_dim);
    let mut sigmas = Vec::with_capacity(k * gen_dim);
    for _ in 0..k * gen_dim {
        centers.push(rng.f32() * spec.spread);
        let m = 1.0 + spec.aniso * (rng.f32() * 2.0 - 1.0);
        sigmas.push(spec.sigma * m);
    }

    let sizes = component_sizes(spec.n, k, spec.zipf, &mut rng);

    // Generate latent points component by component, then shuffle rows so
    // downstream index order carries no label information.
    let mut latent = Vec::with_capacity(spec.n * gen_dim);
    for (c, &sz) in sizes.iter().enumerate() {
        let ctr = &centers[c * gen_dim..(c + 1) * gen_dim];
        let sig = &sigmas[c * gen_dim..(c + 1) * gen_dim];
        for _ in 0..sz {
            for j in 0..gen_dim {
                latent.push(ctr[j] + sig[j] * rng.normal());
            }
        }
    }
    let mut order: Vec<usize> = (0..spec.n).collect();
    rng.shuffle(&mut order);
    let latent = VecSet::from_flat(gen_dim, latent).gather(&order);

    // Optional linear embedding into the ambient space (low intrinsic dim).
    let mut out = if let Some(ld) = spec.latent_dim {
        let mut proj = Vec::with_capacity(ld * spec.dim);
        let scale = 1.0 / (ld as f32).sqrt();
        for _ in 0..ld * spec.dim {
            proj.push(rng.normal() * scale);
        }
        let mut data = vec![0f32; spec.n * spec.dim];
        for i in 0..spec.n {
            let z = latent.row(i);
            let row = &mut data[i * spec.dim..(i + 1) * spec.dim];
            for (a, zv) in z.iter().enumerate() {
                let prow = &proj[a * spec.dim..(a + 1) * spec.dim];
                for (rv, pv) in row.iter_mut().zip(prow) {
                    *rv += zv * pv;
                }
            }
            // small ambient noise so the data is full-rank
            for rv in row.iter_mut() {
                *rv += 0.01 * spec.sigma * rng.normal();
            }
        }
        VecSet::from_flat(spec.dim, data)
    } else {
        latent
    };

    if let Some(c) = spec.clip {
        for v in out.flat_mut() {
            *v = v.clamp(0.0, c);
        }
    }
    if spec.normalize {
        out.l2_normalize();
    }
    out
}

/// SIFT-like: 128-d, non-negative, clipped histogram-ish blobs.
pub fn sift_like(n: usize, seed: u64) -> VecSet {
    blobs(
        &BlobSpec {
            n,
            dim: 128,
            components: (n / 200).clamp(16, 2048),
            spread: 120.0,
            sigma: 18.0,
            aniso: 0.5,
            zipf: 0.6,
            latent_dim: None,
            clip: Some(255.0),
            normalize: false,
        },
        seed,
    )
}

/// VLAD-like: 512-d, ℓ2-normalized, heavy-tailed component sizes.
pub fn vlad_like(n: usize, seed: u64) -> VecSet {
    blobs(
        &BlobSpec {
            n,
            dim: 512,
            components: (n / 400).clamp(16, 4096),
            spread: 4.0,
            sigma: 1.0,
            aniso: 0.4,
            zipf: 1.0,
            latent_dim: None,
            clip: None,
            normalize: true,
        },
        seed,
    )
}

/// GloVe-like: 100-d, broad overlapping clusters (weak structure).
pub fn glove_like(n: usize, seed: u64) -> VecSet {
    blobs(
        &BlobSpec {
            n,
            dim: 100,
            components: (n / 500).clamp(8, 1024),
            spread: 3.0,
            sigma: 1.6, // high overlap: weak cluster structure
            aniso: 0.6,
            zipf: 0.8,
            latent_dim: None,
            clip: None,
            normalize: false,
        },
        seed,
    )
}

/// GIST-like: 960-d ambient, ~24-d intrinsic.
pub fn gist_like(n: usize, seed: u64) -> VecSet {
    blobs(
        &BlobSpec {
            n,
            dim: 960,
            components: (n / 300).clamp(16, 2048),
            spread: 8.0,
            sigma: 1.0,
            aniso: 0.4,
            zipf: 0.5,
            latent_dim: Some(24),
            clip: None,
            normalize: false,
        },
        seed,
    )
}

/// Dispatch by dataset kind name (`sift|vlad|glove|gist|blobs`).
pub fn by_name(kind: &str, n: usize, seed: u64) -> Result<VecSet, String> {
    match kind {
        "sift" | "sift_like" => Ok(sift_like(n, seed)),
        "vlad" | "vlad_like" => Ok(vlad_like(n, seed)),
        "glove" | "glove_like" => Ok(glove_like(n, seed)),
        "gist" | "gist_like" => Ok(gist_like(n, seed)),
        "blobs" => Ok(blobs(&BlobSpec::quick(n, 32, (n / 100).clamp(4, 256)), seed)),
        other => Err(format!("unknown synthetic dataset kind {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = sift_like(500, 1);
        let b = sift_like(500, 1);
        assert_eq!(a.rows(), 500);
        assert_eq!(a.dim(), 128);
        assert_eq!(a, b, "same seed, same data");
        let c = sift_like(500, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn sift_like_range() {
        let v = sift_like(300, 3);
        assert!(v.flat().iter().all(|&x| (0.0..=255.0).contains(&x)));
    }

    #[test]
    fn vlad_like_normalized() {
        let v = vlad_like(100, 4);
        assert_eq!(v.dim(), 512);
        for i in 0..v.rows() {
            let n2: f32 = v.row(i).iter().map(|x| x * x).sum();
            assert!((n2 - 1.0).abs() < 1e-3, "row {i} norm² = {n2}");
        }
    }

    #[test]
    fn gist_like_low_intrinsic_dim() {
        // Rows should live near a 24-d subspace: the energy outside the
        // span of 24 latent directions must be tiny relative to within.
        let v = gist_like(200, 5);
        assert_eq!(v.dim(), 960);
        // crude proxy: variance of random 1-d projections should vary a lot
        // less than for full-rank data of the same norm. Just check it runs
        // and values are finite.
        assert!(v.flat().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn component_sizes_sum_and_tail() {
        let mut rng = Rng::new(6);
        let sz = component_sizes(10_000, 32, 1.0, &mut rng);
        assert_eq!(sz.iter().sum::<usize>(), 10_000);
        let (mx, mn) = (*sz.iter().max().unwrap(), *sz.iter().min().unwrap());
        assert!(mx > mn * 3, "zipf=1 should be heavy-tailed: {mx} vs {mn}");
        let uz = component_sizes(10_000, 32, 0.0, &mut rng);
        let (umx, umn) = (*uz.iter().max().unwrap(), *uz.iter().min().unwrap());
        assert!(umx - umn <= 1, "zipf=0 should be uniform");
    }

    #[test]
    fn by_name_dispatch_and_error() {
        assert_eq!(by_name("glove", 50, 1).unwrap().dim(), 100);
        assert_eq!(by_name("gist", 50, 1).unwrap().dim(), 960);
        assert!(by_name("nope", 50, 1).is_err());
    }

    #[test]
    fn blobs_cluster_structure_exists() {
        // Points from the same component should be far closer than random
        // pairs; verify via mean NN-distance << mean random-pair distance.
        let v = blobs(&BlobSpec::quick(400, 8, 8), 7);
        let d2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut nn_sum = 0.0;
        let mut rnd_sum = 0.0;
        let mut rng = Rng::new(8);
        for i in 0..100 {
            let mut best = f32::MAX;
            for j in 0..v.rows() {
                if i != j {
                    best = best.min(d2(v.row(i), v.row(j)));
                }
            }
            nn_sum += best;
            rnd_sum += d2(v.row(i), v.row(rng.below(v.rows())));
        }
        assert!(nn_sum * 5.0 < rnd_sum, "nn={nn_sum} rnd={rnd_sum}");
    }
}
