//! `VecStore` — the storage abstraction every data-scanning layer runs on.
//!
//! The paper's headline scale (10M × 512-d ≈ 20 GB of raw vectors) does
//! not fit comfortably in RAM as one contiguous `Vec<f32>`, so the scan
//! loops (blocked distance kernels, graph builds, k-means epochs, ANN
//! serving) are written against this trait instead of the concrete
//! [`VecSet`]:
//!
//! * [`VecSet`] implements [`VecStore`] with zero-copy cursors — the
//!   in-RAM fast path is the exact same slices the pre-trait code read,
//!   so serial in-RAM results stay bit-identical.
//! * [`ChunkedVecStore`] streams fixed-size row blocks from disk through
//!   a small resident-chunk cache (`std::fs` only, no mmap crate, no
//!   external deps).  It reads raw flat `f32` files, `fvecs`/`bvecs`
//!   interchange files, and byte ranges inside a larger file — the
//!   GKMODEL v2 vectors section pages through exactly this type.
//!
//! ## Access model
//!
//! A store is shared immutable state (`Sync`); all reads go through a
//! [`StoreCursor`] obtained from [`VecStore::open`].  Cursors own their
//! file handle, chunk cache and scratch buffers, so **each worker thread
//! opens its own cursor** and the store itself needs no locks.  In-RAM
//! cursors are plain slice views with no cache and no copies.
//!
//! ## Errors
//!
//! Constructors validate eagerly (file exists, sizes consistent, headers
//! sane) and return `Err` on anything suspicious.  Cursor reads come in
//! two flavors: the fallible [`StoreCursor::try_row`] /
//! [`StoreCursor::try_block`] / [`StoreCursor::try_d2_pair`] return `Err`
//! on mid-stream corruption (an fvecs/bvecs per-row dimension header that
//! disagrees with the probe, or plain I/O failure), while the infallible
//! `row`/`block`/`d2_pair` the hot scan loops use panic with the same
//! message — threading `Result` through every inner distance loop would
//! poison the hot path for a failure mode (file truncated *mid-run*)
//! that has no sensible recovery there.
//!
//! ## File handles
//!
//! All cursors of one [`ChunkedVecStore`] (and of its clones) share a
//! single pooled read handle, opened lazily on the first cursor: reads
//! go through positioned I/O at per-cursor offsets, so no seek state is
//! shared and opening a cursor never pays a `File::open` (the
//! `ModelVectors::Disk` serving path opens one cursor per query shard).
//! Non-unix targets lack positioned reads and fall back to one handle
//! per cursor.

use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::core_ops::dist::d2;
use crate::data::matrix::VecSet;
use crate::data::plan::ScanGeometry;

/// Read-only `n × d` vector storage: the abstraction the scan loops run
/// on.  See the [module docs](self) for the access model.
pub trait VecStore: Sync {
    /// Number of row vectors.
    fn rows(&self) -> usize;

    /// Dimensionality of each row.
    fn dim(&self) -> usize;

    /// Open a cursor for row/block reads.  Each thread opens its own.
    fn open(&self) -> StoreCursor<'_>;

    /// The whole dataset as one resident flat buffer, when it is in RAM.
    /// Fast paths use this to keep serial in-RAM code bit-identical to
    /// the pre-trait implementation.
    fn as_flat(&self) -> Option<&[f32]> {
        None
    }

    /// The store as an in-RAM [`VecSet`], when it is one (lets engines
    /// that still require resident data borrow it without copying).
    fn as_vecset(&self) -> Option<&VecSet> {
        None
    }

    /// The disk backing of this store, when it streams from a file
    /// (model artifacts keep a cheap handle instead of materializing).
    fn disk_backing(&self) -> Option<&ChunkedVecStore> {
        None
    }

    /// The chunk geometry of this store, when it pages fixed-size row
    /// chunks through a bounded cache — what the locality-aware scan
    /// planner ([`crate::data::plan::ScanPlan`]) aligns super-blocks
    /// with.  Resident stores return `None` (no chunks to be kind to).
    fn scan_geometry(&self) -> Option<ScanGeometry> {
        None
    }
}

impl VecStore for VecSet {
    fn rows(&self) -> usize {
        VecSet::rows(self)
    }

    fn dim(&self) -> usize {
        VecSet::dim(self)
    }

    fn open(&self) -> StoreCursor<'_> {
        StoreCursor::Ram { flat: self.flat(), dim: VecSet::dim(self) }
    }

    fn as_flat(&self) -> Option<&[f32]> {
        Some(self.flat())
    }

    fn as_vecset(&self) -> Option<&VecSet> {
        Some(self)
    }
}

/// Copy every row of `store` into a resident [`VecSet`].
pub fn materialize(store: &dyn VecStore) -> VecSet {
    if let Some(v) = store.as_vecset() {
        return v.clone();
    }
    let (n, d) = (store.rows(), store.dim());
    let mut cur = store.open();
    let mut flat = Vec::with_capacity(n * d);
    const B: usize = 1024;
    let mut lo = 0;
    while lo < n {
        let hi = (lo + B).min(n);
        flat.extend_from_slice(cur.block(lo, hi));
        lo = hi;
    }
    VecSet::from_flat(d, flat)
}

/// Copy the rows at `idx` (in order, repeats allowed) into a [`VecSet`].
///
/// On a paged store the rows are *read* in ascending-row order (so each
/// chunk is loaded from disk at most once, however scattered `idx` is —
/// the k-means++ / random-init sampling pattern) and scattered back to
/// their requested positions: the output is bit-identical to a naive
/// in-order gather.
pub fn gather(store: &dyn VecStore, idx: &[usize]) -> VecSet {
    if let Some(v) = store.as_vecset() {
        return v.gather(idx);
    }
    let d = store.dim();
    let mut cur = store.open();
    let mut flat = vec![0f32; idx.len() * d];
    let mut order: Vec<usize> = (0..idx.len()).collect();
    order.sort_unstable_by_key(|&t| idx[t]);
    for t in order {
        cur.read_row_into(idx[t], &mut flat[t * d..(t + 1) * d]);
    }
    VecSet::from_flat(d, flat)
}

/// Component encoding of a [`ChunkedVecStore`]'s on-disk rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Elem {
    /// Little-endian `f32` components.
    F32,
    /// `u8` components, promoted to `f32` on read (bvecs).
    U8,
}

impl Elem {
    fn size(self) -> u64 {
        match self {
            Elem::F32 => 4,
            Elem::U8 => 1,
        }
    }
}

/// Bounded retry/backoff policy for *transient* chunk-read failures
/// (`Interrupted` / `TimedOut` / `WouldBlock` — the kinds a flaky NFS
/// mount or a signal-interrupted `pread` produces).  Permanent error
/// kinds are never retried: a dead disk fails fast.  The store default
/// is [`FaultPolicy::none`] — zero behavior change unless a policy is
/// installed via [`ChunkedVecStore::with_fault_policy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPolicy {
    /// Retries after the first failed attempt (`0` = fail immediately).
    pub retries: u32,
    /// Sleep before the first retry; doubles on each further retry.
    pub backoff: std::time::Duration,
}

impl FaultPolicy {
    /// No retries: every failure surfaces immediately (the default).
    pub fn none() -> FaultPolicy {
        FaultPolicy { retries: 0, backoff: std::time::Duration::ZERO }
    }

    /// Whether `kind` is worth retrying under this policy.
    pub fn is_transient(kind: std::io::ErrorKind) -> bool {
        matches!(
            kind,
            std::io::ErrorKind::Interrupted
                | std::io::ErrorKind::TimedOut
                | std::io::ErrorKind::WouldBlock
        )
    }
}

impl Default for FaultPolicy {
    /// A sane production policy: 3 retries, 1 ms initial backoff.
    fn default() -> FaultPolicy {
        FaultPolicy { retries: 3, backoff: std::time::Duration::from_millis(1) }
    }
}

/// Shared hit/miss accounting for a [`ChunkedVecStore`]'s resident-chunk
/// cache.  The counters live behind `Arc`s, so every cursor of a store
/// — and of its clones, including the `ModelVectors::Disk` serving path
/// where each query shard opens its own cursor — feeds one ledger.
/// A *miss* is one chunk loaded from disk (exactly what the historical
/// [`ChunkedVecStore::with_read_counter`] test seam counted; that seam
/// now just installs its counter as the miss counter, so the
/// instrumentation and the serving metrics are one mechanism); a *hit*
/// is a chunk access served from the resident cache.
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    hits: Arc<AtomicU64>,
    misses: Arc<AtomicU64>,
}

impl CacheStats {
    pub fn new() -> CacheStats {
        CacheStats::default()
    }

    /// Chunk accesses served from the resident cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Chunks loaded from disk.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// `hits / (hits + misses)`, or `0.0` before any access.
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    #[inline]
    fn add_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    fn add_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Test seam for I/O fault injection: consulted once per physical read
/// attempt *before* the read; returning `Some(err)` fails that attempt
/// with `err` without touching the file.  Lives on the store (not the
/// cursor) so every cursor of a wrapped store shares one deterministic
/// fault schedule — see `testing::fault::FaultStore`.
#[derive(Clone)]
pub struct FaultHook(pub Arc<dyn Fn() -> Option<std::io::Error> + Send + Sync>);

impl std::fmt::Debug for FaultHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("FaultHook(..)")
    }
}

/// Default resident-chunk budget per cursor.
const DEFAULT_CACHE_CHUNKS: usize = 8;
/// Target bytes per chunk when sizing `chunk_rows` automatically.
const DEFAULT_CHUNK_BYTES: usize = 1 << 20;
/// Sanity cap on per-row dimensionality headers read from disk.
const MAX_DIM: usize = 1 << 20;

/// An `n × d` matrix streamed from disk in fixed-size row chunks.
///
/// The struct itself is a cheap, cloneable description (path + layout +
/// cache budget); all I/O state lives in the per-thread
/// [`ChunkedCursor`]s it opens.  Supported layouts: raw flat `f32` rows
/// ([`ChunkedVecStore::open_flat`]), fvecs/bvecs interchange files with
/// their per-row dimension headers ([`ChunkedVecStore::open_fvecs`] /
/// [`ChunkedVecStore::open_bvecs`]), and a byte range inside a larger
/// file ([`ChunkedVecStore::from_section`] — how GKMODEL v2 artifacts
/// page their vectors section).
///
/// ```
/// use gkmeans::data::store::{ChunkedVecStore, VecStore};
///
/// // write 8 rows of 4-d f32 and stream them back with a tiny cache
/// let path = std::env::temp_dir().join(format!("gkm_doc_chunked_{}.f32", std::process::id()));
/// let flat: Vec<f32> = (0..32).map(|v| v as f32).collect();
/// let bytes: Vec<u8> = flat.iter().flat_map(|v| v.to_le_bytes()).collect();
/// std::fs::write(&path, &bytes).unwrap();
///
/// let store = ChunkedVecStore::open_flat(&path, 4)
///     .unwrap()
///     .chunk_rows(2)     // 2 rows per chunk…
///     .cache_chunks(2);  // …and at most 2 resident chunks per cursor
/// assert_eq!((store.rows(), store.dim()), (8, 4));
/// let mut cur = VecStore::open(&store);
/// assert_eq!(cur.row(5), &[20.0, 21.0, 22.0, 23.0]);
/// # std::fs::remove_file(&path).ok();
/// ```
#[derive(Debug, Clone)]
pub struct ChunkedVecStore {
    path: PathBuf,
    rows: usize,
    dim: usize,
    /// Byte offset of row 0's record (including any per-row header).
    base: u64,
    /// Bytes from one row record to the next.
    row_stride: u64,
    /// Per-row header bytes to skip (4 for fvecs/bvecs, 0 for flat).
    row_skip: u64,
    elem: Elem,
    chunk_rows: usize,
    cache_chunks: usize,
    /// Pooled read handle shared by every cursor of this store (and of
    /// its clones); opened lazily by the first cursor.  Cursors read at
    /// absolute offsets (positioned I/O), so no seek state is shared.
    handle: Arc<OnceLock<Arc<File>>>,
    /// Chunk-cache hit/miss ledger shared by every cursor of this store
    /// value (and of its clones) — see [`CacheStats`].
    cache_stats: CacheStats,
    /// Retry/backoff policy for transient read failures.
    fault_policy: FaultPolicy,
    /// Fault-injection seam (tests only in practice).
    fault_hook: Option<FaultHook>,
}

impl ChunkedVecStore {
    fn new(
        path: &Path,
        rows: usize,
        dim: usize,
        base: u64,
        row_skip: u64,
        elem: Elem,
    ) -> ChunkedVecStore {
        let row_stride = row_skip + dim as u64 * elem.size();
        let chunk_rows = (DEFAULT_CHUNK_BYTES / row_stride.max(1) as usize).max(1);
        ChunkedVecStore {
            path: path.to_path_buf(),
            rows,
            dim,
            base,
            row_stride,
            row_skip,
            elem,
            chunk_rows,
            cache_chunks: DEFAULT_CACHE_CHUNKS,
            handle: Arc::new(OnceLock::new()),
            cache_stats: CacheStats::new(),
            fault_policy: FaultPolicy::none(),
            fault_hook: None,
        }
    }

    /// Open a raw flat little-endian `f32` file as `len / (4·dim)` rows.
    pub fn open_flat(path: &Path, dim: usize) -> Result<ChunkedVecStore, String> {
        if dim == 0 {
            return Err("dim must be positive".into());
        }
        let len = file_len(path)?;
        let stride = dim as u64 * 4;
        if len == 0 || len % stride != 0 {
            return Err(format!(
                "{}: {len} bytes is not a whole number of {dim}-d f32 rows",
                path.display()
            ));
        }
        Ok(ChunkedVecStore::new(path, (len / stride) as usize, dim, 0, 0, Elem::F32))
    }

    /// Open an `.fvecs` file (per-row `i32` dim header + `f32` payload).
    /// The dimension is probed from the first record; every record's
    /// header is re-verified as chunks stream in.
    pub fn open_fvecs(path: &Path) -> Result<ChunkedVecStore, String> {
        Self::open_texmex(path, Elem::F32)
    }

    /// Open a `.bvecs` file (per-row `i32` dim header + `u8` payload,
    /// promoted to `f32` on read).
    pub fn open_bvecs(path: &Path) -> Result<ChunkedVecStore, String> {
        Self::open_texmex(path, Elem::U8)
    }

    fn open_texmex(path: &Path, elem: Elem) -> Result<ChunkedVecStore, String> {
        let len = file_len(path)?;
        let mut f = File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut hdr = [0u8; 4];
        f.read_exact(&mut hdr)
            .map_err(|_| format!("{}: empty or truncated header", path.display()))?;
        let d = i32::from_le_bytes(hdr);
        if d <= 0 || d as usize > MAX_DIM {
            return Err(format!("{}: implausible vector dim {d}", path.display()));
        }
        let dim = d as usize;
        let stride = 4 + dim as u64 * elem.size();
        if len % stride != 0 {
            return Err(format!(
                "{}: {len} bytes is not a whole number of {dim}-d records \
                 ({stride} bytes each) — truncated or corrupt",
                path.display()
            ));
        }
        Ok(ChunkedVecStore::new(path, (len / stride) as usize, dim, 0, 4, elem))
    }

    /// Open a raw `rows × dim` little-endian `f32` region starting at
    /// `byte_offset` inside `path` — the GKMODEL v2 vectors section.
    pub fn from_section(
        path: &Path,
        byte_offset: u64,
        rows: usize,
        dim: usize,
    ) -> Result<ChunkedVecStore, String> {
        if dim == 0 {
            return Err("dim must be positive".into());
        }
        let len = file_len(path)?;
        let need = (rows as u64)
            .checked_mul(dim as u64)
            .and_then(|c| c.checked_mul(4))
            .and_then(|c| byte_offset.checked_add(c))
            .ok_or_else(|| "section extent overflows".to_string())?;
        if need > len {
            return Err(format!(
                "{}: vectors section [{byte_offset}, {need}) exceeds file length {len}",
                path.display()
            ));
        }
        Ok(ChunkedVecStore::new(path, rows, dim, byte_offset, 0, Elem::F32))
    }

    /// Dispatch on file extension (`.fvecs` / `.bvecs`).
    pub fn open_auto(path: &Path) -> Result<ChunkedVecStore, String> {
        match path.extension().and_then(|e| e.to_str()) {
            Some("fvecs") => ChunkedVecStore::open_fvecs(path),
            Some("bvecs") => ChunkedVecStore::open_bvecs(path),
            other => Err(format!(
                "unsupported dataset extension {other:?} for streaming (fvecs/bvecs)"
            )),
        }
    }

    /// Set the rows per resident chunk (clamped to ≥ 1).
    pub fn chunk_rows(mut self, rows: usize) -> Self {
        self.chunk_rows = rows.max(1);
        self
    }

    /// Set the resident-chunk budget per cursor (clamped to ≥ 2 so a
    /// pairwise scan always has both operand chunks resident).
    pub fn cache_chunks(mut self, chunks: usize) -> Self {
        self.cache_chunks = chunks.max(2);
        self
    }

    /// Install a chunk-read counter: every chunk any cursor of this
    /// store value loads from disk bumps it once.  The locality tests
    /// and the out-of-core bench assert cache behavior through this.
    /// The counter *is* the [`CacheStats`] miss counter — one mechanism
    /// feeds both the test seam and the serving metrics.
    pub fn with_read_counter(mut self, counter: Arc<AtomicU64>) -> Self {
        self.cache_stats = CacheStats { hits: Arc::new(AtomicU64::new(0)), misses: counter };
        self
    }

    /// The shared chunk-cache hit/miss ledger (see [`CacheStats`]).
    pub fn cache_stats(&self) -> &CacheStats {
        &self.cache_stats
    }

    /// Install a retry/backoff policy for transient read failures (the
    /// default is [`FaultPolicy::none`]: fail immediately).
    pub fn with_fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault_policy = policy;
        self
    }

    /// Install a fault-injection hook (see [`FaultHook`]); the test seam
    /// `testing::fault::FaultStore` builds on this.
    pub fn with_fault_hook(mut self, hook: FaultHook) -> Self {
        self.fault_hook = Some(hook);
        self
    }

    /// Compress this store into a RAM-resident SQ8 code matrix
    /// ([`crate::data::quant::QuantizedVecStore`], ~4× smaller than the
    /// f32 rows).  A bvecs-backed store (`u8` components promoted to
    /// f32 on read) passes through the **identity** quantizer and
    /// round-trips losslessly; f32-backed stores train a per-dimension
    /// min/max affine on an even-stride sample of up to `sample_rows`
    /// rows (`0` = full pass).  Panics on mid-stream read failure, like
    /// every other full-scan loop.
    pub fn quantize_sq8(&self, sample_rows: usize) -> crate::data::quant::QuantizedVecStore {
        use crate::data::quant::{QuantizedVecStore, Sq8Quantizer};
        if self.elem == Elem::U8 {
            return QuantizedVecStore::encode_with(self, Sq8Quantizer::identity(self.dim));
        }
        QuantizedVecStore::from_store(self, sample_rows)
    }

    /// The backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The handle a new cursor reads through: the pooled shared handle
    /// on unix (positioned I/O, per-cursor offsets), a private handle
    /// elsewhere (no positioned reads to share one safely).
    fn cursor_file(&self) -> Result<Arc<File>, String> {
        #[cfg(unix)]
        {
            if let Some(f) = self.handle.get() {
                return Ok(f.clone());
            }
            let f = File::open(&self.path)
                .map_err(|e| format!("{}: {e}", self.path.display()))?;
            Ok(self.handle.get_or_init(|| Arc::new(f)).clone())
        }
        #[cfg(not(unix))]
        {
            File::open(&self.path)
                .map(Arc::new)
                .map_err(|e| format!("{}: {e}", self.path.display()))
        }
    }

    /// Read rows `[lo, hi)` into a fresh flat `f32` buffer, verifying
    /// per-row headers where the layout has them.  Mid-stream corruption
    /// (an fvecs/bvecs record whose dimension header disagrees with the
    /// probe) returns `Err` rather than aborting the process.
    fn read_rows(&self, file: &File, lo: usize, hi: usize) -> Result<Vec<f32>, String> {
        let nrows = hi - lo;
        let nbytes = nrows as u64 * self.row_stride;
        let mut raw = vec![0u8; nbytes as usize];
        let offset = self.base + lo as u64 * self.row_stride;
        // Bounded retry with exponential backoff on *transient* I/O
        // failures; permanent kinds (and exhausted retries) surface as
        // the usual Err.  Each physical attempt first consults the
        // fault-injection hook, so injected faults exercise the exact
        // retry path real ones take.
        let mut attempt = 0u32;
        loop {
            let attempted = match &self.fault_hook {
                Some(h) => match (h.0)() {
                    Some(e) => Err(e),
                    None => read_exact_at(file, &mut raw, offset),
                },
                None => read_exact_at(file, &mut raw, offset),
            };
            match attempted {
                Ok(()) => break,
                Err(e) => {
                    if FaultPolicy::is_transient(e.kind()) && attempt < self.fault_policy.retries {
                        let pause = self.fault_policy.backoff * 2u32.saturating_pow(attempt);
                        if !pause.is_zero() {
                            std::thread::sleep(pause);
                        }
                        attempt += 1;
                        crate::log_debug!(
                            "ChunkedVecStore {}: transient read failure ({e}), retry {attempt}/{}",
                            self.path.display(),
                            self.fault_policy.retries
                        );
                        continue;
                    }
                    let retries = if attempt > 0 {
                        format!(" after {attempt} retries")
                    } else {
                        String::new()
                    };
                    return Err(format!(
                        "ChunkedVecStore {}: reading rows [{lo}, {hi}) failed{retries}: {e}",
                        self.path.display()
                    ));
                }
            }
        }
        self.cache_stats.add_miss();
        let mut out = Vec::with_capacity(nrows * self.dim);
        let stride = self.row_stride as usize;
        let skip = self.row_skip as usize;
        for r in 0..nrows {
            let rec = &raw[r * stride..(r + 1) * stride];
            if skip == 4 {
                let d = i32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]);
                if d as usize != self.dim {
                    return Err(format!(
                        "ChunkedVecStore {}: row {} header says dim {d}, expected {} \
                         — inconsistent or corrupt file",
                        self.path.display(),
                        lo + r,
                        self.dim
                    ));
                }
            }
            match self.elem {
                Elem::F32 => {
                    for c in rec[skip..].chunks_exact(4) {
                        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                    }
                }
                Elem::U8 => out.extend(rec[skip..].iter().map(|&b| b as f32)),
            }
        }
        Ok(out)
    }
}

/// Positioned read at `offset` without touching shared seek state (unix
/// `pread`; the non-unix fallback seeks a cursor-private handle).
fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::os::unix::fs::FileExt::read_exact_at(file, buf, offset)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom};
        let mut f = file;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

impl VecStore for ChunkedVecStore {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn open(&self) -> StoreCursor<'_> {
        let file = self.cursor_file().unwrap_or_else(|e| {
            panic!("ChunkedVecStore reopen failed: {e}")
        });
        StoreCursor::Chunked(ChunkedCursor {
            store: self,
            file,
            slots: Vec::new(),
            tick: 0,
            scratch: Vec::new(),
            pair: Vec::new(),
        })
    }

    fn disk_backing(&self) -> Option<&ChunkedVecStore> {
        Some(self)
    }

    fn scan_geometry(&self) -> Option<ScanGeometry> {
        Some(ScanGeometry { chunk_rows: self.chunk_rows, cache_chunks: self.cache_chunks })
    }
}

fn file_len(path: &Path) -> Result<u64, String> {
    std::fs::metadata(path)
        .map(|m| m.len())
        .map_err(|e| format!("{}: {e}", path.display()))
}

/// A read cursor over a [`ChunkedVecStore`]: the store's pooled file
/// handle, an LRU cache of resident chunks, and scratch for cross-chunk
/// blocks.
pub struct ChunkedCursor<'a> {
    store: &'a ChunkedVecStore,
    file: Arc<File>,
    /// Resident chunks: (chunk index, last-use tick, rows·dim floats).
    slots: Vec<(usize, u64, Vec<f32>)>,
    tick: u64,
    scratch: Vec<f32>,
    pair: Vec<f32>,
}

impl ChunkedCursor<'_> {
    /// Slot index of chunk `c`, loading (and possibly evicting the
    /// least-recently-used resident chunk) on miss.
    fn slot_of(&mut self, c: usize) -> Result<usize, String> {
        self.tick += 1;
        if let Some(s) = self.slots.iter().position(|(ci, _, _)| *ci == c) {
            self.slots[s].1 = self.tick;
            self.store.cache_stats.add_hit();
            return Ok(s);
        }
        let lo = c * self.store.chunk_rows;
        let hi = (lo + self.store.chunk_rows).min(self.store.rows);
        let buf = self.store.read_rows(&self.file, lo, hi)?;
        Ok(if self.slots.len() < self.store.cache_chunks {
            self.slots.push((c, self.tick, buf));
            self.slots.len() - 1
        } else {
            let s = (0..self.slots.len())
                .min_by_key(|&i| self.slots[i].1)
                .expect("cache budget >= 2");
            self.slots[s] = (c, self.tick, buf);
            s
        })
    }

    fn try_row(&mut self, i: usize) -> Result<&[f32], String> {
        debug_assert!(i < self.store.rows, "row {i} out of bounds");
        let cr = self.store.chunk_rows;
        let d = self.store.dim;
        let c = i / cr;
        let s = self.slot_of(c)?;
        let off = (i - c * cr) * d;
        Ok(&self.slots[s].2[off..off + d])
    }

    fn try_block(&mut self, lo: usize, hi: usize) -> Result<&[f32], String> {
        let cr = self.store.chunk_rows;
        let d = self.store.dim;
        if lo >= hi {
            return Ok(&[]);
        }
        if lo / cr == (hi - 1) / cr {
            // fully inside one chunk: serve a direct slice
            let c = lo / cr;
            let s = self.slot_of(c)?;
            let start = (lo - c * cr) * d;
            return Ok(&self.slots[s].2[start..start + (hi - lo) * d]);
        }
        // spans chunks: assemble into scratch
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        scratch.reserve((hi - lo) * d);
        let mut r = lo;
        while r < hi {
            let c = r / cr;
            let seg_hi = ((c + 1) * cr).min(hi);
            let s = match self.slot_of(c) {
                Ok(s) => s,
                Err(e) => {
                    self.scratch = scratch;
                    return Err(e);
                }
            };
            let start = (r - c * cr) * d;
            scratch.extend_from_slice(&self.slots[s].2[start..start + (seg_hi - r) * d]);
            r = seg_hi;
        }
        self.scratch = scratch;
        Ok(&self.scratch)
    }

    fn try_d2_pair(&mut self, i: usize, j: usize) -> Result<f32, String> {
        let mut pair = std::mem::take(&mut self.pair);
        pair.clear();
        // copy row i out first so its borrow ends before row j is read
        let copied = self.try_row(i).map(|row| pair.extend_from_slice(row));
        let dd = match copied {
            Ok(()) => self.try_row(j).map(|row_j| d2(&pair, row_j)),
            Err(e) => Err(e),
        };
        self.pair = pair;
        dd
    }
}

/// A read cursor over any [`VecStore`].  In-RAM stores serve zero-copy
/// slices; chunked stores page through their resident-chunk cache.
///
/// Returned slices borrow the cursor, so hold at most one at a time
/// (copy via [`StoreCursor::read_row_into`] when two rows are needed
/// simultaneously, or use [`StoreCursor::d2_pair`]).
pub enum StoreCursor<'a> {
    /// Zero-copy view of a resident flat buffer.
    Ram {
        /// The `rows · dim` flat buffer.
        flat: &'a [f32],
        /// Row dimensionality.
        dim: usize,
    },
    /// Paged view of a [`ChunkedVecStore`].
    Chunked(ChunkedCursor<'a>),
    /// Decoding view of an SQ8-quantized store
    /// ([`crate::data::quant::QuantizedVecStore`]): rows are
    /// reconstructed into per-cursor scratch on access.  Resident and
    /// infallible — the `try_*` flavors never return `Err`.
    Quant(crate::data::quant::QuantCursor<'a>),
}

impl StoreCursor<'_> {
    /// Borrow row `i`.  Panics on mid-stream I/O failure or corruption
    /// (see [`StoreCursor::try_row`] for the recoverable variant).
    #[inline]
    pub fn row(&mut self, i: usize) -> &[f32] {
        match self {
            StoreCursor::Ram { flat, dim } => &flat[i * *dim..(i + 1) * *dim],
            StoreCursor::Chunked(c) => c.try_row(i).unwrap_or_else(|e| panic!("{e}")),
            StoreCursor::Quant(q) => q.row(i),
        }
    }

    /// Borrow row `i`, surfacing mid-stream read failures (truncation,
    /// an fvecs/bvecs per-row dim header disagreeing with the probe) as
    /// `Err` instead of a panic.  In-RAM cursors never fail.
    #[inline]
    pub fn try_row(&mut self, i: usize) -> Result<&[f32], String> {
        match self {
            StoreCursor::Ram { flat, dim } => Ok(&flat[i * *dim..(i + 1) * *dim]),
            StoreCursor::Chunked(c) => c.try_row(i),
            StoreCursor::Quant(q) => Ok(q.row(i)),
        }
    }

    /// Borrow rows `[lo, hi)` as one flat slice.  Panics on mid-stream
    /// failure (see [`StoreCursor::try_block`]).
    #[inline]
    pub fn block(&mut self, lo: usize, hi: usize) -> &[f32] {
        match self {
            StoreCursor::Ram { flat, dim } => &flat[lo * *dim..hi * *dim],
            StoreCursor::Chunked(c) => c.try_block(lo, hi).unwrap_or_else(|e| panic!("{e}")),
            StoreCursor::Quant(q) => q.block(lo, hi),
        }
    }

    /// Fallible [`StoreCursor::block`].
    #[inline]
    pub fn try_block(&mut self, lo: usize, hi: usize) -> Result<&[f32], String> {
        match self {
            StoreCursor::Ram { flat, dim } => Ok(&flat[lo * *dim..hi * *dim]),
            StoreCursor::Chunked(c) => c.try_block(lo, hi),
            StoreCursor::Quant(q) => Ok(q.block(lo, hi)),
        }
    }

    /// Copy row `i` into `out` (`out.len() == dim`).
    pub fn read_row_into(&mut self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row(i));
    }

    /// Squared L2 distance between rows `i` and `j` (the random-pair
    /// access pattern of NN-Descent and in-cell refinement).  Panics on
    /// mid-stream failure (see [`StoreCursor::try_d2_pair`]).
    #[inline]
    pub fn d2_pair(&mut self, i: usize, j: usize) -> f32 {
        match self {
            StoreCursor::Ram { flat, dim } => {
                let d = *dim;
                d2(&flat[i * d..(i + 1) * d], &flat[j * d..(j + 1) * d])
            }
            StoreCursor::Chunked(c) => c.try_d2_pair(i, j).unwrap_or_else(|e| panic!("{e}")),
            StoreCursor::Quant(q) => q.d2_pair(i, j),
        }
    }

    /// Fallible [`StoreCursor::d2_pair`].
    #[inline]
    pub fn try_d2_pair(&mut self, i: usize, j: usize) -> Result<f32, String> {
        match self {
            StoreCursor::Ram { flat, dim } => {
                let d = *dim;
                Ok(d2(&flat[i * d..(i + 1) * d], &flat[j * d..(j + 1) * d]))
            }
            StoreCursor::Chunked(c) => c.try_d2_pair(i, j),
            StoreCursor::Quant(q) => Ok(q.d2_pair(i, j)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gkm_store_{}_{name}", std::process::id()))
    }

    fn write_flat(path: &Path, v: &VecSet) {
        let mut bytes = Vec::with_capacity(v.flat().len() * 4);
        for &x in v.flat() {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, bytes).unwrap();
    }

    fn random_set(n: usize, d: usize, seed: u64) -> VecSet {
        let mut rng = Rng::new(seed);
        VecSet::from_flat(d, (0..n * d).map(|_| rng.normal()).collect())
    }

    #[test]
    fn vecset_cursor_is_zero_copy_view() {
        let v = random_set(10, 4, 1);
        let mut cur = VecStore::open(&v);
        assert_eq!(cur.row(3), v.row(3));
        assert_eq!(cur.block(2, 7), v.rows_flat(2, 7));
        assert_eq!(VecStore::rows(&v), 10);
        assert_eq!(VecStore::dim(&v), 4);
        assert!(v.as_flat().is_some());
        assert!(v.as_vecset().is_some());
        assert!(v.disk_backing().is_none());
    }

    #[test]
    fn chunked_flat_matches_ram_rows_and_blocks() {
        let v = random_set(137, 7, 2);
        let p = tmp("flat.bin");
        write_flat(&p, &v);
        // deliberately awkward chunk geometry + tiny cache
        let store = ChunkedVecStore::open_flat(&p, 7).unwrap().chunk_rows(11).cache_chunks(2);
        assert_eq!(VecStore::rows(&store), 137);
        assert_eq!(VecStore::dim(&store), 7);
        let mut cur = store.open();
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            let i = rng.below(137);
            assert_eq!(cur.row(i), v.row(i), "row {i}");
        }
        for _ in 0..200 {
            let lo = rng.below(137);
            let hi = lo + rng.below(137 - lo) + 1;
            assert_eq!(cur.block(lo, hi), v.rows_flat(lo, hi), "block [{lo}, {hi})");
        }
        for _ in 0..200 {
            let i = rng.below(137);
            let j = rng.below(137);
            let want = d2(v.row(i), v.row(j));
            assert_eq!(cur.d2_pair(i, j).to_bits(), want.to_bits());
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn materialize_and_gather_roundtrip() {
        let v = random_set(40, 3, 4);
        let p = tmp("mat.bin");
        write_flat(&p, &v);
        let store = ChunkedVecStore::open_flat(&p, 3).unwrap().chunk_rows(7).cache_chunks(2);
        let back = materialize(&store);
        assert_eq!(back, v);
        let idx = [5usize, 0, 39, 5];
        assert_eq!(gather(&store, &idx), v.gather(&idx));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fvecs_streaming_matches_eager_reader() {
        let v = random_set(63, 5, 5);
        let p = tmp("s.fvecs");
        crate::data::io::write_fvecs(&p, &v).unwrap();
        let store = ChunkedVecStore::open_fvecs(&p).unwrap().chunk_rows(4).cache_chunks(3);
        assert_eq!(VecStore::rows(&store), 63);
        assert_eq!(VecStore::dim(&store), 5);
        assert_eq!(materialize(&store), v);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bvecs_streaming_promotes_u8() {
        let p = tmp("s.bvecs");
        let mut bytes = Vec::new();
        for row in [[7u8, 200u8], [0u8, 255u8], [3u8, 4u8]] {
            bytes.extend(2i32.to_le_bytes());
            bytes.extend(row);
        }
        std::fs::write(&p, &bytes).unwrap();
        let store = ChunkedVecStore::open_bvecs(&p).unwrap().chunk_rows(2);
        let mut cur = store.open();
        assert_eq!(cur.row(0), &[7.0, 200.0]);
        assert_eq!(cur.row(2), &[3.0, 4.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn section_view_reads_subrange() {
        let v = random_set(20, 4, 6);
        let p = tmp("sec.bin");
        let mut bytes = vec![0xAAu8; 24]; // unrelated prefix
        for &x in v.flat() {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.extend_from_slice(&[0xBB; 16]); // unrelated suffix
        std::fs::write(&p, &bytes).unwrap();
        let store = ChunkedVecStore::from_section(&p, 24, 20, 4).unwrap().chunk_rows(3);
        assert_eq!(materialize(&store), v);
        // section extent beyond EOF is rejected
        assert!(ChunkedVecStore::from_section(&p, 24, 1000, 4).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn constructors_reject_bad_files() {
        let p = tmp("bad.fvecs");
        // truncated: header promises 3 components, payload has 1
        let mut bytes = Vec::new();
        bytes.extend(3i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(ChunkedVecStore::open_fvecs(&p).is_err());
        // negative dim header
        let mut bytes = Vec::new();
        bytes.extend((-5i32).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(ChunkedVecStore::open_fvecs(&p).unwrap_err().contains("implausible"));
        // flat file not a whole number of rows
        std::fs::write(&p, [0u8; 10]).unwrap();
        assert!(ChunkedVecStore::open_flat(&p, 3).is_err());
        std::fs::remove_file(&p).ok();
        // missing file
        assert!(ChunkedVecStore::open_fvecs(Path::new("/nonexistent.fvecs")).is_err());
    }

    #[test]
    fn mid_stream_dim_mismatch_is_an_error_not_a_panic() {
        // A bvecs file whose *second* record header is corrupt: the
        // constructor's probe (first record) passes, the total length
        // still divides evenly, but paging the bad record in must
        // surface `Err` — not abort the process.
        let p = tmp("corrupt.bvecs");
        let mut bytes = Vec::new();
        for (hdr, row) in [(2i32, [7u8, 200u8]), (3i32, [0u8, 255u8]), (2i32, [3u8, 4u8])] {
            bytes.extend(hdr.to_le_bytes());
            bytes.extend(row);
        }
        std::fs::write(&p, &bytes).unwrap();
        let store = ChunkedVecStore::open_bvecs(&p).unwrap().chunk_rows(1);
        let mut cur = store.open();
        assert_eq!(cur.try_row(0).unwrap(), &[7.0, 200.0]);
        let err = cur.try_row(1).unwrap_err();
        assert!(err.contains("dim 3"), "unexpected error: {err}");
        assert!(err.contains("row 1"), "unexpected error: {err}");
        // the cursor stays usable for intact rows
        assert_eq!(cur.try_row(2).unwrap(), &[3.0, 4.0]);
        // the same corruption through try_block and try_d2_pair
        assert!(cur.try_block(0, 3).is_err());
        assert!(cur.try_d2_pair(0, 1).is_err());
        assert_eq!(cur.try_d2_pair(0, 2).unwrap(), d2(&[7.0, 200.0], &[3.0, 4.0]));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn read_counter_counts_chunk_loads() {
        let v = random_set(40, 3, 8);
        let p = tmp("counted.bin");
        write_flat(&p, &v);
        let counter = Arc::new(AtomicU64::new(0));
        let store = ChunkedVecStore::open_flat(&p, 3)
            .unwrap()
            .chunk_rows(10)
            .cache_chunks(2)
            .with_read_counter(counter.clone());
        // sequential materialize loads each of the 4 chunks exactly once
        assert_eq!(materialize(&store), v);
        assert_eq!(counter.load(Ordering::Relaxed), 4);
        // a cache-hostile back-and-forth scan reloads evicted chunks
        let mut cur = store.open();
        for _ in 0..3 {
            cur.row(0);
            cur.row(15);
            cur.row(35);
        }
        assert!(counter.load(Ordering::Relaxed) > 4);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cache_stats_count_hits_and_misses_across_cursors() {
        let v = random_set(40, 3, 21);
        let p = tmp("cstats.bin");
        write_flat(&p, &v);
        let store = ChunkedVecStore::open_flat(&p, 3).unwrap().chunk_rows(10).cache_chunks(2);
        assert_eq!(store.cache_stats().hit_rate(), 0.0, "no accesses yet");
        // sequential materialize: 4 chunk loads, each followed by 9
        // same-chunk row hits would be the row-at-a-time pattern; block
        // reads touch each chunk once → 4 misses
        assert_eq!(materialize(&store), v);
        assert_eq!(store.cache_stats().misses(), 4);
        // re-reading rows of a resident chunk is all hits
        let mut cur = store.open();
        let before_hits = store.cache_stats().hits();
        cur.row(0);
        cur.row(1);
        cur.row(2);
        let s = store.cache_stats();
        assert!(s.hits() >= before_hits + 2, "resident rereads must hit");
        assert!(s.hit_rate() > 0.0 && s.hit_rate() < 1.0);
        // clones share the same ledger (the serving path clones the
        // store into ModelVectors::Disk and opens cursors per shard)
        let clone = store.clone();
        let h0 = store.cache_stats().hits();
        clone.open().row(0);
        assert!(store.cache_stats().hits() > h0, "clone accesses feed one ledger");
        // the legacy read-counter seam is the same miss counter
        let counter = Arc::new(AtomicU64::new(0));
        let counted = ChunkedVecStore::open_flat(&p, 3)
            .unwrap()
            .chunk_rows(10)
            .with_read_counter(counter.clone());
        materialize(&counted);
        assert_eq!(counter.load(Ordering::Relaxed), 4);
        assert_eq!(counted.cache_stats().misses(), 4);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn scan_geometry_reports_chunk_shape() {
        let v = random_set(30, 2, 9);
        let p = tmp("geom.bin");
        write_flat(&p, &v);
        let store = ChunkedVecStore::open_flat(&p, 2).unwrap().chunk_rows(7).cache_chunks(3);
        let g = VecStore::scan_geometry(&store).unwrap();
        assert_eq!((g.chunk_rows, g.cache_chunks), (7, 3));
        assert_eq!(g.superblock_rows(), 21);
        assert!(VecStore::scan_geometry(&v).is_none(), "resident stores have no geometry");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cursors_share_one_pooled_handle() {
        // Two cursors (and a cursor of a clone) read consistent data
        // through the pooled handle — per-cursor offsets, no seek races.
        let v = random_set(50, 4, 10);
        let p = tmp("pooled.bin");
        write_flat(&p, &v);
        let store = ChunkedVecStore::open_flat(&p, 4).unwrap().chunk_rows(9).cache_chunks(2);
        let clone = store.clone();
        let mut a = store.open();
        let mut b = store.open();
        let mut c = clone.open();
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let i = rng.below(50);
            assert_eq!(a.row(i), v.row(i));
            let j = rng.below(50);
            assert_eq!(b.row(j), v.row(j));
            assert_eq!(c.row(i), v.row(i));
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn transient_faults_are_retried_within_policy() {
        let v = random_set(30, 3, 12);
        let p = tmp("transient.bin");
        write_flat(&p, &v);
        // fail the first two attempts of every chunk read, succeed after
        let attempts = Arc::new(AtomicU64::new(0));
        let a = attempts.clone();
        let hook = FaultHook(Arc::new(move || {
            if a.fetch_add(1, Ordering::SeqCst) % 3 < 2 {
                Some(std::io::Error::new(std::io::ErrorKind::Interrupted, "injected"))
            } else {
                None
            }
        }));
        let store = ChunkedVecStore::open_flat(&p, 3)
            .unwrap()
            .chunk_rows(10)
            .with_fault_policy(FaultPolicy { retries: 4, backoff: std::time::Duration::ZERO })
            .with_fault_hook(hook);
        assert_eq!(materialize(&store), v, "retried reads must return clean data");
        // 3 chunks, 3 attempts each (2 injected failures + 1 success)
        assert_eq!(attempts.load(Ordering::SeqCst), 9);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn transient_faults_exhaust_retries_and_permanent_faults_fail_fast() {
        let v = random_set(10, 2, 13);
        let p = tmp("permanent.bin");
        write_flat(&p, &v);
        // always-transient hook + 2 retries: 3 attempts, then Err
        let attempts = Arc::new(AtomicU64::new(0));
        let a = attempts.clone();
        let always = FaultHook(Arc::new(move || {
            a.fetch_add(1, Ordering::SeqCst);
            Some(std::io::Error::new(std::io::ErrorKind::TimedOut, "injected timeout"))
        }));
        let store = ChunkedVecStore::open_flat(&p, 2)
            .unwrap()
            .with_fault_policy(FaultPolicy { retries: 2, backoff: std::time::Duration::ZERO })
            .with_fault_hook(always);
        let err = store.open().try_row(0).unwrap_err();
        assert!(err.contains("after 2 retries"), "unexpected error: {err}");
        assert_eq!(attempts.load(Ordering::SeqCst), 3);
        // a permanent error kind is never retried, even with retries left
        let attempts2 = Arc::new(AtomicU64::new(0));
        let a2 = attempts2.clone();
        let dead = FaultHook(Arc::new(move || {
            a2.fetch_add(1, Ordering::SeqCst);
            Some(std::io::Error::new(std::io::ErrorKind::Other, "injected dead disk"))
        }));
        let store = ChunkedVecStore::open_flat(&p, 2)
            .unwrap()
            .with_fault_policy(FaultPolicy { retries: 5, backoff: std::time::Duration::ZERO })
            .with_fault_hook(dead);
        assert!(store.open().try_row(0).is_err());
        assert_eq!(attempts2.load(Ordering::SeqCst), 1, "permanent faults must fail fast");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_auto_dispatches_on_extension() {
        let v = random_set(8, 2, 7);
        let p = tmp("auto.fvecs");
        crate::data::io::write_fvecs(&p, &v).unwrap();
        assert_eq!(materialize(&ChunkedVecStore::open_auto(&p).unwrap()), v);
        assert!(ChunkedVecStore::open_auto(Path::new("/tmp/x.csv")).is_err());
        std::fs::remove_file(&p).ok();
    }
}
