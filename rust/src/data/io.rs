//! fvecs / bvecs / ivecs interchange I/O (TEXMEX / SIFT1M conventions).
//!
//! Format: each vector is `<d: i32 little-endian><d components>`, where a
//! component is `f32` (fvecs), `u8` (bvecs) or `i32` (ivecs).  These are
//! the formats the paper's datasets ship in, so real SIFT1M/GIST1M files
//! drop straight into the benchmarks.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::matrix::VecSet;

fn read_i32le(r: &mut impl Read) -> std::io::Result<Option<i32>> {
    let mut buf = [0u8; 4];
    match r.read_exact(&mut buf) {
        Ok(()) => Ok(Some(i32::from_le_bytes(buf))),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
        Err(e) => Err(e),
    }
}

/// Read a `.fvecs` file into a `VecSet`.
pub fn read_fvecs(path: &Path) -> Result<VecSet, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut dim = 0usize;
    let mut data: Vec<f32> = Vec::new();
    while let Some(d) = read_i32le(&mut r).map_err(|e| e.to_string())? {
        let d = d as usize;
        if dim == 0 {
            dim = d;
        } else if d != dim {
            return Err(format!("inconsistent dim: {d} vs {dim}"));
        }
        let mut buf = vec![0u8; d * 4];
        r.read_exact(&mut buf).map_err(|e| e.to_string())?;
        for c in buf.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
    }
    if dim == 0 {
        return Err(format!("{}: empty fvecs file", path.display()));
    }
    Ok(VecSet::from_flat(dim, data))
}

/// Read a `.bvecs` file (u8 components, promoted to f32).
pub fn read_bvecs(path: &Path) -> Result<VecSet, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut dim = 0usize;
    let mut data: Vec<f32> = Vec::new();
    while let Some(d) = read_i32le(&mut r).map_err(|e| e.to_string())? {
        let d = d as usize;
        if dim == 0 {
            dim = d;
        } else if d != dim {
            return Err(format!("inconsistent dim: {d} vs {dim}"));
        }
        let mut buf = vec![0u8; d];
        r.read_exact(&mut buf).map_err(|e| e.to_string())?;
        data.extend(buf.iter().map(|&b| b as f32));
    }
    if dim == 0 {
        return Err(format!("{}: empty bvecs file", path.display()));
    }
    Ok(VecSet::from_flat(dim, data))
}

/// Write a `VecSet` as `.fvecs`.
pub fn write_fvecs(path: &Path, v: &VecSet) -> Result<(), String> {
    let f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    let d = v.dim() as i32;
    for i in 0..v.rows() {
        w.write_all(&d.to_le_bytes()).map_err(|e| e.to_string())?;
        for &x in v.row(i) {
            w.write_all(&x.to_le_bytes()).map_err(|e| e.to_string())?;
        }
    }
    w.flush().map_err(|e| e.to_string())
}

/// Write integer lists (e.g. KNN ground truth) as `.ivecs`.
pub fn write_ivecs(path: &Path, rows: &[Vec<i32>]) -> Result<(), String> {
    let f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes()).map_err(|e| e.to_string())?;
        for &x in row {
            w.write_all(&x.to_le_bytes()).map_err(|e| e.to_string())?;
        }
    }
    w.flush().map_err(|e| e.to_string())
}

/// Read `.ivecs` integer lists.
pub fn read_ivecs(path: &Path) -> Result<Vec<Vec<i32>>, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut out = Vec::new();
    while let Some(d) = read_i32le(&mut r).map_err(|e| e.to_string())? {
        let mut row = Vec::with_capacity(d as usize);
        for _ in 0..d {
            match read_i32le(&mut r).map_err(|e| e.to_string())? {
                Some(v) => row.push(v),
                None => return Err("truncated ivecs row".into()),
            }
        }
        out.push(row);
    }
    Ok(out)
}

/// Dispatch on file extension.
pub fn read_auto(path: &Path) -> Result<VecSet, String> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("fvecs") => read_fvecs(path),
        Some("bvecs") => read_bvecs(path),
        other => Err(format!("unsupported dataset extension {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gkmeans_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn fvecs_roundtrip() {
        let v = VecSet::from_flat(3, vec![1.0, 2.0, 3.0, -4.0, 5.5, 6.25]);
        let p = tmp("rt.fvecs");
        write_fvecs(&p, &v).unwrap();
        let back = read_fvecs(&p).unwrap();
        assert_eq!(v, back);
        let auto = read_auto(&p).unwrap();
        assert_eq!(v, auto);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1, 2, 3], vec![9]];
        let p = tmp("rt.ivecs");
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bvecs_read() {
        // hand-build a 2-vector bvecs file with d=2
        let p = tmp("x.bvecs");
        let mut bytes = Vec::new();
        for row in [[7u8, 200u8], [0u8, 255u8]] {
            bytes.extend(2i32.to_le_bytes());
            bytes.extend(row);
        }
        std::fs::write(&p, &bytes).unwrap();
        let v = read_bvecs(&p).unwrap();
        assert_eq!(v.rows(), 2);
        assert_eq!(v.row(0), &[7.0, 200.0]);
        assert_eq!(v.row(1), &[0.0, 255.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_and_inconsistent_errors() {
        let p = tmp("empty.fvecs");
        std::fs::write(&p, b"").unwrap();
        assert!(read_fvecs(&p).is_err());
        let mut bytes = Vec::new();
        bytes.extend(1i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p).unwrap_err().contains("inconsistent"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unsupported_extension() {
        assert!(read_auto(Path::new("/tmp/foo.csv")).is_err());
    }
}
