//! fvecs / bvecs / ivecs interchange I/O (TEXMEX / SIFT1M conventions).
//!
//! Format: each vector is `<d: i32 little-endian><d components>`, where a
//! component is `f32` (fvecs), `u8` (bvecs) or `i32` (ivecs).  These are
//! the formats the paper's datasets ship in, so real SIFT1M/GIST1M files
//! drop straight into the benchmarks.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::matrix::VecSet;

fn read_i32le(r: &mut impl Read) -> std::io::Result<Option<i32>> {
    let mut buf = [0u8; 4];
    match r.read_exact(&mut buf) {
        Ok(()) => Ok(Some(i32::from_le_bytes(buf))),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
        Err(e) => Err(e),
    }
}

/// Sanity cap on per-row dimension headers: anything above this is a
/// corrupt or garbage header, not a real dataset.
const MAX_DIM: usize = 1 << 20;

/// Validate a raw `i32` record header against the file's remaining
/// length, returning the dimension.  A negative, zero, implausibly
/// large, or beyond-EOF header is an error — never a panic or an OOM
/// allocation (`vec![0; d]` with `d` from a hostile file).
fn check_dim(
    d: i32,
    dim_so_far: usize,
    elem_bytes: u64,
    remaining: u64,
    path: &Path,
) -> Result<usize, String> {
    if d <= 0 || d as usize > MAX_DIM {
        return Err(format!("{}: implausible vector dim {d}", path.display()));
    }
    let d = d as usize;
    if dim_so_far != 0 && d != dim_so_far {
        return Err(format!(
            "{}: inconsistent dim: {d} vs {dim_so_far}",
            path.display()
        ));
    }
    if d as u64 * elem_bytes > remaining {
        return Err(format!(
            "{}: truncated record: header promises {d} components but only \
             {remaining} bytes remain",
            path.display()
        ));
    }
    Ok(d)
}

/// Read a `.fvecs` file into a `VecSet`.
pub fn read_fvecs(path: &Path) -> Result<VecSet, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut remaining = f.metadata().map_err(|e| e.to_string())?.len();
    let mut r = BufReader::new(f);
    let mut dim = 0usize;
    let mut data: Vec<f32> = Vec::new();
    while let Some(d) = read_i32le(&mut r).map_err(|e| e.to_string())? {
        remaining = remaining.saturating_sub(4);
        let d = check_dim(d, dim, 4, remaining, path)?;
        dim = d;
        let mut buf = vec![0u8; d * 4];
        r.read_exact(&mut buf).map_err(|e| e.to_string())?;
        remaining -= d as u64 * 4;
        for c in buf.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
    }
    if dim == 0 {
        return Err(format!("{}: empty fvecs file", path.display()));
    }
    Ok(VecSet::from_flat(dim, data))
}

/// Read a `.bvecs` file (u8 components, promoted to f32).
pub fn read_bvecs(path: &Path) -> Result<VecSet, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut remaining = f.metadata().map_err(|e| e.to_string())?.len();
    let mut r = BufReader::new(f);
    let mut dim = 0usize;
    let mut data: Vec<f32> = Vec::new();
    while let Some(d) = read_i32le(&mut r).map_err(|e| e.to_string())? {
        remaining = remaining.saturating_sub(4);
        let d = check_dim(d, dim, 1, remaining, path)?;
        dim = d;
        let mut buf = vec![0u8; d];
        r.read_exact(&mut buf).map_err(|e| e.to_string())?;
        remaining -= d as u64;
        data.extend(buf.iter().map(|&b| b as f32));
    }
    if dim == 0 {
        return Err(format!("{}: empty bvecs file", path.display()));
    }
    Ok(VecSet::from_flat(dim, data))
}

/// Write a `VecSet` as `.fvecs`.
pub fn write_fvecs(path: &Path, v: &VecSet) -> Result<(), String> {
    let f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    let d = v.dim() as i32;
    for i in 0..v.rows() {
        w.write_all(&d.to_le_bytes()).map_err(|e| e.to_string())?;
        for &x in v.row(i) {
            w.write_all(&x.to_le_bytes()).map_err(|e| e.to_string())?;
        }
    }
    w.flush().map_err(|e| e.to_string())
}

/// Write integer lists (e.g. KNN ground truth) as `.ivecs`.
pub fn write_ivecs(path: &Path, rows: &[Vec<i32>]) -> Result<(), String> {
    let f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut w = BufWriter::new(f);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes()).map_err(|e| e.to_string())?;
        for &x in row {
            w.write_all(&x.to_le_bytes()).map_err(|e| e.to_string())?;
        }
    }
    w.flush().map_err(|e| e.to_string())
}

/// Read `.ivecs` integer lists.
pub fn read_ivecs(path: &Path) -> Result<Vec<Vec<i32>>, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut out = Vec::new();
    while let Some(d) = read_i32le(&mut r).map_err(|e| e.to_string())? {
        if d < 0 || d as usize > MAX_DIM {
            return Err(format!("{}: implausible ivecs row length {d}", path.display()));
        }
        let mut row = Vec::with_capacity(d as usize);
        for _ in 0..d {
            match read_i32le(&mut r).map_err(|e| e.to_string())? {
                Some(v) => row.push(v),
                None => return Err("truncated ivecs row".into()),
            }
        }
        out.push(row);
    }
    Ok(out)
}

/// Dispatch on file extension.
pub fn read_auto(path: &Path) -> Result<VecSet, String> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("fvecs") => read_fvecs(path),
        Some("bvecs") => read_bvecs(path),
        other => Err(format!("unsupported dataset extension {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gkmeans_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn fvecs_roundtrip() {
        let v = VecSet::from_flat(3, vec![1.0, 2.0, 3.0, -4.0, 5.5, 6.25]);
        let p = tmp("rt.fvecs");
        write_fvecs(&p, &v).unwrap();
        let back = read_fvecs(&p).unwrap();
        assert_eq!(v, back);
        let auto = read_auto(&p).unwrap();
        assert_eq!(v, auto);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1, 2, 3], vec![9]];
        let p = tmp("rt.ivecs");
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bvecs_read() {
        // hand-build a 2-vector bvecs file with d=2
        let p = tmp("x.bvecs");
        let mut bytes = Vec::new();
        for row in [[7u8, 200u8], [0u8, 255u8]] {
            bytes.extend(2i32.to_le_bytes());
            bytes.extend(row);
        }
        std::fs::write(&p, &bytes).unwrap();
        let v = read_bvecs(&p).unwrap();
        assert_eq!(v.rows(), 2);
        assert_eq!(v.row(0), &[7.0, 200.0]);
        assert_eq!(v.row(1), &[0.0, 255.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_and_inconsistent_errors() {
        let p = tmp("empty.fvecs");
        std::fs::write(&p, b"").unwrap();
        assert!(read_fvecs(&p).is_err());
        let mut bytes = Vec::new();
        bytes.extend(1i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p).unwrap_err().contains("inconsistent"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unsupported_extension() {
        assert!(read_auto(Path::new("/tmp/foo.csv")).is_err());
    }

    #[test]
    fn truncated_fvecs_is_err_not_panic() {
        // header promises 4 components, payload holds only 2
        let p = tmp("trunc.fvecs");
        let mut bytes = Vec::new();
        bytes.extend(4i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p).unwrap_err().contains("truncated"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn garbage_headers_are_err_not_panic() {
        // a negative dim header used to wrap to a huge usize and abort on
        // allocation; now it is a clean Err
        for (name, header) in [("neg.fvecs", -7i32), ("zero.fvecs", 0), ("huge.fvecs", i32::MAX)] {
            let p = tmp(name);
            let mut bytes = Vec::new();
            bytes.extend(header.to_le_bytes());
            bytes.extend([0u8; 16]);
            std::fs::write(&p, &bytes).unwrap();
            assert!(
                read_fvecs(&p).unwrap_err().contains("implausible"),
                "{name}: header {header}"
            );
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn truncated_and_garbage_bvecs_are_err() {
        let p = tmp("trunc.bvecs");
        let mut bytes = Vec::new();
        bytes.extend(8i32.to_le_bytes());
        bytes.extend([1u8, 2, 3]); // 3 of 8 promised bytes
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_bvecs(&p).unwrap_err().contains("truncated"));
        let mut bytes = Vec::new();
        bytes.extend((-1i32).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_bvecs(&p).unwrap_err().contains("implausible"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn garbage_ivecs_length_is_err() {
        let p = tmp("bad.ivecs");
        let mut bytes = Vec::new();
        bytes.extend((-3i32).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_ivecs(&p).unwrap_err().contains("implausible"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn read_auto_surfaces_dim_mismatch() {
        // rows with different dims routed through the extension dispatcher
        let p = tmp("mismatch.fvecs");
        let mut bytes = Vec::new();
        bytes.extend(2i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2.0f32.to_le_bytes());
        bytes.extend(3i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes());
        bytes.extend(2.0f32.to_le_bytes());
        bytes.extend(3.0f32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_auto(&p).unwrap_err().contains("inconsistent dim"));
        std::fs::remove_file(&p).ok();
    }
}
