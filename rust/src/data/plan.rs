//! Locality-aware scan planning: who decides the *order* in which the
//! scan loops visit rows of a [`VecStore`].
//!
//! PR 3 made every fit run on the storage abstraction, but the shuffled
//! GK-means epoch scan, NN-Descent local joins, and 2M-tree subset reads
//! are *random-access*: correct on a
//! [`ChunkedVecStore`](crate::data::store::ChunkedVecStore), yet cache-
//! hostile — a globally shuffled epoch over a store whose cache holds a
//! small fraction of the chunks degenerates to ≈ one chunk read per
//! sample.  At the paper's headline scale (10M × 512-d) that is the
//! difference between hours and years of wall clock.
//!
//! The fix is the classic out-of-core trick (cluster-closure-style
//! grouping): **shuffle within chunk-aligned super-blocks and permute the
//! super-blocks across epochs**.  Every row is still visited exactly once
//! per epoch and the visit order still varies between epochs (the
//! stochastic ingredient the incremental optimizers need), but the scan
//! only switches chunks when it crosses a super-block boundary, so an
//! epoch costs one read per *chunk* instead of one read per *sample*.
//!
//! [`ScanPlan`] owns that decision.  It is built per fit from the store's
//! [`ScanGeometry`] and a user-facing [`ScanOrder`] knob (params /
//! `RunContext` / CLI `--scan-order`):
//!
//! * [`ScanOrder::Global`] — the historical full Fisher–Yates shuffle.
//!   On a resident [`VecSet`](crate::data::matrix::VecSet) this is the
//!   default and consumes the RNG identically to the pre-planner code,
//!   so resident fits stay **bit-identical** with planning off.
//! * [`ScanOrder::Superblock`] — chunk-aligned super-block order (the
//!   description above).  Ignored (falls back to Global) on stores with
//!   no chunk geometry: a resident store has no chunks to be kind to.
//! * [`ScanOrder::Auto`] — Superblock when the store exposes a geometry,
//!   Global otherwise.  What the engines use unless told otherwise.
//!
//! Besides epoch orders the plan also batches *subset* access patterns:
//! [`ScanPlan::order_subset`] groups an arbitrary row-id list by chunk
//! (2M-tree bisection reads), [`ScanPlan::order_pairs`] groups random row
//! pairs by their chunk pair (NN-Descent local joins), and
//! [`ScanPlan::shuffle_positions`] is the keyed super-block shuffle for
//! visit orders over a subset (the 2M-tree's BKM polish).  All of them
//! are no-ops under [`ScanOrder::Global`], so the resident path never
//! changes behavior.

use crate::data::store::VecStore;
use crate::util::rng::Rng;

/// User-facing epoch visit-order policy (params / `RunContext` / CLI
/// `--scan-order`).  See the [module docs](self) for the semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScanOrder {
    /// Superblock for stores with a chunk geometry, Global otherwise.
    #[default]
    Auto,
    /// Full global shuffle — the historical, cache-oblivious order.
    Global,
    /// Shuffle within chunk-aligned super-blocks, permute super-blocks.
    Superblock,
}

impl ScanOrder {
    /// Parse a CLI value (`auto` / `global` / `superblock`).
    pub fn parse(s: &str) -> Result<ScanOrder, String> {
        Ok(match s {
            "auto" => ScanOrder::Auto,
            "global" => ScanOrder::Global,
            "superblock" | "super-block" => ScanOrder::Superblock,
            other => {
                return Err(format!(
                    "unknown scan order {other:?} (expected auto|global|superblock)"
                ))
            }
        })
    }

    /// The CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            ScanOrder::Auto => "auto",
            ScanOrder::Global => "global",
            ScanOrder::Superblock => "superblock",
        }
    }
}

/// The chunk geometry a paged store exposes so the planner can align
/// super-blocks with its cache (see [`VecStore::scan_geometry`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScanGeometry {
    /// Rows per resident chunk.
    pub chunk_rows: usize,
    /// Resident-chunk budget per cursor.
    pub cache_chunks: usize,
}

impl ScanGeometry {
    /// Rows per super-block: the largest run of whole chunks that fits in
    /// one cursor's cache, so a super-block's chunks are each read from
    /// disk at most once while the scan shuffles freely inside it.
    pub fn superblock_rows(&self) -> usize {
        self.chunk_rows.max(1) * self.cache_chunks.max(1)
    }
}

/// A fit-time visit-order plan for one store (see the [module
/// docs](self)).  Cheap plain data — build one per fit and share it
/// across epochs and worker threads.
#[derive(Debug, Clone)]
pub struct ScanPlan {
    /// `Some(geometry)` ⇔ super-block planning is in effect.
    geometry: Option<ScanGeometry>,
}

impl ScanPlan {
    /// Resolve `order` against the store's geometry.
    pub fn new(store: &dyn VecStore, order: ScanOrder) -> ScanPlan {
        let geometry = match order {
            ScanOrder::Global => None,
            ScanOrder::Auto | ScanOrder::Superblock => store.scan_geometry(),
        };
        ScanPlan { geometry }
    }

    /// A plan that always produces the global order (no geometry).
    pub fn global() -> ScanPlan {
        ScanPlan { geometry: None }
    }

    /// Whether super-block planning is in effect.
    pub fn is_superblock(&self) -> bool {
        self.geometry.is_some()
    }

    /// Rows per super-block (1 super-block spanning everything when the
    /// plan is global — only meaningful when [`ScanPlan::is_superblock`]).
    fn superblock_rows(&self) -> usize {
        self.geometry.map(|g| g.superblock_rows()).unwrap_or(usize::MAX)
    }

    /// Produce this epoch's visit order over rows `0..order.len()`.
    ///
    /// Global: one Fisher–Yates shuffle of the existing `order` — exactly
    /// the RNG consumption of the historical epoch loops, so resident
    /// fits are bit-identical.  Superblock: rebuild `order` as a random
    /// permutation of super-blocks, each internally shuffled.
    pub fn shuffle_epoch(&self, order: &mut [usize], rng: &mut Rng) {
        let n = order.len();
        let sb = self.superblock_rows();
        if self.geometry.is_none() || sb >= n {
            rng.shuffle(order);
            return;
        }
        let nsb = n.div_ceil(sb);
        let mut blocks: Vec<usize> = (0..nsb).collect();
        rng.shuffle(&mut blocks);
        let mut pos = 0usize;
        for &b in &blocks {
            let lo = b * sb;
            let hi = (lo + sb).min(n);
            let seg = &mut order[pos..pos + (hi - lo)];
            for (t, slot) in seg.iter_mut().enumerate() {
                *slot = lo + t;
            }
            rng.shuffle(seg);
            pos += hi - lo;
        }
        debug_assert_eq!(pos, n);
    }

    /// Shuffle a visit order whose entries are *positions* into a subset,
    /// grouping by the super-block of the underlying row id (`row_of`).
    /// Global: plain shuffle (bit-identical RNG use).  Superblock: the
    /// positions are grouped by `row_of(pos) / superblock_rows`, the
    /// group order is permuted, and each group is shuffled internally —
    /// in place, with one transient copy of `order` (the 2M-tree's root
    /// bisection passes the whole dataset through here every polish
    /// sweep, so per-position allocations would dominate).
    pub fn shuffle_positions(
        &self,
        order: &mut [usize],
        row_of: impl Fn(usize) -> usize,
        rng: &mut Rng,
    ) {
        if self.geometry.is_none() {
            rng.shuffle(order);
            return;
        }
        let sb = self.superblock_rows().max(1);
        order.sort_unstable_by_key(|&p| row_of(p) / sb);
        // contiguous group ranges after the sort
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        let mut start = 0usize;
        for t in 1..=order.len() {
            if t == order.len() || row_of(order[t]) / sb != row_of(order[start]) / sb {
                ranges.push((start, t));
                start = t;
            }
        }
        rng.shuffle(&mut ranges);
        let sorted = order.to_vec();
        let mut pos = 0usize;
        for &(lo, hi) in &ranges {
            let seg = &mut order[pos..pos + (hi - lo)];
            seg.copy_from_slice(&sorted[lo..hi]);
            rng.shuffle(seg);
            pos += hi - lo;
        }
        debug_assert_eq!(pos, order.len());
    }

    /// Reorder a row-id subset ascending (≡ grouped by chunk) so a
    /// sweep over it reads each chunk at most once.  No-op when the plan
    /// is global, so the resident path keeps its historical order.
    pub fn order_subset(&self, idx: &mut [u32]) {
        if self.is_superblock() {
            idx.sort_unstable();
        }
    }

    /// Group row pairs by their (chunk, chunk) key so evaluating them in
    /// order keeps both operand chunks hot.  No-op when the plan is
    /// global — the caller's evaluation sequence is unchanged.
    pub fn order_pairs(&self, pairs: &mut [(u32, u32)]) {
        if let Some(g) = self.geometry {
            let cr = g.chunk_rows.max(1) as u32;
            pairs.sort_unstable_by_key(|&(a, b)| (a / cr, b / cr));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::VecSet;

    fn is_permutation(order: &[usize]) -> bool {
        let mut seen = vec![false; order.len()];
        for &i in order {
            if i >= order.len() || seen[i] {
                return false;
            }
            seen[i] = true;
        }
        true
    }

    fn chunked_plan(chunk_rows: usize, cache_chunks: usize) -> ScanPlan {
        ScanPlan {
            geometry: Some(ScanGeometry { chunk_rows, cache_chunks }),
        }
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for s in ["auto", "global", "superblock"] {
            assert_eq!(ScanOrder::parse(s).unwrap().name(), s);
        }
        assert_eq!(ScanOrder::parse("super-block").unwrap(), ScanOrder::Superblock);
        assert!(ScanOrder::parse("wat").is_err());
        assert_eq!(ScanOrder::default(), ScanOrder::Auto);
    }

    #[test]
    fn global_shuffle_is_bit_identical_to_plain_shuffle() {
        // the resident bit-identity contract: a global plan consumes the
        // RNG exactly like the historical `rng.shuffle(order)` epoch top
        let mut a: Vec<usize> = (0..257).collect();
        let mut b = a.clone();
        let mut ra = Rng::new(42);
        let mut rb = Rng::new(42);
        ScanPlan::global().shuffle_epoch(&mut a, &mut ra);
        rb.shuffle(&mut b);
        assert_eq!(a, b);
        assert_eq!(ra.next_u64(), rb.next_u64(), "RNG streams diverged");
    }

    #[test]
    fn resident_store_resolves_to_global() {
        let v = VecSet::from_flat(2, vec![0.0; 20]);
        assert!(!ScanPlan::new(&v, ScanOrder::Auto).is_superblock());
        assert!(!ScanPlan::new(&v, ScanOrder::Superblock).is_superblock());
        assert!(!ScanPlan::new(&v, ScanOrder::Global).is_superblock());
    }

    #[test]
    fn superblock_epoch_is_a_grouped_permutation() {
        let plan = chunked_plan(8, 3); // super-blocks of 24 rows
        let n = 200;
        let mut order = vec![0usize; n];
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            plan.shuffle_epoch(&mut order, &mut rng);
            assert!(is_permutation(&order));
            // within any run of 24 consecutive positions all rows come
            // from one super-block
            let sb = 24;
            let nsb = n.div_ceil(sb);
            let mut pos = 0;
            let mut seen_blocks = Vec::new();
            // reconstruct block sizes: blocks are [0,24), [24,48), ...
            // the epoch emits them contiguously in permuted order
            while pos < n {
                let block = order[pos] / sb;
                let len = if block + 1 == nsb { n - block * sb } else { sb };
                for &r in &order[pos..pos + len] {
                    assert_eq!(r / sb, block, "row {r} outside super-block {block}");
                }
                seen_blocks.push(block);
                pos += len;
            }
            let mut sorted = seen_blocks.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..nsb).collect::<Vec<_>>());
        }
    }

    #[test]
    fn superblock_epochs_permute_block_order() {
        let plan = chunked_plan(4, 4); // 16-row super-blocks
        let mut order = vec![0usize; 160];
        let mut rng = Rng::new(9);
        plan.shuffle_epoch(&mut order, &mut rng);
        let first: Vec<usize> = order.iter().map(|&r| r / 16).collect();
        plan.shuffle_epoch(&mut order, &mut rng);
        let second: Vec<usize> = order.iter().map(|&r| r / 16).collect();
        assert_ne!(first, second, "block order should vary across epochs");
    }

    #[test]
    fn tiny_dataset_degenerates_to_global() {
        // one super-block covers everything -> plain shuffle
        let plan = chunked_plan(64, 8);
        let mut a: Vec<usize> = (0..50).collect();
        let mut b = a.clone();
        let mut ra = Rng::new(3);
        let mut rb = Rng::new(3);
        plan.shuffle_epoch(&mut a, &mut ra);
        rb.shuffle(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn shuffle_positions_groups_by_row_superblock() {
        let plan = chunked_plan(4, 2); // 8-row super-blocks
        let subset: Vec<u32> = vec![33, 1, 9, 34, 2, 10, 0, 8];
        let mut order: Vec<usize> = (0..subset.len()).collect();
        let mut rng = Rng::new(5);
        plan.shuffle_positions(&mut order, |p| subset[p] as usize, &mut rng);
        assert!(is_permutation(&order));
        // positions with the same row super-block must be contiguous
        let keys: Vec<usize> = order.iter().map(|&p| subset[p] as usize / 8).collect();
        let mut seen = std::collections::HashSet::new();
        let mut last = usize::MAX;
        for k in keys {
            if k != last {
                assert!(seen.insert(k), "super-block {k} split across the order");
                last = k;
            }
        }
    }

    #[test]
    fn global_positions_match_plain_shuffle() {
        let mut a: Vec<usize> = (0..31).collect();
        let mut b = a.clone();
        let mut ra = Rng::new(11);
        let mut rb = Rng::new(11);
        ScanPlan::global().shuffle_positions(&mut a, |p| p * 3, &mut ra);
        rb.shuffle(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn order_subset_and_pairs() {
        let plan = chunked_plan(10, 2);
        let mut idx = vec![42u32, 7, 19, 3];
        plan.order_subset(&mut idx);
        assert_eq!(idx, vec![3, 7, 19, 42]);
        let mut pairs = vec![(35u32, 2u32), (5, 40), (12, 3), (4, 4)];
        plan.order_pairs(&mut pairs);
        let keys: Vec<(u32, u32)> = pairs.iter().map(|&(a, b)| (a / 10, b / 10)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "pairs not grouped by chunk pair");
        // global plan leaves both untouched
        let g = ScanPlan::global();
        let mut idx2 = vec![42u32, 7];
        g.order_subset(&mut idx2);
        assert_eq!(idx2, vec![42, 7]);
        let mut p2 = vec![(9u32, 1u32), (1, 9)];
        g.order_pairs(&mut p2);
        assert_eq!(p2, vec![(9, 1), (1, 9)]);
    }
}
