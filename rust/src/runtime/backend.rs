//! The [`Backend`] facade: every bulk distance operation in the library
//! goes through here, running either natively (register-tiled mini-GEMM)
//! or on the PJRT-compiled Pallas artifacts.
//!
//! The two paths compute the same math to f32 tolerance — integration
//! tests cross-check them — so algorithms are backend-agnostic and the
//! perf pass can compare them honestly.
//!
//! The PJRT variant only exists under the `pjrt` cargo feature; the
//! default offline build is dependency-free and [`Backend::pjrt`] returns
//! an error.  Multi-threaded callers (the `util::pool` execution layer)
//! always run the native kernels: PJRT dispatch has not been audited for
//! concurrent use, and the parallel paths construct `Backend::Native`
//! per worker rather than sharing an engine.

use std::path::Path;

use crate::core_ops::argmin::ArgminAcc;
use crate::core_ops::blockdist;
use crate::data::store::VecStore;
use crate::runtime::{RtError, RtResult};

#[cfg(feature = "pjrt")]
use crate::runtime::exec::{literal_f32_2d, pad_block, PAD_SENTINEL};
#[cfg(feature = "pjrt")]
use crate::runtime::pjrt::PjrtEngine;

/// Compute backend for bulk distance math.
#[derive(Debug)]
pub enum Backend {
    /// Pure-Rust path (always available).
    Native,
    /// PJRT path over AOT artifacts, with native fallback for shapes that
    /// have no artifact.
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtEngine),
}

impl Backend {
    /// The native backend.
    pub fn native() -> Backend {
        Backend::Native
    }

    /// PJRT backend over an artifact directory.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifact_dir: &Path) -> RtResult<Backend> {
        Ok(Backend::Pjrt(PjrtEngine::new(artifact_dir)?))
    }

    /// PJRT backend stub: this build was compiled without the `pjrt`
    /// feature, so the request always fails gracefully.
    #[cfg(not(feature = "pjrt"))]
    pub fn pjrt(_artifact_dir: &Path) -> RtResult<Backend> {
        Err(RtError::from(
            "PJRT support not compiled in (rebuild with `--features pjrt` and the xla crate available)",
        ))
    }

    /// PJRT if artifacts are present (and the feature is compiled in),
    /// native otherwise.
    pub fn auto() -> Backend {
        let dir = crate::runtime::artifact::default_dir();
        if dir.join("manifest.tsv").exists() {
            match Backend::pjrt(&dir) {
                Ok(b) => return b,
                Err(e) => crate::log_warn!("PJRT init failed ({e:#}); using native"),
            }
        }
        Backend::Native
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Whether routing a size-`m` batch through blocked execution is
    /// worthwhile.  §Perf: one PJRT dispatch costs ~0.7 ms on this box;
    /// the bisect entry does only `2·m·d` useful FLOPs per 256-row call,
    /// so PJRT lost to native at every realistic subset size (2M-tree
    /// init measured 2.31 s PJRT vs 0.94 s native at n=5000, d=128).
    /// Large thin batches therefore stay native; the PJRT win lives in
    /// the dense `block_l2`/`assign` tiles (2.4–3.2× native there).
    pub fn prefers_blocked(&self, m: usize) -> bool {
        #[cfg(feature = "pjrt")]
        {
            matches!(self, Backend::Pjrt(_)) && m >= 200_000
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = m;
            false
        }
    }

    /// Full `m × n` squared-L2 distance block: `x` is `m × d`, `y` is
    /// `n × d`, `out` is `m × n` row-major.
    pub fn block_l2(&self, x: &[f32], y: &[f32], d: usize, out: &mut [f32]) {
        match self {
            Backend::Native => blockdist::block_l2(x, y, d, out),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(engine) => {
                if let Err(e) = pjrt_block_l2(engine, x, y, d, out) {
                    crate::log_debug!("pjrt block_l2 fell back to native: {e:#}");
                    engine
                        .stats
                        .native_calls
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    blockdist::block_l2(x, y, d, out);
                }
            }
        }
    }

    /// Multi-threaded `m × n` distance block.  Always runs the native
    /// row-sharded kernel (PJRT dispatch is single-threaded by design, see
    /// the module docs); `threads <= 1` falls through to [`Backend::block_l2`]
    /// so the serial numbers are bit-identical to the historical path.
    pub fn block_l2_threaded(&self, x: &[f32], y: &[f32], d: usize, out: &mut [f32], threads: usize) {
        if threads <= 1 {
            self.block_l2(x, y, d, out);
        } else {
            blockdist::block_l2_parallel(x, y, d, out, threads);
        }
    }

    /// Closest-candidate search: returns per-row (best index, best sq-dist)
    /// over all `k` rows of `c` (flat `k × d`).
    pub fn assign_blocks(&self, x: &[f32], c: &[f32], d: usize, k: usize) -> ArgminAcc {
        let m = x.len() / d;
        let mut acc = ArgminAcc::new(m);
        match self {
            Backend::Native => {
                // tile candidates to keep the block in cache
                const CB: usize = 256;
                let mut block = vec![0f32; m.min(CB) * CB];
                let mut row0 = 0;
                while row0 < m {
                    let rows = (m - row0).min(CB);
                    let xb = &x[row0 * d..(row0 + rows) * d];
                    let mut base = 0;
                    while base < k {
                        let cols = (k - base).min(CB);
                        let cb = &c[base * d..(base + cols) * d];
                        let blk = &mut block[..rows * cols];
                        blockdist::block_l2(xb, cb, d, blk);
                        // fold with row offset
                        let mut sub = ArgminAcc::new(rows);
                        sub.fold_block(blk, cols, base as u32);
                        for r in 0..rows {
                            if sub.best[r] < acc.best[row0 + r] {
                                acc.best[row0 + r] = sub.best[r];
                                acc.idx[row0 + r] = sub.idx[r];
                            }
                        }
                        base += cols;
                    }
                    row0 += rows;
                }
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(engine) => {
                if let Err(e) = pjrt_assign(engine, x, c, d, k, &mut acc) {
                    crate::log_debug!("pjrt assign fell back to native: {e:#}");
                    engine
                        .stats
                        .native_calls
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let native = Backend::Native.assign_blocks(x, c, d, k);
                    acc = native;
                }
            }
        }
        acc
    }

    /// Batched candidate-set distance evaluation — the Alg. 2 inner-loop
    /// op: squared distances from one sample `x` (`xx = ‖x‖²`) to a
    /// gathered block of κ̃ candidate centroids with precomputed norms,
    /// through the [`crate::core_ops::dist::d2_batch`] mini-GEMM form.
    ///
    /// §Perf: native on both backends by design — candidate sets are
    /// κ-sized (tens of rows), far below the ~0.7 ms/dispatch PJRT
    /// crossover that already keeps [`Backend::pairwise_among`] native;
    /// batching *dispatches* (many samples per PJRT call) is the recorded
    /// open item, and this method is the seam it would slot into.
    pub fn candidate_d2(
        &self,
        x: &[f32],
        xx: f32,
        block: &[f32],
        norms: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        crate::core_ops::dist::d2_batch(x, xx, block, norms, d, out)
    }

    /// Two-means margins for Alg. 1: `out[t] = d(x_t, c0) − d(x_t, c1)`
    /// for the rows of `data` selected by `subset`.
    pub fn bisect_margins(
        &self,
        data: &dyn VecStore,
        subset: &[u32],
        c0: &[f32],
        c1: &[f32],
        out: &mut [f32],
    ) {
        match self {
            Backend::Native => {
                let mut cur = data.open();
                for (t, &i) in subset.iter().enumerate() {
                    let row = cur.row(i as usize);
                    out[t] = crate::core_ops::dist::d2(row, c0) - crate::core_ops::dist::d2(row, c1);
                }
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(engine) => {
                if let Err(e) = pjrt_bisect(engine, data, subset, c0, c1, out) {
                    crate::log_debug!("pjrt bisect fell back to native: {e:#}");
                    engine
                        .stats
                        .native_calls
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    Backend::Native.bisect_margins(data, subset, c0, c1, out);
                }
            }
        }
    }

    /// Pairwise distances among `rows` of `data` (the KNN-refinement
    /// in-cell scan).  `out` is `rows.len() × rows.len()`.
    ///
    /// §Perf: ξ-sized cells (≤64 rows) are overhead-dominated on PJRT —
    /// measured 2.2 GFLOP/s vs 10.6 native at 64×64×128 (one dispatch per
    /// cell ≈ 0.7 ms against ~0.15 ms of math) — so this op is native on
    /// both backends.  `pjrt_pairwise_small` remains available (and
    /// cross-checked in tests) for batched multi-cell dispatch if cells
    /// ever grow past the crossover.
    pub fn pairwise_among(&self, data: &dyn VecStore, rows: &[u32], out: &mut [f32]) {
        let d = data.dim();
        let mut cur = data.open();
        let mut gathered: Vec<f32> = Vec::with_capacity(rows.len() * d);
        for &i in rows {
            gathered.extend_from_slice(cur.row(i as usize));
        }
        blockdist::block_l2(&gathered, &gathered, d, out);
    }

    /// PJRT variant of [`Backend::pairwise_among`] (kept for the
    /// cross-check tests and as the dispatch point for future batched
    /// refinement; see §Perf note above).
    pub fn pairwise_among_pjrt(&self, data: &dyn VecStore, rows: &[u32], out: &mut [f32]) {
        let d = data.dim();
        let mut cur = data.open();
        let mut gathered: Vec<f32> = Vec::with_capacity(rows.len() * d);
        for &i in rows {
            gathered.extend_from_slice(cur.row(i as usize));
        }
        drop(cur);
        match self {
            Backend::Native => blockdist::block_l2(&gathered, &gathered, d, out),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(engine) => {
                if let Err(e) = pjrt_pairwise_small(engine, &gathered, rows.len(), d, out) {
                    crate::log_debug!("pjrt pairwise fell back to native: {e:#}");
                    engine
                        .stats
                        .native_calls
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    blockdist::block_l2(&gathered, &gathered, d, out);
                }
            }
        }
    }
}

// --- PJRT implementations ---------------------------------------------

#[cfg(feature = "pjrt")]
fn pjrt_block_l2(engine: &PjrtEngine, x: &[f32], y: &[f32], d: usize, out: &mut [f32]) -> RtResult<()> {
    let (bm, bn) = engine
        .block_shape("block_l2", d)
        .ok_or_else(|| RtError::msg(format!("no block_l2 artifact for d={d}")))?;
    let m = x.len() / d;
    let n = y.len() / d;
    if out.len() != m * n {
        return Err(RtError::from("out size mismatch"));
    }
    let mut row0 = 0;
    while row0 < m {
        let rows = (m - row0).min(bm);
        let xb = pad_block(x, d, row0, rows, bm, 0.0);
        let xl = literal_f32_2d(&xb, bm, d)?;
        let mut col0 = 0;
        while col0 < n {
            let cols = (n - col0).min(bn);
            let yb = pad_block(y, d, col0, cols, bn, PAD_SENTINEL);
            let yl = literal_f32_2d(&yb, bn, d)?;
            let outs = engine.run("block_l2", d, &[xl.clone(), yl])?;
            let block: Vec<f32> = outs[0].to_vec()?;
            for r in 0..rows {
                let dst = &mut out[(row0 + r) * n + col0..(row0 + r) * n + col0 + cols];
                dst.copy_from_slice(&block[r * bn..r * bn + cols]);
            }
            col0 += cols;
        }
        row0 += rows;
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_assign(engine: &PjrtEngine, x: &[f32], c: &[f32], d: usize, k: usize, acc: &mut ArgminAcc) -> RtResult<()> {
    let (bm, bn) = engine
        .block_shape("assign_argmin", d)
        .ok_or_else(|| RtError::msg(format!("no assign_argmin artifact for d={d}")))?;
    let m = x.len() / d;
    let mut row0 = 0;
    while row0 < m {
        let rows = (m - row0).min(bm);
        let xb = pad_block(x, d, row0, rows, bm, 0.0);
        let xl = literal_f32_2d(&xb, bm, d)?;
        let mut base = 0;
        while base < k {
            let cols = (k - base).min(bn);
            let cb = pad_block(c, d, base, cols, bn, PAD_SENTINEL);
            let cl = literal_f32_2d(&cb, bn, d)?;
            let outs = engine.run("assign_argmin", d, &[xl.clone(), cl])?;
            let idx: Vec<i32> = outs[0].to_vec()?;
            let dist: Vec<f32> = outs[1].to_vec()?;
            for r in 0..rows {
                let g = row0 + r;
                if dist[r] < acc.best[g] {
                    acc.best[g] = dist[r];
                    acc.idx[g] = base as u32 + idx[r] as u32;
                }
            }
            base += cols;
        }
        row0 += rows;
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_bisect(engine: &PjrtEngine, data: &dyn VecStore, subset: &[u32], c0: &[f32], c1: &[f32], out: &mut [f32]) -> RtResult<()> {
    let d = data.dim();
    let (bm, _) = engine
        .block_shape("bisect_assign", d)
        .ok_or_else(|| RtError::msg(format!("no bisect_assign artifact for d={d}")))?;
    let mut c2 = Vec::with_capacity(2 * d);
    c2.extend_from_slice(c0);
    c2.extend_from_slice(c1);
    let cl = literal_f32_2d(&c2, 2, d)?;
    let m = subset.len();
    let mut cur = data.open();
    let mut t0 = 0;
    while t0 < m {
        let rows = (m - t0).min(bm);
        let mut xb = vec![0f32; bm * d];
        for (r, &i) in subset[t0..t0 + rows].iter().enumerate() {
            cur.read_row_into(i as usize, &mut xb[r * d..(r + 1) * d]);
        }
        let xl = literal_f32_2d(&xb, bm, d)?;
        let outs = engine.run("bisect_assign", d, &[xl, cl.clone()])?;
        let margin: Vec<f32> = outs[1].to_vec()?;
        out[t0..t0 + rows].copy_from_slice(&margin[..rows]);
        t0 += rows;
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_pairwise_small(engine: &PjrtEngine, gathered: &[f32], m: usize, d: usize, out: &mut [f32]) -> RtResult<()> {
    let (bs, _) = engine
        .block_shape("block_l2_small", d)
        .ok_or_else(|| RtError::msg(format!("no block_l2_small artifact for d={d}")))?;
    if m > bs {
        return Err(RtError::msg(format!("cell of {m} exceeds small block {bs}")));
    }
    let xb = pad_block(gathered, d, 0, m, bs, 0.0);
    let yb = pad_block(gathered, d, 0, m, bs, PAD_SENTINEL);
    let xl = literal_f32_2d(&xb, bs, d)?;
    let yl = literal_f32_2d(&yb, bs, d)?;
    let outs = engine.run("block_l2_small", d, &[xl, yl])?;
    let block: Vec<f32> = outs[0].to_vec()?;
    for r in 0..m {
        out[r * m..(r + 1) * m].copy_from_slice(&block[r * bs..r * bs + m]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::VecSet;
    use crate::util::rng::Rng;

    #[test]
    fn native_assign_matches_bruteforce() {
        let mut rng = Rng::new(1);
        let d = 16;
        let (m, k) = (300, 37); // non-multiples of the tile size
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let acc = Backend::Native.assign_blocks(&x, &c, d, k);
        for i in 0..m {
            let xi = &x[i * d..(i + 1) * d];
            let mut best = f32::INFINITY;
            let mut bidx = 0u32;
            for j in 0..k {
                let dd = crate::core_ops::dist::d2(xi, &c[j * d..(j + 1) * d]);
                if dd < best {
                    best = dd;
                    bidx = j as u32;
                }
            }
            assert_eq!(acc.idx[i], bidx, "row {i}");
            assert!((acc.best[i] - best).abs() < 1e-3 * (1.0 + best));
        }
    }

    #[test]
    fn native_pairwise_among() {
        let mut rng = Rng::new(2);
        let flat: Vec<f32> = (0..20 * 4).map(|_| rng.normal()).collect();
        let data = VecSet::from_flat(4, flat);
        let rows: Vec<u32> = vec![3, 7, 11];
        let mut out = vec![0f32; 9];
        Backend::Native.pairwise_among(&data, &rows, &mut out);
        for (a, &ia) in rows.iter().enumerate() {
            for (b, &ib) in rows.iter().enumerate() {
                let want = crate::core_ops::dist::d2(data.row(ia as usize), data.row(ib as usize));
                assert!((out[a * 3 + b] - want).abs() < 1e-4 * (1.0 + want));
            }
        }
    }

    #[test]
    fn auto_backend_is_constructible() {
        // With or without artifacts this must return something usable.
        let b = Backend::auto();
        let x = vec![0.0f32; 8];
        let y = vec![1.0f32; 8];
        let mut out = vec![0f32; 4];
        b.block_l2(&x, &y, 4, &mut out);
        assert!(out.iter().all(|&v| (v - 4.0).abs() < 1e-4));
    }

    #[test]
    fn pjrt_unavailable_is_graceful_without_feature() {
        if cfg!(feature = "pjrt") {
            return; // behaviour depends on artifacts being present
        }
        let err = Backend::pjrt(std::path::Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    #[test]
    fn block_l2_threaded_matches_serial() {
        let mut rng = Rng::new(3);
        let (m, n, d) = (37, 23, 19);
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let mut a = vec![0f32; m * n];
        let mut b = vec![0f32; m * n];
        Backend::Native.block_l2(&x, &y, d, &mut a);
        Backend::Native.block_l2_threaded(&x, &y, d, &mut b, 3);
        assert_eq!(a, b, "threaded kernel must be bit-identical");
    }
}
