//! Literal marshalling and block-padding helpers for PJRT execution.
//!
//! The AOT artifacts have fixed block shapes (`bm × d`, `bn × d`); real
//! workloads rarely align, so callers pad the tail block.  Padding rows of
//! the *candidate* operand are filled with [`PAD_SENTINEL`] so their
//! distances come out astronomically large and never win an argmin/top-κ;
//! padding rows of the *query* operand are zeros and the caller discards
//! those output rows.
//!
//! The padding helpers are pure and always compiled; the `Literal`
//! constructors need the `xla` crate and exist only under the `pjrt`
//! feature.

/// Fill value for padded candidate rows.  Distance to any real point is
/// ≥ (1e9)² per component — far beyond any real squared distance while
/// staying comfortably inside f32 range even at d = 960 (~9.6e20 ≪ 3.4e38).
pub const PAD_SENTINEL: f32 = 1e9;

/// Copy `rows` rows of width `d` from `src` starting at row `row0` into a
/// `block_rows × d` buffer, padding the remainder with `fill`.
pub fn pad_block(src: &[f32], d: usize, row0: usize, rows: usize, block_rows: usize, fill: f32) -> Vec<f32> {
    debug_assert!(rows <= block_rows);
    let mut buf = vec![fill; block_rows * d];
    buf[..rows * d].copy_from_slice(&src[row0 * d..(row0 + rows) * d]);
    buf
}

/// Build an `rows × d` f32 literal from a flat slice.
#[cfg(feature = "pjrt")]
pub fn literal_f32_2d(flat: &[f32], rows: usize, d: usize) -> crate::runtime::RtResult<xla::Literal> {
    debug_assert_eq!(flat.len(), rows * d);
    Ok(xla::Literal::vec1(flat).reshape(&[rows as i64, d as i64])?)
}

/// Build a rank-1 i32 literal.
#[cfg(feature = "pjrt")]
pub fn literal_i32_1d(vals: &[i32]) -> crate::runtime::RtResult<xla::Literal> {
    Ok(xla::Literal::vec1(vals))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_block_copies_and_fills() {
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows, d=2
        let b = pad_block(&src, 2, 1, 2, 4, PAD_SENTINEL);
        assert_eq!(&b[..4], &[3.0, 4.0, 5.0, 6.0]);
        assert!(b[4..].iter().all(|&v| v == PAD_SENTINEL));
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn pad_block_exact_fit() {
        let src = vec![1.0, 2.0];
        let b = pad_block(&src, 2, 0, 1, 1, 0.0);
        assert_eq!(b, vec![1.0, 2.0]);
    }

    #[test]
    fn sentinel_dominates_any_real_distance() {
        // distance from origin to a sentinel row in d dims
        let d = 960f32;
        let dist = d * PAD_SENTINEL * PAD_SENTINEL;
        assert!(dist.is_finite());
        assert!(dist > 1e18);
    }
}
