//! PJRT CPU client + lazily-compiled executable cache (requires the
//! `pjrt` cargo feature, which brings the `xla` crate into the build).
//!
//! One [`PjrtEngine`] per process is plenty: executables are compiled on
//! first use of each `(entry, dim)` pair (XLA compilation is tens of ms —
//! far too slow for the hot loop, so the cache is the point), then reused
//! for every block of every clustering run.
//!
//! Thread-safety note for the parallel execution layer: the executable
//! cache is mutex-guarded, but the underlying PJRT client has not been
//! audited for concurrent dispatch, so the multi-threaded code paths
//! (`util::pool` consumers) always use the native kernels and never share
//! a [`PjrtEngine`] across workers.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::artifact::Manifest;
use crate::runtime::{RtError, RtResult};

impl From<xla::Error> for RtError {
    fn from(e: xla::Error) -> Self {
        RtError::msg(format!("{e}"))
    }
}

/// Counters for the §Perf accounting (shared, lock-free).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    /// PJRT executions performed.
    pub pjrt_calls: AtomicU64,
    /// Executable compilations (cache misses).
    pub compiles: AtomicU64,
    /// Native fallback block operations.
    pub native_calls: AtomicU64,
}

impl RuntimeStats {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.pjrt_calls.load(Ordering::Relaxed),
            self.compiles.load(Ordering::Relaxed),
            self.native_calls.load(Ordering::Relaxed),
        )
    }
}

/// PJRT CPU client with a compile-once executable cache.
pub struct PjrtEngine {
    client: PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, usize), std::sync::Arc<PjRtLoadedExecutable>>>,
    pub stats: RuntimeStats,
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.dir)
            .finish()
    }
}

impl PjrtEngine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> RtResult<PjrtEngine> {
        let manifest = Manifest::load(artifact_dir).map_err(RtError::from)?;
        let client = PjRtClient::cpu()
            .map_err(|e| RtError::from(e).context("creating PJRT CPU client"))?;
        crate::log_info!(
            "PJRT engine up: platform={} artifacts={} entries={}",
            client.platform_name(),
            artifact_dir.display(),
            manifest.by_key.len()
        );
        Ok(PjrtEngine { client, manifest, cache: Mutex::new(HashMap::new()), stats: RuntimeStats::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True if an artifact exists for this entry/dim.
    pub fn supports(&self, entry: &str, dim: usize) -> bool {
        self.manifest.get(entry, dim).is_some()
    }

    /// Block shape `(bm, bn)` of an entry, if present.
    pub fn block_shape(&self, entry: &str, dim: usize) -> Option<(usize, usize)> {
        self.manifest.get(entry, dim).map(|a| (a.bm, a.bn))
    }

    /// Get (compiling on first use) the executable for `(entry, dim)`.
    pub fn executable(&self, entry: &str, dim: usize) -> RtResult<std::sync::Arc<PjRtLoadedExecutable>> {
        let key = (entry.to_string(), dim);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&key) {
                return Ok(exe.clone());
            }
        }
        let art = self
            .manifest
            .get(entry, dim)
            .ok_or_else(|| RtError::msg(format!("no artifact for entry={entry} dim={dim}")))?;
        let proto = HloModuleProto::from_text_file(&art.path)
            .map_err(|e| RtError::from(e).context(format!("parsing HLO text {}", art.path.display())))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| RtError::from(e).context(format!("compiling {}", art.path.display())))?;
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        crate::log_debug!("compiled artifact {entry}_d{dim}");
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an entry with the given literals; returns the result tuple
    /// as a vector of literals (artifacts lower with `return_tuple=True`).
    pub fn run(&self, entry: &str, dim: usize, args: &[Literal]) -> RtResult<Vec<Literal>> {
        let exe = self.executable(entry, dim)?;
        self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
        let result = exe.execute::<Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}
