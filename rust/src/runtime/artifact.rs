//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! `manifest.tsv` columns: `entry  dim  bm  bn  outputs  file  sha256_12`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT-compiled entry point at a fixed shape.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Entry name: `block_l2`, `block_l2_small`, `assign_argmin`,
    /// `bisect_assign`, `centroid_update`.
    pub entry: String,
    /// Data dimensionality the artifact was lowered for.
    pub dim: usize,
    /// Row-block size of the first operand.
    pub bm: usize,
    /// Row-block size of the second operand (0 = non-matrix operand).
    pub bn: usize,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
    /// HLO text file (absolute).
    pub path: PathBuf,
}

/// All artifacts in a directory, keyed by `(entry, dim)`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub by_key: HashMap<(String, usize), Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.tsv`.  Errors if the file is missing/garbled;
    /// callers that want graceful degradation use [`Manifest::try_load`].
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.tsv");
        let text =
            std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut by_key = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() < 6 {
                return Err(format!("manifest line {}: expected 6+ cols", lineno + 1));
            }
            let parse = |s: &str, what: &str| -> Result<usize, String> {
                s.parse().map_err(|e| format!("manifest line {}: bad {what}: {e}", lineno + 1))
            };
            let art = Artifact {
                entry: cols[0].to_string(),
                dim: parse(cols[1], "dim")?,
                bm: parse(cols[2], "bm")?,
                bn: parse(cols[3], "bn")?,
                outputs: parse(cols[4], "outputs")?,
                path: dir.join(cols[5]),
            };
            if !art.path.exists() {
                return Err(format!("manifest references missing file {}", art.path.display()));
            }
            by_key.insert((art.entry.clone(), art.dim), art);
        }
        Ok(Manifest { by_key, dir: dir.to_path_buf() })
    }

    /// `None` (with a log line) instead of an error when unavailable.
    pub fn try_load(dir: &Path) -> Option<Manifest> {
        match Self::load(dir) {
            Ok(m) => Some(m),
            Err(e) => {
                crate::log_warn!("artifacts unavailable ({e}); falling back to native backend");
                None
            }
        }
    }

    pub fn get(&self, entry: &str, dim: usize) -> Option<&Artifact> {
        self.by_key.get(&(entry.to_string(), dim))
    }

    /// Dims available for a given entry.
    pub fn dims_for(&self, entry: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_key
            .keys()
            .filter(|(e, _)| e == entry)
            .map(|(_, d)| *d)
            .collect();
        v.sort_unstable();
        v
    }
}

/// Default artifacts directory: `$GKMEANS_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("GKMEANS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_dir(rows: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "gkmeans_manifest_{}_{:x}",
            std::process::id(),
            rows.len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("dummy.hlo.txt"), "HloModule m").unwrap();
        std::fs::write(dir.join("manifest.tsv"), rows).unwrap();
        dir
    }

    #[test]
    fn parses_rows() {
        let dir = write_dir("# header\nblock_l2\t128\t256\t256\t1\tdummy.hlo.txt\tabc\n");
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("block_l2", 128).unwrap();
        assert_eq!(a.bm, 256);
        assert_eq!(a.outputs, 1);
        assert_eq!(m.dims_for("block_l2"), vec![128]);
        assert!(m.get("block_l2", 64).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        let dir = write_dir("block_l2\t128\t256\t256\t1\tnope.hlo.txt\tabc\n");
        assert!(Manifest::load(&dir).unwrap_err().contains("missing file"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn try_load_absent_dir() {
        assert!(Manifest::try_load(Path::new("/definitely/not/here")).is_none());
    }

    #[test]
    fn bad_numeric_is_error() {
        let dir = write_dir("block_l2\tXX\t256\t256\t1\tdummy.hlo.txt\tabc\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
