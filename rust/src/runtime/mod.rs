//! Runtime: executes the AOT-compiled JAX/Pallas artifacts via PJRT, with
//! a native mirror for every operation.
//!
//! * [`artifact`] — `artifacts/manifest.tsv` discovery and parsing.
//! * [`pjrt`] — the PJRT CPU client and lazily-compiled executable cache
//!   (compiled only under the `pjrt` cargo feature; the default offline
//!   build is dependency-free and `Backend::pjrt` returns [`RtError`]).
//! * [`exec`] — literal marshalling and block padding helpers.
//! * [`backend`] — the [`Backend`] facade all algorithms call.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! xla_extension 0.5.1 backing the `xla` crate rejects jax ≥ 0.5 protos
//! with 64-bit instruction ids, while the text parser reassigns ids.
//! Python runs only at build time (`make artifacts`); this module is the
//! request path.

pub mod artifact;
pub mod backend;
pub mod exec;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::Backend;

/// Minimal runtime error (the in-tree substitute for `anyhow`, which is
/// unavailable in the offline dependency-free build).  Carries a single
/// human-readable message; context is prepended by callers.
#[derive(Debug, Clone)]
pub struct RtError(pub String);

impl RtError {
    /// Build an error from anything displayable.
    pub fn msg(m: impl std::fmt::Display) -> RtError {
        RtError(m.to_string())
    }

    /// Prepend context, anyhow-style: `e.context("compiling artifact")`.
    pub fn context(self, ctx: impl std::fmt::Display) -> RtError {
        RtError(format!("{ctx}: {}", self.0))
    }
}

impl std::fmt::Display for RtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

impl From<String> for RtError {
    fn from(s: String) -> Self {
        RtError(s)
    }
}

impl From<&str> for RtError {
    fn from(s: &str) -> Self {
        RtError(s.to_string())
    }
}

/// Result alias used throughout the runtime layer.
pub type RtResult<T> = Result<T, RtError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rterror_display_and_context() {
        let e = RtError::msg("boom").context("loading artifact");
        assert_eq!(format!("{e}"), "loading artifact: boom");
        // alternate formatting (used by the CLI's `{e:#}`) must not panic
        assert_eq!(format!("{e:#}"), "loading artifact: boom");
        let from_string: RtError = String::from("x").into();
        assert_eq!(from_string.0, "x");
    }
}
