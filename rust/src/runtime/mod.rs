//! Runtime: executes the AOT-compiled JAX/Pallas artifacts via PJRT, with
//! a native mirror for every operation.
//!
//! * [`artifact`] — `artifacts/manifest.tsv` discovery and parsing.
//! * [`pjrt`] — the PJRT CPU client and lazily-compiled executable cache.
//! * [`exec`] — literal marshalling and block padding helpers.
//! * [`backend`] — the [`Backend`] facade all algorithms call.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! xla_extension 0.5.1 backing the `xla` crate rejects jax ≥ 0.5 protos
//! with 64-bit instruction ids, while the text parser reassigns ids.
//! Python runs only at build time (`make artifacts`); this module is the
//! request path.

pub mod artifact;
pub mod backend;
pub mod exec;
pub mod pjrt;

pub use backend::Backend;
