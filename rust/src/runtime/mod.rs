//! Runtime: executes the AOT-compiled JAX/Pallas artifacts via PJRT, with
//! a native mirror for every operation.
//!
//! * [`artifact`] — `artifacts/manifest.tsv` discovery and parsing.
//! * [`pjrt`] — the PJRT CPU client and lazily-compiled executable cache
//!   (compiled only under the `pjrt` cargo feature; the default offline
//!   build is dependency-free and `Backend::pjrt` returns [`RtError`]).
//! * [`exec`] — literal marshalling and block padding helpers.
//! * [`backend`] — the [`Backend`] facade all algorithms call.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! xla_extension 0.5.1 backing the `xla` crate rejects jax ≥ 0.5 protos
//! with 64-bit instruction ids, while the text parser reassigns ids.
//! Python runs only at build time (`make artifacts`); this module is the
//! request path.

pub mod artifact;
pub mod backend;
pub mod exec;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::Backend;

/// What failed — the coarse, matchable classification carried by every
/// [`RtError`].  Most errors are [`Generic`](RtErrorKind::Generic);
/// the reliability layer (PR 6) adds kinds callers genuinely branch on:
/// artifact corruption (refuse to serve) and worker panics (batch
/// degraded, process alive).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RtErrorKind {
    /// A plain human-readable failure (the historical `RtError`).
    Generic,
    /// An on-disk artifact failed validation (bad checksum, truncated or
    /// out-of-bounds section).  `section` names the GKMODEL/GKCKPT
    /// section that failed, e.g. `"CENTROIDS"`.
    Corrupt { section: String },
    /// A pool worker panicked; the panic was contained at the pool
    /// boundary instead of unwinding through the caller.
    WorkerPanic,
}

/// Minimal runtime error (the in-tree substitute for `anyhow`, which is
/// unavailable in the offline dependency-free build).  Carries a typed
/// [`RtErrorKind`] plus a human-readable message; context is prepended
/// by callers.
#[derive(Debug, Clone)]
pub struct RtError {
    /// Matchable classification (most errors are `Generic`).
    pub kind: RtErrorKind,
    message: String,
}

impl RtError {
    /// Build a generic error from anything displayable.
    pub fn msg(m: impl std::fmt::Display) -> RtError {
        RtError { kind: RtErrorKind::Generic, message: m.to_string() }
    }

    /// Build a [`RtErrorKind::Corrupt`] error for a named artifact
    /// section, e.g. `RtError::corrupt("CENTROIDS", "CRC mismatch ...")`.
    pub fn corrupt(section: impl Into<String>, detail: impl std::fmt::Display) -> RtError {
        let section = section.into();
        RtError {
            message: format!("corrupt artifact ({section} section): {detail}"),
            kind: RtErrorKind::Corrupt { section },
        }
    }

    /// Build a [`RtErrorKind::WorkerPanic`] error from a panic payload.
    pub fn worker_panic(detail: impl std::fmt::Display) -> RtError {
        RtError { kind: RtErrorKind::WorkerPanic, message: format!("worker panicked: {detail}") }
    }

    /// Prepend context, anyhow-style: `e.context("compiling artifact")`.
    /// The kind is preserved.
    pub fn context(self, ctx: impl std::fmt::Display) -> RtError {
        RtError { kind: self.kind, message: format!("{ctx}: {}", self.message) }
    }

    /// The human-readable message (what [`Display`](std::fmt::Display)
    /// prints).
    pub fn message(&self) -> &str {
        &self.message
    }

    /// True iff this is a [`RtErrorKind::Corrupt`] error.
    pub fn is_corrupt(&self) -> bool {
        matches!(self.kind, RtErrorKind::Corrupt { .. })
    }
}

impl std::fmt::Display for RtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for RtError {}

impl From<String> for RtError {
    fn from(s: String) -> Self {
        RtError { kind: RtErrorKind::Generic, message: s }
    }
}

impl From<&str> for RtError {
    fn from(s: &str) -> Self {
        RtError::msg(s)
    }
}

/// Result alias used throughout the runtime layer.
pub type RtResult<T> = Result<T, RtError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rterror_display_and_context() {
        let e = RtError::msg("boom").context("loading artifact");
        assert_eq!(format!("{e}"), "loading artifact: boom");
        // alternate formatting (used by the CLI's `{e:#}`) must not panic
        assert_eq!(format!("{e:#}"), "loading artifact: boom");
        let from_string: RtError = String::from("x").into();
        assert_eq!(from_string.message(), "x");
        assert_eq!(from_string.kind, RtErrorKind::Generic);
    }

    #[test]
    fn typed_kinds_survive_context() {
        let e = RtError::corrupt("CENTROIDS", "CRC mismatch").context("loading model");
        assert_eq!(e.kind, RtErrorKind::Corrupt { section: "CENTROIDS".into() });
        assert!(e.is_corrupt());
        assert!(format!("{e}").contains("CENTROIDS"));
        assert!(format!("{e}").starts_with("loading model: "));
        let p = RtError::worker_panic("index out of bounds");
        assert_eq!(p.kind, RtErrorKind::WorkerPanic);
        assert!(format!("{p}").contains("index out of bounds"));
        assert!(!p.is_corrupt());
    }
}
