//! The parallel execution layer: scoped-thread helpers with zero external
//! dependencies (`rayon` is unavailable offline).
//!
//! Workers are `std::thread::scope` spawns over contiguous index ranges.
//! Spawn cost is a few tens of microseconds per worker — negligible at the
//! granularity this layer operates (whole GK-means epochs, NN-Descent
//! rounds, n×n distance blocks, 2M-tree subtree splits) — and scoped
//! lifetimes let workers borrow the dataset/graph/clustering directly,
//! without `Arc` plumbing.
//!
//! ## Determinism contract
//!
//! Every consumer in this crate shards work into contiguous ranges and
//! folds worker results back **in range order**, so a run with a fixed
//! `(seed, threads)` pair is fully reproducible.  `threads = 1` bypasses
//! spawning entirely; the callers additionally keep their historical
//! serial code on that path, so single-threaded results are bit-identical
//! to the pre-parallel implementation.
//!
//! ## Why gather-then-merge everywhere
//!
//! The hot structures (`KnnGraph`, `Clustering`, `DeltaCache`) are
//! deliberately plain — no locks, no atomics — because the single-thread
//! inner loops are the product.  Parallel phases therefore *read* a frozen
//! snapshot, collect their proposed writes into per-worker buffers, and a
//! serial fold applies them (re-validating where semantics demand it, e.g.
//! Δℐ > 0 re-checks in the GK-means commit).  That keeps every invariant
//! single-writer without poisoning the serial path with synchronization.

use std::ops::Range;

/// Resolve a requested worker count.
///
/// * `0` — auto: `GKMEANS_THREADS` env var if set, else the machine's
///   available parallelism.
/// * anything else passes through unchanged (`1` = serial).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("GKMEANS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `[0, n)` into at most `parts` near-equal contiguous ranges.
/// Empty ranges are never produced; `n = 0` yields no ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(n);
    let chunk = (n + parts - 1) / parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// Run `f(part_index, range)` over the ranges of `[0, n)` on up to
/// `threads` workers and collect the results **in range order**.
///
/// With one range (or `threads <= 1`) the closure runs on the caller's
/// thread — no spawn, no overhead, same code path as a plain loop.
pub fn par_map_chunks<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    let ranges = split_ranges(n, threads.max(1));
    if ranges.len() <= 1 {
        return ranges.into_iter().enumerate().map(|(t, r)| f(t, r)).collect();
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(t, r)| s.spawn(move || f(t, r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for (n, parts) in [(10usize, 3usize), (1, 8), (0, 4), (100, 1), (7, 7), (5, 100)] {
            let ranges = split_ranges(n, parts);
            let mut covered = 0;
            let mut prev_end = 0;
            for r in &ranges {
                assert_eq!(r.start, prev_end, "ranges must be contiguous");
                assert!(r.end > r.start, "no empty ranges");
                covered += r.end - r.start;
                prev_end = r.end;
            }
            assert_eq!(covered, n, "n={n} parts={parts}");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn par_map_chunks_matches_serial_fold() {
        let data: Vec<u64> = (0..1000).collect();
        let serial: u64 = data.iter().sum();
        for threads in [1usize, 2, 3, 8] {
            let partial = par_map_chunks(threads, data.len(), |_, r| {
                data[r].iter().sum::<u64>()
            });
            assert_eq!(partial.iter().sum::<u64>(), serial, "threads={threads}");
        }
    }

    #[test]
    fn par_map_chunks_preserves_range_order() {
        let parts = par_map_chunks(4, 100, |t, r| (t, r.start));
        for w in parts.windows(2) {
            assert!(w[0].1 < w[1].1, "results must come back in range order");
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn resolve_threads_passthrough_and_auto() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
        assert!(resolve_threads(0) >= 1, "auto resolves to at least one");
    }

    #[test]
    fn zero_items_runs_nothing() {
        let parts: Vec<usize> = par_map_chunks(4, 0, |_, r| r.len());
        assert!(parts.is_empty());
    }
}
