//! The parallel execution layer: scoped-thread helpers with zero external
//! dependencies (`rayon` is unavailable offline).
//!
//! Workers are `std::thread::scope` spawns over contiguous index ranges.
//! Spawn cost is a few tens of microseconds per worker — negligible at the
//! granularity this layer operates (whole GK-means epochs, NN-Descent
//! rounds, n×n distance blocks, 2M-tree subtree splits) — and scoped
//! lifetimes let workers borrow the dataset/graph/clustering directly,
//! without `Arc` plumbing.
//!
//! ## Determinism contract
//!
//! Every consumer in this crate shards work into contiguous ranges and
//! folds worker results back **in range order**, so a run with a fixed
//! `(seed, threads)` pair is fully reproducible.  `threads = 1` bypasses
//! spawning entirely; the callers additionally keep their historical
//! serial code on that path, so single-threaded results are bit-identical
//! to the pre-parallel implementation.
//!
//! ## Why gather-then-merge everywhere
//!
//! The hot structures (`KnnGraph`, `Clustering`, `DeltaCache`) are
//! deliberately plain — no locks, no atomics — because the single-thread
//! inner loops are the product.  Parallel phases therefore *read* a frozen
//! snapshot, collect their proposed writes into per-worker buffers, and a
//! serial fold applies them (re-validating where semantics demand it, e.g.
//! Δℐ > 0 re-checks in the GK-means commit).  That keeps every invariant
//! single-writer without poisoning the serial path with synchronization.

use std::ops::Range;

/// Resolve a requested worker count.
///
/// * `0` — auto: `GKMEANS_THREADS` env var if set, else the machine's
///   available parallelism.
/// * anything else passes through unchanged (`1` = serial).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("GKMEANS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `[0, n)` into at most `parts` near-equal contiguous ranges.
/// Empty ranges are never produced; `n = 0` yields no ranges.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(n);
    let chunk = (n + parts - 1) / parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// Run `f(part_index, range)` over the ranges of `[0, n)` on up to
/// `threads` workers and collect the results **in range order**.
///
/// With one range (or `threads <= 1`) the closure runs on the caller's
/// thread — no spawn, no overhead, same code path as a plain loop.
///
/// A worker panic is re-raised on the caller's thread with its original
/// payload (so the failure reads like a serial panic, not a generic
/// join error).  Callers that must survive worker panics — the serving
/// surface — use [`try_par_map_chunks`] instead.
pub fn par_map_chunks<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    match try_par_map_chunks(threads, n, f) {
        Ok(out) => out,
        Err((payload, _)) => std::panic::resume_unwind(payload),
    }
}

/// Panic payload + best-effort rendering of its message, as returned by
/// [`try_par_map_chunks`].
type PanicInfo = (Box<dyn std::any::Any + Send>, String);

/// Render a panic payload's message (`&str` / `String` payloads; the
/// overwhelmingly common case for `panic!`/`assert!`/`expect`).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// [`par_map_chunks`] with panic containment: a panicking worker (or a
/// panic on the caller-thread fast path) is caught at the pool boundary
/// and returned as `Err((payload, message))` instead of unwinding the
/// caller.  All workers are still joined first, so no borrowed data is
/// left aliased; results of non-panicking workers are discarded.
///
/// The caller decides whether to re-raise ([`par_map_chunks`] does) or
/// to degrade — e.g. convert to
/// [`RtError::worker_panic`](crate::runtime::RtError::worker_panic) and
/// keep serving.
pub fn try_par_map_chunks<T, F>(threads: usize, n: usize, f: F) -> Result<Vec<T>, PanicInfo>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let ranges = split_ranges(n, threads.max(1));
    if ranges.len() <= 1 {
        return ranges
            .into_iter()
            .enumerate()
            .map(|(t, r)| {
                catch_unwind(AssertUnwindSafe(|| f(t, r)))
                    .map_err(|p| { let m = panic_message(p.as_ref()); (p, m) })
            })
            .collect();
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(t, r)| s.spawn(move || f(t, r)))
            .collect();
        let mut out = Vec::with_capacity(handles.len());
        let mut panic: Option<PanicInfo> = None;
        for h in handles {
            match h.join() {
                Ok(v) => out.push(v),
                Err(p) if panic.is_none() => {
                    let m = panic_message(p.as_ref());
                    panic = Some((p, m));
                }
                Err(_) => {}
            }
        }
        match panic {
            Some(p) => Err(p),
            None => Ok(out),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for (n, parts) in [(10usize, 3usize), (1, 8), (0, 4), (100, 1), (7, 7), (5, 100)] {
            let ranges = split_ranges(n, parts);
            let mut covered = 0;
            let mut prev_end = 0;
            for r in &ranges {
                assert_eq!(r.start, prev_end, "ranges must be contiguous");
                assert!(r.end > r.start, "no empty ranges");
                covered += r.end - r.start;
                prev_end = r.end;
            }
            assert_eq!(covered, n, "n={n} parts={parts}");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn par_map_chunks_matches_serial_fold() {
        let data: Vec<u64> = (0..1000).collect();
        let serial: u64 = data.iter().sum();
        for threads in [1usize, 2, 3, 8] {
            let partial = par_map_chunks(threads, data.len(), |_, r| {
                data[r].iter().sum::<u64>()
            });
            assert_eq!(partial.iter().sum::<u64>(), serial, "threads={threads}");
        }
    }

    #[test]
    fn par_map_chunks_preserves_range_order() {
        let parts = par_map_chunks(4, 100, |t, r| (t, r.start));
        for w in parts.windows(2) {
            assert!(w[0].1 < w[1].1, "results must come back in range order");
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn resolve_threads_passthrough_and_auto() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
        assert!(resolve_threads(0) >= 1, "auto resolves to at least one");
    }

    #[test]
    fn zero_items_runs_nothing() {
        let parts: Vec<usize> = par_map_chunks(4, 0, |_, r| r.len());
        assert!(parts.is_empty());
    }

    #[test]
    fn try_par_map_chunks_contains_worker_panics() {
        for threads in [1usize, 4] {
            let r = try_par_map_chunks(threads, 100, |_, range| {
                if range.contains(&50) {
                    panic!("worker blew up at 50");
                }
                range.len()
            });
            let (_, msg) = r.err().expect("panic must be reported, threads={threads}");
            assert!(msg.contains("worker blew up"), "got {msg:?}");
        }
        // and the non-panicking path is unchanged
        let ok = try_par_map_chunks(4, 10, |_, r| r.len()).unwrap();
        assert_eq!(ok.iter().sum::<usize>(), 10);
    }

    #[test]
    #[should_panic(expected = "original payload")]
    fn par_map_chunks_reraises_original_payload() {
        par_map_chunks(3, 30, |_, r| {
            if r.start == 0 {
                panic!("original payload");
            }
            r.len()
        });
    }
}
