//! Wall-clock timing scopes and simple throughput accounting.

use std::time::Instant;

/// A running stopwatch; `elapsed_s()` at any time, `lap_s()` for splits.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
    last_lap: Instant,
}

impl Timer {
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last_lap: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap_s` (or construction), and reset lap.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last_lap).as_secs_f64();
        self.last_lap = now;
        dt
    }
}

/// Time a closure; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Pretty seconds: "1.23 s", "45.6 ms", "2m03s", "1h02m".
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else if s < 3600.0 {
        format!("{}m{:02.0}s", (s / 60.0) as u64, s % 60.0)
    } else {
        format!("{}h{:02.0}m", (s / 3600.0) as u64, (s % 3600.0) / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap = t.lap_s();
        assert!(lap >= 0.004, "lap={lap}");
        assert!(t.elapsed_s() >= lap * 0.5);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(0.0000005).contains("µs"));
        assert!(fmt_secs(0.05).contains("ms"));
        assert!(fmt_secs(5.0).contains("s"));
        assert_eq!(fmt_secs(125.0), "2m05s");
        assert_eq!(fmt_secs(3720.0), "1h02m");
    }
}
