//! Seedable, reproducible PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! Every stochastic component in the library (dataset synthesis, k-means
//! seeding, BKM visit order, random KNN-graph init, Mini-Batch sampling)
//! takes an explicit [`Rng`] or seed, so whole experiments are reproducible
//! from a single `u64`.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-module seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Snapshot the raw generator state (checkpointing).  Restoring the
    /// returned words with [`Rng::from_state`] continues the stream
    /// exactly where this generator left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    ///
    /// The state words are used verbatim (no SplitMix64 expansion), so
    /// this is only meant for round-tripping a live generator through a
    /// checkpoint — not for seeding (an all-zero state is degenerate and
    /// is remapped through [`Rng::new`]).
    pub fn from_state(s: [u64; 4]) -> Rng {
        if s == [0; 4] {
            return Rng::new(0);
        }
        Rng { s }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` (Lemire's method; `bound > 0`).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not a hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small m, shuffle prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} from {n}");
        if m * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(m);
            return all;
        }
        // Floyd: guarantees distinctness with O(m) expected work.
        let mut chosen = std::collections::HashSet::with_capacity(m * 2);
        let mut out = Vec::with_capacity(m);
        for j in (n - m)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f32_unit_interval_mean() {
        let mut r = Rng::new(4);
        let mean: f64 = (0..20_000).map(|_| r.f32() as f64).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn sample_indices_distinct_both_regimes() {
        let mut r = Rng::new(8);
        for (n, m) in [(100, 5), (100, 80), (10, 10), (1, 1)] {
            let s = r.sample_indices(n, m);
            assert_eq!(s.len(), m);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), m, "duplicates for n={n} m={m}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay, "restored stream must continue bit-identically");
        assert_ne!(Rng::from_state([0; 4]).next_u64(), 0, "zero state is remapped");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
