//! Dependency-free utilities: RNG, CLI parsing, config files, timing,
//! logging, and the scoped-thread parallel execution layer.
//!
//! This environment has no crates.io access in the default build, so the
//! usual suspects (`rand`, `clap`, `serde`, `env_logger`, `rayon`) are
//! replaced by these small, well-tested in-tree versions ([`rng`],
//! [`cli`], [`configfile`], [`logging`], [`pool`]).

pub mod cli;
pub mod configfile;
pub mod crc32;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod timer;
