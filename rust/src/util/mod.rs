//! Dependency-free utilities: RNG, CLI parsing, config files, timing, logging.
//!
//! The offline crate cache in this environment carries only the `xla`
//! dependency tree, so the usual suspects (`rand`, `clap`, `serde`,
//! `env_logger`) are replaced by these small, well-tested in-tree versions.

pub mod cli;
pub mod configfile;
pub mod logging;
pub mod rng;
pub mod timer;
