//! Leveled stderr logging with a process-global level.
//!
//! `GKMEANS_LOG=debug|info|warn|error` (default `info`).  Macros live at
//! crate root via `#[macro_export]`: `log_info!`, `log_warn!`, etc.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("GKMEANS_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level, lazily read from the environment.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// Override the level programmatically (used by `--quiet`/`--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True if a message at `l` should be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[macro_export]
macro_rules! log_at {
    ($lvl:expr, $tag:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($lvl) {
            eprintln!("[{:5}] {}", $tag, format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Error, "ERROR", $($arg)*) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Warn, "WARN", $($arg)*) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Info, "INFO", $($arg)*) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::log_at!($crate::util::logging::Level::Debug, "DEBUG", $($arg)*) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default-ish for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Debug);
        log_error!("e {}", 1);
        log_warn!("w {}", 2);
        log_info!("i {}", 3);
        log_debug!("d {}", 4);
        set_level(Level::Info);
    }
}
