//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial), table-driven and
//! dependency-free — the artifact-integrity checksum for GKMODEL /
//! GKCKPT sections.
//!
//! The table is built in a `const fn` at compile time, so there is no
//! runtime init and no `lazy_static`-style machinery.  [`Crc32`] is a
//! streaming hasher for sections that are produced incrementally (the
//! VECTORS section is streamed block-by-block and never resident);
//! [`crc32`] is the one-shot convenience.

/// Streaming CRC-32 hasher.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Feed bytes into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// The checksum of everything fed so far (the hasher stays usable).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vectors() {
        // the classic check values for CRC-32/ISO-HDLC
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 2654435761) as u8).collect();
        let whole = crc32(&data);
        for chunk in [1usize, 7, 64, 4096] {
            let mut h = Crc32::new();
            for c in data.chunks(chunk) {
                h.update(c);
            }
            assert_eq!(h.finish(), whole, "chunk size {chunk}");
        }
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = vec![0xA5u8; 257];
        let clean = crc32(&data);
        for pos in [0usize, 100, 256] {
            data[pos] ^= 0x01;
            assert_ne!(crc32(&data), clean, "flip at {pos} undetected");
            data[pos] ^= 0x01;
        }
    }
}
