//! `key = value` config files with `[section]` headers and `#` comments.
//!
//! A deliberately small substitute for serde+toml (unavailable offline):
//! enough to express experiment configs (`configs/*.conf`) for the
//! launcher and bench harnesses.  Keys are flattened to `section.key`.

use std::collections::BTreeMap;
use std::path::Path;

/// Flattened `section.key -> value` map.
#[derive(Debug, Clone, Default)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`: {raw:?}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(Config { map })
    }

    pub fn load(path: &Path) -> Result<Config, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Overlay `other` on top of `self` (other wins).
    pub fn merged(mut self, other: Config) -> Config {
        self.map.extend(other.map);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig5"          # quoted strings unquoted
[dataset]
kind = sift_like
n = 100000
[gkmeans]
kappa = 50
tau = 10
converge_eps = 0.001
enabled = yes
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "fig5");
        assert_eq!(c.str_or("dataset.kind", ""), "sift_like");
        assert_eq!(c.usize_or("dataset.n", 0), 100_000);
        assert_eq!(c.usize_or("gkmeans.kappa", 0), 50);
        assert!((c.f64_or("gkmeans.converge_eps", 0.0) - 0.001).abs() < 1e-12);
        assert!(c.bool_or("gkmeans.enabled", false));
    }

    #[test]
    fn defaults_and_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 3), 3);
        assert!(!c.bool_or("nope", false));
    }

    #[test]
    fn malformed_line_errors() {
        assert!(Config::parse("just a token").is_err());
    }

    #[test]
    fn merge_overlays() {
        let a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::parse("y = 3\nz = 4").unwrap();
        let m = a.merged(b);
        assert_eq!(m.usize_or("x", 0), 1);
        assert_eq!(m.usize_or("y", 0), 3);
        assert_eq!(m.usize_or("z", 0), 4);
    }
}
