//! Minimal argv parser: `subcommand --flag --key value --key=value pos...`.
//!
//! Replaces `clap` (unavailable offline).  Supports exactly what the
//! `gkmeans` launcher and the bench harnesses need: one optional
//! subcommand, long options with values, boolean flags, positionals, and
//! typed accessors with defaults.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-option token (if any).
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs (last occurrence wins).
    pub options: BTreeMap<String, String>,
    /// `--flag` tokens with no value.
    pub flags: Vec<String>,
    /// Remaining positional tokens.
    pub positionals: Vec<String>,
}

/// Option keys that take a value; anything else starting with `--` is a flag.
pub fn parse_with(valued: &[&str], argv: impl IntoIterator<Item = String>) -> Args {
    let mut out = Args::default();
    let mut iter = argv.into_iter().peekable();
    while let Some(tok) = iter.next() {
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if valued.contains(&stripped) {
                match iter.next() {
                    Some(v) => {
                        out.options.insert(stripped.to_string(), v);
                    }
                    None => {
                        out.flags.push(stripped.to_string());
                    }
                }
            } else {
                out.flags.push(stripped.to_string());
            }
        } else if out.subcommand.is_none() && out.options.is_empty() && out.flags.is_empty() {
            out.subcommand = Some(tok);
        } else {
            out.positionals.push(tok);
        }
    }
    out
}

/// Parse `std::env::args()` (skipping the binary name).
pub fn parse_env(valued: &[&str]) -> Args {
    parse_with(valued, std::env::args().skip(1))
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("warning: bad value for --{key}: {s:?}; using default");
                default
            }),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(|t| t.to_string())
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse_with(&["n", "k"], argv("cluster --n 1000 --k=64 --verbose extra"));
        assert_eq!(a.subcommand.as_deref(), Some("cluster"));
        assert_eq!(a.usize_or("n", 0), 1000);
        assert_eq!(a.usize_or("k", 0), 64);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse_with(&["x"], argv("--x 5 pos"));
        assert_eq!(a.subcommand, None);
        assert_eq!(a.usize_or("x", 0), 5);
        assert_eq!(a.positionals, vec!["pos"]);
    }

    #[test]
    fn last_occurrence_wins_and_defaults() {
        let a = parse_with(&["k"], argv("run --k 1 --k 2"));
        assert_eq!(a.usize_or("k", 9), 2);
        assert_eq!(a.usize_or("missing", 9), 9);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert_eq!(a.get_or("name", "d"), "d");
    }

    #[test]
    fn bad_value_falls_back() {
        let a = parse_with(&["k"], argv("run --k oops"));
        assert_eq!(a.usize_or("k", 7), 7);
    }

    #[test]
    fn valueless_valued_option_at_end_becomes_flag() {
        let a = parse_with(&["k"], argv("run --k"));
        assert!(a.flag("k"));
    }
}
