//! Cluster-job specification: which method, on what data, with what
//! parameters — the unit of work the pipeline executes and the benches
//! sweep over.

use crate::data::DatasetSpec;
use crate::kmeans::common::{IterStat, KmeansParams};

/// Clustering method selector (the 5 systems of Figs. 5–7 + the Fig. 4
/// configurations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Traditional k-means (Lloyd).
    Lloyd,
    /// Boost k-means [16].
    Boost,
    /// Mini-Batch k-means [20].
    MiniBatch,
    /// Closure k-means [27].
    Closure,
    /// GK-means (Alg. 2 + Alg. 3 graph).
    GkMeans,
    /// GK-means with the NN-Descent graph ("KGraph+GK-means").
    KGraphGkMeans,
    /// GK-means on a traditional k-means core ("GK-means*", Fig. 4).
    GkMeansTrad,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method, String> {
        Ok(match s {
            "lloyd" | "kmeans" => Method::Lloyd,
            "boost" | "bkm" => Method::Boost,
            "minibatch" | "mini-batch" => Method::MiniBatch,
            "closure" => Method::Closure,
            "gkmeans" | "gk" => Method::GkMeans,
            "kgraph-gkmeans" | "kgraph" => Method::KGraphGkMeans,
            "gkmeans-trad" | "gk-trad" => Method::GkMeansTrad,
            other => return Err(format!("unknown method {other:?}")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Lloyd => "k-means",
            Method::Boost => "boost k-means",
            Method::MiniBatch => "mini-batch",
            Method::Closure => "closure k-means",
            Method::GkMeans => "GK-means",
            Method::KGraphGkMeans => "KGraph+GK-means",
            Method::GkMeansTrad => "GK-means*",
        }
    }

    /// Stable on-disk tag (model serialization).  Append-only: tags are
    /// never reused or renumbered.
    pub fn tag(&self) -> u8 {
        match self {
            Method::Lloyd => 0,
            Method::Boost => 1,
            Method::MiniBatch => 2,
            Method::Closure => 3,
            Method::GkMeans => 4,
            Method::KGraphGkMeans => 5,
            Method::GkMeansTrad => 6,
        }
    }

    /// Inverse of [`Method::tag`].
    pub fn from_tag(tag: u8) -> Result<Method, String> {
        Ok(match tag {
            0 => Method::Lloyd,
            1 => Method::Boost,
            2 => Method::MiniBatch,
            3 => Method::Closure,
            4 => Method::GkMeans,
            5 => Method::KGraphGkMeans,
            6 => Method::GkMeansTrad,
            other => return Err(format!("unknown method tag {other}")),
        })
    }

    /// All methods in the paper's standard comparison order.
    pub fn all() -> &'static [Method] {
        &[
            Method::Lloyd,
            Method::Boost,
            Method::MiniBatch,
            Method::Closure,
            Method::GkMeans,
        ]
    }
}

/// One clustering job.
#[derive(Debug, Clone)]
pub struct ClusterJob {
    pub dataset: DatasetSpec,
    pub method: Method,
    pub k: usize,
    /// κ for the graph-driven methods.
    pub kappa: usize,
    /// τ for Alg. 3.
    pub tau: usize,
    /// ξ for Alg. 3.
    pub xi: usize,
    pub base: KmeansParams,
    /// Measure graph recall (costs an exact/sampled ground truth pass).
    pub measure_recall: bool,
    /// Retain the training vectors in the fitted model (ANN serving /
    /// `cluster --save` + `search --model`).
    pub keep_data: bool,
    /// Periodic epoch checkpointing: `(dir, every_n_epochs)` — CLI
    /// `--checkpoint DIR [--checkpoint-every N]`.
    pub checkpoint: Option<(std::path::PathBuf, usize)>,
    /// Resume from the checkpoint dir's `fit.gkckpt` (CLI `--resume`).
    pub resume: bool,
}

impl ClusterJob {
    pub fn new(dataset: DatasetSpec, method: Method, k: usize) -> ClusterJob {
        ClusterJob {
            dataset,
            method,
            k,
            kappa: 50,
            tau: 10,
            xi: 50,
            base: KmeansParams::default(),
            measure_recall: false,
            keep_data: false,
            checkpoint: None,
            resume: false,
        }
    }

    /// The typed [`Clusterer`](crate::model::Clusterer) config this job
    /// describes — the bridge from the CLI/bench job world into the
    /// fit → model API everything now routes through.
    pub fn clusterer(&self) -> Box<dyn crate::model::Clusterer> {
        use crate::model as m;
        match self.method {
            Method::Lloyd => Box::new(m::Lloyd::new(self.k)),
            Method::Boost => Box::new(m::Boost::new(self.k)),
            Method::MiniBatch => Box::new(m::MiniBatch::new(self.k)),
            Method::Closure => Box::new(m::ClosureKmeans::new(self.k)),
            Method::GkMeans => {
                Box::new(m::GkMeans::new(self.k).kappa(self.kappa).xi(self.xi).tau(self.tau))
            }
            Method::GkMeansTrad => {
                Box::new(m::GkMeansStar::new(self.k).kappa(self.kappa).xi(self.xi).tau(self.tau))
            }
            Method::KGraphGkMeans => Box::new(m::KGraphGkMeans::new(self.k).kappa(self.kappa)),
        }
    }

    /// The [`RunContext`](crate::model::RunContext) for this job's
    /// iteration-control fields on the given backend.  Every job gets a
    /// per-epoch heartbeat wired to the debug log level (`--verbose` in
    /// the CLI), firing live from inside the hooked fit loops.
    pub fn context<'a>(
        &self,
        backend: &'a crate::runtime::Backend,
    ) -> crate::model::RunContext<'a> {
        let mut ctx = crate::model::RunContext::new(backend)
            .threads(self.base.threads)
            .seed(self.base.seed)
            .max_iters(self.base.max_iters)
            .min_move_rate(self.base.min_move_rate)
            .keep_data(self.keep_data)
            .scan_order(self.base.scan_order)
            .on_progress(|name, h| {
                crate::log_debug!("{}", crate::coordinator::progress::progress_line(name, h));
            });
        if let Some((dir, every)) = &self.checkpoint {
            ctx = ctx.checkpoint(dir.clone(), *every);
        }
        ctx.resume(self.resume)
    }
}

/// Result of a job, with the columns Tab. 2 reports.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub method: Method,
    pub n: usize,
    pub dim: usize,
    pub k: usize,
    /// Initialization time (2M-tree / seeding / graph construction), s.
    pub init_seconds: f64,
    /// Iteration time, s.
    pub iter_seconds: f64,
    /// Total wall-clock, s.
    pub total_seconds: f64,
    /// Final average distortion ℰ.
    pub distortion: f64,
    /// Graph recall@1 (graph methods with `measure_recall`).
    pub recall: Option<f64>,
    /// Per-epoch history for the Fig. 5 curves.
    pub history: Vec<IterStat>,
}

impl JobResult {
    /// One formatted table row (Tab. 2 layout).
    pub fn table_row(&self) -> String {
        format!(
            "{:<18} {:>9.2} {:>9.2} {:>9.2} {:>12.4} {}",
            self.method.name(),
            self.init_seconds,
            self.iter_seconds,
            self.total_seconds,
            self.distortion,
            self.recall.map(|r| format!("{r:.3}")).unwrap_or_else(|| "N.A.".into()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("lloyd", Method::Lloyd),
            ("bkm", Method::Boost),
            ("minibatch", Method::MiniBatch),
            ("closure", Method::Closure),
            ("gkmeans", Method::GkMeans),
            ("kgraph", Method::KGraphGkMeans),
            ("gk-trad", Method::GkMeansTrad),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("wat").is_err());
    }

    #[test]
    fn tag_roundtrip_is_stable() {
        for (i, &m) in [
            Method::Lloyd,
            Method::Boost,
            Method::MiniBatch,
            Method::Closure,
            Method::GkMeans,
            Method::KGraphGkMeans,
            Method::GkMeansTrad,
        ]
        .iter()
        .enumerate()
        {
            assert_eq!(m.tag() as usize, i, "tags are append-only; never renumber");
            assert_eq!(Method::from_tag(m.tag()).unwrap(), m);
        }
        assert!(Method::from_tag(200).is_err());
    }

    #[test]
    fn job_clusterer_matches_method() {
        use crate::model::Clusterer;
        let j = ClusterJob::new(
            crate::data::DatasetSpec::Synth { kind: "blobs".into(), n: 10, seed: 1 },
            Method::GkMeansTrad,
            4,
        );
        assert_eq!(j.clusterer().method(), Method::GkMeansTrad);
    }

    #[test]
    fn table_row_formats() {
        let r = JobResult {
            method: Method::GkMeans,
            n: 10,
            dim: 2,
            k: 2,
            init_seconds: 1.0,
            iter_seconds: 2.0,
            total_seconds: 3.0,
            distortion: 0.5,
            recall: Some(0.62),
            history: vec![],
        };
        let row = r.table_row();
        assert!(row.contains("GK-means"));
        assert!(row.contains("0.620"));
        let r2 = JobResult { recall: None, ..r };
        assert!(r2.table_row().contains("N.A."));
    }
}
