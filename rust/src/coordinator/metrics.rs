//! Run metrics: distance-evaluation accounting and simple aggregates.
//!
//! The paper's complexity claims are in units of sample–centroid
//! comparisons; [`OpCounts`] tracks them so benches can report measured
//! operation counts next to wall-clock (robust against machine noise).

use std::sync::atomic::{AtomicU64, Ordering};

/// Global-ish operation counters (cheap, relaxed atomics).
#[derive(Debug, Default)]
pub struct OpCounts {
    /// Sample–centroid (or sample–composite) distance/dot evaluations.
    pub dist_evals: AtomicU64,
    /// Cluster-candidate sets examined.
    pub candidate_sets: AtomicU64,
    /// Moves applied.
    pub moves: AtomicU64,
}

impl OpCounts {
    pub fn new() -> OpCounts {
        OpCounts::default()
    }

    #[inline]
    pub fn add_dist(&self, n: u64) {
        self.dist_evals.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_moves(&self, n: u64) {
        self.moves.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.dist_evals.load(Ordering::Relaxed),
            self.candidate_sets.load(Ordering::Relaxed),
            self.moves.load(Ordering::Relaxed),
        )
    }
}

/// Online mean/min/max aggregate for repeated measurements.
#[derive(Debug, Clone, Default)]
pub struct Aggregate {
    pub count: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Aggregate {
    pub fn new() -> Aggregate {
        Aggregate { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = OpCounts::new();
        c.add_dist(5);
        c.add_dist(7);
        c.add_moves(1);
        let (d, _, m) = c.snapshot();
        assert_eq!(d, 12);
        assert_eq!(m, 1);
    }

    #[test]
    fn aggregate_stats() {
        let mut a = Aggregate::new();
        for v in [1.0, 2.0, 3.0] {
            a.push(v);
        }
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert!(Aggregate::new().mean().is_nan());
    }
}
