//! Run metrics: distance-evaluation accounting and simple aggregates.
//!
//! The paper's complexity claims are in units of sample–centroid
//! comparisons; [`OpCounts`] tracks them so benches can report measured
//! operation counts next to wall-clock (robust against machine noise).
//! The serving layer ([`crate::serve`]) reuses the same building blocks:
//! [`Histogram`] is the lock-cheap log-scale histogram behind its
//! latency/batch-size percentiles.

use std::sync::atomic::{AtomicU64, Ordering};

/// Global-ish operation counters (cheap, relaxed atomics).
#[derive(Debug, Default)]
pub struct OpCounts {
    /// Sample–centroid (or sample–composite) distance/dot evaluations.
    pub dist_evals: AtomicU64,
    /// Cluster-candidate sets examined.
    pub candidate_sets: AtomicU64,
    /// Moves applied.
    pub moves: AtomicU64,
}

impl OpCounts {
    pub fn new() -> OpCounts {
        OpCounts::default()
    }

    #[inline]
    pub fn add_dist(&self, n: u64) {
        self.dist_evals.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn add_moves(&self, n: u64) {
        self.moves.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.dist_evals.load(Ordering::Relaxed),
            self.candidate_sets.load(Ordering::Relaxed),
            self.moves.load(Ordering::Relaxed),
        )
    }
}

/// Online mean/min/max aggregate for repeated measurements.
#[derive(Debug, Clone, Default)]
pub struct Aggregate {
    pub count: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Aggregate {
    pub fn new() -> Aggregate {
        Aggregate { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Lock-cheap log₂-bucketed histogram of nonnegative integer
/// measurements (microsecond latencies, batch sizes): one relaxed
/// atomic increment per [`Histogram::record`], percentile queries read
/// the buckets without stopping writers.
///
/// Bucket `i` covers values in `[2^i, 2^(i+1))` (bucket 0 additionally
/// holds zero), so [`Histogram::percentile`] is exact to within a
/// factor of 2 — plenty for p50/p95/p99 serving dashboards, and it
/// never allocates or locks on the hot path.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    /// Bucket count: enough for the full `u64` range.
    const BUCKETS: usize = 64;

    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..Self::BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (u64::BITS - 1 - v.leading_zeros()) as usize
        }
    }

    /// Record one measurement.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Number of recorded measurements.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean of the recorded values (`NaN` when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            f64::NAN
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Largest recorded value (exact, not bucketed).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate `p`-th percentile (`p` in `[0, 1]`): the geometric
    /// midpoint of the bucket holding the `⌈p·count⌉`-th smallest
    /// sample.  Returns `NaN` when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = ((p.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                if i == 0 {
                    return 1.0;
                }
                // geometric midpoint of [2^i, 2^(i+1))
                let lo = (1u64 << i) as f64;
                return (lo * lo * 2.0).sqrt().min(self.max() as f64);
            }
        }
        self.max() as f64
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = OpCounts::new();
        c.add_dist(5);
        c.add_dist(7);
        c.add_moves(1);
        let (d, _, m) = c.snapshot();
        assert_eq!(d, 12);
        assert_eq!(m, 1);
    }

    #[test]
    fn aggregate_stats() {
        let mut a = Aggregate::new();
        for v in [1.0, 2.0, 3.0] {
            a.push(v);
        }
        assert_eq!(a.mean(), 2.0);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert!(Aggregate::new().mean().is_nan());
    }

    #[test]
    fn histogram_percentiles_bracket_the_data() {
        let h = Histogram::new();
        assert!(h.percentile(0.5).is_nan());
        assert!(h.mean().is_nan());
        // 100 samples: 1..=100 µs — p50 must land within 2× of 50
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.mean(), 50.5);
        assert_eq!(h.max(), 100);
        let p50 = h.percentile(0.5);
        assert!((25.0..=100.0).contains(&p50), "p50 estimate {p50}");
        let p99 = h.percentile(0.99);
        assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
        assert!(p99 <= 100.0, "p99 {p99} exceeds the exact max");
        // percentiles are monotone in p
        assert!(h.percentile(0.1) <= h.percentile(0.9));
    }

    #[test]
    fn histogram_handles_zero_and_large_values() {
        let h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(0.01), 1.0, "zero bucket reports ~1");
        assert_eq!(h.max(), u64::MAX);
        // concurrent-ish recording from several threads keeps totals
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for v in 0..1000u64 {
                        h.record(v);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
    }
}
