//! The end-to-end pipeline: dataset → (graph) → clustering → evaluation.
//!
//! Everything the CLI and the bench harnesses run goes through
//! [`run_job`], so the paper's tables/figures and the user-facing launcher
//! share one code path.

use crate::coordinator::job::{ClusterJob, JobResult, Method};
use crate::data::matrix::VecSet;
use crate::gkm::{construct, gkmeans, variant};
use crate::graph::{nn_descent, recall};
use crate::kmeans::{boost, closure, lloyd, minibatch};
use crate::runtime::Backend;
use crate::util::timer::Timer;

/// Execute a job end to end.
pub fn run_job(job: &ClusterJob, backend: &Backend) -> Result<JobResult, String> {
    let data = job.dataset.load()?;
    Ok(run_job_on(job, &data, backend))
}

/// Execute a job on an already-loaded dataset (benches reuse the data).
pub fn run_job_on(job: &ClusterJob, data: &VecSet, backend: &Backend) -> JobResult {
    let n = data.rows();
    let k = job.k.min(n);
    crate::log_info!(
        "job: {} on n={n} d={} k={k} ({})",
        job.method.name(),
        data.dim(),
        backend.name()
    );

    let (out, graph_seconds, recall_val) = match job.method {
        Method::Lloyd => (lloyd::run(data, k, &job.base, backend), 0.0, None),
        Method::Boost => (boost::run(data, k, &job.base, backend), 0.0, None),
        Method::MiniBatch => (
            minibatch::run(
                data,
                k,
                &minibatch::MiniBatchParams { base: job.base.clone(), ..Default::default() },
                backend,
            ),
            0.0,
            None,
        ),
        Method::Closure => (
            closure::run(
                data,
                k,
                &closure::ClosureParams { base: job.base.clone(), ..Default::default() },
                backend,
            ),
            0.0,
            None,
        ),
        Method::GkMeans | Method::GkMeansTrad => {
            let t = Timer::start();
            let build = construct::build(
                data,
                &construct::ConstructParams {
                    kappa: job.kappa,
                    xi: job.xi,
                    tau: job.tau,
                    seed: job.base.seed,
                    threads: job.base.threads,
                },
                backend,
            );
            let graph_seconds = t.elapsed_s();
            let params = gkmeans::GkMeansParams { kappa: job.kappa, base: job.base.clone() };
            let rec = job
                .measure_recall
                .then(|| measure_recall(data, &build.graph, job.base.seed, job.base.threads));
            let out = if job.method == Method::GkMeans {
                gkmeans::run(data, k, &build.graph, &params, backend)
            } else {
                variant::run(data, k, &build.graph, &params, backend)
            };
            (out, graph_seconds, rec)
        }
        Method::KGraphGkMeans => {
            let t = Timer::start();
            let graph = nn_descent::build(
                data,
                job.kappa,
                &nn_descent::NnDescentParams {
                    seed: job.base.seed,
                    threads: job.base.threads,
                    ..Default::default()
                },
            );
            let graph_seconds = t.elapsed_s();
            let rec = job
                .measure_recall
                .then(|| measure_recall(data, &graph, job.base.seed, job.base.threads));
            let params = gkmeans::GkMeansParams { kappa: job.kappa, base: job.base.clone() };
            let out = gkmeans::run(data, k, &graph, &params, backend);
            (out, graph_seconds, rec)
        }
    };

    let mut history = out.history.clone();
    for h in history.iter_mut() {
        h.seconds += graph_seconds; // graph time precedes every epoch
    }
    JobResult {
        method: job.method,
        n,
        dim: data.dim(),
        k,
        init_seconds: out.init_seconds + graph_seconds,
        iter_seconds: out.total_seconds - out.init_seconds,
        total_seconds: out.total_seconds + graph_seconds,
        distortion: out.distortion(),
        recall: recall_val,
        history,
    }
}

/// Top-1 recall (exact below 20K samples, 100-query sampled above —
/// the paper's VLAD10M protocol).  The exact ground-truth build is the
/// dominant cost and honors the job's `threads` knob.
fn measure_recall(data: &VecSet, graph: &crate::graph::knn::KnnGraph, seed: u64, threads: usize) -> f64 {
    if data.rows() <= 20_000 {
        let exact = crate::graph::brute::build_threaded(data, 1, &Backend::native(), threads);
        recall::recall_at_1(graph, &exact)
    } else {
        recall::sampled_recall_at_1(data, graph, 100, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;

    fn quick_job(method: Method) -> ClusterJob {
        let mut j = ClusterJob::new(
            DatasetSpec::Synth { kind: "blobs".into(), n: 400, seed: 5 },
            method,
            8,
        );
        j.kappa = 8;
        j.tau = 3;
        j.xi = 25;
        j.base.max_iters = 5;
        j
    }

    #[test]
    fn every_method_runs_end_to_end() {
        let b = Backend::native();
        for &m in &[
            Method::Lloyd,
            Method::Boost,
            Method::MiniBatch,
            Method::Closure,
            Method::GkMeans,
            Method::KGraphGkMeans,
            Method::GkMeansTrad,
        ] {
            let r = run_job(&quick_job(m), &b).unwrap();
            assert_eq!(r.n, 400);
            assert!(r.distortion.is_finite(), "{m:?}");
            assert!(r.total_seconds > 0.0);
            assert!(!r.history.is_empty());
        }
    }

    #[test]
    fn recall_measured_when_asked() {
        let b = Backend::native();
        let mut j = quick_job(Method::GkMeans);
        j.measure_recall = true;
        let r = run_job(&j, &b).unwrap();
        let rec = r.recall.expect("recall requested");
        assert!((0.0..=1.0).contains(&rec));
    }

    #[test]
    fn gkmeans_total_includes_graph_time() {
        let b = Backend::native();
        let r = run_job(&quick_job(Method::GkMeans), &b).unwrap();
        assert!(r.init_seconds > 0.0);
        assert!(r.total_seconds >= r.init_seconds);
    }
}
