//! The end-to-end pipeline: dataset → fit → [`FittedModel`] → evaluation.
//!
//! Everything the CLI and the bench harnesses run goes through
//! [`run_job`]/[`fit_job`], which route every method through the
//! [`Clusterer`] trait — the paper's tables/figures, the user-facing
//! launcher, and the model-artifact path share one code path.
//!
//! Time accounting: the [`FittedModel`] owns the single shared clock
//! (graph build + init + epochs, folded exactly once — see
//! [`FittedModel::check_time_accounting`]); [`JobResult`] is a plain
//! projection of it, so `total_seconds`, `init_seconds + iter_seconds`,
//! and the per-epoch history can never disagree.

use crate::coordinator::job::{ClusterJob, JobResult};
use crate::data::store::VecStore;
use crate::graph::recall;
use crate::model::{Clusterer, FittedModel};
use crate::runtime::{Backend, RtError, RtResult};

/// Execute a job end to end with the dataset materialized in RAM (see
/// [`run_job_streaming`] for the out-of-core path).  Dataset failures
/// (bad path, truncated file) surface as typed [`RtError`]s rather than
/// panics — the CLI turns them into nonzero exits.
pub fn run_job(job: &ClusterJob, backend: &Backend) -> RtResult<JobResult> {
    let data = job
        .dataset
        .load()
        .map_err(|e| RtError::msg(e).context(format!("loading dataset {:?}", job.dataset)))?;
    Ok(run_job_on(job, &data, backend))
}

/// [`run_job`] without materializing the dataset: file-backed specs
/// stream from disk through the storage layer.
pub fn run_job_streaming(job: &ClusterJob, backend: &Backend) -> RtResult<JobResult> {
    let data = job
        .dataset
        .open_store()
        .map_err(|e| RtError::msg(e).context(format!("opening dataset {:?}", job.dataset)))?;
    Ok(run_job_on(job, data.as_ref(), backend))
}

/// Execute a job on an already-opened store (benches reuse the data).
pub fn run_job_on(job: &ClusterJob, data: &dyn VecStore, backend: &Backend) -> JobResult {
    let (model, rec) = fit_job(job, data, backend);
    result_from_model(&model, rec)
}

/// Fit the job's [`Clusterer`](crate::model::Clusterer) and measure graph
/// recall when the job asks for it.  The CLI calls this directly when it
/// needs the artifact itself (`cluster --save`).
pub fn fit_job(
    job: &ClusterJob,
    data: &dyn VecStore,
    backend: &Backend,
) -> (FittedModel, Option<f64>) {
    crate::log_info!(
        "job: {} on n={} d={} k={} ({}{})",
        job.method.name(),
        data.rows(),
        data.dim(),
        job.k.min(data.rows()),
        backend.name(),
        if data.as_vecset().is_some() { "" } else { ", out-of-core" }
    );
    let model = job.clusterer().fit_store(data, &job.context(backend));
    debug_assert_eq!(model.check_time_accounting(), Ok(()));
    let rec = if job.measure_recall {
        model
            .graph
            .as_ref()
            .map(|g| measure_recall(data, g, job.base.seed, job.base.threads))
    } else {
        None
    };
    (model, rec)
}

/// Project a fitted model onto the Tab. 2-style [`JobResult`] columns.
pub fn result_from_model(model: &FittedModel, recall: Option<f64>) -> JobResult {
    JobResult {
        method: model.method,
        n: model.n_train,
        dim: model.dim,
        k: model.k,
        init_seconds: model.init_seconds,
        iter_seconds: model.iter_seconds(),
        total_seconds: model.total_seconds,
        distortion: model.distortion(),
        recall,
        history: model.history.clone(),
    }
}

/// Top-1 recall (exact below 20K samples, 100-query sampled above —
/// the paper's VLAD10M protocol).  The exact ground-truth build is the
/// dominant cost and honors the job's `threads` knob.
fn measure_recall(
    data: &dyn VecStore,
    graph: &crate::graph::knn::KnnGraph,
    seed: u64,
    threads: usize,
) -> f64 {
    if data.rows() <= 20_000 {
        let exact = crate::graph::brute::build_threaded(data, 1, &Backend::native(), threads);
        recall::recall_at_1(graph, &exact)
    } else {
        recall::sampled_recall_at_1(data, graph, 100, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Method;
    use crate::data::DatasetSpec;

    fn quick_job(method: Method) -> ClusterJob {
        let mut j = ClusterJob::new(
            DatasetSpec::Synth { kind: "blobs".into(), n: 400, seed: 5 },
            method,
            8,
        );
        j.kappa = 8;
        j.tau = 3;
        j.xi = 25;
        j.base.max_iters = 5;
        j
    }

    #[test]
    fn every_method_runs_end_to_end() {
        let b = Backend::native();
        for &m in &[
            Method::Lloyd,
            Method::Boost,
            Method::MiniBatch,
            Method::Closure,
            Method::GkMeans,
            Method::KGraphGkMeans,
            Method::GkMeansTrad,
        ] {
            let r = run_job(&quick_job(m), &b).unwrap();
            assert_eq!(r.n, 400);
            assert_eq!(r.method, m);
            assert!(r.distortion.is_finite(), "{m:?}");
            assert!(r.total_seconds > 0.0);
            assert!(!r.history.is_empty());
        }
    }

    #[test]
    fn recall_measured_when_asked() {
        let b = Backend::native();
        let mut j = quick_job(Method::GkMeans);
        j.measure_recall = true;
        let r = run_job(&j, &b).unwrap();
        let rec = r.recall.expect("recall requested");
        assert!((0.0..=1.0).contains(&rec));
        // non-graph methods have no graph to measure: no recall, no panic
        let mut j = quick_job(Method::Lloyd);
        j.measure_recall = true;
        assert!(run_job(&j, &b).unwrap().recall.is_none());
    }

    #[test]
    fn gkmeans_total_includes_graph_time() {
        let b = Backend::native();
        let job = quick_job(Method::GkMeans);
        let data = job.dataset.load().unwrap();
        let (model, _) = fit_job(&job, &data, &b);
        // the model-level contract: one shared clock, graph time folded
        // exactly once
        model.check_time_accounting().unwrap();
        assert!(model.graph_seconds > 0.0);
        let r = result_from_model(&model, None);
        // projection-level identities: totals and per-epoch history agree
        assert!(r.init_seconds >= model.graph_seconds);
        assert!(r.total_seconds >= r.init_seconds);
        assert!(
            (r.init_seconds + r.iter_seconds - r.total_seconds).abs() <= 1e-9,
            "init {} + iter {} != total {}",
            r.init_seconds,
            r.iter_seconds,
            r.total_seconds
        );
        let first = r.history.first().unwrap();
        let last = r.history.last().unwrap();
        assert!(
            first.seconds >= model.graph_seconds,
            "history clock must start after the graph build"
        );
        assert!(
            last.seconds <= r.total_seconds + 1e-9,
            "history {}s overran total {}s: graph time counted twice",
            last.seconds,
            r.total_seconds
        );
        for w in r.history.windows(2) {
            assert!(w[1].seconds + 1e-9 >= w[0].seconds, "history clock not monotone");
        }
    }

    #[test]
    fn job_result_is_pure_projection_of_model() {
        let b = Backend::native();
        let job = quick_job(Method::KGraphGkMeans);
        let data = job.dataset.load().unwrap();
        let (model, _) = fit_job(&job, &data, &b);
        let r = result_from_model(&model, None);
        assert_eq!(r.k, model.k);
        assert_eq!(r.history.len(), model.history.len());
        assert_eq!(r.distortion, model.distortion());
        assert_eq!(r.total_seconds, model.total_seconds);
    }
}
