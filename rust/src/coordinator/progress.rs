//! Convergence detection and iteration-progress reporting.

use crate::kmeans::common::IterStat;

/// Sliding-window convergence detector: declares convergence when the
/// relative distortion improvement over the last `window` epochs falls
/// below `eps` (the "changes very little after 30 iterations" criterion
/// the paper uses to fix iteration counts).
#[derive(Debug, Clone)]
pub struct Convergence {
    pub window: usize,
    pub eps: f64,
}

impl Default for Convergence {
    fn default() -> Self {
        Convergence { window: 3, eps: 1e-4 }
    }
}

impl Convergence {
    /// True if the history has converged under this criterion.
    pub fn converged(&self, history: &[IterStat]) -> bool {
        if history.len() <= self.window {
            return false;
        }
        let cur = history[history.len() - 1].distortion;
        let past = history[history.len() - 1 - self.window].distortion;
        if past <= 0.0 {
            return true;
        }
        (past - cur) / past < self.eps
    }

    /// Index of the first epoch at which the run was converged, if any.
    pub fn first_converged(&self, history: &[IterStat]) -> Option<usize> {
        (0..=history.len()).find(|&t| self.converged(&history[..t]))
    }
}

/// Render a compact progress line for an epoch.
pub fn progress_line(tag: &str, h: &IterStat) -> String {
    format!(
        "{tag} iter={:>3} t={:>8} E={:<12.5} moves={}",
        h.iter,
        crate::util::timer::fmt_secs(h.seconds),
        h.distortion,
        h.moves
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(ds: &[f64]) -> Vec<IterStat> {
        ds.iter()
            .enumerate()
            .map(|(i, &d)| IterStat { iter: i, seconds: i as f64, distortion: d, moves: 0 })
            .collect()
    }

    #[test]
    fn detects_plateau() {
        let c = Convergence { window: 2, eps: 1e-3 };
        assert!(!c.converged(&hist(&[10.0, 5.0, 2.0])));
        assert!(c.converged(&hist(&[10.0, 5.0, 5.0, 4.9999, 4.9999])));
    }

    #[test]
    fn short_history_not_converged() {
        let c = Convergence::default();
        assert!(!c.converged(&hist(&[1.0])));
        assert!(!c.converged(&[]));
    }

    #[test]
    fn first_converged_index() {
        let c = Convergence { window: 1, eps: 1e-3 };
        let h = hist(&[10.0, 5.0, 5.0, 5.0]);
        assert_eq!(c.first_converged(&h), Some(3));
        assert_eq!(c.first_converged(&hist(&[10.0, 1.0])), None);
    }

    #[test]
    fn progress_line_contains_fields() {
        let l = progress_line("bkm", &IterStat { iter: 7, seconds: 1.0, distortion: 0.5, moves: 3 });
        assert!(l.contains("iter=  7") && l.contains("moves=3"));
    }
}
