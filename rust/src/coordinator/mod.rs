//! The coordinator: job specifications, the end-to-end pipeline that the
//! CLI and bench harnesses drive, and run metrics.

pub mod job;
pub mod metrics;
pub mod pipeline;
pub mod progress;
