//! Bounded top-κ (smallest distance) selection.
//!
//! `TopK` is the per-node neighbor-list builder used by graph refinement
//! and brute-force ground truth: a bounded max-heap keyed on distance so
//! the current worst of the κ best sits at the root and most candidates
//! are rejected with one comparison.

/// One (distance, id) candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub dist: f32,
    pub id: u32,
}

/// Bounded max-heap of the κ smallest-distance neighbors seen so far.
#[derive(Debug, Clone)]
pub struct TopK {
    cap: usize,
    // binary max-heap by dist (root = worst kept)
    heap: Vec<Neighbor>,
}

impl TopK {
    pub fn new(cap: usize) -> TopK {
        assert!(cap > 0);
        TopK { cap, heap: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current acceptance threshold: below this, `push` will keep the item.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.cap {
            f32::INFINITY
        } else {
            self.heap[0].dist
        }
    }

    /// Offer a candidate; returns true if it was kept.
    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) -> bool {
        if self.heap.len() < self.cap {
            self.heap.push(Neighbor { dist, id });
            self.sift_up(self.heap.len() - 1);
            true
        } else if dist < self.heap[0].dist {
            self.heap[0] = Neighbor { dist, id };
            self.sift_down(0);
            true
        } else {
            false
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let p = (i - 1) / 2;
            if self.heap[i].dist > self.heap[p].dist {
                self.heap.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut big = i;
            if l < n && self.heap[l].dist > self.heap[big].dist {
                big = l;
            }
            if r < n && self.heap[r].dist > self.heap[big].dist {
                big = r;
            }
            if big == i {
                return;
            }
            self.heap.swap(i, big);
            i = big;
        }
    }

    /// Drain into ascending-distance order.
    pub fn into_sorted(mut self) -> Vec<Neighbor> {
        self.heap
            .sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.id.cmp(&b.id)));
        self.heap
    }

    /// Peek contents unsorted (for tests / merging).
    pub fn as_slice(&self) -> &[Neighbor] {
        &self.heap
    }
}

/// Select indices of the κ smallest values of `vals` (ascending), stable on
/// ties by index.  Convenience for small dense rows.
pub fn topk_indices(vals: &[f32], k: usize) -> Vec<usize> {
    let mut t = TopK::new(k.min(vals.len()).max(1));
    for (i, &v) in vals.iter().enumerate() {
        t.push(v, i as u32);
    }
    t.into_sorted().into_iter().map(|n| n.id as usize).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 0.5, 9.0, 2.0].iter().enumerate() {
            t.push(*d, i as u32);
        }
        let got: Vec<u32> = t.into_sorted().iter().map(|n| n.id).collect();
        assert_eq!(got, vec![3, 1, 5]); // dists 0.5, 1.0, 2.0
    }

    #[test]
    fn threshold_semantics() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), f32::INFINITY);
        t.push(3.0, 0);
        t.push(1.0, 1);
        assert_eq!(t.threshold(), 3.0);
        assert!(!t.push(5.0, 2), "worse than threshold rejected");
        assert!(t.push(2.0, 3));
        assert_eq!(t.threshold(), 2.0);
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let n = 1 + rng.below(200);
            let k = 1 + rng.below(20);
            let vals: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let got = topk_indices(&vals, k);
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap().then(a.cmp(&b)));
            want.truncate(k.min(n));
            assert_eq!(got, want, "n={n} k={k}");
        }
    }

    #[test]
    fn fewer_than_k_items() {
        let got = topk_indices(&[2.0, 1.0], 10);
        assert_eq!(got, vec![1, 0]);
    }
}
