//! Tile-reducing argmin over distance blocks.
//!
//! The Lloyd/assignment path computes distances block-by-block (native or
//! PJRT) and folds each `m × n` block into running per-row minima; this
//! module owns that fold so both backends share it.

/// Running (best distance, best index) per query row.
#[derive(Debug, Clone)]
pub struct ArgminAcc {
    pub best: Vec<f32>,
    pub idx: Vec<u32>,
}

impl ArgminAcc {
    pub fn new(m: usize) -> ArgminAcc {
        ArgminAcc { best: vec![f32::INFINITY; m], idx: vec![u32::MAX; m] }
    }

    /// Fold one `m × n` distance block whose columns correspond to global
    /// candidate ids `[base, base + n)`.
    pub fn fold_block(&mut self, block: &[f32], n: usize, base: u32) {
        let m = self.best.len();
        assert_eq!(block.len(), m * n);
        for i in 0..m {
            let row = &block[i * n..(i + 1) * n];
            let (mut bd, mut bi) = (self.best[i], self.idx[i]);
            for (j, &dv) in row.iter().enumerate() {
                // strict < keeps the lowest id on ties (matches argmin in HLO)
                if dv < bd {
                    bd = dv;
                    bi = base + j as u32;
                }
            }
            self.best[i] = bd;
            self.idx[i] = bi;
        }
    }

    /// Fold per-block argmin results (from the PJRT `assign_argmin` entry):
    /// `idx[i]` is local to the block, `dist[i]` its distance.
    pub fn fold_argmin(&mut self, idx: &[i32], dist: &[f32], base: u32) {
        let m = self.best.len();
        assert_eq!(idx.len(), m);
        assert_eq!(dist.len(), m);
        for i in 0..m {
            // strict <: blocks arrive in ascending id order, so ties keep
            // the lowest global id, matching the per-block HLO argmin.
            if dist[i] < self.best[i] {
                self.best[i] = dist[i];
                self.idx[i] = base + idx[i] as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_block_fold_matches_global() {
        // 2 queries, 4 candidates split into 2 blocks of 2
        let block_a = vec![5.0, 3.0, /* row0 */ 1.0, 9.0 /* row1 */];
        let block_b = vec![2.0, 4.0, 0.5, 1.0];
        let mut acc = ArgminAcc::new(2);
        acc.fold_block(&block_a, 2, 0);
        acc.fold_block(&block_b, 2, 2);
        assert_eq!(acc.idx, vec![2, 2]); // row0: 2.0 at id 2; row1: 0.5 at id 2
        assert_eq!(acc.best, vec![2.0, 0.5]);
    }

    #[test]
    fn tie_keeps_lowest_id() {
        let block = vec![1.0, 1.0];
        let mut acc = ArgminAcc::new(1);
        acc.fold_block(&block, 2, 0);
        assert_eq!(acc.idx, vec![0]);
    }

    #[test]
    fn fold_argmin_blocks() {
        let mut acc = ArgminAcc::new(2);
        acc.fold_argmin(&[1, 0], &[3.0, 2.0], 0);
        acc.fold_argmin(&[0, 1], &[1.0, 5.0], 8);
        assert_eq!(acc.idx, vec![8, 0]); // row1 keeps block-0's winner (id 0)
        assert_eq!(acc.best, vec![1.0, 2.0]);
    }
}
