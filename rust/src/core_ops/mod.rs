//! Native distance math and selection primitives.
//!
//! These mirror the Layer-1 Pallas kernel semantics exactly (squared L2,
//! clamped non-negative) so the Native and PJRT backends are
//! interchangeable and cross-checkable.

pub mod argmin;
pub mod blockdist;
pub mod dist;
#[cfg(feature = "simd")]
pub mod simd;
pub mod topk;
