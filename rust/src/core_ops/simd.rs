//! Explicit SIMD tier for the batched distance kernels (feature `simd`).
//!
//! The portable kernels in [`dist`](crate::core_ops::dist) are written so
//! LLVM autovectorizes them, but autovectorization neither guarantees the
//! widest ISA the host offers nor lets the tolerance-class kernels use
//! FMA.  This module provides hand-written AVX2 (x86_64) and NEON
//! (aarch64) implementations behind **one runtime dispatch**: the first
//! kernel call probes the CPU (`is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!`), caches a function table in a
//! [`OnceLock`], and every later call is an atomic load plus an indirect
//! call.  Hosts without the required features (and builds without the
//! `simd` feature) run the scalar tier unchanged.
//!
//! ## Exactness contract (the PR 5 split, preserved per tier)
//!
//! | kernel            | class      | SIMD implementation                      |
//! |-------------------|------------|------------------------------------------|
//! | `dot_batch`       | exact bits | 4-lane mul+add = the scalar chains       |
//! | `d2_batch_exact`  | exact bits | 4-lane sub/mul/add = the scalar chains   |
//! | `d2_batch`        | tolerance  | 8-lane FMA (AVX2) / 4-lane FMA (NEON)    |
//! | `d2_batch_sq8`    | tolerance  | u8→f32 widen + FMA                       |
//!
//! The scalar kernels keep **four independent accumulator chains** and
//! reduce them as `((s0 + s1) + s2) + s3`; chain *l* holds the elements
//! with index ≡ *l* (mod 4).  That is exactly one 4-lane SIMD register
//! accumulated with vertical `mul`+`add` and reduced lane 0 → lane 3, so
//! the exact-bits kernels here reproduce the scalar tier **bit for bit**
//! on every input (asserted by the tests below) — the Δℐ GK-means scan
//! and ANN search contracts survive the tier switch.  `d2_batch` and
//! `d2_batch_sq8` are tolerance-class by contract, which frees them to
//! use wider registers and fused multiply-add (FMA contracts `a*b + c`
//! into one rounding, moving results by ulps — why the exact kernels
//! must not use it).
//!
//! Set `GKMEANS_NO_SIMD=1` to force the scalar tier at runtime (used by
//! `benches/hotpath_micro.rs` notes and for A/B debugging).

use std::sync::OnceLock;

/// Function table for one detected tier.  Entries take the same
/// arguments as their [`dist`](crate::core_ops::dist) siblings; callers
/// (the `dist::` entry points) validate lengths *before* dispatching, so
/// the implementations may assume `x.len() == d`,
/// `block.len() == out.len() * d`, etc.
pub(crate) struct KernelTier {
    pub(crate) name: &'static str,
    pub(crate) dot_batch: unsafe fn(&[f32], &[f32], usize, &mut [f32]),
    pub(crate) d2_batch_exact: unsafe fn(&[f32], &[f32], usize, &mut [f32]),
    /// Tiled norm-identity path only — the caller has already checked
    /// [`dist::batch_eligible`](crate::core_ops::dist::batch_eligible)
    /// and takes the scalar fallback itself below the thresholds.
    pub(crate) d2_batch: unsafe fn(&[f32], f32, &[f32], &[f32], usize, &mut [f32]),
    pub(crate) d2_batch_sq8: unsafe fn(&[f32], &[u8], &[f32], &[f32], usize, &mut [f32]),
}

static TIER: OnceLock<Option<KernelTier>> = OnceLock::new();

/// The cached tier, or `None` when the host offers no supported ISA (or
/// `GKMEANS_NO_SIMD` is set).  First call performs detection.
pub(crate) fn kernels() -> Option<&'static KernelTier> {
    TIER.get_or_init(detect).as_ref()
}

/// Name of the active kernel tier: `"avx2"`, `"neon"`, or `"scalar"`.
/// Logged by `gkm-serve` and recorded by `benches/hotpath_micro.rs`.
pub fn tier() -> &'static str {
    kernels().map_or("scalar", |k| k.name)
}

/// Whether a SIMD tier is active (feature compiled in *and* the host CPU
/// supports it *and* no `GKMEANS_NO_SIMD` override).
pub fn active() -> bool {
    kernels().is_some()
}

fn detect() -> Option<KernelTier> {
    if std::env::var_os("GKMEANS_NO_SIMD").is_some_and(|v| v != "0") {
        return None;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
            return Some(KernelTier {
                name: "avx2",
                dot_batch: x86::dot_batch_sse2,
                d2_batch_exact: x86::d2_batch_exact_sse2,
                d2_batch: x86::d2_batch_avx2,
                d2_batch_sq8: x86::d2_batch_sq8_avx2,
            });
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(KernelTier {
                name: "neon",
                dot_batch: neon::dot_batch_neon,
                d2_batch_exact: neon::d2_batch_exact_neon,
                d2_batch: neon::d2_batch_neon,
                d2_batch_sq8: neon::d2_batch_sq8_neon,
            });
        }
    }
    None
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! x86_64 kernels.  The exact-bits pair uses 128-bit SSE2 (baseline
    //! on x86_64 — detection is only kept uniform with the AVX2 pair):
    //! the four scalar accumulator chains *are* one `__m128`, and
    //! separate `mul`/`add` keeps scalar rounding.  The tolerance pair
    //! uses 256-bit AVX2 FMA.

    use crate::core_ops::dist;
    use core::arch::x86_64::*;

    /// Reduce the 4 lanes (= the 4 scalar accumulator chains) in the
    /// scalar kernels' exact order: `((s0 + s1) + s2) + s3`.
    #[target_feature(enable = "sse2")]
    unsafe fn chain_sum(v: __m128) -> f32 {
        let mut t = [0f32; 4];
        _mm_storeu_ps(t.as_mut_ptr(), v);
        ((t[0] + t[1]) + t[2]) + t[3]
    }

    /// Any-order horizontal sum of a 256-bit accumulator (tolerance
    /// class only).
    #[target_feature(enable = "avx")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let mut t = [0f32; 8];
        _mm256_storeu_ps(t.as_mut_ptr(), v);
        ((t[0] + t[4]) + (t[1] + t[5])) + ((t[2] + t[6]) + (t[3] + t[7]))
    }

    /// Bit-identical [`dist::dot_batch`]: 4-column tile, one `__m128`
    /// accumulator per column, mul+add (never FMA).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_batch_sse2(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
        let w = out.len();
        let xp = x.as_ptr();
        let chunks = d / 4;
        let mut j = 0usize;
        while j + 4 <= w {
            let y0 = block.as_ptr().add(j * d);
            let y1 = block.as_ptr().add((j + 1) * d);
            let y2 = block.as_ptr().add((j + 2) * d);
            let y3 = block.as_ptr().add((j + 3) * d);
            let mut s0 = _mm_setzero_ps();
            let mut s1 = _mm_setzero_ps();
            let mut s2 = _mm_setzero_ps();
            let mut s3 = _mm_setzero_ps();
            for i in 0..chunks {
                let b = i * 4;
                let xv = _mm_loadu_ps(xp.add(b));
                s0 = _mm_add_ps(s0, _mm_mul_ps(xv, _mm_loadu_ps(y0.add(b))));
                s1 = _mm_add_ps(s1, _mm_mul_ps(xv, _mm_loadu_ps(y1.add(b))));
                s2 = _mm_add_ps(s2, _mm_mul_ps(xv, _mm_loadu_ps(y2.add(b))));
                s3 = _mm_add_ps(s3, _mm_mul_ps(xv, _mm_loadu_ps(y3.add(b))));
            }
            let mut r = [chain_sum(s0), chain_sum(s1), chain_sum(s2), chain_sum(s3)];
            for t in chunks * 4..d {
                let xv = *xp.add(t);
                r[0] += xv * *y0.add(t);
                r[1] += xv * *y1.add(t);
                r[2] += xv * *y2.add(t);
                r[3] += xv * *y3.add(t);
            }
            out[j..j + 4].copy_from_slice(&r);
            j += 4;
        }
        while j < w {
            out[j] = dist::dot(x, &block[j * d..(j + 1) * d]);
            j += 1;
        }
    }

    /// Bit-identical [`dist::d2_batch_exact`]: sub, mul, add — the
    /// scalar chains on 4 lanes.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn d2_batch_exact_sse2(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
        let w = out.len();
        let xp = x.as_ptr();
        let chunks = d / 4;
        let mut j = 0usize;
        while j + 4 <= w {
            let y0 = block.as_ptr().add(j * d);
            let y1 = block.as_ptr().add((j + 1) * d);
            let y2 = block.as_ptr().add((j + 2) * d);
            let y3 = block.as_ptr().add((j + 3) * d);
            let mut s0 = _mm_setzero_ps();
            let mut s1 = _mm_setzero_ps();
            let mut s2 = _mm_setzero_ps();
            let mut s3 = _mm_setzero_ps();
            for i in 0..chunks {
                let b = i * 4;
                let xv = _mm_loadu_ps(xp.add(b));
                let e0 = _mm_sub_ps(xv, _mm_loadu_ps(y0.add(b)));
                let e1 = _mm_sub_ps(xv, _mm_loadu_ps(y1.add(b)));
                let e2 = _mm_sub_ps(xv, _mm_loadu_ps(y2.add(b)));
                let e3 = _mm_sub_ps(xv, _mm_loadu_ps(y3.add(b)));
                s0 = _mm_add_ps(s0, _mm_mul_ps(e0, e0));
                s1 = _mm_add_ps(s1, _mm_mul_ps(e1, e1));
                s2 = _mm_add_ps(s2, _mm_mul_ps(e2, e2));
                s3 = _mm_add_ps(s3, _mm_mul_ps(e3, e3));
            }
            let mut r = [chain_sum(s0), chain_sum(s1), chain_sum(s2), chain_sum(s3)];
            for t in chunks * 4..d {
                let xv = *xp.add(t);
                let e0 = xv - *y0.add(t);
                let e1 = xv - *y1.add(t);
                let e2 = xv - *y2.add(t);
                let e3 = xv - *y3.add(t);
                r[0] += e0 * e0;
                r[1] += e1 * e1;
                r[2] += e2 * e2;
                r[3] += e3 * e3;
            }
            out[j..j + 4].copy_from_slice(&r);
            j += 4;
        }
        while j < w {
            out[j] = dist::d2(x, &block[j * d..(j + 1) * d]);
            j += 1;
        }
    }

    /// Tolerance-class [`dist::d2_batch`] tiled path: 4-column tile with
    /// one 256-bit FMA accumulator per column, norms folded through
    /// [`dist::d2_via_dot`].  Caller guarantees `batch_eligible`.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn d2_batch_avx2(
        x: &[f32],
        xx: f32,
        block: &[f32],
        norms: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        let w = out.len();
        let xp = x.as_ptr();
        let chunks8 = d / 8;
        let mut j = 0usize;
        while j + 4 <= w {
            let y0 = block.as_ptr().add(j * d);
            let y1 = block.as_ptr().add((j + 1) * d);
            let y2 = block.as_ptr().add((j + 2) * d);
            let y3 = block.as_ptr().add((j + 3) * d);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            for i in 0..chunks8 {
                let b = i * 8;
                let xv = _mm256_loadu_ps(xp.add(b));
                a0 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(y0.add(b)), a0);
                a1 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(y1.add(b)), a1);
                a2 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(y2.add(b)), a2);
                a3 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(y3.add(b)), a3);
            }
            let mut r = [hsum256(a0), hsum256(a1), hsum256(a2), hsum256(a3)];
            for t in chunks8 * 8..d {
                let xv = *xp.add(t);
                r[0] += xv * *y0.add(t);
                r[1] += xv * *y1.add(t);
                r[2] += xv * *y2.add(t);
                r[3] += xv * *y3.add(t);
            }
            out[j] = dist::d2_via_dot(xx, norms[j], r[0]);
            out[j + 1] = dist::d2_via_dot(xx, norms[j + 1], r[1]);
            out[j + 2] = dist::d2_via_dot(xx, norms[j + 2], r[2]);
            out[j + 3] = dist::d2_via_dot(xx, norms[j + 3], r[3]);
            j += 4;
        }
        while j < w {
            let xy = dist::dot(x, &block[j * d..(j + 1) * d]);
            out[j] = dist::d2_via_dot(xx, norms[j], xy);
            j += 1;
        }
    }

    /// Tolerance-class asymmetric SQ8 distance: widen 8 codes at a time
    /// (`u8 → i32 → f32`), dequantize with one FMA (`min + scale·code`),
    /// accumulate `(x − y)²` with a second FMA.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn d2_batch_sq8_avx2(
        x: &[f32],
        codes: &[u8],
        min: &[f32],
        scale: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        let w = out.len();
        let xp = x.as_ptr();
        let mp = min.as_ptr();
        let sp = scale.as_ptr();
        let chunks8 = d / 8;
        for (j, o) in out.iter_mut().enumerate() {
            let row = codes.as_ptr().add(j * d);
            let mut acc = _mm256_setzero_ps();
            for i in 0..chunks8 {
                let b = i * 8;
                let cv = _mm_loadl_epi64(row.add(b) as *const __m128i);
                let cf = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(cv));
                let y = _mm256_fmadd_ps(cf, _mm256_loadu_ps(sp.add(b)), _mm256_loadu_ps(mp.add(b)));
                let e = _mm256_sub_ps(_mm256_loadu_ps(xp.add(b)), y);
                acc = _mm256_fmadd_ps(e, e, acc);
            }
            let mut s = hsum256(acc);
            for t in chunks8 * 8..d {
                let y = *mp.add(t) + *sp.add(t) * f32::from(*row.add(t));
                let e = *xp.add(t) - y;
                s += e * e;
            }
            *o = s;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! aarch64 NEON kernels, mirroring the x86 structure: 128-bit
    //! vectors are 4 lanes = the scalar accumulator chains, so the
    //! exact-bits pair uses `vmulq`/`vaddq` (never fused) and the
    //! tolerance pair uses `vfmaq`.

    use crate::core_ops::dist;
    use core::arch::aarch64::*;

    /// `((s0 + s1) + s2) + s3` — the scalar reduction order.
    #[target_feature(enable = "neon")]
    unsafe fn chain_sum(v: float32x4_t) -> f32 {
        ((vgetq_lane_f32::<0>(v) + vgetq_lane_f32::<1>(v)) + vgetq_lane_f32::<2>(v))
            + vgetq_lane_f32::<3>(v)
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_batch_neon(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
        let w = out.len();
        let xp = x.as_ptr();
        let chunks = d / 4;
        let mut j = 0usize;
        while j + 4 <= w {
            let y0 = block.as_ptr().add(j * d);
            let y1 = block.as_ptr().add((j + 1) * d);
            let y2 = block.as_ptr().add((j + 2) * d);
            let y3 = block.as_ptr().add((j + 3) * d);
            let mut s0 = vdupq_n_f32(0.0);
            let mut s1 = vdupq_n_f32(0.0);
            let mut s2 = vdupq_n_f32(0.0);
            let mut s3 = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let b = i * 4;
                let xv = vld1q_f32(xp.add(b));
                s0 = vaddq_f32(s0, vmulq_f32(xv, vld1q_f32(y0.add(b))));
                s1 = vaddq_f32(s1, vmulq_f32(xv, vld1q_f32(y1.add(b))));
                s2 = vaddq_f32(s2, vmulq_f32(xv, vld1q_f32(y2.add(b))));
                s3 = vaddq_f32(s3, vmulq_f32(xv, vld1q_f32(y3.add(b))));
            }
            let mut r = [chain_sum(s0), chain_sum(s1), chain_sum(s2), chain_sum(s3)];
            for t in chunks * 4..d {
                let xv = *xp.add(t);
                r[0] += xv * *y0.add(t);
                r[1] += xv * *y1.add(t);
                r[2] += xv * *y2.add(t);
                r[3] += xv * *y3.add(t);
            }
            out[j..j + 4].copy_from_slice(&r);
            j += 4;
        }
        while j < w {
            out[j] = dist::dot(x, &block[j * d..(j + 1) * d]);
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn d2_batch_exact_neon(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
        let w = out.len();
        let xp = x.as_ptr();
        let chunks = d / 4;
        let mut j = 0usize;
        while j + 4 <= w {
            let y0 = block.as_ptr().add(j * d);
            let y1 = block.as_ptr().add((j + 1) * d);
            let y2 = block.as_ptr().add((j + 2) * d);
            let y3 = block.as_ptr().add((j + 3) * d);
            let mut s0 = vdupq_n_f32(0.0);
            let mut s1 = vdupq_n_f32(0.0);
            let mut s2 = vdupq_n_f32(0.0);
            let mut s3 = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let b = i * 4;
                let xv = vld1q_f32(xp.add(b));
                let e0 = vsubq_f32(xv, vld1q_f32(y0.add(b)));
                let e1 = vsubq_f32(xv, vld1q_f32(y1.add(b)));
                let e2 = vsubq_f32(xv, vld1q_f32(y2.add(b)));
                let e3 = vsubq_f32(xv, vld1q_f32(y3.add(b)));
                s0 = vaddq_f32(s0, vmulq_f32(e0, e0));
                s1 = vaddq_f32(s1, vmulq_f32(e1, e1));
                s2 = vaddq_f32(s2, vmulq_f32(e2, e2));
                s3 = vaddq_f32(s3, vmulq_f32(e3, e3));
            }
            let mut r = [chain_sum(s0), chain_sum(s1), chain_sum(s2), chain_sum(s3)];
            for t in chunks * 4..d {
                let xv = *xp.add(t);
                let e0 = xv - *y0.add(t);
                let e1 = xv - *y1.add(t);
                let e2 = xv - *y2.add(t);
                let e3 = xv - *y3.add(t);
                r[0] += e0 * e0;
                r[1] += e1 * e1;
                r[2] += e2 * e2;
                r[3] += e3 * e3;
            }
            out[j..j + 4].copy_from_slice(&r);
            j += 4;
        }
        while j < w {
            out[j] = dist::d2(x, &block[j * d..(j + 1) * d]);
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn d2_batch_neon(
        x: &[f32],
        xx: f32,
        block: &[f32],
        norms: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        let w = out.len();
        let xp = x.as_ptr();
        let chunks = d / 4;
        let mut j = 0usize;
        while j + 4 <= w {
            let y0 = block.as_ptr().add(j * d);
            let y1 = block.as_ptr().add((j + 1) * d);
            let y2 = block.as_ptr().add((j + 2) * d);
            let y3 = block.as_ptr().add((j + 3) * d);
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            for i in 0..chunks {
                let b = i * 4;
                let xv = vld1q_f32(xp.add(b));
                a0 = vfmaq_f32(a0, xv, vld1q_f32(y0.add(b)));
                a1 = vfmaq_f32(a1, xv, vld1q_f32(y1.add(b)));
                a2 = vfmaq_f32(a2, xv, vld1q_f32(y2.add(b)));
                a3 = vfmaq_f32(a3, xv, vld1q_f32(y3.add(b)));
            }
            let mut r = [vaddvq_f32(a0), vaddvq_f32(a1), vaddvq_f32(a2), vaddvq_f32(a3)];
            for t in chunks * 4..d {
                let xv = *xp.add(t);
                r[0] += xv * *y0.add(t);
                r[1] += xv * *y1.add(t);
                r[2] += xv * *y2.add(t);
                r[3] += xv * *y3.add(t);
            }
            out[j] = dist::d2_via_dot(xx, norms[j], r[0]);
            out[j + 1] = dist::d2_via_dot(xx, norms[j + 1], r[1]);
            out[j + 2] = dist::d2_via_dot(xx, norms[j + 2], r[2]);
            out[j + 3] = dist::d2_via_dot(xx, norms[j + 3], r[3]);
            j += 4;
        }
        while j < w {
            let xy = dist::dot(x, &block[j * d..(j + 1) * d]);
            out[j] = dist::d2_via_dot(xx, norms[j], xy);
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn d2_batch_sq8_neon(
        x: &[f32],
        codes: &[u8],
        min: &[f32],
        scale: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        let xp = x.as_ptr();
        let mp = min.as_ptr();
        let sp = scale.as_ptr();
        let chunks8 = d / 8;
        for (j, o) in out.iter_mut().enumerate() {
            let row = codes.as_ptr().add(j * d);
            let mut acc = vdupq_n_f32(0.0);
            for i in 0..chunks8 {
                let b = i * 8;
                // widen 8 codes: u8x8 → u16x8 → two u32x4 → two f32x4
                let c8 = vld1_u8(row.add(b));
                let c16 = vmovl_u8(c8);
                let lo = vcvtq_f32_u32(vmovl_u16(vget_low_u16(c16)));
                let hi = vcvtq_f32_u32(vmovl_u16(vget_high_u16(c16)));
                let ylo = vfmaq_f32(vld1q_f32(mp.add(b)), lo, vld1q_f32(sp.add(b)));
                let yhi = vfmaq_f32(vld1q_f32(mp.add(b + 4)), hi, vld1q_f32(sp.add(b + 4)));
                let elo = vsubq_f32(vld1q_f32(xp.add(b)), ylo);
                let ehi = vsubq_f32(vld1q_f32(xp.add(b + 4)), yhi);
                acc = vfmaq_f32(acc, elo, elo);
                acc = vfmaq_f32(acc, ehi, ehi);
            }
            let mut s = vaddvq_f32(acc);
            for t in chunks8 * 8..d {
                let y = *mp.add(t) + *sp.add(t) * f32::from(*row.add(t));
                let e = *xp.add(t) - y;
                s += e * e;
            }
            *o = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core_ops::dist;
    use crate::util::rng::Rng;

    // The ISSUE's ragged-dimension sweep; widths straddle the tile.
    const DIMS: [usize; 5] = [3, 8, 100, 128, 512];
    const WIDTHS: [usize; 6] = [1, 3, 4, 5, 8, 11];

    #[test]
    fn simd_dot_batch_bit_identical_to_scalar() {
        let Some(k) = kernels() else { return };
        let mut rng = Rng::new(31);
        for d in DIMS {
            for w in WIDTHS {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
                let mut want = vec![0f32; w];
                dist::dot_batch_scalar(&x, &block, d, &mut want);
                let mut got = vec![0f32; w];
                // SAFETY: `kernels()` only returns a tier the host supports.
                unsafe { (k.dot_batch)(&x, &block, d, &mut got) };
                for j in 0..w {
                    assert_eq!(
                        got[j].to_bits(),
                        want[j].to_bits(),
                        "tier {} d={d} w={w} col {j}: {} vs {}",
                        k.name,
                        got[j],
                        want[j]
                    );
                }
            }
        }
    }

    #[test]
    fn simd_d2_batch_exact_bit_identical_to_scalar() {
        let Some(k) = kernels() else { return };
        let mut rng = Rng::new(32);
        for d in DIMS {
            for w in WIDTHS {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
                let mut want = vec![0f32; w];
                dist::d2_batch_exact_scalar(&x, &block, d, &mut want);
                let mut got = vec![0f32; w];
                // SAFETY: `kernels()` only returns a tier the host supports.
                unsafe { (k.d2_batch_exact)(&x, &block, d, &mut got) };
                for j in 0..w {
                    assert_eq!(
                        got[j].to_bits(),
                        want[j].to_bits(),
                        "tier {} d={d} w={w} col {j}",
                        k.name
                    );
                }
            }
        }
    }

    #[test]
    fn simd_d2_batch_matches_scalar_within_tolerance() {
        let Some(k) = kernels() else { return };
        let mut rng = Rng::new(33);
        for d in DIMS {
            for w in WIDTHS {
                if !dist::batch_eligible(d, w) {
                    continue; // the wrapper never dispatches these shapes
                }
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
                let xx = dist::norm2(&x);
                let norms: Vec<f32> = block.chunks_exact(d).map(dist::norm2).collect();
                let mut got = vec![0f32; w];
                // SAFETY: `kernels()` only returns a tier the host supports.
                unsafe { (k.d2_batch)(&x, xx, &block, &norms, d, &mut got) };
                for j in 0..w {
                    let want = dist::d2(&x, &block[j * d..(j + 1) * d]);
                    assert!(
                        (got[j] - want).abs() <= 1e-3 * (1.0 + want),
                        "tier {} d={d} w={w} col {j}: got {} want {want}",
                        k.name,
                        got[j]
                    );
                }
            }
        }
    }

    #[test]
    fn simd_d2_batch_sq8_matches_scalar_kernel() {
        let Some(k) = kernels() else { return };
        let mut rng = Rng::new(34);
        for d in DIMS {
            for w in WIDTHS {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let codes: Vec<u8> = (0..w * d).map(|_| (rng.below(256)) as u8).collect();
                let min: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let scale: Vec<f32> = (0..d).map(|_| rng.normal().abs() * 0.01 + 1e-3).collect();
                let mut want = vec![0f32; w];
                dist::d2_batch_sq8_scalar(&x, &codes, &min, &scale, d, &mut want);
                let mut got = vec![0f32; w];
                // SAFETY: `kernels()` only returns a tier the host supports.
                unsafe { (k.d2_batch_sq8)(&x, &codes, &min, &scale, d, &mut got) };
                for j in 0..w {
                    let (g, wv) = (got[j], want[j]);
                    assert!(
                        (g - wv).abs() <= 1e-3 * (1.0 + wv.abs()),
                        "tier {} d={d} w={w} col {j}: got {g} want {wv}",
                        k.name
                    );
                }
            }
        }
    }

    #[test]
    fn tier_name_is_consistent_with_active() {
        if active() {
            assert_ne!(tier(), "scalar");
        } else {
            assert_eq!(tier(), "scalar");
        }
    }

    #[test]
    fn dispatched_entry_points_agree_with_scalar_tier() {
        // end-to-end through the public dist:: wrappers (which dispatch
        // here when the feature is on): exact kernels at exact bits,
        // d2_batch within the documented tolerance class
        let mut rng = Rng::new(35);
        let (d, w) = (128usize, 9usize);
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
        let mut a = vec![0f32; w];
        let mut b = vec![0f32; w];
        dist::dot_batch(&x, &block, d, &mut a);
        dist::dot_batch_scalar(&x, &block, d, &mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        dist::d2_batch_exact(&x, &block, d, &mut a);
        dist::d2_batch_exact_scalar(&x, &block, d, &mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let xx = dist::norm2(&x);
        let norms: Vec<f32> = block.chunks_exact(d).map(dist::norm2).collect();
        dist::d2_batch(&x, xx, &block, &norms, d, &mut a);
        dist::d2_batch_scalar(&x, xx, &block, &norms, d, &mut b);
        for j in 0..w {
            assert!((a[j] - b[j]).abs() <= 1e-3 * (1.0 + b[j]), "col {j}");
        }
    }
}
