//! Blocked pairwise squared-L2 distance — the native mirror of the L1
//! Pallas kernel (`python/compile/kernels/pairwise_l2.py`).
//!
//! Same math: `D[i,j] = ‖x_i‖² + ‖y_j‖² − 2⟨x_i, y_j⟩`, clamped at 0.
//! The cross term is computed with a register-tiled mini-GEMM so the
//! native backend is not hopeless next to XLA; the PJRT backend replaces
//! exactly this function.

use crate::core_ops::dist::{d2_via_dot, norm2};

/// Compute the full `m × n` squared-distance matrix into `out` (row-major,
/// `out.len() == m * n`).  `x` is `m × d` flat, `y` is `n × d` flat.
pub fn block_l2(x: &[f32], y: &[f32], d: usize, out: &mut [f32]) {
    assert!(d > 0);
    let m = x.len() / d;
    let n = y.len() / d;
    assert_eq!(x.len(), m * d);
    assert_eq!(y.len(), n * d);
    assert_eq!(out.len(), m * n);

    let xs: Vec<f32> = x.chunks_exact(d).map(norm2).collect();
    let ys: Vec<f32> = y.chunks_exact(d).map(norm2).collect();

    // With the `simd` feature and a detected tier, route each x-row
    // through the dispatched FMA kernel instead of the portable tile —
    // same norm-identity math, same tolerance class, wider registers.
    // Per-row arithmetic is deterministic, so `block_l2_parallel`'s
    // serial ≡ parallel bit-identity is preserved across tiers.
    #[cfg(feature = "simd")]
    if crate::core_ops::simd::active() && crate::core_ops::dist::batch_eligible(d, n) {
        for i in 0..m {
            let xi = &x[i * d..(i + 1) * d];
            crate::core_ops::dist::d2_batch(xi, xs[i], y, &ys, d, &mut out[i * n..(i + 1) * n]);
        }
        return;
    }

    // X·Yᵀ with 1×4 register tiling over j.  §Perf note: a 2×4 tile was
    // tried and measured 5% SLOWER (10.3 vs 11.1 GFLOP/s at 256×256×128 —
    // the operands are already L1-resident at these block sizes, so the
    // extra register pressure buys nothing); the PJRT/XLA path is the
    // designated fast path for large blocks (25–33 GFLOP/s).
    for i in 0..m {
        let xi = &x[i * d..(i + 1) * d];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let y0 = &y[j * d..(j + 1) * d];
            let y1 = &y[(j + 1) * d..(j + 2) * d];
            let y2 = &y[(j + 2) * d..(j + 3) * d];
            let y3 = &y[(j + 3) * d..(j + 4) * d];
            let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
            for t in 0..d {
                let xv = xi[t];
                a0 += xv * y0[t];
                a1 += xv * y1[t];
                a2 += xv * y2[t];
                a3 += xv * y3[t];
            }
            orow[j] = d2_via_dot(xs[i], ys[j], a0);
            orow[j + 1] = d2_via_dot(xs[i], ys[j + 1], a1);
            orow[j + 2] = d2_via_dot(xs[i], ys[j + 2], a2);
            orow[j + 3] = d2_via_dot(xs[i], ys[j + 3], a3);
            j += 4;
        }
        while j < n {
            let yj = &y[j * d..(j + 1) * d];
            let mut a = 0f32;
            for t in 0..d {
                a += xi[t] * yj[t];
            }
            orow[j] = d2_via_dot(xs[i], ys[j], a);
            j += 1;
        }
    }
}

/// Row-parallel [`block_l2`]: shards the rows of `x` (and the matching
/// rows of `out`) across up to `threads` workers, each running the serial
/// register-tiled kernel on its stripe.  Stripes are disjoint, so the
/// result is **bit-identical** to the serial kernel; `threads <= 1` calls
/// straight through.  Always native — PJRT dispatch is single-threaded by
/// design (see `runtime::backend`).
pub fn block_l2_parallel(x: &[f32], y: &[f32], d: usize, out: &mut [f32], threads: usize) {
    assert!(d > 0);
    let m = x.len() / d;
    let n = y.len() / d;
    assert_eq!(x.len(), m * d);
    assert_eq!(y.len(), n * d);
    assert_eq!(out.len(), m * n);
    let threads = crate::util::pool::resolve_threads(threads).min(m.max(1));
    if threads <= 1 || n == 0 {
        return block_l2(x, y, d, out);
    }
    let rows_per = (m + threads - 1) / threads;
    std::thread::scope(|s| {
        for (t, ochunk) in out.chunks_mut(rows_per * n).enumerate() {
            let lo = t * rows_per;
            let rows = ochunk.len() / n;
            let xs = &x[lo * d..(lo + rows) * d];
            s.spawn(move || block_l2(xs, y, d, ochunk));
        }
    });
}

/// [`block_l2`] with the `x` operand pulled from a
/// [`VecStore`](crate::data::store::VecStore) cursor:
/// rows `[lo, hi)` of the store against all rows of `y`.  The in-RAM
/// cursor serves the exact slice `rows_flat` would (zero copy), so the
/// result is bit-identical to the slice-based kernel; a chunked cursor
/// pages the block through its resident cache first.
pub fn block_l2_store(
    cur: &mut crate::data::store::StoreCursor<'_>,
    lo: usize,
    hi: usize,
    y: &[f32],
    d: usize,
    out: &mut [f32],
) {
    block_l2(cur.block(lo, hi), y, d, out)
}

/// Allocating convenience wrapper around [`block_l2`].
pub fn block_l2_alloc(x: &[f32], y: &[f32], d: usize) -> Vec<f32> {
    let m = x.len() / d;
    let n = y.len() / d;
    let mut out = vec![0f32; m * n];
    block_l2(x, y, d, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core_ops::dist::d2;
    use crate::util::rng::Rng;

    #[test]
    fn matches_rowwise_d2() {
        let mut rng = Rng::new(1);
        for (m, n, d) in [(3, 5, 7), (8, 8, 128), (1, 9, 33), (5, 1, 4)] {
            let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let out = block_l2_alloc(&x, &y, d);
            for i in 0..m {
                for j in 0..n {
                    let want = d2(&x[i * d..(i + 1) * d], &y[j * d..(j + 1) * d]);
                    let got = out[i * n + j];
                    assert!(
                        (got - want).abs() <= 1e-3 * (1.0 + want),
                        "({i},{j}) got={got} want={want}"
                    );
                }
            }
        }
    }

    #[test]
    fn non_negative_under_cancellation() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..64 * 128).map(|_| rng.normal() * 100.0).collect();
        let out = block_l2_alloc(&x, &x, 128);
        assert!(out.iter().all(|&v| v >= 0.0));
        for i in 0..64 {
            assert!(out[i * 64 + i] < 8.0, "diag[{i}]={}", out[i * 64 + i]);
        }
    }

    #[test]
    #[should_panic]
    fn wrong_out_len_panics() {
        block_l2(&[0.0; 4], &[0.0; 4], 2, &mut [0.0; 3]);
    }

    #[test]
    fn store_blocked_kernel_matches_slices() {
        let mut rng = Rng::new(5);
        let (m, n, d) = (23usize, 9usize, 6usize);
        let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let y: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
        let data = crate::data::matrix::VecSet::from_flat(d, x.clone());
        let mut want = vec![0f32; m * n];
        block_l2(&x, &y, d, &mut want);
        // the store-fed kernel over a sub-range matches the slice kernel
        let mut cur = crate::data::store::VecStore::open(&data);
        for (lo, hi) in [(0usize, m), (3, 17), (22, 23)] {
            let mut got = vec![0f32; (hi - lo) * n];
            block_l2_store(&mut cur, lo, hi, &y, d, &mut got);
            assert_eq!(got, want[lo * n..hi * n], "rows [{lo}, {hi})");
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Rng::new(3);
        for (m, n, d) in [(1usize, 1usize, 3usize), (7, 5, 4), (65, 33, 16), (256, 100, 32)] {
            let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            let mut serial = vec![0f32; m * n];
            block_l2(&x, &y, d, &mut serial);
            for threads in [1usize, 2, 3, 8] {
                let mut par = vec![0f32; m * n];
                block_l2_parallel(&x, &y, d, &mut par, threads);
                assert_eq!(serial, par, "m={m} n={n} d={d} threads={threads}");
            }
        }
    }
}
