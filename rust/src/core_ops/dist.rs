//! Scalar vector math: squared-L2 distance, dot product, norms.
//!
//! The 4-way unrolled loops below are the single hottest code in the
//! native backend — `d2` is called `O(n·κ)` times per GK-means epoch and
//! `O(n·ξ)` times per graph-refinement round.  The unrolling gives LLVM
//! independent accumulator chains it reliably vectorizes; see
//! `benches/hotpath_micro.rs` for the measured effect.

/// Squared Euclidean distance ‖a − b‖².
#[inline]
pub fn d2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    // Four independent accumulators -> vectorizable, no loop-carried dep.
    for i in 0..chunks {
        let j = i * 4;
        let e0 = a[j] - b[j];
        let e1 = a[j + 1] - b[j + 1];
        let e2 = a[j + 2] - b[j + 2];
        let e3 = a[j + 3] - b[j + 3];
        s0 += e0 * e0;
        s1 += e1 * e1;
        s2 += e2 * e2;
        s3 += e3 * e3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let e = a[j] - b[j];
        s += e * e;
    }
    s
}

/// Dot product ⟨a, b⟩ with the same unrolling discipline as [`d2`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Squared norm ‖a‖².
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Squared distance from precomputed norms and the cross dot:
/// `‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩`, clamped non-negative.
///
/// This is the identity the tiled mini-GEMM (`blockdist`) and the PJRT
/// Pallas kernel are built on; exposing it lets candidate-evaluation
/// loops (GK-means\*, future batched Δℐ paths) reuse precomputed norms so
/// each candidate costs a single dot — the GEMM-compatible form.
#[inline]
pub fn d2_via_dot(xx: f32, yy: f32, xy: f32) -> f32 {
    (xx + yy - 2.0 * xy).max(0.0)
}

/// Early-exit squared distance: abandons once the partial sum exceeds
/// `bound` (classic "partial distance" pruning; used by graph refinement
/// where most candidates lose to the current κ-th neighbor).
#[inline]
pub fn d2_bounded(a: &[f32], b: &[f32], bound: f32) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut s = 0f32;
    let mut j = 0;
    // check the bound every 16 components: cheap enough, prunes early.
    while j + 16 <= n {
        let mut part = 0f32;
        for t in 0..16 {
            let e = a[j + t] - b[j + t];
            part += e * e;
        }
        s += part;
        if s > bound {
            return s;
        }
        j += 16;
    }
    while j < n {
        let e = a[j] - b[j];
        s += e * e;
        j += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_d2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn d2_matches_naive_various_lengths() {
        let mut rng = crate::util::rng::Rng::new(1);
        for len in [0, 1, 3, 4, 7, 16, 100, 128, 513] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let got = d2(&a, &b);
            let want = naive_d2(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want), "len={len}");
        }
    }

    #[test]
    fn dot_and_norm() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 2.0 + 3.0 + 4.0 + 5.0);
        assert_eq!(norm2(&a), 55.0);
    }

    #[test]
    fn d2_via_dot_matches_direct() {
        let mut rng = crate::util::rng::Rng::new(5);
        for len in [1usize, 4, 33, 128] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let want = d2(&a, &b);
            let got = d2_via_dot(norm2(&a), norm2(&b), dot(&a, &b));
            assert!((got - want).abs() <= 1e-3 * (1.0 + want), "len={len}");
        }
        // cancellation must clamp at zero, never go negative
        let x = vec![100.0f32; 64];
        assert_eq!(d2_via_dot(norm2(&x), norm2(&x), dot(&x, &x)), 0.0);
    }

    #[test]
    fn d2_zero_for_identical() {
        let a: Vec<f32> = (0..128).map(|i| i as f32).collect();
        assert_eq!(d2(&a, &a), 0.0);
    }

    #[test]
    fn bounded_exact_when_under_bound() {
        let mut rng = crate::util::rng::Rng::new(2);
        let a: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let exact = d2(&a, &b);
        let got = d2_bounded(&a, &b, f32::MAX);
        assert!((got - exact).abs() <= 1e-4 * (1.0 + exact));
    }

    #[test]
    fn bounded_early_exit_exceeds_bound() {
        let a = vec![0f32; 128];
        let b = vec![10f32; 128];
        let got = d2_bounded(&a, &b, 50.0);
        assert!(got > 50.0, "must report a value above the bound");
        // and it may be less than the exact distance (early exit)
        assert!(got <= d2(&a, &b));
    }
}
