//! Scalar and batched vector math: squared-L2 distance, dot product,
//! norms, and the gathered-block mini-GEMM candidate kernels.
//!
//! The 4-way unrolled loops below are the single hottest code in the
//! native backend — `d2` is called `O(n·κ)` times per GK-means epoch and
//! `O(n·ξ)` times per graph-refinement round.  The unrolling gives LLVM
//! independent accumulator chains it reliably vectorizes; see
//! `benches/hotpath_micro.rs` for the measured effect.
//!
//! The batched kernels ([`dot_batch`], [`d2_batch`], [`d2_batch_exact`])
//! close the constant-factor gap left by evaluating κ candidates one
//! scalar call at a time: the caller gathers the candidate vectors into
//! a contiguous block and one tiled pass evaluates four candidates per
//! load of the sample — the same mini-GEMM shape as `blockdist`, shrunk
//! to the Alg. 2 candidate-set width.  `dot_batch`/`d2_batch_exact`
//! replicate the scalar accumulation order per column (bit-identical —
//! the Δℐ GK-means and ANN-search contract); `d2_batch` additionally
//! exploits precomputed norms via [`d2_via_dot`] and is allowed to shift
//! at f32 rounding (GK-means\*'s tolerance class).  `cargo bench --bench
//! hotpath_micro` records the batched-vs-scalar gap in `BENCH_gkm.json`.
//!
//! Each batched entry point is a thin dispatcher: under the `simd` cargo
//! feature it consults the runtime-detected kernel tier
//! (`core_ops::simd`) once and routes to AVX2/NEON
//! implementations; otherwise (and on hosts without the ISA) it runs the
//! portable `*_scalar` sibling, which is the reference tier every other
//! tier is pinned against.  [`d2_batch_sq8`] is the asymmetric
//! f32-query × u8-candidate kernel backing the SQ8 quantized store
//! (`data::quant`).

/// Squared Euclidean distance ‖a − b‖².
#[inline]
pub fn d2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    // Four independent accumulators -> vectorizable, no loop-carried dep.
    for i in 0..chunks {
        let j = i * 4;
        let e0 = a[j] - b[j];
        let e1 = a[j + 1] - b[j + 1];
        let e2 = a[j + 2] - b[j + 2];
        let e3 = a[j + 3] - b[j + 3];
        s0 += e0 * e0;
        s1 += e1 * e1;
        s2 += e2 * e2;
        s3 += e3 * e3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let e = a[j] - b[j];
        s += e * e;
    }
    s
}

/// Dot product ⟨a, b⟩ with the same unrolling discipline as [`d2`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Squared norm ‖a‖².
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Squared distance from precomputed norms and the cross dot:
/// `‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩`, clamped non-negative.
///
/// This is the identity the tiled mini-GEMM (`blockdist`) and the PJRT
/// Pallas kernel are built on; exposing it lets candidate-evaluation
/// loops (GK-means\*, future batched Δℐ paths) reuse precomputed norms so
/// each candidate costs a single dot — the GEMM-compatible form.
#[inline]
pub fn d2_via_dot(xx: f32, yy: f32, xy: f32) -> f32 {
    (xx + yy - 2.0 * xy).max(0.0)
}

/// Candidates evaluated per tile of the batched kernels below.  Four
/// columns share each load of `x`, which is where the batched win over
/// per-candidate scalar calls comes from.  Public so callers can skip
/// the gather entirely when a candidate set is too narrow to fill one
/// tile (the kernels would just run per-column scalar calls on the
/// gathered copy).
pub const BATCH_TILE: usize = 4;

/// Dimensionality below which [`d2_batch`] takes its one-shot scalar
/// fallback: at tiny `d` the norm identity saves nothing over a direct
/// `(x − y)²` scan and only adds rounding.
pub const BATCH_MIN_DIM: usize = 16;

/// Whether [`d2_batch`] will run its tiled norm-identity path for a
/// `d`-dimensional sample against `w` candidates (`false` = the one-shot
/// scalar fallback).  Callers that want to skip the gather entirely on
/// fallback shapes branch on this — the single source of truth for the
/// fallback condition, so call sites cannot drift from the kernel.
#[inline]
pub fn batch_eligible(d: usize, w: usize) -> bool {
    d >= BATCH_MIN_DIM && w >= BATCH_TILE
}

/// Batched dot products against a gathered candidate block:
/// `out[j] = ⟨x, block[j·d .. (j+1)·d]⟩` for `out.len()` candidates.
///
/// This is the mini-GEMM form of the Alg. 2 candidate scan: the caller
/// gathers the κ̃ candidate composites/centroids contiguously, and one
/// call produces every cross dot the Δℐ / nearest-centroid evaluation
/// needs.
///
/// **Bit-identity contract**: each output is produced by *exactly* the
/// accumulation sequence of the scalar [`dot`] — four independent
/// accumulator chains over the unrolled body, one sequential remainder
/// loop — and the tile only shares the loads of `x` across four
/// candidate columns.  Callers on an exact-arithmetic budget (the Δℐ
/// GK-means candidate scan, whose `threads = 1` results must stay
/// bit-identical to the seed implementation) can therefore batch without
/// shifting a single ulp; the unit tests assert equality of the raw bit
/// patterns.
///
/// Under the `simd` feature this entry point dispatches to the hand
/// written tier (`core_ops::simd`) when the host CPU supports
/// it; the SIMD implementation reproduces the same accumulation order,
/// so the bit-identity contract holds across tiers.  Without the
/// feature it *is* [`dot_batch_scalar`].
pub fn dot_batch(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(block.len(), w * d, "block is not w × d");
    #[cfg(feature = "simd")]
    if let Some(k) = crate::core_ops::simd::kernels() {
        // SAFETY: the tier was selected by runtime CPU-feature detection
        // and the slice extents were validated above.
        unsafe { (k.dot_batch)(x, block, d, out) };
        return;
    }
    dot_batch_scalar(x, block, d, out);
}

/// The portable scalar tier of [`dot_batch`] (the reference
/// implementation every other tier is pinned against).  Public so
/// benches and tests can compare tiers inside one process.
pub fn dot_batch_scalar(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(block.len(), w * d, "block is not w × d");
    let chunks = d / 4;
    let mut j = 0;
    while j + BATCH_TILE <= w {
        let y0 = &block[j * d..(j + 1) * d];
        let y1 = &block[(j + 1) * d..(j + 2) * d];
        let y2 = &block[(j + 2) * d..(j + 3) * d];
        let y3 = &block[(j + 3) * d..(j + 4) * d];
        // s[c][l]: accumulator chain l of candidate column c — per
        // column, the same four chains the scalar kernel keeps; keeping a
        // column's chains contiguous lets LLVM run one 4-lane FMA per
        // column per chunk with the x loads shared across columns.
        let mut s = [[0f32; 4]; BATCH_TILE];
        for i in 0..chunks {
            let b = i * 4;
            for l in 0..4 {
                let xv = x[b + l];
                s[0][l] += xv * y0[b + l];
                s[1][l] += xv * y1[b + l];
                s[2][l] += xv * y2[b + l];
                s[3][l] += xv * y3[b + l];
            }
        }
        // per column: ((s0 + s1) + s2) + s3, then the sequential tail —
        // the exact reduction order of `dot`
        let mut r = [
            s[0][0] + s[0][1] + s[0][2] + s[0][3],
            s[1][0] + s[1][1] + s[1][2] + s[1][3],
            s[2][0] + s[2][1] + s[2][2] + s[2][3],
            s[3][0] + s[3][1] + s[3][2] + s[3][3],
        ];
        for t in chunks * 4..d {
            let xv = x[t];
            r[0] += xv * y0[t];
            r[1] += xv * y1[t];
            r[2] += xv * y2[t];
            r[3] += xv * y3[t];
        }
        out[j..j + BATCH_TILE].copy_from_slice(&r);
        j += BATCH_TILE;
    }
    while j < w {
        out[j] = dot(x, &block[j * d..(j + 1) * d]);
        j += 1;
    }
}

/// Batched candidate distances in the GEMM-compatible form
/// (`‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩`, see [`d2_via_dot`]) over a
/// gathered candidate block: the caller supplies `xx = ‖x‖²` once per
/// sample and the per-candidate norms once per epoch (the centroid-norm
/// cache GK-means\* keeps, or the `DeltaCache` composite norms), so each
/// candidate costs a single tiled dot.
///
/// Below [`BATCH_MIN_DIM`] — or when the block is narrower than one tile
/// — the kernel takes a **one-shot scalar fallback**: a direct [`d2`]
/// per candidate, which is cheaper than the norm identity at those
/// shapes.  The two paths round differently at f32 (the same tolerance
/// class as the blocked kernels; see [`d2_via_dot`]); callers that must
/// not move an ulp use [`dot_batch`] or [`d2_batch_exact`] instead.
///
/// Under the `simd` feature the *tiled* path dispatches to the FMA
/// implementation in `core_ops::simd` when the host supports it
/// — `d2_batch` is tolerance-class by contract, so the wider registers
/// and fused rounding are free; the one-shot scalar fallback below the
/// eligibility thresholds is taken before dispatch and never moves.
pub fn d2_batch(x: &[f32], xx: f32, block: &[f32], norms: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(block.len(), w * d, "block is not w × d");
    assert_eq!(norms.len(), w, "one precomputed norm per candidate");
    if !batch_eligible(d, w) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = d2(x, &block[j * d..(j + 1) * d]);
        }
        return;
    }
    #[cfg(feature = "simd")]
    if let Some(k) = crate::core_ops::simd::kernels() {
        // SAFETY: tier selected by runtime CPU-feature detection; slice
        // extents validated above; eligibility checked above.
        unsafe { (k.d2_batch)(x, xx, block, norms, d, out) };
        return;
    }
    dot_batch_scalar(x, block, d, out);
    for (o, &nn) in out.iter_mut().zip(norms) {
        *o = d2_via_dot(xx, nn, *o);
    }
}

/// The portable scalar tier of [`d2_batch`] (identical semantics,
/// including the one-shot fallback).  Public so benches and tests can
/// compare tiers inside one process.
pub fn d2_batch_scalar(x: &[f32], xx: f32, block: &[f32], norms: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(block.len(), w * d, "block is not w × d");
    assert_eq!(norms.len(), w, "one precomputed norm per candidate");
    if !batch_eligible(d, w) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = d2(x, &block[j * d..(j + 1) * d]);
        }
        return;
    }
    dot_batch_scalar(x, block, d, out);
    for (o, &nn) in out.iter_mut().zip(norms) {
        *o = d2_via_dot(xx, nn, *o);
    }
}

/// Batched direct squared distances over a gathered block:
/// `out[j] = ‖x − block_j‖²` with per-column arithmetic **bit-identical
/// to [`d2`]** (same four accumulator chains, same reduction and
/// remainder order; the tile only shares the loads of `x`).
///
/// The exact-form sibling of [`d2_batch`] for callers that need the
/// batching without the norm identity's rounding shift and without
/// precomputed norms — the ANN frontier expansion, whose results (and
/// `search` ≡ `search_batch` equivalence) must not move under batching.
///
/// Like [`dot_batch`], the `simd`-feature tier replicates the scalar
/// accumulation order exactly, so dispatch never moves a bit.
pub fn d2_batch_exact(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(block.len(), w * d, "block is not w × d");
    #[cfg(feature = "simd")]
    if let Some(k) = crate::core_ops::simd::kernels() {
        // SAFETY: tier selected by runtime CPU-feature detection; slice
        // extents validated above.
        unsafe { (k.d2_batch_exact)(x, block, d, out) };
        return;
    }
    d2_batch_exact_scalar(x, block, d, out);
}

/// The portable scalar tier of [`d2_batch_exact`].  Public so benches
/// and tests can compare tiers inside one process.
pub fn d2_batch_exact_scalar(x: &[f32], block: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(block.len(), w * d, "block is not w × d");
    let chunks = d / 4;
    let mut j = 0;
    while j + BATCH_TILE <= w {
        let y0 = &block[j * d..(j + 1) * d];
        let y1 = &block[(j + 1) * d..(j + 2) * d];
        let y2 = &block[(j + 2) * d..(j + 3) * d];
        let y3 = &block[(j + 3) * d..(j + 4) * d];
        let mut s = [[0f32; 4]; BATCH_TILE];
        for i in 0..chunks {
            let b = i * 4;
            for l in 0..4 {
                let xv = x[b + l];
                let e0 = xv - y0[b + l];
                let e1 = xv - y1[b + l];
                let e2 = xv - y2[b + l];
                let e3 = xv - y3[b + l];
                s[0][l] += e0 * e0;
                s[1][l] += e1 * e1;
                s[2][l] += e2 * e2;
                s[3][l] += e3 * e3;
            }
        }
        let mut r = [
            s[0][0] + s[0][1] + s[0][2] + s[0][3],
            s[1][0] + s[1][1] + s[1][2] + s[1][3],
            s[2][0] + s[2][1] + s[2][2] + s[2][3],
            s[3][0] + s[3][1] + s[3][2] + s[3][3],
        ];
        for t in chunks * 4..d {
            let e0 = x[t] - y0[t];
            let e1 = x[t] - y1[t];
            let e2 = x[t] - y2[t];
            let e3 = x[t] - y3[t];
            r[0] += e0 * e0;
            r[1] += e1 * e1;
            r[2] += e2 * e2;
            r[3] += e3 * e3;
        }
        out[j..j + BATCH_TILE].copy_from_slice(&r);
        j += BATCH_TILE;
    }
    while j < w {
        out[j] = d2(x, &block[j * d..(j + 1) * d]);
        j += 1;
    }
}

/// Batched **asymmetric** SQ8 distances: the query stays f32, the
/// candidates stay quantized — `out[j] ≈ ‖x − decode(codes_j)‖²` where
/// `decode(c)[t] = min[t] + scale[t] · c[t]` (the per-dimension affine
/// of [`crate::data::quant::Sq8Quantizer`]).  Codes are never expanded
/// to an f32 block in memory, which is the point: a candidate row costs
/// `d` bytes of bandwidth instead of `4d`.
///
/// Tolerance class: the result equals the f32 distance to the *decoded*
/// row up to f32 rounding (the SIMD tier widens u8→f32 and uses FMA);
/// the quantization error itself is bounded by the quantizer's step
/// size, which is why serving re-ranks survivors with the exact f32
/// kernel (see `gkm::ann`).
pub fn d2_batch_sq8(x: &[f32], codes: &[u8], min: &[f32], scale: &[f32], d: usize, out: &mut [f32]) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(codes.len(), w * d, "codes is not w × d");
    assert_eq!(min.len(), d, "one min per dimension");
    assert_eq!(scale.len(), d, "one scale per dimension");
    #[cfg(feature = "simd")]
    if let Some(k) = crate::core_ops::simd::kernels() {
        // SAFETY: tier selected by runtime CPU-feature detection; slice
        // extents validated above.
        unsafe { (k.d2_batch_sq8)(x, codes, min, scale, d, out) };
        return;
    }
    d2_batch_sq8_scalar(x, codes, min, scale, d, out);
}

/// The portable scalar tier of [`d2_batch_sq8`]: per row, the same
/// four-chain unrolling as [`d2`] with an inline dequantize.  Public so
/// benches and tests can compare tiers inside one process.
pub fn d2_batch_sq8_scalar(
    x: &[f32],
    codes: &[u8],
    min: &[f32],
    scale: &[f32],
    d: usize,
    out: &mut [f32],
) {
    let w = out.len();
    assert_eq!(x.len(), d, "x is not d-dimensional");
    assert_eq!(codes.len(), w * d, "codes is not w × d");
    assert_eq!(min.len(), d, "one min per dimension");
    assert_eq!(scale.len(), d, "one scale per dimension");
    let chunks = d / 4;
    for (j, o) in out.iter_mut().enumerate() {
        let row = &codes[j * d..(j + 1) * d];
        let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
        for i in 0..chunks {
            let b = i * 4;
            let e0 = x[b] - (min[b] + scale[b] * f32::from(row[b]));
            let e1 = x[b + 1] - (min[b + 1] + scale[b + 1] * f32::from(row[b + 1]));
            let e2 = x[b + 2] - (min[b + 2] + scale[b + 2] * f32::from(row[b + 2]));
            let e3 = x[b + 3] - (min[b + 3] + scale[b + 3] * f32::from(row[b + 3]));
            s0 += e0 * e0;
            s1 += e1 * e1;
            s2 += e2 * e2;
            s3 += e3 * e3;
        }
        let mut s = s0 + s1 + s2 + s3;
        for t in chunks * 4..d {
            let e = x[t] - (min[t] + scale[t] * f32::from(row[t]));
            s += e * e;
        }
        *o = s;
    }
}

/// Early-exit squared distance: abandons once the partial sum exceeds
/// `bound` (classic "partial distance" pruning; used by graph refinement
/// where most candidates lose to the current κ-th neighbor).
#[inline]
pub fn d2_bounded(a: &[f32], b: &[f32], bound: f32) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut s = 0f32;
    let mut j = 0;
    // check the bound every 16 components: cheap enough, prunes early.
    while j + 16 <= n {
        let mut part = 0f32;
        for t in 0..16 {
            let e = a[j + t] - b[j + t];
            part += e * e;
        }
        s += part;
        if s > bound {
            return s;
        }
        j += 16;
    }
    while j < n {
        let e = a[j] - b[j];
        s += e * e;
        j += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_d2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn d2_matches_naive_various_lengths() {
        let mut rng = crate::util::rng::Rng::new(1);
        for len in [0, 1, 3, 4, 7, 16, 100, 128, 513] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let got = d2(&a, &b);
            let want = naive_d2(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want), "len={len}");
        }
    }

    #[test]
    fn dot_and_norm() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(dot(&a, &b), 2.0 + 3.0 + 4.0 + 5.0);
        assert_eq!(norm2(&a), 55.0);
    }

    #[test]
    fn d2_via_dot_matches_direct() {
        let mut rng = crate::util::rng::Rng::new(5);
        for len in [1usize, 4, 33, 128] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let want = d2(&a, &b);
            let got = d2_via_dot(norm2(&a), norm2(&b), dot(&a, &b));
            assert!((got - want).abs() <= 1e-3 * (1.0 + want), "len={len}");
        }
        // cancellation must clamp at zero, never go negative
        let x = vec![100.0f32; 64];
        assert_eq!(d2_via_dot(norm2(&x), norm2(&x), dot(&x, &x)), 0.0);
    }

    #[test]
    fn d2_zero_for_identical() {
        let a: Vec<f32> = (0..128).map(|i| i as f32).collect();
        assert_eq!(d2(&a, &a), 0.0);
    }

    #[test]
    fn dot_batch_bit_identical_to_scalar_dot() {
        // the load-bearing lemma for the batched Δℐ candidate scan: every
        // column of the tiled kernel reproduces the scalar `dot` to the bit
        let mut rng = crate::util::rng::Rng::new(7);
        for d in [0usize, 1, 3, 4, 7, 15, 16, 33, 128, 513] {
            for w in [0usize, 1, 2, 3, 4, 5, 7, 8, 11] {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
                let mut out = vec![0f32; w];
                dot_batch(&x, &block, d, &mut out);
                for j in 0..w {
                    let want = dot(&x, &block[j * d..(j + 1) * d]);
                    assert_eq!(
                        out[j].to_bits(),
                        want.to_bits(),
                        "d={d} w={w} col {j}: {} vs {want}",
                        out[j]
                    );
                }
            }
        }
    }

    #[test]
    fn d2_batch_exact_bit_identical_to_scalar_d2() {
        let mut rng = crate::util::rng::Rng::new(8);
        for d in [0usize, 1, 4, 6, 16, 31, 128] {
            for w in [0usize, 1, 3, 4, 6, 9] {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
                let mut out = vec![0f32; w];
                d2_batch_exact(&x, &block, d, &mut out);
                for j in 0..w {
                    let want = d2(&x, &block[j * d..(j + 1) * d]);
                    assert_eq!(out[j].to_bits(), want.to_bits(), "d={d} w={w} col {j}");
                }
            }
        }
    }

    #[test]
    fn d2_batch_matches_scalar_within_tolerance() {
        // both branches (scalar fallback below the threshold, norm
        // identity above it) stay in the blocked-kernel tolerance class
        let mut rng = crate::util::rng::Rng::new(9);
        for d in [1usize, 4, 8, 15, 16, 32, 100, 128, 200] {
            for w in [1usize, 2, 3, 4, 5, 10, 17] {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
                let xx = norm2(&x);
                let norms: Vec<f32> = block.chunks_exact(d.max(1)).map(norm2).collect();
                let mut out = vec![0f32; w];
                d2_batch(&x, xx, &block, &norms, d, &mut out);
                for j in 0..w {
                    let want = d2(&x, &block[j * d..(j + 1) * d]);
                    assert!(
                        (out[j] - want).abs() <= 1e-3 * (1.0 + want),
                        "d={d} w={w} col {j}: got {} want {want}",
                        out[j]
                    );
                }
            }
        }
    }

    #[test]
    fn d2_batch_non_negative_under_cancellation() {
        // self-distance through the norm identity must clamp at zero
        let d = 128;
        let x: Vec<f32> = (0..d).map(|i| (i as f32) * 10.0).collect();
        let mut block = Vec::new();
        for _ in 0..4 {
            block.extend_from_slice(&x);
        }
        let xx = norm2(&x);
        let norms = vec![xx; 4];
        let mut out = vec![f32::NAN; 4];
        d2_batch(&x, xx, &block, &norms, d, &mut out);
        assert!(out.iter().all(|&v| v >= 0.0), "{out:?}");
    }

    #[test]
    #[should_panic]
    fn dot_batch_rejects_ragged_block() {
        let mut out = [0f32; 2];
        dot_batch(&[1.0, 2.0], &[1.0, 2.0, 3.0], 2, &mut out);
    }

    #[test]
    fn bounded_exact_when_under_bound() {
        let mut rng = crate::util::rng::Rng::new(2);
        let a: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..100).map(|_| rng.normal()).collect();
        let exact = d2(&a, &b);
        let got = d2_bounded(&a, &b, f32::MAX);
        assert!((got - exact).abs() <= 1e-4 * (1.0 + exact));
    }

    #[test]
    fn bounded_early_exit_exceeds_bound() {
        let a = vec![0f32; 128];
        let b = vec![10f32; 128];
        let got = d2_bounded(&a, &b, 50.0);
        assert!(got > 50.0, "must report a value above the bound");
        // and it may be less than the exact distance (early exit)
        assert!(got <= d2(&a, &b));
    }

    #[test]
    fn d2_batch_sq8_matches_decoded_f32_distance() {
        // the asymmetric kernel against the obvious spec: decode every
        // code row to f32, then take the plain d2
        let mut rng = crate::util::rng::Rng::new(12);
        for d in [1usize, 3, 8, 100, 128] {
            for w in [1usize, 2, 4, 7] {
                let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let codes: Vec<u8> = (0..w * d).map(|_| rng.below(256) as u8).collect();
                let min: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let scale: Vec<f32> = (0..d).map(|_| rng.normal().abs() * 0.01 + 1e-4).collect();
                let mut out = vec![0f32; w];
                d2_batch_sq8(&x, &codes, &min, &scale, d, &mut out);
                for j in 0..w {
                    let decoded: Vec<f32> = (0..d)
                        .map(|t| min[t] + scale[t] * f32::from(codes[j * d + t]))
                        .collect();
                    let want = d2(&x, &decoded);
                    assert!(
                        (out[j] - want).abs() <= 1e-3 * (1.0 + want),
                        "d={d} w={w} col {j}: got {} want {want}",
                        out[j]
                    );
                }
            }
        }
    }

    #[test]
    fn scalar_tiers_are_the_dispatched_kernels_without_the_feature() {
        // with `simd` off these are literally the same code path; with it
        // on, the exact kernels must still agree to the bit (tolerance
        // kernels are covered in core_ops::simd's own tests)
        let mut rng = crate::util::rng::Rng::new(13);
        let (d, w) = (100usize, 7usize);
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let block: Vec<f32> = (0..w * d).map(|_| rng.normal()).collect();
        let mut a = vec![0f32; w];
        let mut b = vec![0f32; w];
        dot_batch(&x, &block, d, &mut a);
        dot_batch_scalar(&x, &block, d, &mut b);
        for j in 0..w {
            assert_eq!(a[j].to_bits(), b[j].to_bits(), "dot col {j}");
        }
        d2_batch_exact(&x, &block, d, &mut a);
        d2_batch_exact_scalar(&x, &block, d, &mut b);
        for j in 0..w {
            assert_eq!(a[j].to_bits(), b[j].to_bits(), "d2 col {j}");
        }
    }
}
