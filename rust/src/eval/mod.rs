//! Evaluation: distortion (Eqn. 4), the Fig. 1 co-occurrence statistic,
//! and table/CSV reporting shared by the bench harnesses.

pub mod cooccur;
pub mod distortion;
pub mod report;
