//! Fig. 1's statistic: the co-occurrence rate of a sample and its i-th
//! nearest neighbor in the same cluster.
//!
//! For each rank i ∈ [1, κ]: the fraction of samples whose exact i-th
//! nearest neighbor carries the same cluster label.  The paper measures
//! this on SIFT100K with cluster size fixed to 50 (k = n/50) for both
//! traditional k-means and the 2M-tree, observing rates ≫ the random-
//! collision probability 50/n.

use crate::data::matrix::VecSet;
use crate::graph::knn::KnnGraph;

/// Co-occurrence rate per neighbor rank (index 0 = nearest neighbor).
pub fn cooccurrence_by_rank(exact: &KnnGraph, labels: &[u32], kappa: usize) -> Vec<f64> {
    let n = exact.n();
    assert_eq!(labels.len(), n);
    let kappa = kappa.min(exact.kappa());
    let mut hits = vec![0usize; kappa];
    let mut counts = vec![0usize; kappa];
    for i in 0..n {
        let nb = exact.neighbors(i);
        for r in 0..kappa {
            let j = nb[r];
            if j == u32::MAX {
                continue;
            }
            counts[r] += 1;
            if labels[j as usize] == labels[i] {
                hits[r] += 1;
            }
        }
    }
    hits.iter()
        .zip(&counts)
        .map(|(&h, &c)| if c == 0 { f64::NAN } else { h as f64 / c as f64 })
        .collect()
}

/// The random-collision baseline the paper quotes: expected co-occurrence
/// rate of two random samples = Σ_r (n_r/n)² ≈ cluster_size/n for equal
/// sizes.
pub fn random_collision_rate(labels: &[u32], k: usize) -> f64 {
    let n = labels.len() as f64;
    let mut counts = vec![0f64; k];
    for &l in labels {
        counts[l as usize] += 1.0;
    }
    counts.iter().map(|c| (c / n) * (c / n)).sum()
}

/// Convenience: full Fig. 1 data for one clustering of `data`.
pub fn figure1_series(data: &VecSet, labels: &[u32], kappa: usize, backend: &crate::runtime::Backend) -> Vec<f64> {
    let exact = crate::graph::brute::build(data, kappa, backend);
    cooccurrence_by_rank(&exact, labels, kappa)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::kmeans::common::KmeansParams;
    use crate::runtime::Backend;

    #[test]
    fn clustered_data_cooccurs_far_above_random() {
        let data = blobs(&BlobSpec { sigma: 0.5, ..BlobSpec::quick(500, 6, 10) }, 1);
        let out = crate::kmeans::lloyd::run_core(&data, 10, &KmeansParams::default(), &Backend::native());
        let series = figure1_series(&data, &out.clustering.labels, 5, &Backend::native());
        let random = random_collision_rate(&out.clustering.labels, 10);
        assert!(series[0] > 0.8, "NN co-occurrence {series:?}");
        assert!(series[0] > random * 3.0);
    }

    #[test]
    fn rate_decreases_with_rank_on_average() {
        let data = blobs(&BlobSpec::quick(400, 4, 8), 2);
        let out = crate::kmeans::lloyd::run_core(&data, 8, &KmeansParams::default(), &Backend::native());
        let series = figure1_series(&data, &out.clustering.labels, 20, &Backend::native());
        // paper Fig. 1: closer neighbors co-occur more; compare first vs last
        assert!(series[0] >= series[19], "{series:?}");
    }

    #[test]
    fn random_collision_for_balanced_clusters() {
        let labels: Vec<u32> = (0..1000).map(|i| (i % 20) as u32).collect();
        let r = random_collision_rate(&labels, 20);
        assert!((r - 0.05).abs() < 1e-9);
    }

    #[test]
    fn random_labels_near_collision_rate() {
        let data = blobs(&BlobSpec::quick(400, 4, 4), 3);
        let mut rng = crate::util::rng::Rng::new(4);
        let labels: Vec<u32> = (0..400).map(|_| rng.below(8) as u32).collect();
        let series = figure1_series(&data, &labels, 3, &Backend::native());
        let random = random_collision_rate(&labels, 8);
        assert!((series[0] - random).abs() < 0.08, "{} vs {random}", series[0]);
    }
}
