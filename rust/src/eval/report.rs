//! Table / CSV emitters shared by the bench harnesses, so every figure
//! regeneration prints the same layout the paper uses and also drops a
//! machine-readable CSV next to it.

use std::io::Write;
use std::path::Path;

/// A simple column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with per-column width alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }
}

/// Format a float with fixed significant-ish digits for tables.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// Results directory for bench outputs (`$GKMEANS_RESULTS` or `results/`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("GKMEANS_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "time"]);
        t.row(&["k-means".into(), "12.5".into()]);
        t.row(&["GK".into(), "0.3".into()]);
        let s = t.render();
        assert!(s.contains("method"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(&["x".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let p = std::env::temp_dir().join(format!("gkm_report_{}.csv", std::process::id()));
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn float_format_ranges() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(1234.6), "1235");
        assert_eq!(f(12.345), "12.35");
        assert_eq!(f(0.12345), "0.1235"); // round-to-nearest at 4 places
    }
}
