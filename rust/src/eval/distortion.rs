//! Average distortion ℰ (Eqn. 4) — the paper's clustering-quality metric,
//! identical to WCSSD/MSE in [27]/[30].

use crate::core_ops::dist::d2;
use crate::data::matrix::VecSet;
use crate::kmeans::common::Clustering;

/// ℰ = Σᵢ ‖C_{q(i)} − x_i‖² / n computed from scratch.
pub fn average_distortion(data: &VecSet, c: &Clustering) -> f64 {
    let centroids = c.centroids();
    let mut s = 0f64;
    for (i, &l) in c.labels.iter().enumerate() {
        s += d2(data.row(i), centroids.row(l as usize)) as f64;
    }
    s / data.rows().max(1) as f64
}

/// Distortion of an arbitrary label assignment against given centroids
/// (used to evaluate cross-method label transfers).
pub fn distortion_of(data: &VecSet, labels: &[u32], centroids: &VecSet) -> f64 {
    crate::kmeans::common::distortion_exact(data, labels, centroids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::util::rng::Rng;

    #[test]
    fn matches_incremental_identity() {
        // Clustering::distortion uses the Σ‖x‖² − ℐ identity; this module
        // computes the sum directly. They must agree to fp tolerance.
        let data = blobs(&BlobSpec::quick(300, 8, 6), 1);
        let mut rng = Rng::new(2);
        let labels: Vec<u32> = (0..300).map(|_| rng.below(6) as u32).collect();
        let c = Clustering::from_labels(&data, labels, 6);
        let a = average_distortion(&data, &c);
        let b = c.distortion(&data);
        assert!((a - b).abs() < 1e-6 * (1.0 + a), "{a} vs {b}");
    }

    #[test]
    fn zero_for_self_clusters() {
        let data = blobs(&BlobSpec::quick(10, 3, 2), 3);
        let labels: Vec<u32> = (0..10).map(|i| i as u32).collect();
        let c = Clustering::from_labels(&data, labels, 10);
        assert!(average_distortion(&data, &c) < 1e-9);
    }

    #[test]
    fn worse_labels_higher_distortion() {
        let data = blobs(&BlobSpec { sigma: 0.1, spread: 100.0, ..BlobSpec::quick(200, 4, 4) }, 4);
        let good = crate::kmeans::lloyd::run_core(
            &data,
            4,
            &crate::kmeans::common::KmeansParams::default(),
            &crate::runtime::Backend::native(),
        );
        let mut rng = Rng::new(5);
        let bad_labels: Vec<u32> = (0..200).map(|_| rng.below(4) as u32).collect();
        let bad = Clustering::from_labels(&data, bad_labels, 4);
        assert!(
            average_distortion(&data, &good.clustering) * 5.0
                < average_distortion(&data, &bad),
        );
    }
}
