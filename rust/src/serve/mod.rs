//! Production ANN serving: the `gkm-serve` subsystem.
//!
//! A fitted GKMODEL artifact is already a *servable* index — centroids
//! for `predict`, a KNN graph plus (RAM- or disk-resident) vectors for
//! `search`.  This module turns one or more of them into a network
//! service without adding a single dependency:
//!
//! * [`proto`] — the length-prefixed binary wire protocol and the
//!   blocking [`Client`](proto::Client) everything speaks it with.
//! * [`batcher`] — the latency-bounded micro-batcher that coalesces
//!   concurrent single-query connections into the batched kernels
//!   ([`FittedModel::search_batch`](crate::model::FittedModel::search_batch))
//!   the engine is actually fast at.
//! * [`shard`] — one logical index fanned across several artifacts,
//!   with deterministic scatter-gather top-k merging.
//! * [`metrics`] — lock-cheap serving counters/histograms behind the
//!   `STATS` verb and the stderr heartbeat.
//! * [`server`] — the TCP front door tying the above together, with
//!   panic-contained connection workers and signal-driven shutdown.
//!
//! The `gkm-serve` binary (`rust/src/bin/gkm_serve.rs`) is a thin CLI
//! over [`server::Server::start`]; the `serve_load` bench drives it
//! over loopback and emits `BENCH_serve.json`.

pub mod batcher;
pub mod metrics;
pub mod proto;
pub mod server;
pub mod shard;

pub use batcher::Batcher;
pub use metrics::{RequestKind, ServeMetrics};
pub use proto::{Client, Request, Response};
pub use server::{
    install_termination_handler, termination_requested, ServeConfig, Server, ServerHandle,
};
pub use shard::ShardedIndex;
