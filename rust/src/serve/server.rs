//! The `gkm-serve` server: a dependency-free TCP front door wiring the
//! protocol ([`super::proto`]), the micro-batcher ([`super::batcher`]),
//! the shard fan-out ([`super::shard`]) and the metrics layer
//! ([`super::metrics`]) into one process.
//!
//! ## Data flow
//!
//! ```text
//! client ── frame ──► connection thread ── submit ──► Batcher queue
//!                        ▲                               │ window / max_batch
//!                        │                               ▼
//!                     response ◄── scatter ◄── exec: group by (topk, ef)
//!                                                ├─ ShardedIndex::search_batch
//!                                                ├─ ShardedIndex::predict_batch
//!                                                └─ ShardedIndex::extend_rows (write lock,
//!                                                   after the batch's queries)
//! ```
//!
//! The index lives behind an `RwLock`: queries share a read lock, and
//! EXTEND mutations take the write lock *inside the batcher's single
//! executor thread, after the batch's queries ran* — so a batch's
//! queries all see the same index, writers never interleave, and the
//! read path costs one uncontended lock acquisition per batch.  EXTEND
//! grows the in-memory index only; the artifact files on disk are not
//! rewritten (persistence stays `gkmeans extend` / `FittedModel::save`).
//!
//! One acceptor thread hands each connection its own worker thread
//! (bounded by [`ServeConfig::max_conns`]); workers block in
//! [`Batcher::submit`], so concurrency across connections is recovered
//! *inside* the batch by the model's thread pool — the design that
//! makes batched throughput beat one-at-a-time dispatch.
//!
//! ## Fault containment
//!
//! Each connection loop runs under `catch_unwind` (the PR 6 panic-safe
//! worker idiom): a handler panic closes that connection and nothing
//! else.  Malformed frames get typed ERROR responses; an oversized
//! length prefix closes the connection (the stream can no longer be
//! trusted to be framed); a peer that stalls mid-frame is dropped after
//! the [`proto::MAX_STALL_TICKS`] stall budget so it cannot pin a
//! `max_conns` slot or block shutdown (slowloris).  SEARCH `topk`/`ef`
//! are bounded at decode time and clamped to the indexed row count
//! before they size anything.  Per-query faults degrade through the
//! `try_*` kernels and arrive as ERROR frames, counted in
//! `degraded`.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::data::matrix::VecSet;
use crate::gkm::ann::SearchParams;
use crate::runtime::{RtError, RtResult};
use crate::serve::batcher::Batcher;
use crate::serve::metrics::{RequestKind, ServeMetrics};
use crate::serve::proto::{self, Request, Response};
use crate::serve::shard::ShardedIndex;

/// Process-wide termination flag set by SIGTERM/SIGINT (see
/// [`install_termination_handler`]) and by the SHUTDOWN verb's server.
static TERM: AtomicBool = AtomicBool::new(false);

/// Install a SIGTERM/SIGINT handler that flips the process-wide
/// termination flag, without any signal-handling dependency: `signal`
/// is declared by hand (libc is linked anyway on unix) and the handler
/// only stores to an atomic — the async-signal-safe subset.
/// [`ServerHandle::wait`] observes the flag and drains.
#[cfg(unix)]
pub fn install_termination_handler() {
    unsafe extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let handler = on_term as unsafe extern "C" fn(i32) as usize;
    unsafe {
        signal(SIGTERM, handler);
        signal(SIGINT, handler);
    }
}

#[cfg(not(unix))]
pub fn install_termination_handler() {}

/// Whether process-wide termination was requested (signal or SHUTDOWN).
pub fn termination_requested() -> bool {
    TERM.load(Ordering::SeqCst)
}

/// Serving knobs (every one surfaced as a `gkm-serve` CLI flag).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 = ephemeral, for tests).
    pub addr: String,
    /// Micro-batch window: how long the dispatcher holds the first
    /// queued query open for company (0 = dispatch immediately).
    pub batch_window: Duration,
    /// Execute as soon as this many queries wait (1 = no batching).
    pub max_batch: usize,
    /// `ef` used when a SEARCH frame passes 0.
    pub default_ef: usize,
    /// Override every shard's worker-thread preference (0 = keep what
    /// the artifacts carry).
    pub threads: usize,
    /// Concurrent-connection cap (each connection is one thread).
    pub max_conns: usize,
    /// Stderr heartbeat period (None = silent).
    pub heartbeat: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            batch_window: Duration::from_micros(200),
            max_batch: 64,
            default_ef: 64,
            threads: 0,
            max_conns: 256,
            heartbeat: None,
        }
    }
}

/// The queries the batcher coalesces (R = wire [`Response`]).
enum Work {
    Predict(Vec<f32>),
    Search { query: Vec<f32>, topk: usize, ef: usize },
    /// Index mutation: applied under the write lock after the batch's
    /// queries, so a batch is "all queries at state S, then appends".
    Extend(VecSet),
}

struct Inner {
    index: Arc<RwLock<ShardedIndex>>,
    metrics: Arc<ServeMetrics>,
    batcher: Batcher<Work, Response>,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
    dim: usize,
}

impl Inner {
    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || termination_requested()
    }
}

/// A running server.  Dropping the handle does *not* stop it — call
/// [`ServerHandle::shutdown`] (tests) or [`ServerHandle::wait`]
/// (the binary, which exits on SIGTERM/SHUTDOWN).
pub struct Server;

pub struct ServerHandle {
    addr: SocketAddr,
    inner: Arc<Inner>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    heartbeat: Option<std::thread::JoinHandle<()>>,
}

/// Execute one coalesced batch against the index: predicts ride
/// together, searches group by `(topk, ef)` so each group is one
/// batched kernel call, and results scatter back in submit order.
/// EXTEND mutations apply *after* the batch's queries, one at a time
/// under the write lock — every query in a batch sees the pre-append
/// index.
fn exec_batch(
    index: &RwLock<ShardedIndex>,
    metrics: &ServeMetrics,
    seed: u64,
    default_ef: usize,
    batch: Vec<Work>,
) -> Vec<Response> {
    metrics.batch(batch.len());
    let mut out: Vec<Option<Response>> = (0..batch.len()).map(|_| None).collect();

    let mut predict_idx: Vec<usize> = Vec::new();
    let mut predict_flat: Vec<f32> = Vec::new();
    // (topk, ef) -> (original indices, flat queries)
    let mut groups: Vec<((usize, usize), Vec<usize>, Vec<f32>)> = Vec::new();
    let mut extends: Vec<(usize, VecSet)> = Vec::new();
    for (i, w) in batch.into_iter().enumerate() {
        match w {
            Work::Predict(q) => {
                predict_idx.push(i);
                predict_flat.extend_from_slice(&q);
            }
            Work::Search { query, topk, ef } => {
                let ef = if ef == 0 { default_ef } else { ef }.max(topk);
                let key = (topk, ef);
                match groups.iter_mut().find(|(k, _, _)| *k == key) {
                    Some((_, idx, flat)) => {
                        idx.push(i);
                        flat.extend_from_slice(&query);
                    }
                    None => groups.push((key, vec![i], query)),
                }
            }
            Work::Extend(rows) => extends.push((i, rows)),
        }
    }

    {
        let index = index.read().unwrap_or_else(|p| p.into_inner());
        let dim = index.dim();

        if !predict_idx.is_empty() {
            let queries = VecSet::from_flat(dim, predict_flat);
            match index.predict_batch(&queries) {
                Ok(rows) => {
                    for (&i, row) in predict_idx.iter().zip(rows) {
                        out[i] = Some(match row {
                            Ok(label) => Response::Label(label),
                            Err(e) => Response::Error(e),
                        });
                    }
                }
                Err(e) => {
                    for &i in &predict_idx {
                        out[i] = Some(Response::Error(e.to_string()));
                    }
                }
            }
        }

        for ((topk, ef), idx, flat) in groups {
            let queries = VecSet::from_flat(dim, flat);
            let params = SearchParams { ef, seed, ..SearchParams::default() };
            match index.search_batch(&queries, topk, &params) {
                Ok(rows) => {
                    for (&i, row) in idx.iter().zip(rows) {
                        out[i] = Some(match row {
                            Ok(hits) => {
                                Response::Hits(hits.into_iter().map(|(d, id)| (id, d)).collect())
                            }
                            Err(e) => Response::Error(e),
                        });
                    }
                }
                Err(e) => {
                    for &i in &idx {
                        out[i] = Some(Response::Error(e.to_string()));
                    }
                }
            }
        }
    }

    if !extends.is_empty() {
        let mut index = index.write().unwrap_or_else(|p| p.into_inner());
        for (i, rows) in extends {
            out[i] = Some(match index.extend_rows(&rows) {
                Ok(_report) => Response::Extended(index.total_rows() as u64),
                Err(e) => Response::Error(e.to_string()),
            });
        }
    }

    out.into_iter()
        .map(|r| r.unwrap_or_else(|| Response::Error("internal: query lost in batch".into())))
        .collect()
}

/// Serve one connection until it closes, errors, or shutdown drains it.
fn handle_conn(inner: &Inner, mut stream: TcpStream) {
    stream.set_nodelay(true).ok();
    // the read timeout is the shutdown poll period for idle connections
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
    loop {
        if inner.stopping() {
            return;
        }
        let payload = match proto::read_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean EOF between requests
            Err(e) if proto::is_frame_stall(&e) => {
                // the peer stalled mid-frame past the stall budget
                // (slowloris): drop it so this thread frees its
                // max_conns slot and observes shutdown
                inner.metrics.degraded_only();
                return;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue; // idle poll tick — recheck shutdown
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // hostile length prefix: answer typed, then drop the
                // stream — it can no longer be trusted to be framed
                inner.metrics.degraded_only();
                let resp = proto::encode_response(&Response::Error(e.to_string()));
                proto::write_frame(&mut stream, &resp).ok();
                return;
            }
            Err(_) => return, // mid-frame disconnect or transport error
        };
        let req = match proto::decode_request(&payload) {
            Ok(r) => r,
            Err(msg) => {
                // framing was intact, the payload was junk: typed error,
                // connection stays usable
                inner.metrics.degraded_only();
                let resp = proto::encode_response(&Response::Error(format!("bad request: {msg}")));
                if proto::write_frame(&mut stream, &resp).is_err() {
                    return;
                }
                continue;
            }
        };
        let response = match req {
            Request::Ping => Response::Pong,
            Request::Stats => {
                let cache = inner.index.read().unwrap_or_else(|p| p.into_inner()).cache_totals();
                Response::Text(inner.metrics.render(cache))
            }
            Request::Shutdown => {
                inner.shutdown.store(true, Ordering::SeqCst);
                let resp = proto::encode_response(&Response::Pong);
                proto::write_frame(&mut stream, &resp).ok();
                return;
            }
            Request::Predict { query } => {
                if query.len() != inner.dim {
                    inner.metrics.degraded_only();
                    Response::Error(format!(
                        "query dim {} != index dim {}",
                        query.len(),
                        inner.dim
                    ))
                } else {
                    let _live = inner.metrics.begin();
                    let t0 = Instant::now();
                    let r = inner.batcher.submit(Work::Predict(query));
                    let ok = !matches!(r, Response::Error(_));
                    inner.metrics.finish(RequestKind::Predict, ok, t0.elapsed().as_micros() as u64);
                    r
                }
            }
            Request::Search { query, topk, ef } => {
                if query.len() != inner.dim {
                    inner.metrics.degraded_only();
                    Response::Error(format!(
                        "query dim {} != index dim {}",
                        query.len(),
                        inner.dim
                    ))
                } else {
                    // decode already bounded topk/ef (MAX_TOPK/MAX_EF);
                    // clamp both to the data so a wire value can never
                    // size an allocation past the dataset itself (more
                    // hits than rows cannot exist, and a beam wider
                    // than the union cannot improve recall).  ef == 0
                    // stays 0 — the server-default sentinel.
                    let rows = inner
                        .index
                        .read()
                        .unwrap_or_else(|p| p.into_inner())
                        .total_rows()
                        .max(1);
                    let topk = (topk as usize).clamp(1, rows);
                    let ef = (ef as usize).min(rows);
                    let _live = inner.metrics.begin();
                    let t0 = Instant::now();
                    let r = inner.batcher.submit(Work::Search { query, topk, ef });
                    let ok = !matches!(r, Response::Error(_));
                    inner.metrics.finish(RequestKind::Search, ok, t0.elapsed().as_micros() as u64);
                    r
                }
            }
            Request::Extend { rows, flat } => {
                // decode bounded rows (MAX_EXTEND_ROWS) and the payload
                // shape; the index's own dim is the last gate
                if flat.len() != rows as usize * inner.dim {
                    inner.metrics.degraded_only();
                    Response::Error(format!(
                        "extend rows have dim {} != index dim {}",
                        if rows == 0 { 0 } else { flat.len() / rows as usize },
                        inner.dim
                    ))
                } else {
                    let _live = inner.metrics.begin();
                    let t0 = Instant::now();
                    let batch = VecSet::from_flat(inner.dim, flat);
                    let r = inner.batcher.submit(Work::Extend(batch));
                    let ok = !matches!(r, Response::Error(_));
                    if ok {
                        inner.metrics.extended_rows(rows as u64);
                    }
                    inner.metrics.finish(RequestKind::Extend, ok, t0.elapsed().as_micros() as u64);
                    r
                }
            }
        };
        let resp = proto::encode_response(&response);
        if proto::write_frame(&mut stream, &resp).is_err() {
            // the client left mid-response; the batcher already ran, so
            // nothing is poisoned — just close
            return;
        }
    }
}

impl Server {
    /// Bind, spawn the batcher/acceptor/heartbeat, and return a handle.
    pub fn start(mut index: ShardedIndex, cfg: &ServeConfig) -> RtResult<ServerHandle> {
        if cfg.threads > 0 {
            // override the worker-thread preference the artifacts carry
            for m in index.shards_mut() {
                m.threads = cfg.threads;
            }
        }
        let dim = index.dim();
        let index = Arc::new(RwLock::new(index));
        let metrics = Arc::new(ServeMetrics::new());
        let (bi, bm) = (Arc::clone(&index), Arc::clone(&metrics));
        let default_ef = cfg.default_ef.max(1);
        let seed = SearchParams::default().seed;
        let batcher = Batcher::new(
            cfg.batch_window,
            cfg.max_batch,
            move |batch| exec_batch(&bi, &bm, seed, default_ef, batch),
            |msg| Response::Error(format!("batch failed: {msg}")),
        );
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| RtError::msg(format!("bind {}: {e}", cfg.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| RtError::msg(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| RtError::msg(format!("set_nonblocking: {e}")))?;

        let inner = Arc::new(Inner {
            index,
            metrics,
            batcher,
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            dim,
        });

        let max_conns = cfg.max_conns.max(1);
        let acceptor = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || {
                while !inner.stopping() {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // honor the connection cap before spawning
                            while inner.active_conns.load(Ordering::SeqCst) >= max_conns
                                && !inner.stopping()
                            {
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            if inner.stopping() {
                                return;
                            }
                            inner.metrics.connection();
                            inner.active_conns.fetch_add(1, Ordering::SeqCst);
                            let conn_inner = Arc::clone(&inner);
                            std::thread::spawn(move || {
                                // a handler panic closes this connection
                                // only — the PR 6 panic-safe worker idiom
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| {
                                        handle_conn(&conn_inner, stream)
                                    }),
                                );
                                if r.is_err() {
                                    conn_inner.metrics.degraded_only();
                                }
                                conn_inner.active_conns.fetch_sub(1, Ordering::SeqCst);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })
        };

        let heartbeat = cfg.heartbeat.map(|period| {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || {
                let mut last = Instant::now();
                while !inner.stopping() {
                    std::thread::sleep(Duration::from_millis(50));
                    if last.elapsed() >= period {
                        let cache = inner
                            .index
                            .read()
                            .unwrap_or_else(|p| p.into_inner())
                            .cache_totals();
                        eprintln!("{}", inner.metrics.heartbeat_line(cache));
                        last = Instant::now();
                    }
                }
            })
        });

        Ok(ServerHandle { addr, inner, acceptor: Some(acceptor), heartbeat })
    }
}

impl ServerHandle {
    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live serving metrics (shared with the worker threads).
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        Arc::clone(&self.inner.metrics)
    }

    /// The served index (behind the serving `RwLock` — EXTEND requests
    /// mutate it; for tests and config echo).
    pub fn index(&self) -> Arc<RwLock<ShardedIndex>> {
        Arc::clone(&self.inner.index)
    }

    /// Whether the server has begun stopping (SHUTDOWN verb, signal, or
    /// [`ServerHandle::shutdown`]).
    pub fn stopping(&self) -> bool {
        self.inner.stopping()
    }

    fn drain(&mut self) {
        if let Some(a) = self.acceptor.take() {
            a.join().ok();
        }
        if let Some(h) = self.heartbeat.take() {
            h.join().ok();
        }
        // connection threads observe the flag within one read-timeout
        // tick; give them a bounded drain window
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.inner.active_conns.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Stop accepting, drain connections, and join the service threads.
    pub fn shutdown(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.drain();
    }

    /// Block until shutdown is requested (SHUTDOWN verb or signal),
    /// then drain.  This is the binary's main loop.
    pub fn wait(mut self) {
        while !self.inner.stopping() {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{blobs, BlobSpec};
    use crate::model::{Clusterer, GkMeans, RunContext};
    use crate::runtime::Backend;
    use crate::serve::proto::Client;

    fn serving_model() -> (crate::model::FittedModel, crate::data::matrix::VecSet) {
        let data = blobs(&BlobSpec::quick(200, 6, 3), 11);
        let b = Backend::native();
        let ctx = RunContext::new(&b).max_iters(2).keep_data(true);
        let model = GkMeans::new(3).kappa(6).tau(2).xi(25).fit(&data, &ctx);
        (model, data)
    }

    fn start_server(max_batch: usize) -> (ServerHandle, crate::data::matrix::VecSet) {
        let (model, data) = serving_model();
        let index = ShardedIndex::new(vec![model]).unwrap();
        let cfg = ServeConfig {
            batch_window: Duration::from_micros(100),
            max_batch,
            ..ServeConfig::default()
        };
        (Server::start(index, &cfg).unwrap(), data)
    }

    #[test]
    fn ping_predict_search_stats_roundtrip() {
        let (model, data) = serving_model();
        let index = ShardedIndex::new(vec![model.clone()]).unwrap();
        let cfg = ServeConfig { max_batch: 16, ..ServeConfig::default() };
        let handle = Server::start(index, &cfg).unwrap();
        let mut c = Client::connect(handle.addr()).unwrap();
        c.ping().unwrap();
        let label = c.predict(data.row(0)).unwrap();
        assert_eq!(label, model.predict_batch(&data)[0], "served label == engine label");
        // served search must be bit-identical to the engine's (same ef:
        // the client's 0 resolves to the server default, which matches
        // SearchParams::default())
        let hits = c.search(data.row(0), 5, 0).unwrap();
        let want = model.search(data.row(0), 5, &SearchParams::default()).unwrap();
        let want: Vec<(u32, f32)> = want.into_iter().map(|(d, id)| (id, d)).collect();
        assert_eq!(hits, want, "served hits == engine hits");
        let stats = c.stats().unwrap();
        assert_eq!(proto::stats_value(&stats, "searches"), Some(1.0), "{stats}");
        assert_eq!(proto::stats_value(&stats, "predicts"), Some(1.0));
        assert!(proto::stats_value(&stats, "lat_p50_us").unwrap() > 0.0, "{stats}");
        handle.shutdown();
    }

    #[test]
    fn shutdown_verb_stops_the_server() {
        let (handle, _data) = start_server(4);
        let addr = handle.addr();
        let mut c = Client::connect(addr).unwrap();
        c.shutdown().unwrap();
        handle.wait(); // must return promptly, not hang
        // subsequent connects are refused once the listener is gone
        std::thread::sleep(Duration::from_millis(50));
        let again = Client::connect(addr);
        if let Ok(mut c2) = again {
            assert!(c2.ping().is_err(), "server must not answer after shutdown");
        }
    }

    #[test]
    fn garbage_bytes_do_not_kill_the_worker() {
        use std::io::Write as _;
        let (handle, data) = start_server(8);
        // connection 1: a hostile length prefix (4 GiB frame)
        let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let resp = proto::read_frame(&mut s).unwrap().unwrap();
        match proto::decode_response(&resp).unwrap() {
            Response::Error(e) => assert!(e.contains("cap"), "{e}"),
            other => panic!("expected typed error, got {other:?}"),
        }
        // connection 2: a well-framed junk payload — typed error, and the
        // *same* connection keeps serving
        let mut c = Client::connect(handle.addr()).unwrap();
        // (reach into the stream via a raw frame)
        let mut s2 = std::net::TcpStream::connect(handle.addr()).unwrap();
        proto::write_frame(&mut s2, &[99u8, 1, 2, 3]).unwrap();
        let resp = proto::read_frame(&mut s2).unwrap().unwrap();
        assert!(matches!(proto::decode_response(&resp).unwrap(), Response::Error(_)));
        proto::write_frame(&mut s2, &proto::encode_request(&Request::Ping)).unwrap();
        let resp = proto::read_frame(&mut s2).unwrap().unwrap();
        assert!(matches!(proto::decode_response(&resp).unwrap(), Response::Pong));
        // connection 3: disconnect mid-frame — server must keep serving
        let mut s3 = std::net::TcpStream::connect(handle.addr()).unwrap();
        s3.write_all(&100u32.to_le_bytes()).unwrap();
        s3.write_all(&[1, 2, 3]).unwrap();
        drop(s3);
        std::thread::sleep(Duration::from_millis(50));
        // the healthy client still gets answers after all of the above
        assert!(c.search(data.row(1), 3, 0).is_ok());
        let mut fresh = Client::connect(handle.addr()).unwrap();
        fresh.ping().unwrap();
        let stats = fresh.stats().unwrap();
        assert!(proto::stats_value(&stats, "degraded").unwrap() >= 2.0, "{stats}");
        handle.shutdown();
    }

    #[test]
    fn extend_verb_grows_the_served_index() {
        let (handle, _data) = start_server(8);
        let mut c = Client::connect(handle.addr()).unwrap();
        let extra = blobs(&BlobSpec::quick(20, 6, 3), 17);
        let total = c.extend(extra.flat(), 6).unwrap();
        assert_eq!(total, 220, "200 fitted rows + 20 appended");
        // an appended row is immediately searchable, at the top of the
        // global id space, as its own nearest neighbor
        let hits = c.search(extra.row(0), 1, 0).unwrap();
        assert_eq!(hits[0].0, 200, "appended row's global id");
        assert!(hits[0].1 <= 1e-6, "self-hit at distance ~0, got {}", hits[0].1);
        // a dim mismatch is a typed error and the connection survives
        assert!(c.extend(&[1.0, 2.0, 3.0], 3).is_err());
        c.ping().unwrap();
        let stats = c.stats().unwrap();
        assert_eq!(proto::stats_value(&stats, "extends"), Some(1.0), "{stats}");
        assert_eq!(proto::stats_value(&stats, "extended_rows"), Some(20.0), "{stats}");
        // the handle sees the grown index too
        assert_eq!(handle.index().read().unwrap().total_rows(), 220);
        handle.shutdown();
    }

    #[test]
    fn dim_mismatch_is_a_typed_error_not_a_panic() {
        let (handle, _data) = start_server(4);
        let mut c = Client::connect(handle.addr()).unwrap();
        let err = c.search(&[1.0, 2.0], 3, 0).unwrap_err();
        assert!(err.contains("dim"), "{err}");
        let err = c.predict(&[1.0]).unwrap_err();
        assert!(err.contains("dim"), "{err}");
        c.ping().unwrap(); // connection survives
        handle.shutdown();
    }
}
