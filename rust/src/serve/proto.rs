//! The `gkm-serve` wire protocol: dependency-free, length-prefixed
//! binary frames over TCP, plus the blocking [`Client`] every consumer
//! (the `serve_load` load generator, `examples/ann_service.rs`, tests)
//! speaks it with.
//!
//! ## Framing
//!
//! Every message — request or response — is one frame:
//!
//! ```text
//! [u32 LE payload length][payload bytes]
//! ```
//!
//! A payload longer than [`MAX_FRAME`] is rejected *before* any
//! allocation: the server answers with a typed error frame and closes
//! the connection (a desynced peer cannot be trusted to frame the next
//! message correctly).  All integers are little-endian; vectors are raw
//! `f32` components.
//!
//! ## Requests (first payload byte = verb)
//!
//! | verb | name     | body                                            |
//! |------|----------|-------------------------------------------------|
//! | 1    | PREDICT  | `u32 dim`, `dim × f32` query                    |
//! | 2    | SEARCH   | `u32 topk`, `u32 ef` (0 = server default), `u32 dim`, `dim × f32` |
//! | 3    | STATS    | (empty) — serving metrics as `key=value` lines  |
//! | 4    | PING     | (empty)                                         |
//! | 5    | SHUTDOWN | (empty) — graceful server stop (tests/benches)  |
//! | 6    | EXTEND   | `u32 rows`, `u32 dim`, `rows × dim × f32`       |
//!
//! ## Responses (first payload byte = tag)
//!
//! | tag | name     | body                                      |
//! |-----|----------|-------------------------------------------|
//! | 0   | LABEL    | `u32` cluster label                       |
//! | 1   | HITS     | `u32 count`, `count × (u32 id, f32 d²)`   |
//! | 2   | TEXT     | UTF-8 text (STATS payload)                |
//! | 3   | PONG     | (empty)                                   |
//! | 4   | ERROR    | UTF-8 message                             |
//! | 5   | EXTENDED | `u64` total indexed rows after the append |

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// Hard cap on one frame's payload (16 MiB): large enough for a
/// [`MAX_QUERY_DIM`]-component query, small enough that a garbage
/// length prefix cannot OOM the server.
pub const MAX_FRAME: u32 = 16 << 20;

/// Sanity cap on query dimensionality (matches the store layer's cap).
pub const MAX_QUERY_DIM: usize = 1 << 20;

/// Sanity cap on SEARCH `topk`.  The server sizes result buffers from
/// this field (`topk × shards` merge slots), so it is validated at
/// decode time like [`MAX_QUERY_DIM`] — a hostile `u32::MAX` must be a
/// typed error, never an allocation.  The server additionally clamps
/// `topk` to the number of indexed rows.
pub const MAX_TOPK: u32 = 1 << 16;

/// Sanity cap on SEARCH `ef`.  `ef` sizes the per-worker candidate
/// heap, so like [`MAX_TOPK`] it is bounded before any allocation; the
/// server further clamps it to the indexed row count (a larger beam
/// than the dataset cannot improve recall).
pub const MAX_EF: u32 = 1 << 20;

/// Sanity cap on EXTEND `rows` per frame.  Like [`MAX_TOPK`], it is
/// validated at decode time so a hostile `u32::MAX` is a typed error,
/// never an allocation; the frame cap bounds the actual payload anyway
/// (`rows · dim · 4 ≤` [`MAX_FRAME`]).  Bigger ingests ship as several
/// frames.
pub const MAX_EXTEND_ROWS: u32 = 1 << 20;

/// Consecutive zero-progress read-timeout ticks [`read_frame`] tolerates
/// in the middle of a frame before giving up with a [`is_frame_stall`]
/// error (~5 s at the server's 50 ms poll tick).  Without this bound a
/// client that sends a partial frame and stalls would pin its connection
/// thread forever — holding a `max_conns` slot and ignoring shutdown
/// (the slowloris pattern).
pub const MAX_STALL_TICKS: u32 = 100;

/// Marker error source for a mid-frame stall abort, so the server can
/// tell "peer stalled mid-frame, drop it" from the idle poll tick
/// (which surfaces only before any byte of a frame) without relying on
/// platform-specific `ErrorKind`s.
#[derive(Debug)]
struct FrameStall;

impl std::fmt::Display for FrameStall {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "peer stalled mid-frame for {MAX_STALL_TICKS} read-timeout ticks")
    }
}

impl std::error::Error for FrameStall {}

fn frame_stall_error() -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::TimedOut, FrameStall)
}

/// Whether an I/O error is [`read_frame`] giving up on a mid-frame
/// stall (vs. the pre-frame idle tick, which keeps the connection).
pub fn is_frame_stall(e: &std::io::Error) -> bool {
    match e.get_ref() {
        Some(inner) => inner.is::<FrameStall>(),
        None => false,
    }
}

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Nearest-centroid assignment for one query vector.
    Predict { query: Vec<f32> },
    /// Graph-ANN top-`topk` search; `ef = 0` means the server default.
    Search { query: Vec<f32>, topk: u32, ef: u32 },
    /// Serving metrics snapshot.
    Stats,
    /// Liveness probe.
    Ping,
    /// Graceful server stop.
    Shutdown,
    /// Append `rows` vectors (flattened row-major) to the served index.
    /// In-memory only: the server's artifact files are not rewritten.
    Extend { rows: u32, flat: Vec<f32> },
}

/// A decoded response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// PREDICT result.
    Label(u32),
    /// SEARCH result: ascending-distance `(id, d²)` pairs (global ids
    /// when the server shards).
    Hits(Vec<(u32, f32)>),
    /// STATS text.
    Text(String),
    /// PING reply.
    Pong,
    /// Typed failure: the request was understood to be broken, or the
    /// query could not be served (degraded row, worker panic, …).
    Error(String),
    /// EXTEND result: total indexed rows after the append.
    Extended(u64),
}

const VERB_PREDICT: u8 = 1;
const VERB_SEARCH: u8 = 2;
const VERB_STATS: u8 = 3;
const VERB_PING: u8 = 4;
const VERB_SHUTDOWN: u8 = 5;
const VERB_EXTEND: u8 = 6;

const TAG_LABEL: u8 = 0;
const TAG_HITS: u8 = 1;
const TAG_TEXT: u8 = 2;
const TAG_PONG: u8 = 3;
const TAG_ERROR: u8 = 4;
const TAG_EXTENDED: u8 = 5;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Byte-stream reader with bounds checking (every decode error is a
/// `String` the server can echo back as a typed ERROR frame).
struct Take<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Take<'a> {
    fn new(buf: &'a [u8]) -> Take<'a> {
        Take { buf, pos: 0 }
    }

    fn u8(&mut self) -> Result<u8, String> {
        let b = *self.buf.get(self.pos).ok_or("truncated frame")?;
        self.pos += 1;
        Ok(b)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.buf.len() {
            return Err("truncated frame".into());
        }
        let v = u32::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64, String> {
        let end = self.pos + 8;
        if end > self.buf.len() {
            return Err("truncated frame".into());
        }
        let v = u64::from_le_bytes(self.buf[self.pos..end].try_into().unwrap());
        self.pos = end;
        Ok(v)
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let need = n.checked_mul(4).ok_or("vector length overflows")?;
        if self.pos + need > self.buf.len() {
            return Err("truncated frame".into());
        }
        let mut out = Vec::with_capacity(n);
        for c in self.buf[self.pos..self.pos + need].chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        self.pos += need;
        Ok(out)
    }

    fn rest(&mut self) -> &'a [u8] {
        let r = &self.buf[self.pos..];
        self.pos = self.buf.len();
        r
    }

    fn done(&self) -> Result<(), String> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after message", self.buf.len() - self.pos))
        }
    }
}

fn check_dim(dim: u32) -> Result<usize, String> {
    let d = dim as usize;
    if d == 0 || d > MAX_QUERY_DIM {
        return Err(format!("implausible query dim {d}"));
    }
    Ok(d)
}

/// Encode a request payload (no length prefix — [`write_frame`] adds it).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::new();
    match req {
        Request::Predict { query } => {
            out.push(VERB_PREDICT);
            put_u32(&mut out, query.len() as u32);
            for &v in query {
                put_f32(&mut out, v);
            }
        }
        Request::Search { query, topk, ef } => {
            out.push(VERB_SEARCH);
            put_u32(&mut out, *topk);
            put_u32(&mut out, *ef);
            put_u32(&mut out, query.len() as u32);
            for &v in query {
                put_f32(&mut out, v);
            }
        }
        Request::Stats => out.push(VERB_STATS),
        Request::Ping => out.push(VERB_PING),
        Request::Shutdown => out.push(VERB_SHUTDOWN),
        Request::Extend { rows, flat } => {
            out.push(VERB_EXTEND);
            put_u32(&mut out, *rows);
            let dim = if *rows == 0 { 0 } else { flat.len() as u32 / *rows };
            put_u32(&mut out, dim);
            for &v in flat {
                put_f32(&mut out, v);
            }
        }
    }
    out
}

/// Decode a request payload.  Every failure names what was wrong — the
/// server echoes it back as a typed ERROR frame before closing.
pub fn decode_request(payload: &[u8]) -> Result<Request, String> {
    let mut t = Take::new(payload);
    let req = match t.u8().map_err(|_| "empty frame")? {
        VERB_PREDICT => {
            let dim = check_dim(t.u32()?)?;
            Request::Predict { query: t.f32s(dim)? }
        }
        VERB_SEARCH => {
            let topk = t.u32()?;
            if topk == 0 || topk > MAX_TOPK {
                return Err(format!("topk {topk} out of range 1..={MAX_TOPK}"));
            }
            let ef = t.u32()?;
            if ef > MAX_EF {
                return Err(format!("ef {ef} exceeds the {MAX_EF} cap"));
            }
            let dim = check_dim(t.u32()?)?;
            Request::Search { query: t.f32s(dim)?, topk, ef }
        }
        VERB_STATS => Request::Stats,
        VERB_PING => Request::Ping,
        VERB_SHUTDOWN => Request::Shutdown,
        VERB_EXTEND => {
            let rows = t.u32()?;
            if rows == 0 || rows > MAX_EXTEND_ROWS {
                return Err(format!("extend rows {rows} out of range 1..={MAX_EXTEND_ROWS}"));
            }
            let dim = check_dim(t.u32()?)?;
            let total = (rows as usize)
                .checked_mul(dim)
                .ok_or("extend payload size overflows")?;
            Request::Extend { rows, flat: t.f32s(total)? }
        }
        v => return Err(format!("unknown request verb {v}")),
    };
    t.done()?;
    Ok(req)
}

/// Encode a response payload (no length prefix).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut out = Vec::new();
    match resp {
        Response::Label(l) => {
            out.push(TAG_LABEL);
            put_u32(&mut out, *l);
        }
        Response::Hits(hits) => {
            out.push(TAG_HITS);
            put_u32(&mut out, hits.len() as u32);
            for &(id, d) in hits {
                put_u32(&mut out, id);
                put_f32(&mut out, d);
            }
        }
        Response::Text(s) => {
            out.push(TAG_TEXT);
            out.extend_from_slice(s.as_bytes());
        }
        Response::Pong => out.push(TAG_PONG),
        Response::Error(msg) => {
            out.push(TAG_ERROR);
            out.extend_from_slice(msg.as_bytes());
        }
        Response::Extended(total) => {
            out.push(TAG_EXTENDED);
            put_u64(&mut out, *total);
        }
    }
    out
}

/// Decode a response payload.
pub fn decode_response(payload: &[u8]) -> Result<Response, String> {
    let mut t = Take::new(payload);
    let resp = match t.u8().map_err(|_| "empty frame")? {
        TAG_LABEL => Response::Label(t.u32()?),
        TAG_HITS => {
            let n = t.u32()? as usize;
            if n > MAX_FRAME as usize / 8 {
                return Err(format!("implausible hit count {n}"));
            }
            let mut hits = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                let id = t.u32()?;
                let d = t.f32()?;
                hits.push((id, d));
            }
            Response::Hits(hits)
        }
        TAG_TEXT => Response::Text(String::from_utf8_lossy(t.rest()).into_owned()),
        TAG_PONG => Response::Pong,
        TAG_ERROR => Response::Error(String::from_utf8_lossy(t.rest()).into_owned()),
        TAG_EXTENDED => Response::Extended(t.u64()?),
        v => return Err(format!("unknown response tag {v}")),
    };
    t.done()?;
    Ok(resp)
}

/// Whether an I/O error is a read-timeout tick (the server polls with
/// a read timeout so idle connections can observe shutdown).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one frame.  `Ok(None)` = clean EOF before a length prefix (the
/// peer hung up between requests).  A length prefix above [`MAX_FRAME`]
/// fails with `ErrorKind::InvalidData` *without reading the body* — the
/// caller answers with a typed error and closes.
///
/// A read timeout (`WouldBlock`/`TimedOut`) surfaces as `Err` only when
/// it hits *before any byte* of the length prefix — an idle-poll tick
/// the server uses to check its shutdown flag.  Mid-frame timeouts
/// retry (a slow sender cannot desync the stream) but only up to
/// [`MAX_STALL_TICKS`] consecutive zero-progress ticks; past that the
/// read fails with an [`is_frame_stall`] error so a stalled peer cannot
/// pin its connection thread forever.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    // distinguish clean EOF (no bytes at all) from a truncated prefix
    let mut got = 0;
    let mut stalls = 0u32;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed mid-frame (length prefix)",
                    ))
                };
            }
            Ok(n) => {
                got += n;
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) && got > 0 => {
                stalls += 1;
                if stalls >= MAX_STALL_TICKS {
                    return Err(frame_stall_error());
                }
            }
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len as usize];
    let mut filled = 0;
    stalls = 0;
    while filled < payload.len() {
        match r.read(&mut payload[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame (payload)",
                ));
            }
            Ok(n) => {
                filled += n;
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                stalls += 1;
                if stalls >= MAX_STALL_TICKS {
                    return Err(frame_stall_error());
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Some(payload))
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME as usize);
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Blocking client for one `gkm-serve` connection.  One request is in
/// flight at a time (the server answers in order); open several clients
/// for concurrency — that is exactly what the micro-batcher coalesces.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a serving address (`host:port`).
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response, String> {
        let payload = encode_request(req);
        write_frame(&mut self.stream, &payload).map_err(|e| format!("send: {e}"))?;
        let resp = read_frame(&mut self.stream)
            .map_err(|e| format!("recv: {e}"))?
            .ok_or("server closed the connection")?;
        decode_response(&resp)
    }

    /// Nearest-centroid label for `query`.
    pub fn predict(&mut self, query: &[f32]) -> Result<u32, String> {
        match self.roundtrip(&Request::Predict { query: query.to_vec() })? {
            Response::Label(l) => Ok(l),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Top-`topk` ANN hits for `query` (`ef = 0` → server default).
    /// Returns ascending-distance `(id, d²)` pairs.
    pub fn search(
        &mut self,
        query: &[f32],
        topk: usize,
        ef: usize,
    ) -> Result<Vec<(u32, f32)>, String> {
        let req = Request::Search { query: query.to_vec(), topk: topk as u32, ef: ef as u32 };
        match self.roundtrip(&req)? {
            Response::Hits(h) => Ok(h),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Serving metrics snapshot (`key=value` lines).
    pub fn stats(&mut self) -> Result<String, String> {
        match self.roundtrip(&Request::Stats)? {
            Response::Text(s) => Ok(s),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), String> {
        match self.roundtrip(&Request::Ping)? {
            Response::Pong => Ok(()),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Append `flat` (row-major, `flat.len() / dim` rows) to the served
    /// index; returns the total indexed rows after the append.  The
    /// growth is in-memory only — the server's artifact files are not
    /// rewritten.
    pub fn extend(&mut self, flat: &[f32], dim: usize) -> Result<u64, String> {
        if dim == 0 || flat.is_empty() || flat.len() % dim != 0 {
            return Err(format!(
                "extend payload of {} floats is not a whole number of dim-{dim} rows",
                flat.len()
            ));
        }
        let rows = (flat.len() / dim) as u32;
        match self.roundtrip(&Request::Extend { rows, flat: flat.to_vec() })? {
            Response::Extended(total) => Ok(total),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Ask the server to stop accepting, drain, and exit.
    pub fn shutdown(&mut self) -> Result<(), String> {
        match self.roundtrip(&Request::Shutdown)? {
            Response::Pong => Ok(()),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }
}

/// Parse one `key=value` line out of a STATS text blob (convenience for
/// benches/CI scripts asserting on specific metrics).
pub fn stats_value(stats: &str, key: &str) -> Option<f64> {
    for line in stats.lines() {
        if let Some((k, v)) = line.split_once('=') {
            if k.trim() == key {
                return v.trim().parse().ok();
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = [
            Request::Predict { query: vec![1.0, -2.5, 3.25] },
            Request::Search { query: vec![0.5; 7], topk: 10, ef: 64 },
            Request::Stats,
            Request::Ping,
            Request::Shutdown,
            Request::Extend { rows: 3, flat: vec![0.25; 12] },
        ];
        for req in &reqs {
            let bytes = encode_request(req);
            assert_eq!(&decode_request(&bytes).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = [
            Response::Label(7),
            Response::Hits(vec![(3, 0.25), (9, 1.5)]),
            Response::Hits(Vec::new()),
            Response::Text("qps=100\np50_us=42".into()),
            Response::Pong,
            Response::Error("query dim 3 != model dim 8".into()),
            Response::Extended(1 << 40),
        ];
        for resp in &resps {
            let bytes = encode_response(resp);
            assert_eq!(&decode_response(&bytes).unwrap(), resp, "{resp:?}");
        }
    }

    #[test]
    fn malformed_payloads_are_typed_errors() {
        assert!(decode_request(&[]).is_err(), "empty");
        assert!(decode_request(&[99]).is_err(), "unknown verb");
        // PREDICT claiming 5 components but carrying 1
        let mut bad = vec![1u8];
        bad.extend(5u32.to_le_bytes());
        bad.extend(1.0f32.to_le_bytes());
        assert!(decode_request(&bad).unwrap_err().contains("truncated"));
        // implausible dim
        let mut huge = vec![1u8];
        huge.extend(u32::MAX.to_le_bytes());
        assert!(decode_request(&huge).unwrap_err().contains("implausible"));
        // zero topk
        let mut zk = vec![2u8];
        zk.extend(0u32.to_le_bytes());
        zk.extend(0u32.to_le_bytes());
        zk.extend(1u32.to_le_bytes());
        zk.extend(1.0f32.to_le_bytes());
        assert!(decode_request(&zk).unwrap_err().contains("topk"));
        // hostile topk: must be rejected at decode, before any buffer
        // is sized from it
        let mut hk = vec![2u8];
        hk.extend(u32::MAX.to_le_bytes());
        hk.extend(0u32.to_le_bytes());
        hk.extend(1u32.to_le_bytes());
        hk.extend(1.0f32.to_le_bytes());
        assert!(decode_request(&hk).unwrap_err().contains("topk"));
        // hostile ef: same treatment
        let mut he = vec![2u8];
        he.extend(1u32.to_le_bytes());
        he.extend(u32::MAX.to_le_bytes());
        he.extend(1u32.to_le_bytes());
        he.extend(1.0f32.to_le_bytes());
        assert!(decode_request(&he).unwrap_err().contains("ef"));
        // the caps themselves are accepted
        let mut ok = vec![2u8];
        ok.extend(MAX_TOPK.to_le_bytes());
        ok.extend(MAX_EF.to_le_bytes());
        ok.extend(1u32.to_le_bytes());
        ok.extend(1.0f32.to_le_bytes());
        assert!(decode_request(&ok).is_ok());
        // trailing garbage after a valid PING
        assert!(decode_request(&[4u8, 0, 0]).unwrap_err().contains("trailing"));
        // hostile extend row count: typed error before any allocation
        let mut hx = vec![6u8];
        hx.extend(u32::MAX.to_le_bytes());
        hx.extend(4u32.to_le_bytes());
        assert!(decode_request(&hx).unwrap_err().contains("rows"));
        // zero extend rows
        let mut zx = vec![6u8];
        zx.extend(0u32.to_le_bytes());
        zx.extend(4u32.to_le_bytes());
        assert!(decode_request(&zx).unwrap_err().contains("rows"));
        // extend claiming 2×3 floats but carrying 1
        let mut tx = vec![6u8];
        tx.extend(2u32.to_le_bytes());
        tx.extend(3u32.to_le_bytes());
        tx.extend(1.0f32.to_le_bytes());
        assert!(decode_request(&tx).unwrap_err().contains("truncated"));
    }

    #[test]
    fn frame_io_roundtrip_and_oversize_rejection() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
        // a hostile length prefix is rejected without allocating
        let mut hostile = std::io::Cursor::new((u32::MAX).to_le_bytes().to_vec());
        let err = read_frame(&mut hostile).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // a truncated length prefix is UnexpectedEof, not a clean None
        let mut trunc = std::io::Cursor::new(vec![1u8, 0]);
        assert_eq!(read_frame(&mut trunc).unwrap_err().kind(), std::io::ErrorKind::UnexpectedEof);
        // a truncated body is UnexpectedEof
        let mut body = Vec::new();
        body.extend(10u32.to_le_bytes());
        body.extend([1u8, 2, 3]);
        let mut body = std::io::Cursor::new(body);
        assert_eq!(read_frame(&mut body).unwrap_err().kind(), std::io::ErrorKind::UnexpectedEof);
    }

    /// A reader that yields its bytes, then times out on every read —
    /// the shape of a client that stalls mid-frame with its socket open.
    struct StallingReader {
        data: Vec<u8>,
        pos: usize,
        ticks: u32,
    }

    impl Read for StallingReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos < self.data.len() {
                let n = buf.len().min(self.data.len() - self.pos);
                buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
                self.pos += n;
                Ok(n)
            } else {
                self.ticks += 1;
                Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "tick"))
            }
        }
    }

    #[test]
    fn mid_frame_stall_fails_after_a_bounded_number_of_ticks() {
        // partial payload, then an endless stall: read_frame must give
        // up after MAX_STALL_TICKS instead of spinning forever
        let mut data = Vec::new();
        data.extend(10u32.to_le_bytes());
        data.extend([1u8, 2, 3]);
        let mut r = StallingReader { data, pos: 0, ticks: 0 };
        let err = read_frame(&mut r).unwrap_err();
        assert!(is_frame_stall(&err), "{err}");
        assert_eq!(r.ticks, MAX_STALL_TICKS, "must stop retrying at the budget");

        // a partial length prefix stalls the same way
        let mut r = StallingReader { data: vec![1u8, 0], pos: 0, ticks: 0 };
        let err = read_frame(&mut r).unwrap_err();
        assert!(is_frame_stall(&err), "{err}");

        // but a timeout before ANY byte is the idle poll tick: it
        // surfaces immediately and is NOT a stall abort
        let mut r = StallingReader { data: Vec::new(), pos: 0, ticks: 0 };
        let err = read_frame(&mut r).unwrap_err();
        assert!(!is_frame_stall(&err), "{err}");
        assert_eq!(r.ticks, 1, "idle tick must surface on the first timeout");
    }

    #[test]
    fn stats_value_parses_lines() {
        let s = "uptime_s=1.5\nqps=250\ncache_hit_rate=0.93\n";
        assert_eq!(stats_value(s, "qps"), Some(250.0));
        assert_eq!(stats_value(s, "cache_hit_rate"), Some(0.93));
        assert_eq!(stats_value(s, "missing"), None);
    }
}
